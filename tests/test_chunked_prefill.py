"""Chunked-prefill exactness (DESIGN.md §14): chunked admission must
produce byte-identical token streams to whole-prompt admission in every
cache mode — the chunk window commits the same KV bytes as the one-shot
prefill, and greedy decoding is deterministic — including chunk sizes
that don't divide the prompt length, mid-prefill preemption/OOM replay,
and speculative decoding riding on top."""
import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.serving import ContinuousScheduler, SchedConfig

# mixed lengths: several not divisible by any tested chunk size, one
# shorter than every chunk size, one longer than 3 chunks
PLENS = (16, 23, 7, 16, 31, 5)
GENS = (6, 3, 8, 2, 5, 7)


def _cfg(**overrides):
    return get_config("ternary-paper", reduced=True, num_layers=2,
                      **overrides)


def _workload(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
            for n in PLENS]


def _run(cfg, params=None, *, slots=3, max_len=48, seed=0, **kw):
    eng = ContinuousScheduler(cfg, max_slots=slots, max_len=max_len, **kw)
    if params is None:
        params = eng.model.init(jax.random.PRNGKey(seed))
    eng.load(params)
    reqs = [eng.submit(p, g) for p, g in zip(_workload(cfg), GENS)]
    metrics = eng.run()
    return params, [list(r.tokens) for r in reqs], metrics


@pytest.mark.parametrize("chunk", [1, 5, 64])
def test_chunked_dense_token_exact(chunk):
    """Dense mode, chunk sizes from pathological (1 token/step) through
    non-dividing (5) to degenerate (64 > every prompt: single-chunk)."""
    cfg = _cfg()
    params, ref, _ = _run(cfg)
    _, got, m = _run(cfg, params, sched=SchedConfig(chunk_tokens=chunk))
    assert got == ref
    assert m["sched"]["chunked_prefill"]
    assert m["sched"]["prefill_completions"] == len(PLENS)
    assert m["sched"]["chunk_tokens_committed"] == sum(PLENS)
    # chunk=64 exceeds every prompt, but windows are rounded down to
    # powers of two (bounded jit shapes), so an L-token prompt completes
    # in at most bit_length(L) pow2-descent rounds — and a pow2-length
    # prompt in exactly one
    if chunk == 64:
        assert all(r["chunks"] <= int(r["prompt_len"]).bit_length()
                   for r in m["per_request"])
        assert all(r["chunks"] == 1 for r in m["per_request"]
                   if r["prompt_len"] == 16)


def test_chunked_paged_token_exact():
    """Paged mode (fp pages): chunked == whole-prompt under the same
    cache config."""
    cfg = _cfg()
    params, ref, _ = _run(cfg, cache="paged", page_size=4)
    _, got, m = _run(cfg, params, cache="paged", page_size=4,
                     sched=SchedConfig(chunk_tokens=8))
    assert got == ref
    assert m["sched"]["prefill_completions"] == len(PLENS)


def test_chunked_paged_int8_chunk_size_invariant():
    """int8 pages: whole-prompt prefill attends bf16 in-flight K/V while
    chunk windows attend the *quantized* pages, so chunked-vs-whole is
    not a bitwise contract under quantized caches. The contract that
    does hold: every chunk granularity stores and attends the same
    dequantized bytes at every position, so the token stream is
    chunk-size-invariant."""
    cfg = _cfg()
    params, ref, _ = _run(cfg, cache="paged", page_size=4, kv_dtype="int8",
                          sched=SchedConfig(chunk_tokens=8))
    for chunk in (4, 64):
        _, got, m = _run(cfg, params, cache="paged", page_size=4,
                         kv_dtype="int8",
                         sched=SchedConfig(chunk_tokens=chunk))
        assert got == ref, chunk
        assert m["sched"]["prefill_completions"] == len(PLENS)


def test_chunked_spec_token_exact():
    """Speculative decoding over chunked prefill: the draft cache
    catches up with a whole-prompt draft prefill at chunk completion, so
    spec+chunked == spec+whole == plain dense."""
    from repro.spec import SpecConfig
    cfg = _cfg()
    params, ref, _ = _run(cfg)
    _, spec_whole, _ = _run(cfg, params, max_len=64, spec=SpecConfig(k=3))
    _, spec_chunk, m = _run(cfg, params, max_len=64, spec=SpecConfig(k=3),
                            sched=SchedConfig(chunk_tokens=8))
    assert spec_whole == ref
    assert spec_chunk == ref
    assert m["spec"]["rounds"] > 0


def test_mid_prefill_oom_replay_token_exact():
    """Injected allocation failures after the first chunk step force
    mid-prefill preemptions; the replay restarts from prefill_pos=0 and
    must regenerate the exact stream."""
    cfg = _cfg()
    params, ref, _ = _run(cfg)
    eng = ContinuousScheduler(cfg, max_slots=3, max_len=48, cache="paged",
                              page_size=4, sched=SchedConfig(chunk_tokens=8))
    eng.load(params)
    reqs = [eng.submit(p, g) for p, g in zip(_workload(cfg), GENS)]
    eng.step()                          # admit + first chunk round
    assert eng._prefills                # someone is mid-prefill
    eng.pool.inject_alloc_failures(3)
    m = eng.run()
    assert m["cache"]["preemptions"] >= 1
    assert [list(r.tokens) for r in reqs] == ref


def test_tiny_pool_preemption_token_exact():
    """A page pool too small for the full working set: chunked admission
    defers/preempts under genuine pressure and still drains exactly."""
    cfg = _cfg()
    params, ref, _ = _run(cfg, max_len=40)
    _, got, m = _run(cfg, params, max_len=40, cache="paged", page_size=4,
                     n_pages=14, sched=SchedConfig(chunk_tokens=8))
    assert got == ref
    assert (m["cache"]["preemptions"] + m["cache"]["deferrals"]) >= 1


def test_slo_admission_whole_prompt_exact():
    """chunk_tokens=0: SLO-ordered admission with whole-prompt prefill
    (the two tentpole pieces are orthogonal). An all-best-effort
    workload degenerates to FIFO, so streams match the baseline."""
    cfg = _cfg()
    params, ref, _ = _run(cfg)
    _, got, m = _run(cfg, params, sched=SchedConfig(chunk_tokens=0))
    assert got == ref
    assert not m["sched"]["chunked_prefill"]
    assert m["sched"]["chunk_steps"] == 0


def test_step_token_budget_trickle_still_drains():
    """A budget the decode batch alone saturates: the liveness floor
    trickles prefill forward one token per step and everything still
    drains token-exact."""
    cfg = _cfg()
    params, ref, _ = _run(cfg)
    _, got, m = _run(cfg, params,
                     sched=SchedConfig(chunk_tokens=8, step_token_budget=4))
    assert got == ref
    # each round commits at most `budget` prompt tokens, so the budget
    # implies a hard floor on the number of chunk rounds
    assert m["sched"]["chunk_steps"] >= -(-sum(PLENS) // 4)


def test_chunked_rejects_ssm_stack():
    """Chunked prefill rides the decode batch as garbage lanes, which is
    only safe when stale writes can be overwritten — SSM recurrent state
    cannot, so the engine must refuse loudly."""
    cfg = get_config("mamba2-130m", reduced=True, num_layers=2)
    with pytest.raises(ValueError, match="attention-only"):
        ContinuousScheduler(cfg, max_slots=2, max_len=32,
                            sched=SchedConfig(chunk_tokens=8))


def test_metrics_split_and_percentiles():
    """Satellite: TTFT decomposes into queue_wait + prefill, tpot_s is
    populated, and run() reports exact p50/p90/p99 aggregates."""
    cfg = _cfg()
    _, _, m = _run(cfg, sched=SchedConfig(chunk_tokens=8))
    for r in m["per_request"]:
        assert r["queue_wait_s"] is not None
        assert r["prefill_s"] is not None
        assert r["ttft_s"] == pytest.approx(
            r["queue_wait_s"] + r["prefill_s"], abs=1e-6)
        if r["gen_len"] > 1:
            assert r["tpot_s"] is not None
        assert r["chunks"] >= 1
    lat = m["latency"]
    for key in ("ttft_s", "queue_wait_s", "prefill_s", "tpot_s", "e2e_s"):
        block = lat[key]
        assert block is not None, key
        assert block["p50"] <= block["p90"] <= block["p99"] <= block["max"]
    assert lat["ttft_s"]["n"] == len(PLENS)
