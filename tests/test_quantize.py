"""Ternary quantization properties: TWN values/scales, target-sparsity
quantile, straight-through gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import quantize


def test_ternarize_values_and_scale():
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.standard_normal((256, 64)), jnp.float32)
    t, alpha = quantize.ternarize(w)
    assert set(np.unique(np.asarray(t))) <= {-1, 0, 1}
    assert alpha.shape == (1, 64)
    assert (np.asarray(alpha) > 0).all()
    # signs preserved where nonzero
    tz = np.asarray(t)
    wz = np.asarray(w)
    nz = tz != 0
    assert (np.sign(wz[nz]) == tz[nz]).all()


@pytest.mark.parametrize("s", [0.5, 0.25, 0.125, 0.0625])
def test_target_sparsity(s):
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.standard_normal((512, 32)), jnp.float32)
    t, _ = quantize.ternarize_target_sparsity(w, s)
    got = (np.asarray(t) != 0).mean()
    assert abs(got - s) < 0.02


def test_ste_gradient_passthrough():
    rng = np.random.default_rng(2)
    w = jnp.asarray(rng.standard_normal((64, 16)) * 0.1, jnp.float32)

    def f(w):
        return jnp.sum(quantize.ste_ternarize(w) * 3.0)

    g = jax.grad(f)(w)
    # STE: gradient flows (not identically zero), bounded by upstream grad
    assert np.abs(np.asarray(g)).max() <= 3.0 + 1e-6
    assert (np.asarray(g) != 0).mean() > 0.5


def test_ste_forward_equals_ternarize():
    rng = np.random.default_rng(3)
    w = jnp.asarray(rng.standard_normal((128, 8)), jnp.float32)
    t, alpha = quantize.ternarize(w)
    got = quantize.ste_ternarize(w)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(t.astype(jnp.float32) * alpha),
                               rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(thresh=st.floats(0.1, 1.5), seed=st.integers(0, 10**6))
def test_threshold_monotonic_sparsity(thresh, seed):
    """Higher threshold factor => more zeros (monotone sparsity)."""
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((256, 16)), jnp.float32)
    t1, _ = quantize.ternarize(w, thresh)
    t2, _ = quantize.ternarize(w, thresh + 0.3)
    assert (np.asarray(t1) != 0).sum() >= (np.asarray(t2) != 0).sum()


def test_alpha_is_l1_optimal():
    """alpha = mean |w| over the mask minimizes ||w - alpha*t||^2 given t."""
    rng = np.random.default_rng(5)
    w = jnp.asarray(rng.standard_normal((512, 1)), jnp.float32)
    t, alpha = quantize.ternarize(w, per_channel=False)
    tz = np.asarray(t, np.float32)
    wz = np.asarray(w)
    a = float(np.asarray(alpha).reshape(()))
    base = ((wz - a * tz) ** 2).sum()
    for da in (-0.05, 0.05):
        assert ((wz - (a + da) * tz) ** 2).sum() >= base - 1e-6
