"""Continuous-batching engine tests: mixed-length workloads drain
completely, slot reuse never corrupts a live request's cache (token-exact vs
the static server), metrics are populated, the static CLI serves ragged
request counts, and serving phases key the autotuner separately."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import autotune, ops as kops
from repro.launch import serve
from repro.serving import ContinuousScheduler


def _cfg(**overrides):
    return get_config("ternary-paper", reduced=True, num_layers=2,
                      **overrides)


def _workload(cfg, n, prompt_len=16, seed=0, lens=(2, 9)):
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(n, prompt_len)).astype(np.int32)
    gens = [int(g) for g in rng.integers(lens[0], lens[1], size=n)]
    return prompts, gens


def _engine(cfg, slots, max_len, seed=0):
    eng = ContinuousScheduler(cfg, max_slots=slots, max_len=max_len)
    eng.load(eng.model.init(jax.random.PRNGKey(seed)))
    return eng


def test_mixed_length_workload_drains():
    """More requests than slots, mixed budgets: everything drains, each
    request gets exactly its budget, and drained == submitted."""
    cfg = _cfg()
    eng = _engine(cfg, slots=3, max_len=32)
    prompts, gens = _workload(cfg, 8)
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    metrics = eng.run()
    assert metrics["submitted"] == metrics["drained"] == 8
    assert eng.total_drained == eng.queue.submitted == 8
    for req, g in zip(reqs, gens):
        assert len(req.tokens) == g
        assert req.slot is None                      # evicted
    assert metrics["generated_tokens"] == sum(gens)
    # continuous scheduling actually happened: fewer decode steps than a
    # static loop would take (ceil(8/3) batches x max budget each)
    assert metrics["decode_steps"] < metrics["generated_tokens"]


def test_slot_reuse_token_exact_vs_static():
    """Slot reuse under churn must not corrupt a live request's cache: with
    2 slots and one long request pinned while short ones cycle through the
    other slot, every request's tokens must equal the static server's."""
    cfg = _cfg()
    max_len = 40
    eng = _engine(cfg, slots=2, max_len=max_len)
    prompts, _ = _workload(cfg, 6, seed=1)
    gens = [12, 2, 2, 2, 2, 3]     # req 0 stays live across many evictions
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    eng.run()

    srv = serve.BatchedServer(cfg, max_len=max_len)
    srv.load(eng.params)
    ref = srv.generate(prompts, gen_len=max(gens))
    for i, req in enumerate(reqs):
        np.testing.assert_array_equal(
            np.asarray(req.tokens, np.int32), ref[i, :len(req.tokens)],
            err_msg=f"request {i} diverged (slot-reuse corruption?)")


@pytest.mark.parametrize("arch", ["mamba2-130m", "mixtral-8x22b"])
def test_cross_family_token_exact(arch):
    """The slot-pool cache contract covers SSM state/conv caches and the
    rolling sliding-window KV cache, not just dense full-attention KV:
    mamba2 (ssm) and mixtral (moe + SWA rolling cache) must be token-exact
    through the engine too."""
    cfg = get_config(arch, reduced=True)
    eng = _engine(cfg, slots=2, max_len=40)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(4, 16)).astype(np.int32)
    gens = [9, 2, 3, 2]
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    eng.run()
    srv = serve.BatchedServer(cfg, max_len=40)
    srv.load(eng.params)
    ref = srv.generate(prompts, gen_len=max(gens))
    for i, req in enumerate(reqs):
        np.testing.assert_array_equal(
            np.asarray(req.tokens, np.int32), ref[i, :len(req.tokens)],
            err_msg=f"{arch} request {i} diverged")


def test_metrics_populated():
    cfg = _cfg()
    eng = _engine(cfg, slots=2, max_len=24)
    prompts, gens = _workload(cfg, 5, prompt_len=8, lens=(1, 5))
    for p, g in zip(prompts, gens):
        eng.submit(p, g)
    m = eng.run()
    assert m["tok_per_s"] > 0 and m["wall_s"] > 0
    assert m["queue_depth"]["max"] >= 5 - 2      # 5 queued, 2 slots
    assert m["queue_depth"]["mean"] >= 0
    assert m["ttft_s"]["mean"] is not None and m["ttft_s"]["mean"] >= 0
    # grouped admission: between 1 call (all five at once) and 5 (singles)
    assert 1 <= m["prefill_steps"] <= 5
    assert len(m["per_request"]) == 5
    for r in m["per_request"]:
        assert r["ttft_s"] is not None and r["latency_s"] is not None
        assert r["latency_s"] >= r["ttft_s"] >= 0
    json.dumps(m)                                # JSON-serializable


def test_engine_reusable_across_runs():
    """After a drain the pool is fully free; a second workload reuses it."""
    cfg = _cfg()
    eng = _engine(cfg, slots=2, max_len=24)
    prompts, gens = _workload(cfg, 3, prompt_len=8, lens=(1, 4))
    for p, g in zip(prompts, gens):
        eng.submit(p, g)
    eng.run()
    assert eng.pool.n_free == 2
    r = eng.submit(prompts[0], 3)
    m = eng.run()
    assert m["drained"] == 1 and len(r.tokens) == 3


def test_engine_rejects_oversized_and_encdec():
    cfg = _cfg()
    eng = _engine(cfg, slots=1, max_len=16)
    with pytest.raises(AssertionError):
        eng.submit(np.zeros(12, np.int32), 8)    # 12 + 8 > 16
    with pytest.raises(ValueError):
        ContinuousScheduler(get_config("seamless-m4t-large-v2", reduced=True),
                            max_slots=1, max_len=16)


def test_serve_cli_static_ragged_batches(capsys):
    """requests % batch != 0 must not drop the remainder (the old
    ``requests // batch`` bug): all 7 requests are served."""
    metrics = serve.main(["--arch", "ternary-paper", "--reduced",
                          "--static", "--requests", "7", "--batch", "4",
                          "--prompt-len", "8", "--gen-lens", "2,4"])
    out = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert out["submitted"] == out["drained"] == 7
    assert metrics["drained"] == 7


def test_serve_cli_continuous(capsys):
    metrics = serve.main(["--arch", "ternary-paper", "--reduced",
                          "--requests", "5", "--slots", "2",
                          "--prompt-len", "8", "--gen-lens", "2,5"])
    out = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert out["engine"] == "continuous"
    assert out["submitted"] == out["drained"] == 5
    assert metrics["queue_depth"]["max"] >= 3


def test_vector_pos_decode_matches_scalar():
    """A whole-batch decode with a per-slot position *vector* must match the
    scalar-position decode bit-for-bit (same positions, both cache layouts)."""
    for overrides in ({}, {"cache_layout": "opt"}):
        cfg = _cfg(**overrides)
        from repro.models import LM
        m = LM(cfg)
        params = m.init(jax.random.PRNGKey(0))
        toks = np.arange(24, dtype=np.int32).reshape(2, 12) % cfg.vocab_size
        cache, logits = jax.jit(lambda p, b: m.prefill(p, b, 20))(
            params, {"tokens": toks})
        tok = np.asarray(np.argmax(logits[:, -1:], -1), np.int32)
        lg_s, _ = jax.jit(m.decode_step)(params, cache, tok)
        cache_v = dict(cache, pos=np.full((2,), 12, np.int32))
        lg_v, cache_v2 = jax.jit(m.decode_step)(params, cache_v, tok)
        np.testing.assert_array_equal(np.asarray(lg_s), np.asarray(lg_v),
                                      err_msg=f"layout={overrides}")
        assert cache_v2["pos"].shape == (2,)


def test_serving_phase_keys_autotuner(tmp_path):
    """prefill (GEMM) and decode (GEMV) dispatches must tune under distinct
    cache keys, and the decode grid includes GEMV-shaped candidates."""
    k_pre = autotune.cache_key(8, 4096, 4096, phase="prefill")
    k_dec = autotune.cache_key(8, 4096, 4096, phase="decode")
    k_none = autotune.cache_key(8, 4096, 4096)
    assert len({k_pre, k_dec, k_none}) == 3
    assert kops.current_phase() is None
    with kops.serving_phase("decode"):
        assert kops.current_phase() == "decode"
        with kops.serving_phase("prefill"):
            assert kops.current_phase() == "prefill"
        assert kops.current_phase() == "decode"
    assert kops.current_phase() is None
    tuner = autotune.Autotuner(path=str(tmp_path / "cache.json"),
                               mode="model")
    cands = tuner.candidates(8, 4096, 4096, phase="decode")
    assert any(c.block_m <= 8 and c.block_k >= 1024 for c in cands)
    cfg = tuner.lookup(8, 4096, 4096, phase="decode")
    assert cfg.block_m <= 8
