"""Fault tolerance: supervised restart resumes from the last durable
checkpoint with bitwise-identical state evolution; straggler watchdog flags
slow steps."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.distributed.fault_tolerance import StragglerWatchdog, TrainSupervisor


def _deterministic_trainer(tmp_path, fail_at=None, ckpt_every=5):
    calls = {"fails": 0}

    def make_state(resume):
        if resume is None:
            return 0, {"x": jnp.asarray(0.0), "step": jnp.asarray(0)}
        from repro import checkpoint as ckpt
        step, st = ckpt.restore(str(tmp_path), resume,
                                target={"x": jnp.zeros(()),
                                        "step": jnp.zeros((), jnp.int32)})
        return step, st

    def step_fn(step, state):
        # deterministic update: x += step
        return ({"x": state["x"] + step, "step": state["step"] + 1},
                {"x": float(state["x"])})

    def injector(step):
        if fail_at is not None and step == fail_at and calls["fails"] == 0:
            calls["fails"] += 1
            raise RuntimeError("simulated node failure")

    sup = TrainSupervisor(str(tmp_path), make_state, step_fn,
                          ckpt_every=ckpt_every)
    return sup, injector


def test_restart_resumes_identically(tmp_path):
    # ground truth without failure
    sup0, _ = _deterministic_trainer(tmp_path / "clean")
    state0, hist0 = sup0.run(20)
    # with a failure at step 13 (after ckpt at 10): must restart and converge
    sup1, inj = _deterministic_trainer(tmp_path / "faulty", fail_at=13)
    state1, hist1 = sup1.run(20, failure_injector=inj)
    assert sup1.restarts == 1
    assert float(state0["x"]) == float(state1["x"])
    assert int(state1["step"]) == 20


def test_restart_budget_exhaustion(tmp_path):
    def make_state(resume):
        return 0, {}

    def step_fn(step, state):
        raise RuntimeError("always fails")

    sup = TrainSupervisor(str(tmp_path), make_state, step_fn, max_restarts=2)
    with pytest.raises(RuntimeError):
        sup.run(5)
    assert sup.restarts == 3


def test_straggler_watchdog():
    w = StragglerWatchdog(factor=2.0, alpha=0.5)
    for i in range(10):
        assert not w.observe(i, 1.0)
    assert w.observe(10, 5.0)          # 5x slower than ewma -> straggler
    assert w.straggler_steps == 1
    assert w.events[0][0] == 10
    # ewma absorbs the spike; next normal step not flagged
    assert not w.observe(11, 1.0)


def test_straggler_ewma_math():
    """Exact EWMA semantics: the first observation seeds (never flags), the
    flag threshold compares against the *pre-update* ewma, and the update
    is (1-alpha)*ewma + alpha*dt — spikes are absorbed, not adopted."""
    w = StragglerWatchdog(factor=2.0, alpha=0.1)
    assert not w.observe(0, 1.0)       # seed: no ewma to compare against
    assert w.ewma == 1.0
    assert not w.observe(1, 2.0)       # 2.0 == factor*ewma, not >
    assert abs(w.ewma - 1.1) < 1e-12   # 0.9*1.0 + 0.1*2.0
    assert w.observe(2, 2.3)           # 2.3 > 2*1.1
    assert abs(w.ewma - (0.9 * 1.1 + 0.1 * 2.3)) < 1e-12
    assert w.straggler_steps == 1


def test_straggler_events_bounded():
    """`events` is a ring capped at events_cap — a week of stragglers on a
    flaky node must not grow host memory — while `straggler_steps` stays
    exact and the ring holds the most recent records."""
    w = StragglerWatchdog(factor=2.0, alpha=0.0, events_cap=8)
    w.observe(0, 1.0)                  # seed the ewma (alpha=0: frozen)
    for i in range(1, 101):
        w.observe(i, 5.0)
    assert w.straggler_steps == 100
    assert len(w.events) == 8
    kept = sorted(e[0] for e in w.events)
    assert kept == list(range(93, 101))   # the 8 newest straggler steps


def test_elastic_restore_smaller_world(tmp_path):
    """Checkpoints are logical: save from one 'world', restore into another
    (different sharding/device count is a device_put detail)."""
    import jax
    from repro import checkpoint as ckpt
    big = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    ckpt.save(str(tmp_path), 1, big)
    step, restored = ckpt.restore(str(tmp_path), target=jax.eval_shape(lambda: big))
    np.testing.assert_array_equal(np.asarray(big["w"]), np.asarray(restored["w"]))
