"""The trip-count-aware HLO cost walker: exactness on unrolled programs
(vs XLA's own cost analysis) and loop-trip recovery on scanned programs."""
import jax
import jax.numpy as jnp

from repro.launch import hlo_cost


def _dots_flops(n, dim):
    return n * 2 * dim**3


def test_matches_xla_on_unrolled():
    def f(x, ws):
        for i in range(10):
            x = jnp.dot(x, ws[i]) * 1.5
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    mine = hlo_cost.analyze(c.as_text())
    xla = hlo_cost.xla_cost(c)["flops"]
    assert abs(mine.flops - xla) / xla < 0.02


def test_recovers_scan_trip_count():
    def body(x, w):
        return jnp.dot(x, w) * 1.5, None

    def f(x, ws):
        x, _ = jax.lax.scan(body, x, ws)
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    c = jax.jit(f).lower(x, ws).compile()
    mine = hlo_cost.analyze(c.as_text())
    want = _dots_flops(10, 64)
    assert abs(mine.flops - want) / want < 0.05
    # XLA itself undercounts (documents why the walker exists)
    assert hlo_cost.xla_cost(c)["flops"] < want / 2


def test_nested_scans_multiply():
    def inner(x, w):
        return jnp.dot(x, w), None

    def outer(x, ws):
        def obody(x, _):
            x, _ = jax.lax.scan(inner, x, ws)
            return x, None
        x, _ = jax.lax.scan(obody, x, None, length=4)
        return x

    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    ws = jax.ShapeDtypeStruct((3, 32, 32), jnp.float32)
    c = jax.jit(outer).lower(x, ws).compile()
    mine = hlo_cost.analyze(c.as_text())
    want = _dots_flops(12, 32)
    assert abs(mine.flops - want) / want < 0.10


def test_grad_with_remat():
    def blk(x, w):
        return jnp.tanh(jnp.dot(x, w)), None

    def loss(x, ws):
        y, _ = jax.lax.scan(jax.checkpoint(blk), x, ws)
        return jnp.sum(y**2)

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((10, 64, 64), jnp.float32)
    c = jax.jit(jax.grad(loss)).lower(x, ws).compile()
    mine = hlo_cost.analyze(c.as_text())
    # fwd (10) + remat fwd (10) + bwd 2x(10) = ~40 dot-equivalents
    want = _dots_flops(40, 64)
    assert 0.7 * want < mine.flops < 1.4 * want


def test_gather_bytes_not_full_table():
    """Embedding-style gather must count gathered rows, not the table."""
    def f(table, idx):
        return table[idx]

    table = jax.ShapeDtypeStruct((50000, 512), jnp.float32)
    idx = jax.ShapeDtypeStruct((64,), jnp.int32)
    c = jax.jit(f).lower(table, idx).compile()
    mine = hlo_cost.analyze(c.as_text())
    table_bytes = 50000 * 512 * 4
    assert mine.bytes < table_bytes / 10


def test_collective_bytes_on_mesh():
    import subprocess, sys, os, json
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, json
from jax.sharding import NamedSharding, PartitionSpec as P
import sys
sys.path.insert(0, %r)
from repro.launch import hlo_cost

mesh = jax.make_mesh((4,), ("data",))
sh = NamedSharding(mesh, P("data", None))
rep = NamedSharding(mesh, P())

def f(x):
    return jnp.sum(x, axis=0)          # cross-shard reduce -> all-reduce

x = jax.ShapeDtypeStruct((64, 128), jnp.float32)
c = jax.jit(f, in_shardings=sh, out_shardings=rep).lower(x).compile()
mine = hlo_cost.analyze(c.as_text())
print(json.dumps({"coll": mine.total_collective()}))
"""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code % src],
                         capture_output=True, text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    # all-reduce of a (16?,128)->... some per-device bytes > 0
    assert res["coll"] > 0
