"""The compressed-gradient DP trainer (shard_map + TernGrad sync) trains a
tiny model to decreasing loss on 8 fake devices (subprocess)."""
import json
import os
import subprocess
import sys

import pytest


@pytest.mark.slow
def test_compressed_dp_trainer_reduces_loss():
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import jax.numpy as jnp
from repro.configs import get_config
from repro.models import LM
from repro.data import SyntheticLM
from repro.launch.train import make_compressed_dp_step
from repro.distributed import compression
from repro.optim import constant

mesh = jax.make_mesh((8,), ("data",))
cfg = get_config("ternary-paper", reduced=True, quantization="none",
                 num_layers=2, vocab_size=64)
model = LM(cfg)
params = model.init(jax.random.PRNGKey(0))
step, opt_init = make_compressed_dp_step(model, cfg, mesh, constant(1e-2))
opt = opt_init(params)
err = compression.init_error_state(params)
data = SyntheticLM(cfg, 16, 32, noise=0.0)
jstep = jax.jit(step, donate_argnums=(0, 1, 2))
losses = []
for i in range(30):
    batch = {k: jnp.asarray(v) for k, v in data.global_batch(i % 4).items()}
    params, opt, err, metrics = jstep(params, opt, err, batch)
    losses.append(float(metrics["loss"]))
print(json.dumps({"first": losses[0], "last": losses[-1]}))
"""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["last"] < res["first"] * 0.85, res
