"""TernaryWeight container API: pytree round-trips (flatten / jit-closure /
jit-argument / device_put / scan slicing), registry planning (GemmPlan),
the deprecation shim's bit-exact equivalence with the old operand union,
unified K validation, the base3 format, and checkpoint save -> restore ->
serve token-exactness against a direct packed boot."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats, weights
from repro.kernels import ops, ref

ALL_FORMATS = ["dense2bit", "tiled", "bitplane", "base3"]


def _mk(fmt, k=96, n=48, s=0.25, seed=0, **opts):
    rng = np.random.default_rng(seed)
    w = formats.random_ternary(rng, k, n, s)
    if fmt == "tiled":
        opts.setdefault("tile_k", 32)
        opts.setdefault("tile_n", 16)
    wc = weights.pack(w, fmt, **opts)
    x = jnp.asarray(rng.standard_normal((8, k)), jnp.float32)
    return x, w, wc


# ---------------------------------------------------------------------------
# Pytree contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ALL_FORMATS)
def test_pytree_roundtrip(fmt):
    x, w, wc = _mk(fmt)
    leaves, treedef = jax.tree_util.tree_flatten(wc)
    wc2 = jax.tree_util.tree_unflatten(treedef, leaves)
    assert type(wc2) is type(wc)
    assert wc2.shape == wc.shape and int(wc2.nnz) == int(wc.nnz)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(ops.ternary_gemm(x, wc2)),
                               np.asarray(y0), rtol=1e-4, atol=1e-4)
    # named key paths (checkpoint leaf keys) resolve to the field names
    key_leaves = jax.tree_util.tree_flatten_with_path(wc)[0]
    names = {path[-1].name for path, _ in key_leaves}
    assert names <= set(type(wc)._leaves)


@pytest.mark.parametrize("fmt", ALL_FORMATS)
def test_pytree_jit_closure_and_argument(fmt):
    x, w, wc = _mk(fmt)
    y0 = np.asarray(ref.ternary_matmul_dense(x, jnp.asarray(w)))
    y_closure = jax.jit(lambda xx: ops.ternary_gemm(xx, wc))(x)
    np.testing.assert_allclose(np.asarray(y_closure), y0,
                               rtol=1e-4, atol=1e-4)
    # as a jit *argument* the leaves become tracers: planning must rely on
    # static aux only
    y_arg = jax.jit(lambda xx, ww: ops.ternary_gemm(xx, ww))(x, wc)
    np.testing.assert_allclose(np.asarray(y_arg), y0, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("fmt", ALL_FORMATS)
def test_pytree_device_put(fmt):
    x, w, wc = _mk(fmt)
    wd = jax.device_put(wc)
    assert type(wd) is type(wc) and wd.shape == wc.shape
    # stats survive the round-trip as plain ints with plain int equality
    assert type(wd.nnz) is int and wd.nnz == int(wc.nnz)
    np.testing.assert_array_equal(
        np.asarray(wd.materialize(jnp.int8)), w)
    assert wc.device_put().shape == wc.shape


def test_stacked_container_scan_slicing():
    """A scan-stacked Dense2Bit (leading L dim on every leaf) slices to the
    per-layer 2-D container inside jax.lax.scan with static aux intact."""
    rng = np.random.default_rng(3)
    k, n, layers = 64, 32, 3
    ws = np.stack([formats.random_ternary(rng, k, n, 0.5)
                   for _ in range(layers)])
    wc = weights.Dense2Bit.from_dense(ws)
    assert wc.shape == (k, n) and wc.packed.ndim == 3
    x = jnp.asarray(rng.standard_normal((4, k)), jnp.float32)

    def body(carry, layer_wc):
        y = ops.ternary_gemm(carry[:, :k], layer_wc, impl="ref")
        return jnp.pad(y, ((0, 0), (0, k - n))), y

    _, ys = jax.lax.scan(body, x, wc)
    for i in range(layers):
        y0 = ref.ternary_matmul_dense(
            x if i == 0 else jnp.pad(np.asarray(ys[i - 1]),
                                     ((0, 0), (0, k - n))),
            jnp.asarray(ws[i]))
        np.testing.assert_allclose(np.asarray(ys[i]), np.asarray(y0),
                                   rtol=1e-4, atol=1e-4)
    # un-sliced stacked containers are rejected with a clear error
    with pytest.raises(ValueError, match="stacked"):
        ops.ternary_gemm(x, wc)


# ---------------------------------------------------------------------------
# Planner / registry
# ---------------------------------------------------------------------------

def test_gemm_plan_inspectable():
    rng = np.random.default_rng(5)
    w = formats.random_tile_ternary(rng, 96, 48, 32, 16, 0.0625)
    wc = weights.pack(w, "tiled", tile_k=32, tile_n=16)
    plan = ops.ternary_gemm_plan(wc, 8)
    assert plan.format == "tiled" and plan.impl == "skip_db"
    assert (plan.k, plan.n) == (96, 48)
    assert plan.block_n == wc.tile_n and plan.block_k == wc.tile_k
    assert 0.0 < plan.occupancy <= 1.0
    # phase keying
    with ops.serving_phase("decode"):
        assert ops.ternary_gemm_plan(wc, 8).phase == "decode"
    assert ops.ternary_gemm_plan(wc, 8, phase="prefill").phase == "prefill"


def test_registry_contents_and_unknown_impl():
    reg = ops.kernel_registry()
    for key in [("dense2bit", "dense"), ("dense2bit", "ref"),
                ("tiled", "skip"), ("tiled", "skip_db"), ("tiled", "dense"),
                ("tiled", "ref"),
                ("bitplane", "bitplane"), ("bitplane", "bitplane_factorized"),
                ("bitplane", "ref"), ("base3", "ref")]:
        assert key in reg, key
    _, _, wc = _mk("dense2bit")
    with pytest.raises(ValueError, match="available"):
        ops.ternary_gemm_plan(wc, 8, impl="skip")


def test_precompute_plans_warms_phase_keys():
    _, _, wc = _mk("dense2bit")
    tree = {"layer": {"w_packed": wc, "w_in": wc}}
    plans = ops.precompute_plans(tree, prefill_ms=(8, 64), decode_ms=(4,))
    assert len(plans) == 6                      # both containers, no filter
    assert {p.phase for p in plans.values()} == {"prefill", "decode"}
    # the engine's filter: only containers that dispatch through the gemm
    # (packed linears) are planned, not materialized MoE banks
    plans = ops.precompute_plans(
        tree, prefill_ms=(8, 64), decode_ms=(4,),
        select=lambda path, w: getattr(path[-1], "key", None) == "w_packed")
    assert len(plans) == 3


@pytest.mark.parametrize("fmt", ALL_FORMATS)
def test_k_validation_unified(fmt):
    """The planner validates X-vs-weight K once, for every format."""
    x, w, wc = _mk(fmt)
    bad = jnp.zeros((4, wc.k + 16), jnp.float32)
    with pytest.raises(ValueError, match="encodes K"):
        ops.ternary_gemm(bad, wc)
    with pytest.raises(ValueError, match="does not match"):
        ops.ternary_gemm(x, wc, k=wc.k + 16)


# ---------------------------------------------------------------------------
# Removed shim: legacy raw operands are a hard error with a migration hint
# ---------------------------------------------------------------------------

def test_legacy_operands_raise_with_migration_hint():
    """The PR-3 DeprecationWarning shim is gone: passing the old operand
    union (raw packed words / TiledTernary / bitplane tuples) raises a
    TypeError naming the ``weights`` constructor to migrate to."""
    rng = np.random.default_rng(7)
    k, n = 128, 64
    w = formats.random_tile_ternary(rng, k, n, 32, 16, 0.125)
    x = jnp.asarray(rng.standard_normal((8, k)), jnp.float32)

    legacy = {
        "dense2bit": (jnp.asarray(formats.pack_2bit(w)), {"k": k},
                      r"Dense2Bit\.from_packed"),
        "tiled": (formats.TiledTernary.from_dense(w, tile_k=32, tile_n=16),
                  {}, r"Tiled\.from_tiled"),
        "bitplane": (tuple(jnp.asarray(a)
                           for a in formats.pack_bitplanes(w)), {"k": k},
                     r"Bitplane\.from_planes"),
    }
    for fmt, (old_operand, kw, hint) in legacy.items():
        with pytest.raises(TypeError, match=hint):
            ops.ternary_gemm(x, old_operand, **kw)
        # the container path still works and stays the single entry point
        y = ops.ternary_gemm(x, weights.pack(w, fmt) if fmt != "tiled"
                             else weights.pack(w, fmt, tile_k=32, tile_n=16))
        assert y.shape == (8, n), fmt


# ---------------------------------------------------------------------------
# Base3 is a first-class, dispatchable format
# ---------------------------------------------------------------------------

def test_base3_registered_and_correct():
    assert "base3" in weights.FORMATS
    rng = np.random.default_rng(9)
    k, n = 100, 40                       # K not a multiple of 5: padding path
    w = formats.random_ternary(rng, k, n, 0.25)
    x = jnp.asarray(rng.standard_normal((6, k)), jnp.float32)
    alpha = jnp.asarray(rng.standard_normal(n) ** 2 + 0.1, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(n), jnp.float32)
    wc = weights.pack(w, "base3", scale=alpha, bias=bias)
    assert ops.ternary_gemm_plan(wc, 6).impl == "ref"
    y = ops.ternary_gemm(x, wc)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w), alpha, bias)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)
    # 5 trits/byte beats 2-bit packing on code bytes
    assert wc.packed.nbytes < weights.pack(w, "dense2bit").packed.nbytes
    np.testing.assert_array_equal(np.asarray(wc.materialize(jnp.int8)), w)


# ---------------------------------------------------------------------------
# Checkpoint: save -> restore -> serve without re-packing
# ---------------------------------------------------------------------------

def test_checkpoint_packed_restore_serves_token_exact(tmp_path):
    """A server restoring a packed TernaryWeight checkpoint into the
    ternary_packed model skeleton must produce exactly the tokens of the
    boot that packed the weights in-process (no re-quantization drift)."""
    from repro import checkpoint as ckpt
    from repro.configs import get_config
    from repro.models import LM, layers as L
    from repro.serving import ContinuousScheduler

    cfg = get_config("ternary-paper", reduced=True, ternary_min_dim=64,
                     num_layers=2, dtype="float32")
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    packed = L.pack_params(params, cfg)
    cfg_packed = dataclasses.replace(cfg, quantization="ternary_packed")

    ckpt.save(str(tmp_path), 1, {"params": packed})
    target = {"params": LM(cfg_packed).init(jax.random.PRNGKey(1))}
    step, restored = ckpt.restore(str(tmp_path), target=target)
    assert step == 1

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(4, 8)).astype(np.int32)
    gens = [5, 2, 3, 4]

    def serve(ps):
        eng = ContinuousScheduler(cfg_packed, max_slots=2, max_len=16)
        eng.load(ps)
        reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
        metrics = eng.run()
        assert metrics["planned_gemms"] > 0      # plans precomputed at load
        return [list(r.tokens) for r in reqs]

    assert serve(packed) == serve(restored["params"])


def test_checkpoint_rejects_nothing_on_plain_trees(tmp_path):
    """Sanity: the GetAttrKey path support doesn't disturb plain trees."""
    from repro import checkpoint as ckpt
    state = {"a": jnp.arange(4.0), "nested": {"b": jnp.ones((2, 2))}}
    ckpt.save(str(tmp_path), 3, state)
    step, out = ckpt.restore(str(tmp_path), target=state)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(out["a"]), np.arange(4.0))


# ---------------------------------------------------------------------------
# Container metadata defaults flow into the gemm
# ---------------------------------------------------------------------------

def test_container_scale_bias_defaults_and_override():
    rng = np.random.default_rng(11)
    k, n = 64, 32
    w = formats.random_ternary(rng, k, n, 0.5)
    x = jnp.asarray(rng.standard_normal((4, k)), jnp.float32)
    alpha = jnp.asarray(rng.standard_normal(n) ** 2 + 0.1, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(n), jnp.float32)
    wc = weights.pack(w, "dense2bit", scale=alpha, bias=bias)
    y_implicit = ops.ternary_gemm(x, wc)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w), alpha, bias)
    np.testing.assert_allclose(np.asarray(y_implicit), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)
    # explicit operands override the container's metadata
    y_override = ops.ternary_gemm(x, wc, scale=jnp.ones_like(alpha))
    y1 = ref.ternary_matmul_dense(x, jnp.asarray(w), None, bias)
    np.testing.assert_allclose(np.asarray(y_override), np.asarray(y1),
                               rtol=1e-4, atol=1e-4)


def test_legacy_raw_packed_param_dict_rejected_clearly():
    """A pre-container param dict ({'w_packed': raw uint32 array}) must
    fail with an actionable TypeError, not a KeyError mid-forward."""
    from repro.configs import get_config
    from repro.models import layers as L
    cfg = get_config("ternary-paper", reduced=True,
                     quantization="ternary_packed")
    legacy = {"w_packed": jnp.zeros((8, 64), jnp.uint32),
              "w_scale": jnp.ones((64,), jnp.float32)}
    with pytest.raises(TypeError, match="from_packed"):
        L.linear_apply(legacy, jnp.zeros((2, 128), jnp.float32), cfg)


def test_spec_twins_survive_packing():
    """Sharding-spec twins built at init (nnz=-1 placeholders) must stay
    structurally compatible with params packed from a trained latent tree
    (real nnz): pack statistics ride in aux data but are excluded from
    treedef identity."""
    from repro.configs import get_config
    from repro.distributed import sharding
    from repro.models import LM, layers as L
    cfg = get_config("ternary-paper", reduced=True, ternary_min_dim=64,
                     num_layers=2, dtype="float32")
    cfg_packed = dataclasses.replace(cfg, quantization="ternary_packed")
    _, specs = LM(cfg_packed).init_with_specs(jax.random.PRNGKey(0))
    packed = L.pack_params(LM(cfg).init(jax.random.PRNGKey(0)), cfg)
    mesh = jax.make_mesh((1,), ("model",))
    shardings = sharding.resolve_specs(specs, packed, mesh, fsdp=False)
    assert jax.tree_util.tree_structure(shardings) == \
        jax.tree_util.tree_structure(packed)


def test_pack_params_respects_quantization_gate():
    """pack_params must be a no-op on an unquantized config — packing is
    lossy and must never be applied unrequested (MoE banks included)."""
    from repro.configs import get_config
    from repro.models import LM, layers as L
    cfg = get_config("mixtral-8x22b", reduced=True)    # quantization="none"
    assert cfg.quantization == "none"
    params = LM(cfg).init(jax.random.PRNGKey(0))
    packed = L.pack_params(params, cfg)
    assert jax.tree_util.tree_structure(packed) == \
        jax.tree_util.tree_structure(params)
    assert not any(isinstance(w, weights.TernaryWeight)
                   for w in jax.tree_util.tree_leaves(
                       packed, is_leaf=lambda v: isinstance(
                           v, weights.TernaryWeight)))


def test_float_pack_autoternarizes():
    rng = np.random.default_rng(13)
    wf = jnp.asarray(rng.standard_normal((64, 32)) * 0.05, jnp.float32)
    wc = weights.pack(wf, "dense2bit")
    assert wc.scale is not None and 0.0 < wc.occupancy() <= 1.0
    from repro.core import quantize
    t, alpha = quantize.ternarize(wf)
    x = jnp.asarray(rng.standard_normal((4, 64)), jnp.float32)
    y0 = ref.ternary_matmul_dense(x, t, alpha.reshape(-1))
    np.testing.assert_allclose(np.asarray(ops.ternary_gemm(x, wc)),
                               np.asarray(y0), rtol=1e-4, atol=1e-4)
