"""Scheduler-policy units (DESIGN.md §14), pinned without an engine:
``SLOQueue`` ordering (priority > deadline > submit order, replays
absolute-head, retries re-stamped to the tail), the pure ``plan_chunks``
token budgeter, the rid-keyed ``take_expired`` contract on both queue
flavours, and the seeded open-loop traffic schedule."""
import time

import numpy as np
import pytest

from repro.serving.queue import Request, RequestQueue
from repro.serving.sched import SchedConfig, SLOClass, SLOQueue, plan_chunks
from repro.serving.sched.slo import slo_key, ttft_deadline
from repro.serving.traffic import TrafficConfig, make_schedule

INTERACTIVE = SLOClass("interactive", ttft_target_s=0.5,
                       tpot_target_s=0.1, priority=0)
BATCH = SLOClass("batch", ttft_target_s=10.0, priority=1)


def _req(rid, *, plen=8, slo=None, submit_t=0.0, seq=None, prefill_pos=0):
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32),
                   max_new=4, slo=slo, submit_t=submit_t,
                   seq=rid if seq is None else seq,
                   prefill_pos=prefill_pos)


# ---------------------------------------------------------------- SLOQueue

def test_slo_key_priority_dominates_deadline():
    urgent_batch = _req(0, slo=BATCH, submit_t=0.0)        # dl = 10
    lazy_inter = _req(1, slo=INTERACTIVE, submit_t=100.0)  # dl = 100.5
    assert slo_key(lazy_inter) < slo_key(urgent_batch)
    assert ttft_deadline(_req(2)) == float("inf")


def test_sloqueue_orders_by_class_then_deadline():
    q = SLOQueue()
    p = np.arange(4, dtype=np.int32)
    b = q.submit(p, 4, slo=BATCH)          # first in, low priority
    e1 = q.submit(p, 4)                    # best-effort: inf deadline
    i1 = q.submit(p, 4, slo=INTERACTIVE)   # tight deadline, priority 0
    i2 = q.submit(p, 4, slo=INTERACTIVE)   # same class, later submit
    assert [q.pop() for _ in range(4)] == [i1, i2, e1, b]


def test_sloqueue_best_effort_degenerates_to_fifo():
    q = SLOQueue()
    reqs = [q.submit(np.arange(3, dtype=np.int32), 2) for _ in range(5)]
    assert [q.pop() for _ in range(5)] == reqs


def test_sloqueue_replays_win_over_tighter_deadlines():
    q = SLOQueue()
    p = np.arange(4, dtype=np.int32)
    victim = q.submit(p, 4, slo=BATCH)
    q.submit(p, 4, slo=INTERACTIVE)
    assert q.pop() is not victim or True  # interactive pops first
    q.push_front(victim)                  # preempted: holds drain progress
    assert q.peek() is victim             # absolute head, despite BATCH
    assert q.pop() is victim


def test_sloqueue_retry_restamps_seq_to_tail():
    q = SLOQueue()
    p = np.arange(4, dtype=np.int32)
    r0 = q.submit(p, 4)
    r1 = q.submit(p, 4)
    assert q.pop() is r0
    q.requeue(r0)                         # quarantine retry
    assert r0.seq > r1.seq                # re-stamped behind the waiter
    assert [q.pop(), q.pop()] == [r1, r0]


def test_sloqueue_backoff_skips_to_eligible():
    q = SLOQueue()
    p = np.arange(4, dtype=np.int32)
    gated = q.submit(p, 4, slo=INTERACTIVE)
    gated.not_before = time.monotonic() + 60.0  # deep in backoff
    ok = q.submit(p, 4, slo=BATCH)
    assert q.peek() is ok                 # eligible beats better-ranked
    assert q.pop() is ok
    # only the gated request left: surface it so the engine's not_before
    # check idles (FIFO-head behaviour)
    assert q.peek() is gated


def test_sloqueue_peek_pop_consistent():
    q = SLOQueue()
    p = np.arange(4, dtype=np.int32)
    q.submit(p, 4, slo=INTERACTIVE)
    q.submit(p, 4, slo=INTERACTIVE)
    head = q.peek()
    assert q.peek() is head               # memoized
    assert q.pop() is head                # pop honours the peek
    assert len(q) == 1 and bool(q) and q.depth() == 1


# ------------------------------------------------- take_expired (satellite)

def test_take_expired_rid_order_despite_push_front_interleaving():
    """Preemption replays scramble the deque; expiry must still report in
    submit (rid) order and leave the survivors' order intact."""
    q = RequestQueue()
    p = np.arange(4, dtype=np.int32)
    reqs = [q.submit(p, 4, deadline_s=(0.0 if i % 2 else None))
            for i in range(4)]
    r0, r1 = q.pop(), q.pop()
    q.push_front(r0)
    q.push_front(r1)                      # deque now [r1, r0, r2, r3]
    assert q.peek() is r1
    expired = q.take_expired(time.monotonic() + 1.0)
    assert [r.rid for r in expired] == [1, 3]      # rid order, not deque
    assert all(r.expired(time.monotonic() + 1.0) for r in expired)
    assert [q.pop(), q.pop()] == [reqs[0], reqs[2]]  # replay head kept


def test_sloqueue_take_expired_covers_replays():
    q = SLOQueue()
    p = np.arange(4, dtype=np.int32)
    r0 = q.submit(p, 4, deadline_s=0.0)
    r1 = q.submit(p, 4, deadline_s=0.0, slo=INTERACTIVE)
    assert q.pop() is r1
    q.push_front(r1)                      # expired request in the replay deque
    expired = q.take_expired(time.monotonic() + 1.0)
    assert [r.rid for r in expired] == [r0.rid, r1.rid]
    assert q.empty() and not q


# -------------------------------------------------------------- plan_chunks

CFG8 = SchedConfig(chunk_tokens=8)


def test_plan_chunks_splits_residual_in_slo_order():
    a = _req(0, plen=20, slo=INTERACTIVE, submit_t=0.0)
    b = _req(1, plen=20, slo=BATCH, submit_t=0.0)
    jobs, meta = plan_chunks([(5, b), (3, a)], cfg=CFG8, budget=16,
                             n_decode_tokens=4, max_len=64, now=0.0)
    # residual 12: interactive first (priority) gets its full chunk of 8,
    # batch gets the 4 left over
    assert [(s, r.rid, c) for s, r, c in jobs] == [(3, 0, 8), (5, 1, 4)]
    assert meta["residual"] == 12 and meta["assigned"] == 12
    assert meta["window"] == 8


def test_plan_chunks_liveness_floor():
    a = _req(0, plen=20)
    jobs, meta = plan_chunks([(0, a)], cfg=CFG8, budget=4,
                             n_decode_tokens=6, max_len=64, now=0.0)
    assert meta["residual"] == 1
    assert jobs == [(0, a, 1)]


def test_plan_chunks_tpot_pressure_halves_residual():
    a = _req(0, plen=40)
    jobs, meta = plan_chunks([(0, a)], cfg=CFG8, budget=16,
                             n_decode_tokens=4, max_len=64, now=0.0,
                             step_s=0.2, tpot_floor=0.1)
    assert meta["residual"] == 6          # (16 - 4) // 2
    assert jobs == [(0, a, 4)]            # 6 rounded down to a pow2 window
    # no pressure when steps are under the floor
    _, meta2 = plan_chunks([(0, a)], cfg=CFG8, budget=16,
                           n_decode_tokens=4, max_len=64, now=0.0,
                           step_s=0.05, tpot_floor=0.1)
    assert meta2["residual"] == 12


def test_plan_chunks_deadline_pressure_claims_residual():
    late = _req(0, plen=30, slo=INTERACTIVE, submit_t=0.0)
    jobs, _ = plan_chunks([(0, late)], cfg=CFG8, budget=64,
                          n_decode_tokens=0, max_len=64,
                          now=10.0, step_s=0.01)   # deadline long past
    # claims past its one polite chunk of 8 — the whole remaining 30,
    # pow2-rounded to a 16-wide window
    assert jobs == [(0, late, 16)]
    calm = _req(1, plen=30, slo=INTERACTIVE, submit_t=9.9)
    jobs, _ = plan_chunks([(0, calm)], cfg=CFG8, budget=64,
                          n_decode_tokens=0, max_len=64,
                          now=0.0, step_s=0.01)
    assert jobs == [(0, calm, 8)]         # polite chunk when not pressed


def test_plan_chunks_window_capped_by_cache_bounds():
    near_end = _req(0, plen=40, prefill_pos=38)    # 2 tokens left, pos 38
    fresh = _req(1, plen=20)
    jobs, meta = plan_chunks([(0, near_end), (1, fresh)], cfg=CFG8,
                             budget=64, n_decode_tokens=0, max_len=40,
                             now=0.0)
    # rectangular window: S <= min(max_len - prefill_pos) over rows = 2
    assert meta["window"] == 2
    assert all(c <= 2 for _, _, c in jobs)


def test_plan_chunks_empty_and_exhausted():
    assert plan_chunks([], cfg=CFG8, budget=16, n_decode_tokens=0,
                       max_len=64, now=0.0)[0] == []
    many = [(i, _req(i, plen=30)) for i in range(4)]
    jobs, meta = plan_chunks(many, cfg=CFG8, budget=10, n_decode_tokens=0,
                             max_len=64, now=0.0)
    assert meta["assigned"] <= 10         # budget respected
    assert len(jobs) == 2                 # 8 + 2, remaining slots starved


# ------------------------------------------------------------------ config

def test_sched_config_budget():
    cfg = SchedConfig(chunk_tokens=32)
    assert cfg.chunked
    assert cfg.budget_for(max_slots=4, spec_k=0) == 4 * 1 + 32
    assert cfg.budget_for(max_slots=4, spec_k=3) == 4 * 4 + 32
    assert SchedConfig(chunk_tokens=0, step_token_budget=7).budget_for(
        8, 0) == 7
    assert not SchedConfig(chunk_tokens=0).chunked


# ----------------------------------------------------------------- traffic

def test_traffic_schedule_deterministic():
    tc = TrafficConfig(kind="poisson", rate=20.0, n_requests=32,
                       prompt_lens=(8, 24), gen_lens=(4, 12), seed=7)
    a = make_schedule(tc, vocab_size=1000)
    b = make_schedule(tc, vocab_size=1000)
    assert [x.t for x in a] == [x.t for x in b]
    assert all(np.array_equal(x.prompt, y.prompt) for x, y in zip(a, b))
    assert [x.max_new for x in a] == [x.max_new for x in b]
    # different seed -> different schedule
    c = make_schedule(TrafficConfig(kind="poisson", rate=20.0,
                                    n_requests=32, prompt_lens=(8, 24),
                                    gen_lens=(4, 12), seed=8), 1000)
    assert [x.t for x in a] != [x.t for x in c]


def test_traffic_poisson_rate_sanity():
    tc = TrafficConfig(kind="poisson", rate=50.0, n_requests=400, seed=3)
    sched = make_schedule(tc, vocab_size=100)
    ts = np.asarray([a.t for a in sched])
    assert np.all(np.diff(ts) >= 0)       # sorted arrivals
    mean_gap = float(np.diff(ts).mean())
    assert 0.5 / tc.rate < mean_gap < 2.0 / tc.rate


def test_traffic_bursty_shares_instants():
    tc = TrafficConfig(kind="bursty", rate=50.0, n_requests=200,
                       burst_size=8, seed=3)
    sched = make_schedule(tc, vocab_size=100)
    ts = [a.t for a in sched]
    assert len(set(ts)) < len(ts) / 2     # real bursts: shared instants


def test_traffic_assigns_slo_classes():
    tc = TrafficConfig(rate=10.0, n_requests=50, seed=1)
    sched = make_schedule(tc, vocab_size=100,
                          classes=(INTERACTIVE, BATCH),
                          class_weights=(0.5, 0.5))
    names = {a.slo.name for a in sched}
    assert names == {"interactive", "batch"}
    with pytest.raises(AssertionError):
        TrafficConfig(kind="nope")
