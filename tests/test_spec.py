"""Speculative-decoding subsystem tests (DESIGN.md §10).

The exactness chain is pinned bottom-up: (1) a multi-token verify window
through ``LM.decode_step`` is *bitwise* equal to the same tokens fed
sequentially (dense and paged caches, GQA/window/layout variants), (2)
greedy longest-prefix acceptance therefore emits a prefix of the
sequential stream, so (3) the spec engine's outputs are token-exact vs the
non-spec engine across k, cache modes, mixed-length batches and mid-decode
preemption. Paged rollback is additionally pinned leak-free (refcounts +
free list) under prefix sharing and COW.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM, layers as L
from repro.serving import ContinuousScheduler
from repro.spec import (SpecConfig, build_draft, layer_skip,
                        longest_prefix_match, resparsify)


def _cfg(**overrides):
    overrides.setdefault("num_layers", 2)
    return get_config("ternary-paper", reduced=True, **overrides)


def _workload(cfg, n, prompt_len=12, seed=0, lens=(2, 9)):
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(n, prompt_len)).astype(np.int32)
    gens = [int(g) for g in rng.integers(lens[0], lens[1], size=n)]
    return prompts, gens


# ---------------------------------------------------------------------------
# (1) multi-token verify windows are bitwise-equal to sequential decode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("overrides", [
    {},                                   # GQA (reduced: 4 heads, 2 kv)
    {"num_kv_heads": 4},                  # MHA
    {"sliding_window": 8},                # rolling SWA -> unrolled path
    {"cache_layout": "opt"},              # delta-commit -> unrolled path
    {"decode_cache_shard": "flat"},       # flat (B,S,kv*hd) cache storage
], ids=["gqa", "mha", "window", "opt", "flat"])
def test_verify_window_bitwise_dense(overrides):
    cfg = _cfg(**overrides)
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = (np.arange(24, dtype=np.int32).reshape(2, 12) * 7 + 3) \
        % cfg.vocab_size
    cache, logits = jax.jit(lambda p, b: m.prefill(p, b, 24))(
        params, {"tokens": toks})
    rng = np.random.default_rng(1)
    win = np.concatenate(
        [np.asarray(np.argmax(logits[:, -1:], -1), np.int32),
         rng.integers(0, cfg.vocab_size, size=(2, 3)).astype(np.int32)],
        axis=1)                                          # (B, 4) window
    pos = np.full((2,), 12, np.int32)

    step = jax.jit(m.decode_step)
    c_seq = dict(cache, pos=jnp.asarray(pos))
    lgs = []
    for j in range(win.shape[1]):
        lg, c_seq = step(params, c_seq, jnp.asarray(win[:, j:j + 1]))
        lgs.append(lg)
    lg_seq = jnp.concatenate(lgs, axis=1)

    c_win = dict(cache, pos=jnp.asarray(pos))
    lg_win, c_win = step(params, c_win, jnp.asarray(win))

    np.testing.assert_array_equal(np.asarray(lg_seq), np.asarray(lg_win))
    for a, b in zip(jax.tree_util.tree_leaves(c_seq["layers"]),
                    jax.tree_util.tree_leaves(c_win["layers"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(np.asarray(c_win["pos"])[0]) == 12 + win.shape[1]


@pytest.mark.parametrize("kv_dtype", [None, "int8"], ids=["bf16", "int8"])
def test_verify_window_bitwise_paged(kv_dtype):
    from repro.paging import PagePool
    cfg = _cfg()
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    ps, n_win = 4, 3
    pool = PagePool(m, max_slots=2, max_len=24, page_size=ps,
                    kv_dtype=kv_dtype)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab_size, size=(2, 12)).astype(np.int32)
    adms = [pool.admit(p) for p in prompts]
    cache, logits = jax.jit(lambda p, b: m.prefill(p, b, 12))(
        params, {"tokens": prompts})
    pool.insert(adms, cache["layers"])
    win = np.concatenate(
        [np.asarray(np.argmax(logits[:, -1:], -1), np.int32),
         rng.integers(0, cfg.vocab_size, size=(2, n_win - 1)
                      ).astype(np.int32)], axis=1)
    for slot in (0, 1):                      # pre-grow the window's pages
        for p in range(n_win):
            assert pool.ensure_append(slot, 12 + p)
    table = jnp.asarray(pool.table)
    pos = jnp.full((2,), 12, jnp.int32)
    step = jax.jit(m.decode_step)

    c = {"layers": pool.layers, "pos": pos, "block_table": table}
    lgs = []
    for j in range(n_win):
        lg, c = step(params, c, jnp.asarray(win[:, j:j + 1]))
        lgs.append(lg)
    lg_seq = jnp.concatenate(lgs, axis=1)

    c2 = {"layers": pool.layers, "pos": pos, "block_table": table}
    lg_win, c2 = step(params, c2, jnp.asarray(win))

    np.testing.assert_array_equal(np.asarray(lg_seq), np.asarray(lg_win))
    for a, b in zip(jax.tree_util.tree_leaves(c["layers"]),
                    jax.tree_util.tree_leaves(c2["layers"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# (2) acceptance + rollback primitives
# ---------------------------------------------------------------------------

def test_longest_prefix_match():
    window = jnp.asarray([[5, 1, 2, 3],      # drafts d=[1,2,3]
                          [5, 1, 9, 3],
                          [5, 9, 9, 9],
                          [5, 1, 2, 3]])
    greedy = jnp.asarray([[1, 2, 3, 4],      # accepts all 3, bonus g3=4
                          [1, 7, 8, 9],      # d2=9 != g1=7 -> n=1, bonus g1
                          [7, 8, 9, 1],      # d1 mismatch -> n=0, bonus g0
                          [1, 2, 9, 6]])     # d3=3 != g2=9 -> n=2, bonus g2
    n_acc, bonus = jax.jit(longest_prefix_match)(window, greedy)
    np.testing.assert_array_equal(np.asarray(n_acc), [3, 1, 0, 2])
    np.testing.assert_array_equal(np.asarray(bonus), [4, 7, 7, 9])


def test_paged_rollback_leak_free_under_sharing():
    """Grow a slot through COW + fresh pages, truncate back: every dropped
    page returns to the free list, shared/prefix pages keep their
    refcounts, and full release restores the pool exactly."""
    from repro.paging import PagePool
    cfg = _cfg()
    m = LM(cfg)
    ps = 4
    pool = PagePool(m, max_slots=3, max_len=32, page_size=ps, n_pages=24)
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
    a = pool.admit(prefix)
    b = pool.admit(prefix)                   # identical prompt: full +
    assert b.n_shared == 3                   # partial-tail pages all shared
    free0 = len(pool._free_pages)
    used0 = pool.pages_used

    # slot b's first append lands in the shared tail page -> COW, then a
    # k=6 verify window grows fresh pages beyond it
    for p in range(6):
        assert pool.ensure_append(b.slot, 10 + p)
    assert pool.cow_count == 1
    grown = len(pool.slot_pages[b.slot])
    consumed = free0 - len(pool._free_pages)     # COW copy + fresh tails
    assert consumed == 1 + (grown - 3)
    # roll back to 12 committed tokens: ceil(12/4)=3 pages kept
    reclaimed = pool.truncate(b.slot, 12)
    assert reclaimed == grown - 3
    assert len(pool.slot_pages[b.slot]) == 3
    assert len(pool._free_pages) == free0 - consumed + reclaimed
    assert (pool.table[b.slot, 3:] == 0).all()
    # rollback never frees a page another slot references
    for pid in pool.slot_pages[a.slot]:
        assert pool._refcount[pid] >= 1
    # truncate to current length is a no-op
    assert pool.truncate(b.slot, 12) == 0
    pool.release(a.slot)
    pool.release(b.slot)
    # registered prefix pages stay pinned (reclaimable), nothing leaks:
    # re-admitting the same prompt reuses them without allocation
    c = pool.admit(prefix)
    assert c.n_shared == 3
    pool.release(c.slot)
    assert pool.pages_used <= used0


# ---------------------------------------------------------------------------
# (3) engine: token-exact vs sequential, both cache modes, preemption
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, prompts, gens, max_len, **kw):
    eng = ContinuousScheduler(cfg, max_slots=2, max_len=max_len, **kw)
    eng.load(params)
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    metrics = eng.run()
    return [np.asarray(r.tokens, np.int32) for r in reqs], metrics, eng


@pytest.mark.parametrize("k", [1, 2, 4])
def test_spec_engine_token_exact_dense(k):
    cfg = _cfg(num_layers=4)
    params = LM(cfg).init(jax.random.PRNGKey(0))
    prompts, gens = _workload(cfg, 6, lens=(1, 10))
    base, _, _ = _run_engine(cfg, params, prompts, gens, 40)
    outs, m, _ = _run_engine(cfg, params, prompts, gens, 40,
                             spec=SpecConfig(draft="layer_skip", k=k,
                                             draft_layers=2))
    for i, (a, b) in enumerate(zip(base, outs)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}, k={k}")
    s = m["spec"]
    assert s["rounds"] > 0 and s["draft_tokens_proposed"] % k == 0
    assert s["draft_tokens_accepted"] <= s["draft_tokens_proposed"]
    assert 1.0 <= s["mean_accepted_len"] <= k + 1
    json.dumps(m)                            # spec block JSON-serializable


@pytest.mark.parametrize("kv_dtype", [None, "int8"], ids=["bf16", "int8"])
def test_spec_engine_token_exact_paged(kv_dtype):
    cfg = _cfg(num_layers=2)
    params = LM(cfg).init(jax.random.PRNGKey(0))
    prompts, gens = _workload(cfg, 6, lens=(1, 10))
    base, _, _ = _run_engine(cfg, params, prompts, gens, 40,
                             cache="paged", page_size=4, kv_dtype=kv_dtype)
    outs, m, _ = _run_engine(cfg, params, prompts, gens, 40,
                             cache="paged", page_size=4, kv_dtype=kv_dtype,
                             spec=SpecConfig(draft="layer_skip", k=2,
                                             draft_layers=1))
    for i, (a, b) in enumerate(zip(base, outs)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")
    assert m["spec"]["rollback_page_reclaims"] >= 0


def test_spec_engine_token_exact_under_preemption():
    """A page pool too small for both live requests forces mid-decode
    preempt-and-replay; spec mode must stay token-exact through it."""
    cfg = _cfg(num_layers=2)
    params = LM(cfg).init(jax.random.PRNGKey(0))
    prompts, gens = _workload(cfg, 4, prompt_len=8, lens=(8, 14))
    kw = dict(cache="paged", page_size=4, n_pages=9, prefix_cache=False)
    base, _, _ = _run_engine(cfg, params, prompts, gens, 28, **kw)
    outs, ms, _ = _run_engine(cfg, params, prompts, gens, 28,
                              spec=SpecConfig(draft="layer_skip", k=2,
                                              draft_layers=1), **kw)
    assert ms["cache"]["preemptions"] + ms["cache"]["deferrals"] > 0, \
        "workload did not stress the pool; tighten n_pages"
    for i, (a, b) in enumerate(zip(base, outs)):
        np.testing.assert_array_equal(a, b, err_msg=f"request {i}")


def test_spec_engine_rejects_unsupported():
    with pytest.raises(ValueError, match="attention-only"):
        ContinuousScheduler(get_config("mamba2-130m", reduced=True),
                            max_slots=1, max_len=16,
                            spec=SpecConfig(k=2))
    with pytest.raises(ValueError, match="sliding-window"):
        ContinuousScheduler(_cfg(sliding_window=8), max_slots=1, max_len=16,
                            spec=SpecConfig(k=2))
    with pytest.raises(ValueError, match="bshd"):
        ContinuousScheduler(_cfg(cache_layout="opt"), max_slots=1,
                            max_len=16, spec=SpecConfig(k=2))
    eng = ContinuousScheduler(_cfg(), max_slots=1, max_len=16,
                              spec=SpecConfig(k=4))
    with pytest.raises(AssertionError):      # k headroom enforced
        eng.submit(np.zeros(8, np.int32), 8)


# ---------------------------------------------------------------------------
# (4) drafts
# ---------------------------------------------------------------------------

def test_acceptance_monotone_in_draft_sparsity():
    """As the resparsify draft's nnz fraction approaches the target's own
    occupancy its proposals converge to the target's stream, so the
    aggregate acceptance rate is (weakly) monotone in sparsity."""
    cfg = _cfg(ternary_min_dim=64)
    params = LM(cfg).init(jax.random.PRNGKey(0))
    packed = L.pack_params(params, cfg)
    pcfg = dataclasses.replace(cfg, quantization="ternary_packed")
    prompts, gens = _workload(pcfg, 4, lens=(4, 8))
    rates = []
    for s in (0.1, 0.5, 1.0):
        _, m, _ = _run_engine(pcfg, packed, prompts, gens, 32,
                              spec=SpecConfig(draft="resparsify", k=2,
                                              draft_sparsity=s))
        rates.append(m["spec"]["acceptance_rate"])
    assert rates == sorted(rates), rates
    assert rates[-1] > 0.9, (
        "a draft re-packed at the target's own support should accept "
        f"nearly everything, got {rates[-1]}")


def test_draft_builders():
    cfg = _cfg(num_layers=4)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    d = layer_skip(model, params, 2)
    assert d.model.cfg.num_layers == 2
    # sliced stacks share storage with the target (a view, not a copy)
    assert d.params["block0"]["mixer"]["q"]["w"].shape[0] == 2
    assert d.params["embed"]["table"] is params["embed"]["table"]
    with pytest.raises(ValueError):
        layer_skip(model, params, 4)         # must be a strict prefix
    with pytest.raises(ValueError, match="TernaryWeight"):
        resparsify(model, params, 0.25)      # unpacked params
    d2 = build_draft(SpecConfig(draft="layer_skip", k=2), model, params)
    assert d2.model.cfg.num_layers == 2      # default: half the stack
    with pytest.raises(ValueError, match="draft_cfg"):
        build_draft(SpecConfig(draft="external", k=2), model, params)
    with pytest.raises(ValueError, match="unknown draft"):
        build_draft(SpecConfig(draft="nope", k=2), model, params)


def test_resparsify_hits_requested_sparsity():
    cfg = _cfg(ternary_min_dim=64)
    model = LM(cfg)
    packed = L.pack_params(model.init(jax.random.PRNGKey(0)), cfg)
    d = resparsify(model, packed, 0.25)
    from repro.core import weights
    containers = [w for w in jax.tree_util.tree_leaves(
        d.params, is_leaf=lambda v: isinstance(v, weights.TernaryWeight))
        if isinstance(w, weights.TernaryWeight)]
    assert containers
    for w in containers:
        assert w.occupancy() <= 0.27, (w.shape, w.occupancy())


# ---------------------------------------------------------------------------
# (5) engine bookkeeping satellites
# ---------------------------------------------------------------------------

def test_running_stat_bounded_and_exact():
    from repro.serving.engine import _RunningStat
    st = _RunningStat(cap=16)
    vals = [int(v) for v in np.random.default_rng(0).integers(0, 99, 5000)]
    for v in vals:
        st.push(v)
    assert len(st.ring) <= 16                # bounded, unlike the old list
    assert st.peak == max(vals)              # exact over all samples
    assert st.mean == pytest.approx(float(np.mean(vals)))
    assert st.n == len(vals)


def test_serve_cli_spec(capsys):
    from repro.launch import serve
    metrics = serve.main(["--arch", "ternary-paper", "--reduced",
                          "--requests", "4", "--slots", "2",
                          "--prompt-len", "8", "--gen-lens", "2,5",
                          "--spec", "layer_skip", "--spec-k", "2"])
    out = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert out["submitted"] == out["drained"] == 4
    assert out["spec"]["k"] == 2
    assert out["spec"]["draft"].startswith("layer_skip")
    assert metrics["spec"]["draft_tokens_proposed"] > 0
    per = metrics["spec"]["per_request"]
    assert len(per) == 4 and all(r["proposed"] >= 0 for r in per)
