"""End-to-end behaviour tests: training reduces loss, serving generates,
packed-ternary serving matches QAT logits, the train CLI round-trips through
checkpoint/restart."""
import json
import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch import steps as steps_lib
from repro.models import LM
from repro.optim import constant


def _train(cfg, steps=40, batch=8, seq=32, lr=1e-2, seed=0):
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(seed))
    step_fn, opt_init = steps_lib.make_train_step(m, cfg, constant(lr))
    opt = opt_init(params)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    data = SyntheticLM(cfg, batch, seq, noise=0.0)
    losses = []
    for i in range(steps):
        b = {k: jnp.asarray(v) for k, v in data.global_batch(i % 4).items()}
        params, opt, metrics = jitted(params, opt, b)
        losses.append(float(metrics["loss"]))
    return losses, params, m


def test_training_reduces_loss_dense():
    cfg = get_config("ternary-paper", reduced=True, quantization="none",
                     num_layers=2, vocab_size=64)
    losses, _, _ = _train(cfg)
    assert losses[-1] < losses[0] * 0.7, losses[::8]


def test_training_reduces_loss_ternary_qat():
    """The paper's technique integrated in training: QAT converges too."""
    cfg = get_config("ternary-paper", reduced=True, ternary_min_dim=64,
                     num_layers=2, vocab_size=64)
    assert cfg.quantization == "ternary"
    losses, _, _ = _train(cfg, steps=50)
    assert losses[-1] < losses[0] * 0.8, losses[::8]


def test_training_reduces_loss_ssm():
    cfg = get_config("mamba2-130m", reduced=True, num_layers=2, vocab_size=64)
    losses, _, _ = _train(cfg, steps=40)
    assert losses[-1] < losses[0] * 0.8, losses[::8]


def test_packed_serving_matches_qat_logits():
    """quantize -> pack to 2-bit -> serve must equal the QAT (STE) forward:
    the serving format is lossless wrt the quantized weights."""
    from repro.models import layers as L
    # float32 end to end: the QAT path rounds alpha*T through the compute
    # dtype while the packed path applies alpha in the f32 epilogue — in
    # bf16 that dtype asymmetry dominates; in f32 the formats must agree
    # to numerical precision.
    cfg = get_config("ternary-paper", reduced=True, ternary_min_dim=64,
                     num_layers=2, dtype="float32")
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(1, 32)}
    x, _, _ = m.forward(params, batch)
    logits_qat = np.asarray(m._logits(params, x), np.float32)

    # pack every ternarizable linear (2-D or scan-stacked 3-D) into the
    # TernaryWeight serving format
    packed_params = L.pack_params(params, cfg)
    cfg_packed = get_config("ternary-paper", reduced=True, ternary_min_dim=64,
                            num_layers=2, quantization="ternary_packed",
                            dtype="float32")
    m2 = LM(cfg_packed)
    x2, _, _ = m2.forward(packed_params, batch)
    logits_packed = np.asarray(m2._logits(packed_params, x2), np.float32)
    np.testing.assert_allclose(logits_packed, logits_qat, rtol=1e-3, atol=1e-3)


def test_serve_driver_generates():
    from repro.launch.serve import BatchedServer
    cfg = get_config("ternary-paper", reduced=True, num_layers=2)
    srv = BatchedServer(cfg, max_len=48)
    srv.load(srv.model.init(jax.random.PRNGKey(0)))
    prompts = np.arange(64, dtype=np.int32).reshape(2, 32) % cfg.vocab_size
    out = srv.generate(prompts, gen_len=8)
    assert out.shape == (2, 8)
    assert (out >= 0).all() and (out < cfg.padded_vocab()).all()


@pytest.mark.slow
def test_train_cli_checkpoint_restart(tmp_path):
    """Kill the training CLI mid-run; restart resumes from the checkpoint
    and finishes with the same total step count."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    args = [sys.executable, "-m", "repro.launch.train",
            "--arch", "ternary-paper", "--reduced", "--batch", "4",
            "--seq", "32", "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
            "--set", "num_layers=2", "--set", "vocab_size=64"]
    out1 = subprocess.run(args + ["--steps", "10"], capture_output=True,
                          text=True, timeout=900, env=env)
    assert out1.returncode == 0, out1.stderr[-2000:]
    r1 = json.loads(out1.stdout.strip().splitlines()[-1])
    assert r1["steps"] == 10
    out2 = subprocess.run(args + ["--steps", "15"], capture_output=True,
                          text=True, timeout=900, env=env)
    assert out2.returncode == 0, out2.stderr[-2000:]
    r2 = json.loads(out2.stdout.strip().splitlines()[-1])
    assert r2["steps"] == 5  # only the remaining steps ran
