"""Data pipeline: determinism, shapes, learnability structure."""
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLM


def test_deterministic_across_instances():
    cfg = get_config("mistral-nemo-12b", reduced=True)
    a = SyntheticLM(cfg, 4, 32, seed=7).global_batch(3)
    b = SyntheticLM(cfg, 4, 32, seed=7).global_batch(3)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])


def test_steps_differ():
    cfg = get_config("mistral-nemo-12b", reduced=True)
    d = SyntheticLM(cfg, 4, 32)
    assert not np.array_equal(d.global_batch(0)["tokens"],
                              d.global_batch(1)["tokens"])


def test_targets_are_shifted_tokens():
    cfg = get_config("mistral-nemo-12b", reduced=True)
    b = SyntheticLM(cfg, 2, 16).global_batch(0)
    assert b["tokens"].shape == (2, 16)
    assert b["targets"].shape == (2, 16)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["targets"][:, :-1])


def test_vocab_bounds():
    cfg = get_config("mamba2-130m", reduced=True)
    b = SyntheticLM(cfg, 8, 64).global_batch(0)
    assert b["tokens"].min() >= 0
    assert b["tokens"].max() < cfg.vocab_size


def test_frontend_embeds():
    cfg = get_config("internvl2-76b", reduced=True)
    b = SyntheticLM(cfg, 2, 32).global_batch(0)
    assert "vision_embeds" in b
    assert b["vision_embeds"].shape == (2, cfg.frontend_seq, cfg.d_model)
    cfg2 = get_config("seamless-m4t-large-v2", reduced=True)
    b2 = SyntheticLM(cfg2, 2, 32).global_batch(0)
    assert "enc_embeds" in b2


def test_learnable_structure():
    """Affine recurrence: next token is (mostly) a deterministic function of
    the previous one within a sequence."""
    cfg = get_config("mistral-nemo-12b", reduced=True)
    d = SyntheticLM(cfg, 1, 256, noise=0.0)
    b = d.global_batch(0)
    t = b["tokens"][0]
    # recover a, c from two consecutive transitions and verify the rest
    v = cfg.vocab_size
    found = False
    for a in range(1, 8):
        c = (t[1] - a * t[0]) % v
        if all((a * t[i] + c) % v == t[i + 1] for i in range(len(t) - 1)):
            found = True
            break
    assert found
