"""Optimizer correctness vs hand-computed AdamW math + clipping + schedules."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import (adamw, clip_by_global_norm, constant, global_norm,
                         sgd_momentum, warmup_cosine)


def test_adamw_single_step_math():
    init, update = adamw(b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.1)
    p = {"w": jnp.asarray([1.0, -2.0], jnp.float32)}
    g = {"w": jnp.asarray([0.5, 0.25], jnp.float32)}
    st = init(p)
    lr = 0.1
    p2, st2 = update(g, st, p, lr)
    # manual
    gw = np.array([0.5, 0.25])
    m = 0.1 * gw
    v = 0.01 * gw**2
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = np.array([1.0, -2.0]) - lr * (
        mhat / (np.sqrt(vhat) + 1e-8) + 0.1 * np.array([1.0, -2.0]))
    np.testing.assert_allclose(np.asarray(p2["w"]), want, rtol=1e-5)
    assert int(st2["step"]) == 1


def test_adamw_bf16_state_dtype():
    init, update = adamw(state_dtype="bfloat16")
    p = {"w": jnp.ones((4, 4), jnp.float32)}
    st = init(p)
    assert st["m"]["w"].dtype == jnp.bfloat16
    p2, st2 = update({"w": jnp.ones((4, 4))}, st, p, 0.01)
    assert st2["v"]["w"].dtype == jnp.bfloat16
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_adamw_converges_quadratic():
    init, update = adamw(weight_decay=0.0)
    p = {"w": jnp.asarray(5.0)}
    st = init(p)

    @jax.jit
    def step(p, st):
        g = jax.grad(lambda q: (q["w"] - 2.0) ** 2)(p)
        return update(g, st, p, 0.1)

    for _ in range(300):
        p, st = step(p, st)
    assert abs(float(p["w"]) - 2.0) < 0.05


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 3.0), "b": jnp.full((10,), 4.0)}
    norm = float(global_norm(g))
    np.testing.assert_allclose(norm, np.sqrt(10 * 9 + 10 * 16), rtol=1e-6)
    clipped, n = clip_by_global_norm(g, 1.0)
    np.testing.assert_allclose(float(global_norm(clipped)), 1.0, rtol=1e-5)
    # no-op when under the limit
    clipped2, _ = clip_by_global_norm(g, 100.0)
    np.testing.assert_allclose(np.asarray(clipped2["a"]), 3.0, rtol=1e-6)


def test_sgd_momentum():
    init, update = sgd_momentum(0.9)
    p = {"w": jnp.asarray(1.0)}
    st = init(p)
    p, st = update({"w": jnp.asarray(1.0)}, st, p, 0.1)
    np.testing.assert_allclose(float(p["w"]), 0.9, rtol=1e-6)
    p, st = update({"w": jnp.asarray(1.0)}, st, p, 0.1)
    np.testing.assert_allclose(float(p["w"]), 0.9 - 0.1 * 1.9, rtol=1e-6)


def test_warmup_cosine_schedule():
    lr = warmup_cosine(1.0, 10, 100, final_frac=0.1)
    assert float(lr(0)) == 0.0
    np.testing.assert_allclose(float(lr(5)), 0.5, rtol=1e-6)
    np.testing.assert_allclose(float(lr(10)), 1.0, rtol=1e-5)
    np.testing.assert_allclose(float(lr(100)), 0.1, rtol=1e-4)
    assert float(lr(55)) < 1.0
    assert float(constant(0.3)(123)) == np.float32(0.3)
