"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + no NaNs, plus decode-vs-forward consistency
(the KV/SSM cache correctness proof) per model family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_archs
from repro.models import LM

ALL_ARCHS = [a for a in list_archs()]


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
        "targets": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_seq, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    if cfg.is_encdec:
        batch["enc_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.frontend_seq, cfg.d_model)) * 0.02,
            jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_forward_loss(arch):
    cfg = get_config(arch, reduced=True)
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss, metrics = jax.jit(m.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss not finite"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_smoke_train_step(arch):
    from repro.launch import steps as steps_lib
    cfg = get_config(arch, reduced=True, grad_accum=2)
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    step, opt_init = steps_lib.make_train_step(m, cfg)
    opt = opt_init(params)
    batch = _batch(cfg, b=4)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    assert bool(jnp.isfinite(metrics["grad_norm"]))
    assert int(opt2["step"]) == 1
    # params actually changed
    d = jax.tree.map(lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                                - b.astype(jnp.float32)).max()),
                     params, params2)
    assert max(jax.tree.leaves(d)) > 0


@pytest.mark.parametrize("arch", ["mistral-nemo-12b", "mixtral-8x22b",
                                  "mamba2-130m", "jamba-v0.1-52b",
                                  "seamless-m4t-large-v2", "internvl2-76b"])
def test_decode_matches_forward(arch):
    """prefill(prompt) + step-by-step decode logits == full-forward logits.
    This validates KV caches, rolling SWA caches, SSM state carry, and
    cross-attention caches in one shot.

    Run in float32: the SSM chunked (train) and stepwise (decode) state
    recurrences are mathematically identical but round differently in bf16,
    compounding over layers x steps (verified: error collapses ~1e4x in
    f32 — pure rounding, not logic)."""
    cfg = get_config(arch, reduced=True, dtype="float32")
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    batch = _batch(cfg, b=b, s=s)
    del batch["targets"]

    # full forward logits over the whole sequence
    x, n_front, _ = m.forward(params, batch)
    full_logits = np.asarray(m._logits(params, x)[:, n_front:], np.float32)

    # prefill on the first s0 tokens, then decode the rest one by one
    s0 = 16
    max_len = s + (cfg.frontend_seq if cfg.family == "vlm" else 0)
    pre = dict(batch)
    pre["tokens"] = batch["tokens"][:, :s0]
    cache, logits = jax.jit(lambda p, bb: m.prefill(
        p, bb, max_len, cache_dtype=jnp.float32))(params, pre)
    np.testing.assert_allclose(
        np.asarray(logits[:, -1], np.float32), full_logits[:, s0 - 1],
        rtol=2e-3, atol=2e-3)
    decode = jax.jit(m.decode_step)
    for t in range(s0, s):
        logits, cache = decode(params, cache, batch["tokens"][:, t:t + 1])
        np.testing.assert_allclose(
            np.asarray(logits[:, 0], np.float32), full_logits[:, t],
            rtol=2e-2, atol=2e-2,
            err_msg=f"{arch} decode step {t}")


def test_sliding_window_rolling_cache():
    """SWA must only attend inside the window: with 2 layers the receptive
    field of the last position is 2*(W-1)=14 tokens, so garbage in tokens
    [0, 8) cannot change the last-position logits of a 24-token sequence."""
    cfg = get_config("mixtral-8x22b", reduced=True, sliding_window=8,
                     num_layers=2)
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    toks_a = rng.integers(0, cfg.vocab_size, (1, 24))
    toks_b = toks_a.copy()
    toks_b[:, :8] = rng.integers(0, cfg.vocab_size, (1, 8))  # outside window

    def last_logits(toks):
        x, _, _ = m.forward(params, {"tokens": jnp.asarray(toks, jnp.int32)})
        return np.asarray(m._logits(params, x)[:, -1], np.float32)

    la, lb = last_logits(toks_a), last_logits(toks_b)
    np.testing.assert_allclose(la, lb, rtol=1e-3, atol=1e-3)


def test_ternary_qat_forward_differs_and_trains():
    cfg = get_config("ternary-paper", reduced=True,
                     ternary_min_dim=64)
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss_t, _ = jax.jit(m.loss)(params, batch)

    cfg_d = get_config("ternary-paper", reduced=True, quantization="none")
    m_d = LM(cfg_d)
    loss_d, _ = jax.jit(m_d.loss)(params, batch)
    assert bool(jnp.isfinite(loss_t)) and bool(jnp.isfinite(loss_d))
    assert abs(float(loss_t) - float(loss_d)) > 1e-6  # quantization is live

    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    gn = max(float(jnp.abs(x).max()) for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_param_count_analytic_matches_init():
    for arch in ["mistral-nemo-12b", "mixtral-8x22b", "mamba2-130m"]:
        cfg = get_config(arch, reduced=True)
        m = LM(cfg)
        params = m.init(jax.random.PRNGKey(0))
        got = sum(x.size for x in jax.tree.leaves(params))
        want = cfg.param_count()
        assert abs(got - want) / want < 0.06, (arch, got, want)
