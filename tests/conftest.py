"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the 1 real CPU
device; multi-device GSPMD tests spawn subprocesses that set the flag
themselves (see test_distributed.py)."""
import os
import tempfile

import numpy as np
import pytest

# Keep autotune-cache writes out of the repo checkout during test runs.
os.environ.setdefault(
    "REPRO_AUTOTUNE_CACHE",
    os.path.join(tempfile.mkdtemp(prefix="repro-autotune-"), "cache.json"))


@pytest.fixture
def rng():
    return np.random.default_rng(0)
