"""Shared fixtures. NOTE: no XLA_FLAGS here — tests run on the 1 real CPU
device; multi-device GSPMD tests spawn subprocesses that set the flag
themselves (see test_distributed.py)."""
import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)
