"""Format round-trips + hypothesis property tests (paper's TCSC family and
the TPU packed formats)."""
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import formats

SPARSITIES = [0.5, 0.25, 0.125, 0.0625]


def _rand(k, n, s, seed=0):
    return formats.random_ternary(np.random.default_rng(seed), k, n, s)


@pytest.mark.parametrize("s", SPARSITIES)
@pytest.mark.parametrize("k,n", [(64, 32), (96, 40), (128, 128), (33, 7)])
def test_tcsc_roundtrip(k, n, s):
    w = _rand(k, n, s)
    t = formats.TCSC.from_dense(w)
    assert (t.to_dense() == w).all()
    # invariants
    assert t.col_start_pos[-1] == len(t.row_index_pos)
    assert t.col_start_neg[-1] == len(t.row_index_neg)
    assert len(t.row_index_pos) + len(t.row_index_neg) == (w != 0).sum()


@pytest.mark.parametrize("block", [16, 32, 4096])
def test_blocked_tcsc_roundtrip(block):
    w = _rand(96, 24, 0.25)
    bt = formats.BlockedTCSC.from_dense(w, block)
    assert (bt.to_dense() == w).all()
    # every block's row indices stay inside the block window
    for blk in bt.blocks:
        if len(blk.row_index_pos):
            assert blk.row_index_pos.max() < block
        if len(blk.row_index_neg):
            assert blk.row_index_neg.max() < block


@pytest.mark.parametrize("group", [1, 2, 4])
def test_interleaved_roundtrip(group):
    w = _rand(64, 16, 0.5)
    it = formats.InterleavedTCSC.from_dense(w, group)
    assert (it.to_dense() == w).all()
    # sign decoding matches dense values at the stored indices
    signs = it.signs()
    seg = it.segment_ids()
    for idx, sg, col in zip(it.all_indices, signs, seg):
        assert w[idx, col] == sg


def test_packed_formats_roundtrip():
    w = _rand(96, 40, 0.25)
    p, m = formats.pack_bitplanes(w)
    assert p.shape == (12, 40)
    got = np.asarray(formats.decode_bitplanes(jnp.asarray(p), jnp.asarray(m),
                                              96, jnp.int8))
    assert (got == w).all()
    p2 = formats.pack_2bit(w)
    assert p2.shape == (6, 40) and p2.dtype == np.uint32
    assert (np.asarray(formats.decode_2bit(jnp.asarray(p2), 96, jnp.int8)) == w).all()
    b3 = formats.pack_base3(w)
    assert b3.shape == (20, 40)
    assert (np.asarray(formats.decode_base3(jnp.asarray(b3), 96, jnp.int8)) == w).all()


def test_compression_ratios():
    """The paper's memory argument: packed sizes vs f32 dense."""
    k, n = 4096, 1024
    w = _rand(k, n, 0.25)
    dense_f32 = k * n * 4
    p2 = formats.pack_2bit(w)
    assert p2.nbytes * 16 == dense_f32                  # 2 bits/weight
    b3 = formats.pack_base3(w)
    assert b3.nbytes == -(-k // 5) * n                  # 1.6 bits/weight
    tcsc = formats.TCSC.from_dense(w)
    assert tcsc.nbytes() == pytest.approx(
        (w != 0).sum() * 4 + 2 * (n + 1) * 4, rel=0.01)


@settings(max_examples=25, deadline=None)
@given(
    k=st.integers(1, 70), n=st.integers(1, 20),
    s=st.sampled_from(SPARSITIES), seed=st.integers(0, 2**31 - 1),
)
def test_all_formats_agree(k, n, s, seed):
    w = _rand(k, n, s, seed)
    assert (formats.TCSC.from_dense(w).to_dense() == w).all()
    assert (formats.BlockedTCSC.from_dense(w, 16).to_dense() == w).all()
    assert (formats.InterleavedTCSC.from_dense(w, 2).to_dense() == w).all()
    p, m = formats.pack_bitplanes(w)
    assert (np.asarray(formats.decode_bitplanes(
        jnp.asarray(p), jnp.asarray(m), k, jnp.int8)) == w).all()
    assert (np.asarray(formats.decode_2bit(
        jnp.asarray(formats.pack_2bit(w)), k, jnp.int8)) == w).all()
    assert (np.asarray(formats.decode_base3(
        jnp.asarray(formats.pack_base3(w)), k, jnp.int8)) == w).all()


@settings(max_examples=15, deadline=None)
@given(s=st.floats(0.05, 0.6), seed=st.integers(0, 2**31 - 1))
def test_random_ternary_sparsity(s, seed):
    w = formats.random_ternary(np.random.default_rng(seed), 128, 64, s)
    got = (w != 0).mean()
    assert abs(got - s) < 0.01
    assert set(np.unique(w)) <= {-1, 0, 1}
