"""Pallas flash attention kernel vs naive oracle (interpret mode)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention_pallas
from repro.models.attention import naive_attention


def _ref(q, k, v, causal):
    # naive_attention wants (B, S, H, hd); collapse BH -> B with H=1
    qq = q[:, :, None, :]
    kk = k[:, :, None, :]
    vv = v[:, :, None, :]
    o = naive_attention(qq, kk, vv, causal=causal, window=0)
    return o[:, :, 0, :]


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("bh,s,hd", [(4, 128, 64), (2, 257, 64), (8, 96, 128)])
def test_flash_kernel_matches_naive(bh, s, hd, causal):
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.standard_normal((bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, s, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, hd)), jnp.float32)
    o = flash_attention_pallas(q, k, v, causal=causal, block_q=64,
                               block_kv=64, interpret=True)
    o_ref = _ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=2e-4, atol=2e-4)


def test_flash_kernel_bf16():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.bfloat16)
    k = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.bfloat16)
    v = jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.bfloat16)
    o = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                               block_kv=64, interpret=True)
    o_ref = _ref(q, k, v, True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(o_ref, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_flash_kernel_vmem_budget():
    """Scratch + tiles must fit 16 MB VMEM at production block sizes."""
    bq, bkv, hd = 512, 512, 128
    tiles = (bq * hd + 2 * bkv * hd + bq * hd) * 2      # q,k,v,o bf16
    scratch = (bq * 1 * 2 + bq * hd) * 4                # m,l,acc f32
    assert tiles + scratch < 16 * 2**20
