"""Hypothesis compatibility shim.

The property tests use a tiny slice of hypothesis (``given``/``settings`` +
``integers``/``floats``/``sampled_from`` strategies). When the real package
is installed we re-export it; otherwise a minimal seeded-random fallback
runs each property over ``max_examples`` deterministic draws, so tier-1
collection and the properties themselves still run in containers without
hypothesis. No shrinking/reporting — install hypothesis for real fuzzing.
"""
try:
    from hypothesis import given, settings       # noqa: F401
    import hypothesis.strategies as st           # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:

    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    class st:  # noqa: N801 — mimics hypothesis.strategies
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            # No functools.wraps: pytest must see a zero-arg function, not
            # the wrapped signature (it would resolve params as fixtures).
            def wrapper():
                rng = random.Random(0)
                n = getattr(wrapper, "_max_examples", 20)
                for _ in range(n):
                    fn(**{name: s.draw(rng)
                          for name, s in strategies.items()})
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
