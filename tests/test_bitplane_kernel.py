"""Bitplane-format Pallas kernel vs oracle (the structural-sign TCSC
translation, DESIGN.md §2)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats
from repro.kernels import ref
from repro.kernels.ternary_gemm_bitplane import ternary_gemm_bitplane


@pytest.mark.parametrize("s", [0.5, 0.25, 0.0625])
@pytest.mark.parametrize("m,k,n", [(8, 128, 64), (32, 512, 256), (5, 96, 40)])
def test_bitplane_kernel_matches_oracle(m, k, n, s):
    rng = np.random.default_rng(0)
    w = formats.random_ternary(rng, k, n, s)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    plus, minus = formats.pack_bitplanes(w)
    y = ternary_gemm_bitplane(x, jnp.asarray(plus), jnp.asarray(minus),
                              block_n=64, block_k=64, interpret=True)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_bitplane_kernel_with_scale():
    rng = np.random.default_rng(1)
    k, n = 256, 128
    w = formats.random_ternary(rng, k, n, 0.25)
    x = jnp.asarray(rng.standard_normal((16, k)), jnp.float32)
    alpha = jnp.asarray(rng.standard_normal(n) ** 2, jnp.float32)
    plus, minus = formats.pack_bitplanes(w)
    y = ternary_gemm_bitplane(x, jnp.asarray(plus), jnp.asarray(minus),
                              alpha, block_n=64, block_k=128, interpret=True)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w), alpha)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_bitplane_equals_2bit_kernel():
    """Both packed formats are lossless encodings of the same ternary T."""
    from repro.kernels import ops
    rng = np.random.default_rng(2)
    k, n = 128, 96
    w = formats.random_ternary(rng, k, n, 0.5)
    x = jnp.asarray(rng.standard_normal((8, k)), jnp.float32)
    plus, minus = formats.pack_bitplanes(w)
    y1 = ternary_gemm_bitplane(x, jnp.asarray(plus), jnp.asarray(minus),
                               block_n=32, block_k=64, interpret=True)
    from repro.core import weights
    y2 = ops.ternary_gemm(x, weights.pack(w, "dense2bit"),
                          block_n=32, block_k=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-5)
