"""Regression tests for the §Perf features: transpose-free cache layouts +
delta cache updates (A6/A7), head padding + repeat-KV (B1), packed MoE
experts (C1). Each must preserve the model function exactly (f32)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import LM


def _decode_consistency(cfg, steps=16):  # 16+16: SSD chunk-divisible
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    s = 16 + steps
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s)), jnp.int32)
    x, _, _ = m.forward(params, {"tokens": toks})
    full = np.asarray(m._logits(params, x), np.float32)
    cache, _ = m.prefill(params, {"tokens": toks[:, :16]}, s,
                         cache_dtype=jnp.float32)
    dec = jax.jit(m.decode_step)
    errs = []
    for t in range(16, s):
        lg, cache = dec(params, cache, toks[:, t:t + 1])
        errs.append(float(np.abs(np.asarray(lg[:, 0], np.float32)
                                 - full[:, t]).max()))
    return max(errs)


@pytest.mark.parametrize("arch,overrides", [
    ("mistral-nemo-12b", {}),
    ("mixtral-8x22b", {"sliding_window": 8}),      # rolling SWA + delta
    ("jamba-v0.1-52b", {}),                        # hybrid: attn delta + ssm
])
def test_opt_cache_layout_delta_updates(arch, overrides):
    """cache_layout=opt (K (B,KV,S,hd) / V (B,KV,hd,S) + delta commits)
    must decode identically to the full forward."""
    cfg = get_config(arch, reduced=True, dtype="float32",
                     cache_layout="opt", **overrides)
    assert _decode_consistency(cfg) < 2e-2


def test_head_pad_preserves_function_shape():
    """Padded q-heads: extra heads exist, forward finite, decode == forward
    (pad heads participate but with learned weights; function class is a
    superset — here we check the machinery, not equivalence to unpadded)."""
    cfg = get_config("deepseek-coder-33b", reduced=True, dtype="float32",
                     num_heads=6, num_kv_heads=2, head_pad=2,
                     gqa_repeat_kv=True)
    assert _decode_consistency(cfg) < 2e-2


def test_repeat_kv_equals_gqa():
    """repeat_kv is a pure re-expression of GQA: logits must be identical
    with and without it."""
    base = get_config("mistral-nemo-12b", reduced=True, dtype="float32")
    rep = get_config("mistral-nemo-12b", reduced=True, dtype="float32",
                     gqa_repeat_kv=True)
    m1, m2 = LM(base), LM(rep)
    params = m1.init(jax.random.PRNGKey(0))
    toks = jnp.arange(64, dtype=jnp.int32).reshape(2, 32) % base.vocab_size
    x1, _, _ = m1.forward(params, {"tokens": toks})
    x2, _, _ = m2.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(x1, np.float32),
                               np.asarray(x2, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_pallas_attn_impl_matches_flash():
    """attn_impl='pallas' (the VMEM flash kernel, interpret on CPU) must
    produce the same logits as the XLA flash path."""
    base = get_config("mistral-nemo-12b", reduced=True, dtype="float32")
    pallas = get_config("mistral-nemo-12b", reduced=True, dtype="float32",
                        attn_impl="pallas")
    m1, m2 = LM(base), LM(pallas)
    params = m1.init(jax.random.PRNGKey(0))
    toks = jnp.arange(64, dtype=jnp.int32).reshape(2, 32) % base.vocab_size
    x1, _, _ = m1.forward(params, {"tokens": toks})
    x2, _, _ = m2.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(x1, np.float32),
                               np.asarray(x2, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_blocked_moe_routing_matches_global():
    """moe_route_blocks (per-DP-shard dispatch, §Perf D1) must equal global
    routing when capacity is not binding."""
    base = get_config("mixtral-8x22b", reduced=True, dtype="float32",
                      capacity_factor=8.0)
    blocked = get_config("mixtral-8x22b", reduced=True, dtype="float32",
                         capacity_factor=8.0, moe_route_blocks=4)
    m1, m2 = LM(base), LM(blocked)
    params = m1.init(jax.random.PRNGKey(0))
    toks = jnp.arange(128, dtype=jnp.int32).reshape(4, 32) % base.vocab_size
    x1, _, _ = m1.forward(params, {"tokens": toks})
    x2, _, _ = m2.forward(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(x1, np.float32),
                               np.asarray(x2, np.float32),
                               rtol=1e-4, atol=1e-4)


def test_packed_moe_matches_qat():
    """MoE experts in the 2-bit packed serving format == QAT forward."""
    import dataclasses
    from repro.core import weights
    from repro.models import layers as L
    cfg = get_config("mixtral-8x22b", reduced=True, dtype="float32",
                     ternary_min_dim=64, quantization="ternary",
                     d_model=128, d_ff_expert=128)
    m = LM(cfg)
    params = m.init(jax.random.PRNGKey(0))
    toks = jnp.arange(64, dtype=jnp.int32).reshape(2, 32) % cfg.vocab_size
    x1, _, _ = m.forward(params, {"tokens": toks})

    # one call packs expert banks (per layer, per expert) and linears alike
    packed = L.pack_params(params, cfg)
    moe_node = packed["block0"]["ffn"]
    assert isinstance(moe_node["w_in"], weights.TernaryWeight)
    assert moe_node["w_in"].packed.ndim == 4       # (L, E, K/16, N) leaves
    cfg2 = dataclasses.replace(cfg, quantization="ternary_packed")
    m2 = LM(cfg2)
    x2, _, _ = m2.forward(packed, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(x1, np.float32),
                               np.asarray(x2, np.float32),
                               rtol=1e-3, atol=1e-3)
