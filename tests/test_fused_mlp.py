"""Fused ternary kernel pass (DESIGN.md §12): LUT decode, double-buffered
tile-skipping, the fused MLP lowering, fusion autotune keys, rooflines.

Every equality here is *bitwise* (``np.array_equal``), not allclose — the
fused/LUT/double-buffered paths are pure scheduling changes over the same
f32 accumulation order, so exact equality is the contract the registry
relies on to dispatch them transparently.
"""
from __future__ import annotations

import importlib
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats, weights
from repro.kernels import ops
from repro.kernels.autotune import Autotuner, BlockConfig, FusedBlockConfig

# the package __init__ re-exports the ternary_gemm *function*, shadowing
# the submodule attribute — import the kernel module explicitly
tg = importlib.import_module("repro.kernels.ternary_gemm")


def _rt(rng, k, n, density=0.25):
    return formats.random_ternary(rng, k, n, density)


def _mlp_weights(fmt, k=256, ff=384, n=128, *, scale=True, bias=True,
                 seed=0):
    rng = np.random.default_rng(seed)

    def pk(w):
        kw = dict(tile_k=64, tile_n=32) if fmt == "tiled" else {}
        sc = (np.abs(rng.standard_normal(w.shape[1])) + 0.5).astype(
            np.float32) if scale else None
        b = rng.standard_normal(w.shape[1]).astype(np.float32) if bias \
            else None
        return weights.pack(w, fmt, scale=sc, bias=b, **kw)

    return pk(_rt(rng, k, ff)), pk(_rt(rng, ff, n)), pk(_rt(rng, k, ff))


# ---------------------------------------------------------------------------
# LUT decode == shift/mask decode, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lut_decode_bit_exact_dense(dtype):
    rng = np.random.default_rng(0)
    m, k, n = 16, 256, 128
    packed = jnp.asarray(formats.pack_2bit(_rt(rng, k, n)))
    scale = jnp.asarray(np.abs(rng.standard_normal(n)) + 0.5, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(n), jnp.float32)
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    kw = dict(block_m=16, block_n=64, block_k=64, interpret=True,
              fuse_prelu=True, prelu_alpha=0.1)
    y_lut = tg.ternary_gemm_pallas(x, packed, scale, bias, decode="lut", **kw)
    y_shift = tg.ternary_gemm_pallas(x, packed, scale, bias, decode="shift",
                                     **kw)
    assert y_lut.dtype == x.dtype
    assert np.array_equal(np.asarray(y_lut), np.asarray(y_shift))


def test_lut_decode_bit_exact_skip():
    rng = np.random.default_rng(1)
    m, k, n = 16, 256, 128
    w = formats.random_tile_ternary(rng, k, n, 64, 32, 0.125)
    wc = weights.pack(w, "tiled", tile_k=64, tile_n=32)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    kw = dict(block_m=16, block_n=32, block_k=64, interpret=True)
    ys = [tg.ternary_gemm_skip_pallas(x, wc.packed, wc.kt_indices,
                                      wc.kt_counts, decode=d, **kw)
          for d in tg.DECODE_MODES]
    assert np.array_equal(np.asarray(ys[0]), np.asarray(ys[1]))


def test_nibble_lut_tables_match_code_map():
    # lo nibble decodes codes (n & 3), hi nibble (n >> 2): 0,+1,-1,0
    lo, hi = np.asarray(tg.NIBBLE_LUT_LO), np.asarray(tg.NIBBLE_LUT_HI)
    for nib in range(16):
        assert lo[nib] == tg._CODE_VAL[nib & 3]
        assert hi[nib] == tg._CODE_VAL[nib >> 2]


# ---------------------------------------------------------------------------
# Double-buffered skip kernel == skip == dense, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("density", [0.5, 0.125, 0.0])
def test_skip_db_bit_exact(density):
    rng = np.random.default_rng(2)
    m, k, n = 16, 256, 128
    w = formats.random_tile_ternary(rng, k, n, 64, 32, density)
    wc = weights.pack(w, "tiled", tile_k=64, tile_n=32)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    y_db = ops.ternary_gemm(x, wc, impl="skip_db")
    y_skip = ops.ternary_gemm(x, wc, impl="skip")
    y_dense = ops.ternary_gemm(x, wc, block_n=32, block_k=64, impl="dense")
    assert np.array_equal(np.asarray(y_db), np.asarray(y_skip))
    assert np.array_equal(np.asarray(y_db), np.asarray(y_dense))


def test_skip_db_epilogue_and_grad():
    rng = np.random.default_rng(3)
    m, k, n = 8, 128, 64
    w = formats.random_tile_ternary(rng, k, n, 32, 16, 0.25)
    sc = (np.abs(rng.standard_normal(n)) + 0.5).astype(np.float32)
    b = rng.standard_normal(n).astype(np.float32)
    wc = weights.pack(w, "tiled", tile_k=32, tile_n=16, scale=sc, bias=b)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    y_db = ops.ternary_gemm(x, wc, fuse_prelu=True, impl="skip_db")
    y_skip = ops.ternary_gemm(x, wc, fuse_prelu=True, impl="skip")
    assert np.array_equal(np.asarray(y_db), np.asarray(y_skip))
    g = jax.grad(lambda xx: ops.ternary_gemm(xx, wc, impl="skip_db").sum())(x)
    g0 = jax.grad(lambda xx: ops.ternary_gemm(xx, wc, impl="skip").sum())(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0), rtol=1e-5)


def test_skip_db_outranks_skip_in_auto_dispatch():
    rng = np.random.default_rng(4)
    w = formats.random_tile_ternary(rng, 128, 64, 32, 16, 0.0625)
    wc = weights.pack(w, "tiled", tile_k=32, tile_n=16)
    assert ops.ternary_gemm_plan(wc, 8).impl == "skip_db"


# ---------------------------------------------------------------------------
# Fused MLP == unfused chain, bitwise
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("fmt", ["dense2bit", "tiled"])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_mlp_bit_exact(fmt, dtype):
    wi, wo, wg = _mlp_weights(fmt)
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.standard_normal((12, wi.k)), dtype)
    y_fused = ops.fused_mlp(x, wi, wo, wg, impl="pallas")
    y_chain = ops.fused_mlp(x, wi, wo, wg, impl="chain")
    assert y_fused.dtype == x.dtype and y_fused.shape == (12, wo.n)
    assert np.array_equal(np.asarray(y_fused), np.asarray(y_chain))


@pytest.mark.parametrize("activation", ["silu", "relu", "none"])
def test_fused_mlp_ungated_activations(activation):
    wi, wo, _ = _mlp_weights("dense2bit", scale=False, bias=False)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((8, wi.k)), jnp.float32)
    y_fused = ops.fused_mlp(x, wi, wo, activation=activation, impl="pallas")
    y_chain = ops.fused_mlp(x, wi, wo, activation=activation, impl="chain")
    assert np.array_equal(np.asarray(y_fused), np.asarray(y_chain))


@pytest.mark.parametrize("phase", ops.SERVING_PHASES)
def test_fused_mlp_bit_exact_under_phases(phase):
    wi, wo, wg = _mlp_weights("dense2bit")
    rng = np.random.default_rng(12)
    x = jnp.asarray(rng.standard_normal((4, wi.k)), jnp.float32)
    with ops.serving_phase(phase):
        y_fused = ops.fused_mlp(x, wi, wo, wg, impl="pallas")
        y_chain = ops.fused_mlp(x, wi, wo, wg, impl="chain")
    assert np.array_equal(np.asarray(y_fused), np.asarray(y_chain))


def test_fused_mlp_misaligned_shapes():
    # nothing divides the default blocks: padding must stay bit-invisible
    wi, wo, wg = _mlp_weights("dense2bit", k=208, ff=176, n=144)
    rng = np.random.default_rng(13)
    x = jnp.asarray(rng.standard_normal((5, 208)), jnp.float32)
    y_fused = ops.fused_mlp(x, wi, wo, wg, impl="pallas")
    y_chain = ops.fused_mlp(x, wi, wo, wg, impl="chain")
    assert y_fused.shape == (5, 144)
    assert np.array_equal(np.asarray(y_fused), np.asarray(y_chain))


def test_fused_mlp_auto_and_bitplane_fallback():
    # auto on a fusable pair resolves the pallas lowering
    wi, wo, wg = _mlp_weights("dense2bit")
    plan = ops.fused_mlp_plan(wi, wo, wg, m=8)
    assert plan.impl == "pallas" and plan.gated
    up, down = plan.sub_plans()
    assert (up.block_n, up.block_k) == (plan.block_n1, plan.block_k1)
    # bitplane containers are not fusable -> the chain lowering serves them
    bi, bo, bg = _mlp_weights("bitplane")
    plan_b = ops.fused_mlp_plan(bi, bo, bg, m=8)
    assert plan_b.impl == "chain"
    rng = np.random.default_rng(14)
    x = jnp.asarray(rng.standard_normal((8, bi.k)), jnp.float32)
    y = ops.fused_mlp(x, bi, bo, bg)     # dispatches, no error
    assert y.shape == (8, bo.n)


def test_fused_mlp_validates_chain_k():
    wi, _, _ = _mlp_weights("dense2bit", k=256, ff=384, n=128)
    wo_bad, _, _ = _mlp_weights("dense2bit", k=256, ff=384, n=128, seed=1)
    with pytest.raises(ValueError, match="down projection expects"):
        ops.fused_mlp_plan(wi, wo_bad, m=8)


def test_fused_mlp_grad_matches_chain():
    wi, wo, wg = _mlp_weights("dense2bit")
    rng = np.random.default_rng(15)
    x = jnp.asarray(rng.standard_normal((8, wi.k)), jnp.float32)
    g = jax.grad(lambda xx: ops.fused_mlp(xx, wi, wo, wg,
                                          impl="pallas").sum())(x)
    g0 = jax.grad(lambda xx: ops.fused_mlp(xx, wi, wo, wg,
                                           impl="chain").sum())(x)
    assert np.all(np.isfinite(np.asarray(g)))
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# layers.mlp_apply adoption + engine-style plan warmup
# ---------------------------------------------------------------------------

def _tiny_cfg(**over):
    from repro.configs.base import ModelConfig
    base = dict(name="t", family="dense", num_layers=1, d_model=256,
                num_heads=4, num_kv_heads=4, d_ff=384, vocab_size=512,
                quantization="ternary_packed", ternary_min_dim=64,
                ternary_kernel="pallas")
    base.update(over)
    return ModelConfig(**base)


def test_mlp_apply_adopts_fused_lowering():
    from repro.models import layers
    wi, wo, wg = _mlp_weights("dense2bit")
    params = {"in": {"w_packed": wi}, "gate": {"w_packed": wg},
              "out": {"w_packed": wo}}
    rng = np.random.default_rng(16)
    x = jnp.asarray(rng.standard_normal((2, 3, wi.k)), jnp.float32)
    y_fused = layers.mlp_apply(params, x, _tiny_cfg())
    y_off = layers.mlp_apply(params, x, _tiny_cfg(fused_mlp="off"))
    assert y_fused.shape == y_off.shape == (2, 3, wo.n)
    assert np.array_equal(np.asarray(y_fused), np.asarray(y_off))
    # fused path requires the full packed triple; a latent MLP falls back
    assert layers._fused_mlp_weights({"in": {}, "out": {}, "gate": {}},
                                     _tiny_cfg()) is None


def test_precompute_fused_plans_warms_phase_keys():
    wi, wo, wg = _mlp_weights("dense2bit")
    tree = {"blk": {"mlp": {"in": {"w_packed": wi}, "gate": {"w_packed": wg},
                            "out": {"w_packed": wo}}}}
    plans = ops.precompute_fused_plans(tree, prefill_ms=(8, 64),
                                       decode_ms=(4,), verify_ms=(5,),
                                       chunk_ms=(16,))
    assert len(plans) == 5
    assert {p.phase for p in plans.values()} == set(ops.SERVING_PHASES)
    assert all(p.gated for p in plans.values())
    assert all(p.impl == "pallas" for p in plans.values())


def test_precompute_fused_plans_stacked_containers():
    """Scan-stacked (L, K/16, N) containers plan through their layer-0
    slice — the 2-D per-layer view each scan step dispatches on — so the
    warmed plans match the runtime lowering (pallas, not chain)."""
    wi, wo, wg = _mlp_weights("dense2bit")
    stack = jax.tree_util.tree_map(lambda a: jnp.stack([a, a]), wi)
    assert stack.packed.ndim == 3
    tree = {"blk": {"mlp": {
        "in": {"w_packed": jax.tree_util.tree_map(
            lambda a: jnp.stack([a, a]), wi)},
        "gate": {"w_packed": jax.tree_util.tree_map(
            lambda a: jnp.stack([a, a]), wg)},
        "out": {"w_packed": jax.tree_util.tree_map(
            lambda a: jnp.stack([a, a]), wo)}}}}
    plans = ops.precompute_fused_plans(tree, decode_ms=(4,))
    assert len(plans) == 1
    assert all(p.impl == "pallas" for p in plans.values())


# ---------------------------------------------------------------------------
# Autotuner fusion keys
# ---------------------------------------------------------------------------

def test_fused_cache_key_roundtrip():
    path = os.path.join(tempfile.mkdtemp(prefix="repro_fused_"), "c.json")
    tuner = Autotuner(path=path, mode="model")
    cfg = tuner.lookup_fused(32, 256, 384, 128, phase="decode")
    assert isinstance(cfg, FusedBlockConfig)
    # composed from the per-GEMM lookups -> fused/unfused tiling agrees
    up = tuner.lookup(32, 256, 384, sparsity=1.0, impl="dense",
                      phase="decode")
    assert (cfg.block_n1, cfg.block_k1) == (up.block_n, up.block_k)
    assert cfg.up() == BlockConfig(cfg.block_m, cfg.block_n1, cfg.block_k1)
    reloaded = Autotuner(path=path, mode="model")
    assert reloaded.lookup_fused(32, 256, 384, 128, phase="decode") == cfg
    # 5-int fused entries and 3-int gemm entries coexist in one cache file
    assert any(isinstance(v, FusedBlockConfig)
               for v in reloaded.entries().values())
    assert any(isinstance(v, BlockConfig)
               for v in reloaded.entries().values())


def test_fused_key_pins_to_chain_tiles():
    path = os.path.join(tempfile.mkdtemp(prefix="repro_fused_"), "c.json")
    tuner = Autotuner(path=path, mode="model")
    a = tuner.lookup_fused(32, 256, 384, 128, fixed_n1=32, fixed_k1=64)
    assert (a.block_n1, a.block_k1) == (32, 64)
    b = tuner.lookup_fused(32, 256, 384, 128)
    assert isinstance(b, FusedBlockConfig)   # re-resolve, pins dropped


# ---------------------------------------------------------------------------
# Rooflines
# ---------------------------------------------------------------------------

def test_gemm_plan_roofline():
    rng = np.random.default_rng(20)
    wc = weights.pack(_rt(rng, 256, 128), "dense2bit")
    rl = ops.ternary_gemm_plan(wc, 32).roofline()
    assert rl["flops"] == 2 * 32 * 256 * 128
    assert rl["bound"] in ("compute", "memory")
    assert 0 < rl["achieved_flops"] <= rl["ceiling_flops"] <= rl["peak_flops"]
    assert 0.0 <= rl["headroom"] < 1.0


def test_fused_plan_roofline_beats_chain_on_bytes():
    wi, wo, wg = _mlp_weights("dense2bit", k=512, ff=2048, n=512)
    rl = ops.fused_mlp_plan(wi, wo, wg, m=256, impl="pallas").roofline()
    # fused never spills h to HBM -> strictly fewer modeled bytes
    assert rl["bytes"] < rl["unfused_bytes"]
    assert rl["fused_speedup"] > 1.0
