"""Serving fault-tolerance tests (DESIGN.md §11): the numerical guard
quarantines exactly the poisoned slot and retries token-exact, deadlines
cancel requests refcount-clean wherever they are, retry budgets terminate
rather than wedge, forced page-OOM storms drain completely, the degradation
ladder sheds speculation, and the chaos CLI smoke-runs end to end."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch import serve
from repro.serving import (ContinuousScheduler, FaultConfig, FaultInjector,
                           RequestQueue, ResilienceConfig)
from repro.serving.faults import FAIL_DEADLINE, FAIL_NUMERIC


def _cfg(**overrides):
    return get_config("ternary-paper", reduced=True, num_layers=2,
                      **overrides)


_PARAMS = {}


def _engine(cfg, slots=3, max_len=32, **kw):
    eng = ContinuousScheduler(cfg, max_slots=slots, max_len=max_len, **kw)
    key = id(cfg)
    if key not in _PARAMS:
        _PARAMS[key] = eng.model.init(jax.random.PRNGKey(0))
    eng.load(_PARAMS[key])
    return eng


def _workload(cfg, lens=(4, 4, 6, 5), seed=0):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, cfg.vocab_size, size=n).astype(np.int32)
            for n in lens]


def _reference(cfg, prompts, gen=8, **kw):
    eng = _engine(cfg, **kw)
    reqs = [eng.submit(p, gen) for p in prompts]
    eng.run()
    return [list(r.tokens) for r in reqs]


def test_injector_schedule_deterministic():
    """Same seed -> identical step schedule; *_at lists fire exactly."""
    cfg = FaultConfig(seed=3, nan_rate=0.3, oom_rate=0.3, nan_at=(5,))
    a = [FaultInjector(cfg).plan(s) for s in range(1, 20)]
    b = [FaultInjector(cfg).plan(s) for s in range(1, 20)]
    assert a == b
    assert a[4].nan                    # step 5 pinned by nan_at
    assert any(f.oom for f in a)       # rate fires somewhere in 19 draws


def test_nan_quarantine_isolates_slot_and_retry_is_token_exact():
    """A NaN-poisoned slot is quarantined and replayed; every request —
    including the poisoned one after its retry — ends with exactly the
    fault-free run's tokens, and untouched slots never notice."""
    cfg = _cfg()
    prompts = _workload(cfg)
    ref = _reference(cfg, prompts)
    eng = _engine(cfg, faults=FaultConfig(nan_at=(3, 5)),
                  resilience=ResilienceConfig(max_retries=2))
    reqs = [eng.submit(p, 8) for p in prompts]
    m = eng.run()
    assert m["faults"]["injected"]["nan_logits"] == 2
    assert m["faults"]["quarantines"] == 2
    assert m["faults"]["retries"] == 2
    assert m["faults"]["failed_requests"] == 0
    assert any(r.attempts > 0 for r in reqs)
    for r, want in zip(reqs, ref):
        assert r.state == "done" and list(r.tokens) == want, r.rid
    assert eng.pool.n_free == eng.max_slots


def test_guard_disabled_outputs_unchanged():
    """No injector, default ResilienceConfig: the always-on guard must be
    bitwise-neutral — outputs identical to each other run to run, zero
    fault metrics."""
    cfg = _cfg()
    prompts = _workload(cfg)
    a = _reference(cfg, prompts)
    eng = _engine(cfg, resilience=ResilienceConfig())
    reqs = [eng.submit(p, 8) for p in prompts]
    m = eng.run()
    assert [list(r.tokens) for r in reqs] == a
    assert m["faults"]["quarantines"] == 0
    assert m["faults"]["injected"] == {}


def test_retries_exhausted_terminates_failed():
    """max_retries=0: the first quarantine is terminal — state='failed',
    reason nan_logits, slot freed, drained counts still reconcile."""
    cfg = _cfg()
    prompts = _workload(cfg)
    eng = _engine(cfg, faults=FaultConfig(nan_at=tuple(range(2, 30))),
                  resilience=ResilienceConfig(max_retries=0))
    req = eng.submit(prompts[0], 4)
    m = eng.run()
    assert req.state == "failed" and req.fail_reason == FAIL_NUMERIC
    assert req.slot is None and eng.pool.n_free == eng.max_slots
    assert m["faults"]["failed_requests"] == 1
    assert eng.total_drained == eng.queue.submitted
    assert req.metrics()["fail_reason"] == FAIL_NUMERIC


def test_deadline_cancels_queued_and_mid_decode():
    """deadline_s=0 cancels while queued; a live request pushed past its
    deadline by slow steps is cancelled mid-decode — in both cases the
    slot/pages come back and the reason code is 'deadline'."""
    cfg = _cfg()
    prompts = _workload(cfg)
    eng = _engine(cfg)
    doomed = eng.submit(prompts[0], 8, deadline_s=0.0)
    ok = eng.submit(prompts[1], 4)
    m = eng.run()
    assert doomed.state == "failed" and doomed.fail_reason == FAIL_DEADLINE
    assert doomed.tokens == [] and doomed.slot is None
    assert ok.state == "done" and len(ok.tokens) == 4
    assert m["faults"]["degradations"]["deadline_cancellations"] == 1

    # mid-decode: every step sleeps 50ms against a 600ms deadline — step 1's
    # sweep (one sleep elapsed, ~0.55s of slack for scheduler noise) admits
    # and prefills the request, but finishing needs >= 30 slow steps (1.5s),
    # so a later sweep is guaranteed to cancel it live, first token emitted
    slow = _engine(cfg, max_len=40,
                   faults=FaultConfig(slow_at=tuple(range(1, 200)),
                                      slow_s=0.05))
    req = slow.submit(prompts[0], 30, deadline_s=0.6)
    slow.run()
    assert req.state == "failed" and req.fail_reason == FAIL_DEADLINE
    assert req.first_token_t is not None      # it was live when cancelled
    assert req.slot is None and slow.pool.n_free == slow.max_slots


def test_paged_chaos_drains_token_exact_and_reclaims():
    """NaN + forced-OOM storm on the paged engine: everything drains,
    survivors are token-exact vs the fault-free run, and the page pool
    comes back refcount-clean (no leaked pages/slots)."""
    cfg = _cfg()
    prompts = _workload(cfg)
    kw = dict(cache="paged", page_size=4, n_pages=40, paged_attn="jax")
    ref = _reference(cfg, prompts, **kw)
    eng = _engine(cfg, faults=FaultConfig(nan_at=(3,), oom_at=(4, 6),
                                          oom_burst=2), **kw)
    reqs = [eng.submit(p, 8) for p in prompts]
    m = eng.run()
    assert m["faults"]["injected"]["page_oom"] == 2
    for r, want in zip(reqs, ref):
        assert r.state == "done" and list(r.tokens) == want, r.rid
    assert eng.pool.all_reclaimed
    assert eng.total_drained == eng.queue.submitted


def test_spec_auto_disable_degradation():
    """Ladder rung 1: with an unreachable acceptance floor the engine
    disables speculation after the rolling window fills, finishes the
    workload on plain decode, and stays token-exact."""
    from repro.spec import SpecConfig
    cfg = _cfg()
    prompts = _workload(cfg)
    spec = SpecConfig(k=2)
    ref = _reference(cfg, prompts, spec=spec)
    eng = _engine(cfg, spec=spec,
                  resilience=ResilienceConfig(spec_accept_floor=1.1,
                                              spec_floor_window=2))
    reqs = [eng.submit(p, 8) for p in prompts]
    m = eng.run()
    deg = m["faults"]["degradations"]
    assert deg["spec_disabled"] and deg["spec_disables"] == 1
    assert m["spec"]["disabled"]
    assert [list(r.tokens) for r in reqs] == ref


def test_spec_draft_fault_falls_back_token_exact():
    """A draft-model fault downgrades that round to plain decode; the
    stream (including the draft re-sync bookkeeping) stays token-exact."""
    from repro.spec import SpecConfig
    cfg = _cfg()
    prompts = _workload(cfg)
    spec = SpecConfig(k=2)
    ref = _reference(cfg, prompts, spec=spec, slots=2)
    eng = _engine(cfg, slots=2, spec=spec,
                  faults=FaultConfig(draft_fail_at=(2, 4), nan_at=(3,)))
    reqs = [eng.submit(p, 8) for p in prompts]
    m = eng.run()
    assert m["spec"]["draft_fallbacks"] == 2
    assert m["faults"]["injected"]["draft_fail"] == 2
    assert [list(r.tokens) for r in reqs] == ref


def test_queue_pop_empty_raises_descriptive():
    q = RequestQueue()
    with pytest.raises(IndexError, match="empty RequestQueue"):
        q.pop()
    assert q.empty() and q.depth() == 0


def test_serve_cli_chaos_smoke(capsys):
    """--chaos end to end: all requests terminal, faults block emitted."""
    metrics = serve.main(["--arch", "ternary-paper", "--reduced",
                          "--requests", "6", "--slots", "2",
                          "--prompt-len", "8", "--gen-lens", "2,6",
                          "--chaos", "--max-retries", "2"])
    out = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert out["submitted"] == out["drained"] == 6
    assert "faults" in out and "injected" in out["faults"]
    done = sum(r["state"] == "done" for r in out["per_request"])
    failed = sum(r["state"] == "failed" for r in out["per_request"])
    assert done + failed == 6
    assert metrics["faults"]["failed_requests"] == failed
