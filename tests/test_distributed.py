"""Distributed layer: PartitionSpec resolution rules (unit) + multi-device
GSPMD lowering + ternary gradient compression (subprocess with fake devices,
since the main test process must keep the single real CPU device)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed import compression, sharding as shlib


# ---------------------------------------------------------------------------
# Spec resolution (pure unit tests on a fake mesh via jax.make_mesh on 1 dev)
# ---------------------------------------------------------------------------

class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape  # dict axis -> size

    @property
    def axis_names(self):
        return tuple(self.shape)


def test_resolve_divisibility():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # d_ff divisible -> sharded on model; fsdp on
    assert shlib.resolve_spec(P("fsdp", "model"), (4096, 14336), mesh, True) \
        == P(("data",), "model")
    # fsdp off -> replicated on dim 0
    assert shlib.resolve_spec(P("fsdp", "model"), (4096, 14336), mesh, False) \
        == P(None, "model")
    # kv=8 not divisible by 16 -> replicated
    assert shlib.resolve_spec(P(None, "model"), (64, 8), mesh, True) == P()


def test_resolve_expert_steals_model_axis():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # kimi: E=384 divisible -> expert-parallel on model, d_ff replicated
    # (trailing Nones are stripped by the resolver)
    assert shlib.resolve_spec(P("expert", "fsdp", "model"),
                              (384, 7168, 2048), mesh, True) \
        == P("model", ("data",))
    # mixtral: E=8 not divisible -> experts replicated, d_ff TP on model
    assert shlib.resolve_spec(P("expert", "fsdp", "model"),
                              (8, 6144, 16384), mesh, True) \
        == P(None, ("data",), "model")


def test_resolve_multipod_batch_axes():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    assert shlib.resolve_spec(P(("pod", "data"), None), (256, 128), mesh,
                              False) == P(("pod", "data"))
    # batch=1 (long_500k): nothing to shard
    assert shlib.resolve_spec(P(("pod", "data"), None), (1, 128), mesh,
                              False) == P()
    # literal axis missing from mesh is dropped
    mesh1 = _FakeMesh({"data": 16, "model": 16})
    assert shlib.resolve_spec(P(("pod", "data"), "model"), (256, 128), mesh1,
                              False) == P(("data",), "model")


def test_no_axis_reuse():
    mesh = _FakeMesh({"data": 2, "model": 4})
    got = shlib.resolve_spec(P("model", "model"), (8, 8), mesh, False)
    assert got == P("model")  # second use dropped


# ---------------------------------------------------------------------------
# Gradient compression (pure math)
# ---------------------------------------------------------------------------

def test_ternarize_gradient_error_feedback():
    import jax.numpy as jnp
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(1024), jnp.float32)
    err = jnp.zeros(1024)
    t, scale, err2 = compression.ternarize_gradient(g, err)
    assert set(np.unique(np.asarray(t, np.float32))) <= {-1.0, 0.0, 1.0}
    # error feedback identity: s*t + err2 == g + err
    np.testing.assert_allclose(
        np.asarray(float(scale) * t.astype(jnp.float32) + err2),
        np.asarray(g), rtol=1e-4, atol=1e-4)
    # compounded error stays bounded over repeated steps
    e = jnp.zeros(1024)
    for i in range(20):
        gi = jnp.asarray(rng.standard_normal(1024), jnp.float32)
        _, _, e = compression.ternarize_gradient(gi, e)
    assert float(jnp.abs(e).max()) < 10.0


def test_init_error_state_leaf_typing():
    """Error-feedback state: float leaves get same-shape f32 accumulators;
    non-float leaves (step counters etc.) get inert f32 scalars so the tree
    still zips with the grad tree under jax.tree.map."""
    import jax
    import jax.numpy as jnp
    params = {"w": jnp.zeros((4, 8), jnp.bfloat16),
              "b": jnp.zeros((8,), jnp.float32),
              "step": jnp.zeros((), jnp.int32)}
    err = compression.init_error_state(params)
    assert err["w"].shape == (4, 8) and err["w"].dtype == jnp.float32
    assert err["b"].shape == (8,) and err["b"].dtype == jnp.float32
    assert err["step"].shape == () and err["step"].dtype == jnp.float32
    assert all(float(jnp.sum(jnp.abs(v))) == 0.0
               for v in jax.tree.leaves(err))


def test_compress_grads_cli_needs_dp_mesh():
    """--compress-grads is the pure-DP shard_map trainer: it must refuse a
    meshless or model-parallel launch instead of silently training dense."""
    from repro.launch import train
    with pytest.raises(SystemExit, match="data-parallel"):
        train.main(["--reduced", "--steps", "1", "--compress-grads"])


# ---------------------------------------------------------------------------
# Multi-device subprocess tests (8 fake CPU devices)
# ---------------------------------------------------------------------------

_SUBPROC_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import jax.numpy as jnp
import numpy as np
"""


SUBPROC_TIMEOUT = int(os.environ.get("REPRO_TEST_SUBPROC_TIMEOUT", "900"))


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    try:
        out = subprocess.run([sys.executable, "-c", _SUBPROC_PRELUDE + code],
                             capture_output=True, text=True,
                             timeout=SUBPROC_TIMEOUT, env=env)
    except subprocess.TimeoutExpired:
        # Slow CPU container, not a code defect: the subprocess is compiling
        # a full GSPMD model. Raise REPRO_TEST_SUBPROC_TIMEOUT to insist.
        pytest.skip(f"model-compile subprocess exceeded {SUBPROC_TIMEOUT}s "
                    "on this machine")
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_gspmd_train_step_on_mesh():
    """Reduced model lowers, compiles AND runs a real sharded train step on
    a 2x4 fake mesh; loss finite, params sharded per the resolved specs."""
    res = _run_sub("""
from repro.configs import get_config
from repro.models import LM, set_mesh
from repro.launch import steps as steps_lib
from repro.distributed import sharding as shlib
from repro.data import SyntheticLM

mesh = jax.make_mesh((2, 4), ("data", "model"))
cfg = get_config("mixtral-8x22b", reduced=True, num_experts=4,
                 d_model=64, d_ff_expert=64, vocab_size=512, grad_accum=2)
set_mesh(mesh)
model = LM(cfg)
p_shapes, p_sh = steps_lib.model_shardings(model, cfg, mesh)
params = jax.jit(model.init, out_shardings=p_sh)(jax.random.PRNGKey(0))
train_step, opt_init = steps_lib.make_train_step(model, cfg)
opt = jax.jit(opt_init)(params)
data = SyntheticLM(cfg, 8, 32)
batch = data.sharded_batch(0, mesh)
p2, opt2, metrics = jax.jit(train_step, donate_argnums=(0, 1))(params, opt, batch)
emb = p2["embed"]["table"]
print(json.dumps({
  "loss": float(metrics["loss"]),
  "emb_shards": len(set(d.id for d in emb.sharding.device_set)),
  "step": int(opt2["step"]),
}))
""")
    assert np.isfinite(res["loss"])
    assert res["step"] == 1
    assert res["emb_shards"] >= 4  # vocab sharded over the model axis


@pytest.mark.slow
def test_compressed_psum_shard_map():
    """TernGrad-style compressed gradient sync under shard_map: the synced
    gradient approximates the true mean across the data axis."""
    res = _run_sub("""
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.distributed import compression

mesh = jax.make_mesh((8,), ("data",))
rng = np.random.default_rng(0)
g_all = jnp.asarray(rng.standard_normal((8, 4096)), jnp.float32)

def sync(g_local, err):
    g, e = compression.compressed_psum({"g": g_local[0]}, {"g": err[0]}, "data")
    return g["g"][None], e["g"][None]

f = shard_map(sync, mesh=mesh,
              in_specs=(P("data", None), P("data", None)),
              out_specs=(P("data", None), P("data", None)))
err = jnp.zeros((8, 4096))
true_mean = jnp.mean(g_all, axis=0)
# one round: coarse; with error feedback over rounds the bias shrinks
synced, err = f(g_all, err)
cos = jnp.sum(synced[0] * true_mean) / (jnp.linalg.norm(synced[0]) * jnp.linalg.norm(true_mean))
# feed same gradient again with error feedback: closer
synced2, err = f(g_all, err)
cos2 = jnp.sum((synced[0]+synced2[0]) * true_mean) / (jnp.linalg.norm(synced[0]+synced2[0]) * jnp.linalg.norm(true_mean))
print(json.dumps({"cos1": float(cos), "cos2": float(cos2)}))
""")
    assert res["cos1"] > 0.7          # sign-style compression preserves direction
    assert res["cos2"] >= res["cos1"] - 0.02  # error feedback doesn't degrade


@pytest.mark.slow
def test_dryrun_cell_multipod_small():
    """End-to-end dry-run machinery on a (2,2,2) pod mesh (the multi-pod
    code path) for a reduced config."""
    res = _run_sub("""
os.environ["REPRO_DRYRUN_DEVICES"] = "8"
from repro.launch import dryrun
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rec = dryrun.run_cell("granite-3-8b", "train_4k", mesh=mesh, reduced=True,
                      overrides={"grad_accum": 2})
print(json.dumps({"status": rec["status"],
                  "dominant": rec.get("dominant"),
                  "flops": rec.get("hlo_flops_per_chip", 0)}))
""")
    assert res["status"] == "ok"
    assert res["flops"] > 0
