"""Public-API surface lock.

Golden lists of the exported names of the packages whose surface downstream
code (benchmarks, examples, serving deployments) programs against. An
accidental rename / deletion / unexported addition fails here before it
breaks a consumer; a *deliberate* API change updates the golden list in the
same PR (that diff is the review signal).
"""
import importlib

import pytest

GOLDEN = {
    "repro": {
        "configs", "core", "checkpoint", "data", "distributed", "kernels",
        "launch", "models", "obs", "optim", "paging", "serving", "spec",
        "TernaryWeight", "Dense2Bit", "Tiled", "Bitplane", "Base3", "pack",
        "ternary_gemm", "ternary_gemm_plan",
    },
    "repro.core": {
        "formats", "quantize", "weights",
        "TernaryWeight", "Dense2Bit", "Tiled", "Bitplane", "Base3",
        "pack", "register_format",
    },
    "repro.core.weights": {
        "TernaryWeight", "Dense2Bit", "Tiled", "Bitplane", "Base3",
        "FORMATS", "register_format", "pack", "ternarize_stacked",
        "validate_spec_twin",
    },
    "repro.distributed": {
        "sharding", "compression", "fault_tolerance", "tp", "router",
    },
    "repro.distributed.tp": {
        "parse_mesh", "replica_meshes", "validate_param_specs",
        "shard_params", "cache_sharding", "replicated_sharding",
        "device_put_cache", "mesh_axis_sizes", "gemm_shard_fn",
    },
    "repro.distributed.router": {"Router"},
    "repro.kernels": {
        "ternary_gemm", "ternary_gemm_plan", "GemmPlan",
        "register_kernel", "kernel_registry", "serving_phase",
        "SERVING_PHASES", "kernel_probe",
        "fused_mlp", "fused_mlp_plan", "FusedMlpPlan",
        "register_fused", "fused_registry", "precompute_fused_plans",
        "fused_mlp_pallas",
        "pack_weights", "pack_weights_tiled",
        "ternary_gemm_pallas", "ternary_gemm_skip_pallas",
        "ternary_gemm_skip_db_pallas", "DECODE_MODES",
        "ternary_gemm_bitplane", "K_PER_WORD", "flash_attention_pallas",
        "paged_decode_attention", "register_paged_attn",
        "paged_attention_registry",
        "Autotuner", "BlockConfig", "FusedBlockConfig", "get_tuner",
    },
    "repro.serving": {
        "ContinuousScheduler", "Request", "RequestQueue", "SlotPool",
        "FaultConfig", "FaultInjector", "ResilienceConfig",
        "SchedConfig", "SLOClass", "SLOQueue",
        "Arrival", "TrafficConfig", "make_schedule", "run_open_loop",
    },
    "repro.serving.sched": {
        "ChunkRunner", "DEFAULT_SLO_CLASSES", "SLOClass", "SLOQueue",
        "SchedConfig", "plan_chunks",
    },
    "repro.paging": {
        "PagePool", "Admission", "PrefixCache", "Int8Pages",
        "page_keys", "tree_nbytes",
    },
    "repro.spec": {
        "SpecConfig", "DraftModel", "Draft", "build_draft",
        "resparsify", "layer_skip", "external",
        "make_draft_round", "make_verify_step", "longest_prefix_match",
        "rollback_dense", "rollback_paged",
    },
    "repro.checkpoint": {"save", "restore", "latest_step",
                         "CheckpointCorruptError"},
    "repro.obs": {
        "clock", "trace", "metrics",
        "Tracer", "load_trace", "validate_events",
        "MetricsRegistry", "Counter", "Gauge", "Histogram", "Ewma",
        "RunningStat", "percentiles",
    },
}

# Formats every deployment depends on being registered + dispatchable.
GOLDEN_FORMATS = {"dense2bit", "tiled", "bitplane", "base3"}
GOLDEN_KERNELS = {
    ("dense2bit", "dense"), ("dense2bit", "ref"),
    ("tiled", "skip"), ("tiled", "skip_db"), ("tiled", "dense"),
    ("tiled", "ref"),
    ("bitplane", "bitplane"), ("bitplane", "bitplane_factorized"),
    ("bitplane", "ref"),
    ("base3", "ref"),
}
GOLDEN_PAGED_ATTN = {"jax", "pallas"}
# Autotune phase keys the serving engine traces under (prefill GEMM /
# decode GEMV / speculative verify small-GEMM / chunked-prefill window,
# DESIGN.md §10 + §14).
GOLDEN_PHASES = ("prefill", "decode", "verify", "chunk")


@pytest.mark.parametrize("module", sorted(GOLDEN))
def test_all_matches_golden(module):
    mod = importlib.import_module(module)
    assert set(mod.__all__) == GOLDEN[module], (
        f"{module}.__all__ drifted from the golden list — if intentional, "
        f"update tests/test_api_surface.py in the same change")


@pytest.mark.parametrize("module", sorted(GOLDEN))
def test_exports_resolve(module):
    mod = importlib.import_module(module)
    for name in GOLDEN[module]:
        assert getattr(mod, name, None) is not None, f"{module}.{name}"


def test_format_and_kernel_registries_locked():
    from repro.core import weights
    from repro.kernels import ops
    assert GOLDEN_FORMATS <= set(weights.FORMATS), (
        "a registered weight format disappeared")
    assert GOLDEN_KERNELS <= set(ops.kernel_registry()), (
        "a registered kernel lowering disappeared")
    assert GOLDEN_PAGED_ATTN <= set(ops.paged_attention_registry()), (
        "a registered paged-attention lowering disappeared")
    assert ops.SERVING_PHASES == GOLDEN_PHASES, (
        "the serving-phase autotune keys drifted from the golden tuple")


def test_legacy_shim_is_contained():
    """The old weight-operand union is gone — raw operands raise TypeError
    in ops (see test_weights_api), and no public module re-exports the
    legacy config type."""
    import repro.kernels as K
    assert not hasattr(K, "TernaryGemmConfig")
    assert not hasattr(importlib.import_module("repro.kernels.ops"),
                       "TernaryGemmConfig")
