"""Observability tests (DESIGN.md §15): the fake-able clock, the
ring-buffer tracer and its Perfetto-loadable export, the metrics
registry, the golden metrics-JSON schema (byte-compatibility lock for
``run()``/``collect_metrics``/``run_open_loop``), trace-vs-metrics
TTFT/TPOT agreement, and the kernel probe."""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_config
from repro.obs import (MetricsRegistry, Tracer, load_trace, percentiles,
                       validate_events)
from repro.obs import clock as obs_clock
from repro.obs.clock import FakeClock, fake_clock
from repro.obs.metrics import Counter, Ewma, Gauge, Histogram, RunningStat
from repro.serving import ContinuousScheduler


def _cfg(**overrides):
    return get_config("ternary-paper", reduced=True, num_layers=2,
                      **overrides)


def _engine(cfg, slots=3, max_len=32, seed=0, **kw):
    eng = ContinuousScheduler(cfg, max_slots=slots, max_len=max_len, **kw)
    eng.load(eng.model.init(jax.random.PRNGKey(seed)))
    return eng


def _workload(cfg, n, prompt_len=16, seed=0, lens=(2, 9)):
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(n, prompt_len)).astype(np.int32)
    gens = [int(g) for g in rng.integers(lens[0], lens[1], size=n)]
    return prompts, gens


# ---------------------------------------------------------------------------
# clock
# ---------------------------------------------------------------------------

def test_clock_is_monotonic_and_fakeable():
    a, b = obs_clock.now(), obs_clock.now()
    assert b >= a
    with fake_clock(FakeClock(t0=100.0)) as fc:
        assert obs_clock.now() == 100.0
        fc.advance(2.5)
        assert obs_clock.now() == 102.5
    assert obs_clock.now() < 100.0 or obs_clock.now() != 102.5


def test_fake_clock_tick_advances_per_read():
    """Busy-wait loops (admission backoff, deadline sweeps) must observe
    progress under test — the optional tick adds on every read."""
    with fake_clock(tick=0.5) as fc:
        assert obs_clock.now() == 0.5
        assert obs_clock.now() == 1.0
        fc.advance(10.0)
        assert obs_clock.now() == 11.5


def test_fake_clock_rejects_rewind():
    with pytest.raises(AssertionError):
        FakeClock().advance(-1.0)


def test_set_clock_restores():
    prev = obs_clock.set_clock(lambda: 42.0)
    try:
        assert obs_clock.now() == 42.0
    finally:
        obs_clock.set_clock(prev)
    assert obs_clock.now() != 42.0


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------

def test_tracer_ring_drops_oldest():
    with fake_clock(tick=0.001) as fc:
        tr = Tracer(capacity=4, clock=fc)
        for i in range(10):
            tr.instant("ev", args={"i": i})
    assert len(tr) == 4
    assert tr.dropped == 6
    kept = [e["args"]["i"] for e in tr.events()]
    assert kept == [6, 7, 8, 9]          # newest survive
    # drop accounting reaches the export
    assert tr.to_dict()["otherData"]["dropped_events"] == 6


def test_tracer_metadata_survives_overflow():
    tr = Tracer(capacity=2)
    pid = tr.new_pid("engine")
    tr.thread_name(pid, 5, "req 4")
    for _ in range(10):
        tr.instant("x", pid=pid)
    meta = [e for e in tr.to_dict()["traceEvents"] if e["ph"] == "M"]
    names = {(e["name"], e.get("args", {}).get("name")) for e in meta}
    assert ("process_name", "engine") in names
    assert ("thread_name", "req 4") in names


def test_tracer_span_and_complete_agree():
    with fake_clock(FakeClock(t0=10.0)) as fc:
        tr = Tracer(clock=fc)
        with tr.span("work", args={"k": 1}):
            fc.advance(0.25)
        tr.complete("retro", 10.0, 10.25)
    evs = tr.events()
    assert [e["name"] for e in evs] == ["work", "retro"]
    for e in evs:
        assert e["ph"] == "X" and e["ts"] == 0 and e["dur"] == 250_000


def test_tracer_export_is_perfetto_loadable(tmp_path):
    with fake_clock(tick=0.001) as fc:
        tr = Tracer(clock=fc)
        pid = tr.new_pid("engine")
        with tr.span("step", pid=pid):
            pass
        tr.instant("mark", pid=pid, args={"rid": 3}, tid=4)
        tr.counter("sched", {"depth": 2.0}, pid=pid)
    path = str(tmp_path / "t.json")
    n = tr.export(path)
    doc = load_trace(path)
    assert isinstance(doc["traceEvents"], list) and len(doc["traceEvents"]) == n
    assert doc["displayTimeUnit"] == "ms"
    validate_events(doc["traceEvents"])
    phases = {e["ph"] for e in doc["traceEvents"]}
    assert {"M", "X", "i", "C"} <= phases


def test_validate_events_catches_track_mismatch():
    with pytest.raises(AssertionError):
        validate_events([{"ph": "i", "name": "x", "cat": "e", "ts": 0,
                          "pid": 0, "tid": 1, "args": {"rid": 5}}])


def test_tracer_counter_copies_values():
    tr = Tracer()
    vals = {"depth": 1.0}
    tr.counter("sched", vals)
    vals["depth"] = 99.0
    assert tr.events()[0]["args"]["depth"] == 1.0


def test_tracer_is_always_truthy():
    assert bool(Tracer()) and len(Tracer()) == 0


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_percentiles_shape_and_none():
    assert percentiles([]) is None
    assert percentiles([None, None]) is None
    p = percentiles([1.0, None, 3.0, 2.0])
    assert set(p) == {"p50", "p90", "p99", "mean", "max", "n"}
    assert p["n"] == 3 and p["p50"] == 2.0 and p["max"] == 3.0


def test_registry_get_or_create_and_kind_lock():
    reg = MetricsRegistry()
    c = reg.counter("hits")
    assert reg.counter("hits") is c and c.inc() == 1
    with pytest.raises(AssertionError):
        reg.gauge("hits")
    reg.gauge("depth").set(3)
    reg.histogram("lat").observe(0.5)
    reg.ewma("step", alpha=0.3).update(1.0)
    reg.stat("q").push(7)
    assert len(reg) == 5 and "hits" in reg
    snap = reg.snapshot()
    assert snap["hits"] == 1 and snap["depth"] == 3.0
    assert snap["lat"]["n"] == 1 and snap["q"]["peak"] == 7
    reg.reset("q")
    assert "q" not in reg


def test_ewma_seeding_and_update_math():
    e = Ewma("t", alpha=0.3)
    assert e.value is None
    assert e.update(2.0) == 2.0                 # first observation seeds
    assert abs(e.update(4.0) - (0.7 * 2.0 + 0.3 * 4.0)) < 1e-12


def test_histogram_windowed_but_exact_count():
    h = Histogram("lat", cap=4)
    for v in range(10):
        h.observe(float(v))
    p = h.percentiles()
    assert p["n"] == 10                          # exact total
    assert p["max"] <= 9.0 and p["p50"] >= 4.0   # window holds newest


def test_running_stat_exact_mean_peak():
    s = RunningStat("q", cap=2)
    for v in (1, 5, 3):
        s.push(v)
    assert s.n == 3 and s.peak == 5 and abs(s.mean - 3.0) < 1e-12
    assert len(s.ring) == 2                      # bounded detail


# ---------------------------------------------------------------------------
# engine integration: registry-backed counters, golden metrics schema
# ---------------------------------------------------------------------------

TOP_LEVEL_KEYS = {
    "engine", "max_slots", "max_len", "mesh", "cache", "spec",
    "concurrency", "planned_gemms", "per_request", "submitted", "drained",
    "generated_tokens", "wall_s", "tok_per_s", "prefill_steps",
    "decode_steps", "ttft_s", "latency", "sched", "queue_depth", "faults",
}
PER_REQUEST_KEYS = {
    "rid", "prompt_len", "gen_len", "ttft_s", "queue_wait_s", "prefill_s",
    "tpot_s", "latency_s", "state", "fail_reason", "attempts", "chunks",
    "slo",
}
LATENCY_KEYS = {"ttft_s", "queue_wait_s", "prefill_s", "tpot_s", "e2e_s"}
PCT_KEYS = {"p50", "p90", "p99", "mean", "max", "n"}
FAULTS_KEYS = {"injected", "quarantines", "retries", "failed_requests",
               "degradations"}
DEGRADATION_KEYS = {"spec_disabled", "spec_disables", "admission_pauses",
                    "deadline_cancellations"}
TRAFFIC_KEYS = {"n", "time_scale", "offered_rate", "degenerate_schedule",
                "makespan_s", "max_submit_lag_s"}


@pytest.fixture(scope="module")
def drained():
    cfg = _cfg()
    eng = _engine(cfg)
    prompts, gens = _workload(cfg, 5)
    for p, g in zip(prompts, gens):
        eng.submit(p, g)
    return eng, eng.run()


def test_metrics_json_golden_schema(drained):
    """The metrics JSON shape is load-bearing (CI parses it, docs quote
    it): registry-backing the counters must not change a single key."""
    _, m = drained
    assert set(m) == TOP_LEVEL_KEYS
    for r in m["per_request"]:
        assert set(r) == PER_REQUEST_KEYS
    assert set(m["latency"]) == LATENCY_KEYS
    for block in m["latency"].values():
        assert block is None or set(block) == PCT_KEYS
    assert set(m["faults"]) == FAULTS_KEYS
    assert set(m["faults"]["degradations"]) == DEGRADATION_KEYS
    assert set(m["ttft_s"]) == {"mean", "max"}
    assert set(m["queue_depth"]) == {"max", "mean"}
    assert set(m["concurrency"]) == {"peak", "mean"}
    assert m["cache"]["mode"] == "dense" and "nbytes" in m["cache"]
    json.dumps(m)                                 # serializable end-to-end


def test_engine_counters_are_registry_backed(drained):
    eng, m = drained
    assert eng.total_drained == 5
    assert eng.metrics.counter("total_drained").value == 5
    snap = eng.metrics.snapshot()
    assert snap["decode_steps"] == eng.decode_steps > 0
    assert snap["step_time_s"] == pytest.approx(eng._step_ema)
    # writable through the attribute (legacy reset idiom)
    eng.deferrals = 7
    assert eng.metrics.counter("deferrals").value == 7
    eng.deferrals = 0


def test_traffic_block_golden_schema_and_degenerate_flag():
    from repro.serving import Arrival, run_open_loop
    cfg = _cfg()
    eng = _engine(cfg)
    rng = np.random.default_rng(0)

    def arrival(t):
        return Arrival(t=t, prompt=rng.integers(
            0, cfg.vocab_size, size=8, dtype=np.int32), max_new=2)

    # n=1: no arrival spacing exists — rate must be numeric 0.0, flagged
    _, m1 = run_open_loop(eng, [arrival(0.0)])
    assert set(m1["traffic"]) == TRAFFIC_KEYS
    assert m1["traffic"]["offered_rate"] == 0.0
    assert m1["traffic"]["degenerate_schedule"] is True

    # time_scale=0 burst: same degeneracy
    _, m0 = run_open_loop(eng, [arrival(0.0), arrival(1.0)], time_scale=0.0)
    assert m0["traffic"]["offered_rate"] == 0.0
    assert m0["traffic"]["degenerate_schedule"] is True

    # real spacing: rate = (n-1)/span, not flagged
    _, m2 = run_open_loop(eng, [arrival(0.0), arrival(0.05)])
    assert m2["traffic"]["offered_rate"] == pytest.approx(20.0)
    assert m2["traffic"]["degenerate_schedule"] is False


def test_queue_submit_stamps_obs_clock():
    from repro.serving.queue import RequestQueue
    with fake_clock(FakeClock(t0=500.0)):
        q = RequestQueue()
        req = q.submit(np.ones(4, np.int32), 2)
    assert req.submit_t == 500.0


# ---------------------------------------------------------------------------
# trace <-> metrics agreement
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def traced_run(tmp_path_factory):
    cfg = _cfg()
    tracer = Tracer(capacity=1 << 16)
    eng = _engine(cfg, tracer=tracer)
    prompts, gens = _workload(cfg, 6)
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    metrics = eng.run()
    path = str(tmp_path_factory.mktemp("trace") / "t.json")
    tracer.export(path)
    return reqs, metrics, load_trace(path)


def test_trace_file_is_valid_and_rid_consistent(traced_run):
    reqs, _, doc = traced_run
    evs = doc["traceEvents"]
    validate_events(evs)
    # every request's lifecycle landed on its own track with the full
    # span set: submit -> queue_wait -> prefill -> first_token ->
    # decode -> done
    for r in reqs:
        names = {e["name"] for e in evs
                 if (e.get("args") or {}).get("rid") == r.rid}
        assert {"submit", "queue_wait", "prefill", "first_token",
                "decode", "done"} <= names, (r.rid, names)


def test_trace_reconstructs_ttft_tpot(traced_run):
    """Trace spans are emitted from the same clock stamps the Request
    metrics use — TTFT (queue_wait + prefill) and TPOT (decode / (n-1))
    reconstructed from the file must agree with Request.metrics()."""
    reqs, _, doc = traced_run
    by_rid = {}
    for e in doc["traceEvents"]:
        rid = (e.get("args") or {}).get("rid")
        if rid is not None and e["ph"] == "X":
            by_rid.setdefault(rid, {})[e["name"]] = e
    for r in reqs:
        spans = by_rid[r.rid]
        mm = r.metrics()
        ttft = (spans["queue_wait"]["dur"] + spans["prefill"]["dur"]) / 1e6
        assert ttft == pytest.approx(mm["ttft_s"], abs=5e-3)
        if mm["tpot_s"] is not None and len(r.tokens) > 1:
            tpot = spans["decode"]["dur"] / 1e6 / (len(r.tokens) - 1)
            assert tpot == pytest.approx(mm["tpot_s"], abs=5e-3)


def test_engine_kernel_spans_emitted(traced_run):
    _, metrics, doc = traced_run
    evs = doc["traceEvents"]
    decode_spans = [e for e in evs
                    if e["ph"] == "X" and e["name"] == "decode_step"]
    assert len(decode_spans) == metrics["decode_steps"]
    assert all(e["tid"] == 0 for e in decode_spans)
    counters = [e for e in evs if e["ph"] == "C" and e["name"] == "sched"]
    assert counters and all(
        {"queue_depth", "live_slots", "prefilling"} <= set(e["args"])
        for e in counters)


def test_trace_report_end_to_end(traced_run, tmp_path):
    import sys
    sys.path.insert(0, "scripts")
    try:
        import trace_report
    finally:
        sys.path.pop(0)
    _, metrics, doc = traced_run
    path = str(tmp_path / "t.json")
    with open(path, "w") as f:
        json.dump(doc, f)
    rep = trace_report.report(path)
    assert rep["step_breakdown"]["decode_step"]["n"] == \
        metrics["decode_steps"]
    il = rep["interleave"]
    assert 0.0 < il["busy_frac"] <= 1.0
    assert il["busy_frac"] + il["bubble_frac"] == pytest.approx(1.0)
    assert len(rep["ttft_waterfall"]) == metrics["drained"]
    # waterfall agrees with the engine's own percentile source
    worst = rep["ttft_waterfall"][0]["ttft_s"]
    assert worst == pytest.approx(metrics["latency"]["ttft_s"]["max"],
                                  abs=5e-3)
    json.dumps(rep)


# ---------------------------------------------------------------------------
# kernel probe
# ---------------------------------------------------------------------------

def test_kernel_probe_times_eager_dispatch():
    from repro.core import weights
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    w = weights.pack(rng.integers(-1, 2, size=(64, 32)).astype(np.int8))
    x = np.asarray(rng.normal(size=(4, 64)), np.float32)
    seen = []
    with ops.kernel_probe(lambda plan, dt: seen.append((plan, dt))):
        y1 = ops.ternary_gemm(jax.numpy.asarray(x), w)
    assert len(seen) == 1
    plan, dt = seen[0]
    assert plan.m == 4 and dt > 0
    assert "model_time_s" in plan.roofline()
    # same dispatch outside the scope: no callback, identical result
    y2 = ops.ternary_gemm(jax.numpy.asarray(x), w)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))
    assert len(seen) == 1


def test_kernel_probe_skips_traced_dispatch():
    """Under jit tracing there is no wall time to measure — the probe
    must not fire (and must not bake a callback into the jaxpr)."""
    from repro.core import weights
    from repro.kernels import ops
    rng = np.random.default_rng(0)
    w = weights.pack(rng.integers(-1, 2, size=(64, 32)).astype(np.int8))
    x = np.asarray(rng.normal(size=(4, 64)), np.float32)
    seen = []
    fn = jax.jit(lambda a: ops.ternary_gemm(a, w))
    with ops.kernel_probe(lambda plan, dt: seen.append(dt)):
        fn(x).block_until_ready()
    assert seen == []


# ---------------------------------------------------------------------------
# straggler watchdog (registry-backed, API preserved)
# ---------------------------------------------------------------------------

def test_watchdog_shares_registry_mechanism():
    from repro.distributed.fault_tolerance import StragglerWatchdog
    reg = MetricsRegistry()
    w = StragglerWatchdog(factor=2.0, alpha=0.5, registry=reg)
    w.observe(0, 1.0)
    assert w.observe(1, 5.0)
    # the same names the serving engine uses — one mechanism, two users
    assert reg.ewma("step_time_s", alpha=0.5) is w._ewma
    assert reg.counter("straggler_steps").value == w.straggler_steps == 1
    # legacy attribute writes still work
    w.ewma = 2.0
    w.straggler_steps = 0
    assert reg.snapshot() == {"step_time_s": 2.0, "straggler_steps": 0}
