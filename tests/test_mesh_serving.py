"""Mesh-sharded serving (DESIGN.md §13): spec-twin pack-boundary
validation, collective-aware GEMM/MLP plans, the prefix-affinity router
(unit, stub engines) and end-to-end tensor-parallel token exactness
(subprocess with 8 fake CPU devices, like test_distributed)."""
import json
import os
import subprocess
import sys
import types

import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import weights
from repro.distributed import tp as tp_lib
from repro.distributed.router import Router
from repro.kernels import ops
from repro.paging.prefix import PrefixCache, page_keys


def _ternary(k, n, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(-1, 2, size=(k, n)).astype(np.int8)


# ---------------------------------------------------------------------------
# Shard constraints per physical format
# ---------------------------------------------------------------------------

def test_shard_constraints_per_format():
    t = _ternary(512, 256)
    assert weights.pack(t, "dense2bit").shard_constraints() == \
        {"k": (512, 16), "n": (256, 1)}
    assert weights.pack(t, "bitplane").shard_constraints() == \
        {"k": (512, 8), "n": (256, 1)}
    assert weights.pack(t, "base3").shard_constraints() == \
        {"k": (512, 5), "n": (256, 1)}
    tiled = weights.pack(t, "tiled", tile_k=128, tile_n=128)
    # tile-padded extents, whole-tile multiples
    assert tiled.shard_constraints() == \
        {"k": (512, 128), "n": (256, 128)}


# ---------------------------------------------------------------------------
# validate_spec_twin — pack-boundary enforcement (plain-dict meshes)
# ---------------------------------------------------------------------------

def test_spec_twin_legal_splits_pass():
    wc = weights.pack(_ternary(64, 32), "dense2bit")
    mesh = {"model": 4}
    # N column split: multiple 1, any divisor of 32 works
    assert weights.validate_spec_twin(
        wc, wc.replace(packed=P(None, "model")), mesh) is None
    # K row split: 64 / 4 = 16 per shard == one pack word exactly
    assert weights.validate_spec_twin(
        wc, wc.replace(packed=P("model", None)), mesh) is None


def test_spec_twin_off_multiple_split_raises():
    wc = weights.pack(_ternary(64, 32), "dense2bit")
    # 8-way K split -> 8 values/shard, half a 16-value pack word
    with pytest.raises(ValueError) as ei:
        weights.validate_spec_twin(
            wc, wc.replace(packed=P("model", None)), {"model": 8})
    msg = str(ei.value)
    assert "16-value pack multiple" in msg
    assert "nearest legal boundary is 16" in msg
    assert "K" in msg


def test_spec_twin_tiled_whole_tile_rule():
    wc = weights.pack(_ternary(512, 256), "tiled", tile_k=128, tile_n=128)
    mesh = {"model": 4}
    # K: 4 tiles / 4 shards -> one whole tile each
    assert weights.validate_spec_twin(
        wc, wc.replace(packed=P("model", None)), mesh) is None
    # N: 2 tiles cannot split 4 ways without cutting a tile
    with pytest.raises(ValueError, match="128-value pack multiple"):
        weights.validate_spec_twin(
            wc, wc.replace(packed=P(None, "model")), mesh)


def test_spec_twin_stack_axis_burns_mesh_axis():
    # a leading stack entry consumes "model" -> the trailing K entry
    # resolves to nothing (no-reuse rule), so no boundary to violate
    wc = weights.pack(_ternary(24, 32), "dense2bit")  # 24 % 16 != 0
    twin = wc.replace(packed=P("model", "model", None))
    assert weights.validate_spec_twin(wc, twin, {"model": 8}) is None


def test_spec_twin_replicated_is_noop():
    wc = weights.pack(_ternary(24, 32), "dense2bit")
    assert weights.validate_spec_twin(
        wc, wc.replace(packed=P()), {"model": 8}) is None
    # no spec leaf at all -> nothing sharded -> nothing to check
    assert weights.validate_spec_twin(
        wc, wc.replace(packed=None), {"model": 8}) is None


def test_validate_param_specs_counts_containers():
    wc = weights.pack(_ternary(64, 32), "dense2bit")
    params = {"a": {"w_packed": wc}, "b": np.zeros(3)}
    specs = {"a": {"w_packed": wc.replace(packed=P(None, "model"),
                                          scale=P("model"), bias=None)},
             "b": P()}
    mesh = tp_lib.mesh_axis_sizes({"model": 4})
    assert tp_lib.validate_param_specs(params, specs, mesh) == 1


# ---------------------------------------------------------------------------
# Collective-aware GEMM plans
# ---------------------------------------------------------------------------

def test_gemm_plan_k_partition_records_psum():
    w = weights.pack(_ternary(512, 256), "dense2bit")
    plan = ops.ternary_gemm_plan(w, 32, phase="decode",
                                 partition="k", tp=4)
    assert (plan.partition, plan.collective, plan.tp) == ("k", "psum", 4)
    assert (plan.k, plan.n) == (128, 256)          # per-shard K
    r = plan.roofline()
    assert sorted(r) == [
        "achieved_flops", "arithmetic_intensity", "bound", "bytes",
        "ceiling_flops", "collective", "collective_bytes", "flops",
        "headroom", "model_time_s", "peak_flops", "tp"]
    # ring all-reduce: 2*(tp-1)/tp of the (m, n) f32 partial output
    assert r["collective_bytes"] == 2.0 * 3 / 4 * 32 * 256 * 4
    assert r["collective"] == "psum" and r["tp"] == 4


def test_gemm_plan_n_partition_no_collective():
    w = weights.pack(_ternary(512, 256), "dense2bit")
    plan = ops.ternary_gemm_plan(w, 32, phase="decode",
                                 partition="n", tp=4)
    assert (plan.partition, plan.collective) == ("n", None)
    assert (plan.k, plan.n) == (512, 64)           # per-shard N
    assert plan.roofline()["collective_bytes"] == 0.0
    # per-shard tiles never exceed the shard extent
    if plan.block_n:
        assert plan.block_n <= 64


def test_gemm_plan_partition_validation():
    w = weights.pack(_ternary(512, 256), "dense2bit")
    with pytest.raises(ValueError, match="partition must be"):
        ops.ternary_gemm_plan(w, 32, phase="decode", partition="m", tp=4)
    with pytest.raises(ValueError, match="tp must be"):
        ops.ternary_gemm_plan(w, 32, phase="decode", tp=0)
    # 3-way K split of 512 lands off the 16-value word boundary
    with pytest.raises(ValueError, match="pack multiple"):
        ops.ternary_gemm_plan(w, 32, phase="decode", partition="k", tp=3)
    # tp=1 degenerates to an unsharded plan
    p1 = ops.ternary_gemm_plan(w, 32, phase="decode", partition="k", tp=1)
    assert p1.partition is None and p1.collective is None and p1.tp == 1


def test_fused_mlp_plan_tp_shards_hidden_dim():
    w_in = weights.pack(_ternary(128, 256, seed=1), "dense2bit")
    w_out = weights.pack(_ternary(256, 128, seed=2), "dense2bit")
    plan = ops.fused_mlp_plan(w_in, w_out, m=32, phase="prefill", tp=4)
    assert plan.ff == 64 and plan.tp == 4          # per-shard hidden width
    assert plan.collective == "psum"
    up, down = plan.sub_plans()
    assert (up.partition, up.collective) == ("n", None)
    assert (down.partition, down.collective) == ("k", "psum")
    assert up.n == 64 and down.k == 64
    r = plan.roofline()
    assert r["tp"] == 4 and r["collective"] == "psum"
    assert r["collective_bytes"] == 2.0 * 3 / 4 * 32 * 128 * 4
    # indivisible hidden dim refuses to plan
    with pytest.raises(ValueError, match="pack multiple"):
        ops.fused_mlp_plan(w_in, w_out, m=32, phase="prefill", tp=3)


def test_gemm_shard_fn_reads_placed_specs():
    shard = tp_lib.gemm_shard_fn({"model": 4})

    def stub(spec, ndim):
        arr = types.SimpleNamespace(
            sharding=None if spec is None
            else types.SimpleNamespace(spec=spec), ndim=ndim)
        return types.SimpleNamespace(packed=arr)

    assert shard((), stub(P(None, "model"), 2)) == ("n", 4)
    assert shard((), stub(P("model"), 2)) == ("k", 4)
    # placed specs strip trailing Nones: a stacked (L, Kw, N) down-proj
    # reads back as P(None, 'model') — still the K axis once padded
    assert shard((), stub(P(None, "model"), 3)) == ("k", 4)
    assert shard((), stub(P(None, None, "model"), 3)) == ("n", 4)
    assert shard((), stub(P(), 2)) == (None, 1)
    assert shard((), stub(None, 2)) == (None, 1)
    # no "model" axis on the mesh -> never sharded
    assert tp_lib.gemm_shard_fn({"data": 8})(
        (), stub(P(None, "model"), 2)) == (None, 1)


# ---------------------------------------------------------------------------
# parse_mesh / replica_meshes
# ---------------------------------------------------------------------------

def test_parse_mesh():
    assert tp_lib.parse_mesh("2,4") == (2, 4)
    assert tp_lib.parse_mesh("4") == (1, 4)       # bare tp
    assert tp_lib.parse_mesh(" 1 , 2 ") == (1, 2)
    with pytest.raises(ValueError):
        tp_lib.parse_mesh("1,2,3")
    with pytest.raises(ValueError):
        tp_lib.parse_mesh("0,4")


def test_replica_meshes_needs_enough_devices():
    # the main test process keeps the single real CPU device
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        tp_lib.replica_meshes(2, 4)


# ---------------------------------------------------------------------------
# Router placement policy (stub engines)
# ---------------------------------------------------------------------------

class _StubEngine:
    def __init__(self, probe=0, depth=0, live=0, with_prefix=True):
        prefix = (types.SimpleNamespace(probe=lambda p: probe)
                  if with_prefix else None)
        self.pool = types.SimpleNamespace(prefix=prefix)
        self.queue = types.SimpleNamespace(depth=lambda: depth)
        self._live = {i: None for i in range(live)}


def test_router_validates_args():
    with pytest.raises(ValueError):
        Router([])
    with pytest.raises(ValueError):
        Router([_StubEngine()], spill_threshold=-1)


def test_router_cold_traffic_goes_to_least_load():
    r = Router([_StubEngine(depth=3), _StubEngine(live=1), _StubEngine()])
    assert r.place(np.arange(8)) == 2
    assert r.affinity_candidates == 0


def test_router_prefix_affinity_wins():
    r = Router([_StubEngine(probe=0), _StubEngine(probe=3, depth=2),
                _StubEngine(probe=1)])
    assert r.place(np.arange(8)) == 1       # deepest prefix, despite load
    assert (r.affinity_candidates, r.affinity_hits, r.spills) == (1, 1, 0)


def test_router_probe_tie_breaks_by_load():
    r = Router([_StubEngine(probe=2, depth=2), _StubEngine(probe=2)])
    assert r.place(np.arange(8)) == 1


def test_router_spills_past_threshold():
    r = Router([_StubEngine(probe=4, depth=6, live=1), _StubEngine()],
               spill_threshold=4)
    assert r.place(np.arange(8)) == 1       # favorite is 7 deeper -> spill
    assert (r.spills, r.affinity_hits) == (1, 0)
    # threshold is a > comparison: exactly at threshold stays sticky
    r2 = Router([_StubEngine(probe=4, depth=4), _StubEngine()],
                spill_threshold=4)
    assert r2.place(np.arange(8)) == 0
    assert r2.spills == 0


def test_router_dense_engines_fall_back_to_load():
    # SlotPool engines have no prefix cache: probe 0 everywhere
    r = Router([_StubEngine(with_prefix=False, depth=1),
                _StubEngine(with_prefix=False)])
    assert r.place(np.arange(8)) == 1
    assert r.affinity_candidates == 0


# ---------------------------------------------------------------------------
# PrefixCache.probe — the router's non-mutating placement signal
# ---------------------------------------------------------------------------

def test_prefix_probe_counts_without_mutating():
    cache = PrefixCache(page_size=4)
    prompt = np.arange(10, dtype=np.int32)
    keys = page_keys(prompt, 4)
    cache.register(keys[0], 7)
    cache.register(keys[1], 8)
    other = np.arange(100, 108, dtype=np.int32)
    cache.register(page_keys(other, 4)[0], 9)
    order = list(cache._entries)
    assert cache.probe(prompt) == 2         # 2 leading pages held, tail not
    assert cache.probe(other) == 1
    assert cache.probe(np.arange(50, 60)) == 0
    # no LRU touch, no counters — unlike lookup()
    assert list(cache._entries) == order
    assert (cache.lookups, cache.hits) == (0, 0)
    cache.lookup(prompt)
    assert cache.lookups == 3 and cache.hits == 2
    assert list(cache._entries) != order    # lookup DOES touch


# ---------------------------------------------------------------------------
# Multi-device subprocess tests (8 fake CPU devices; the main process must
# keep the single real device, so these fork like test_distributed does)
# ---------------------------------------------------------------------------

_SUBPROC_PRELUDE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, json
import jax.numpy as jnp
import numpy as np
"""

_SERVE_SETUP = """
import dataclasses
from repro.configs import get_config
from repro.models import LM
from repro.models.layers import pack_params
from repro.serving.engine import ContinuousScheduler
from repro.distributed import tp as tp_lib

cfg = get_config("ternary-paper", reduced=True)
cfg = dataclasses.replace(cfg, ternary_min_dim=64)
model = LM(cfg)
params = model.init(jax.random.PRNGKey(0))
packed = pack_params(params, cfg)
pcfg = dataclasses.replace(cfg, quantization="ternary_packed")
rng = np.random.default_rng(0)
"""

SUBPROC_TIMEOUT = int(os.environ.get("REPRO_TEST_SUBPROC_TIMEOUT", "900"))


def _run_sub(code: str) -> dict:
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    try:
        out = subprocess.run([sys.executable, "-c", _SUBPROC_PRELUDE + code],
                             capture_output=True, text=True,
                             timeout=SUBPROC_TIMEOUT, env=env)
    except subprocess.TimeoutExpired:
        pytest.skip(f"model-compile subprocess exceeded {SUBPROC_TIMEOUT}s "
                    "on this machine")
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.slow
def test_tp_serving_token_exact_dense_and_paged():
    """A tp=4 engine produces bitwise-identical tokens to the single-device
    engine, dense and paged, and reports its collective plans."""
    res = _run_sub(_SERVE_SETUP + """
prompts = [rng.integers(1, cfg.vocab_size, size=12).astype(np.int32)
           for _ in range(4)]

def serve(mesh, cache):
    eng = ContinuousScheduler(pcfg, 2, 32, cache=cache, mesh=mesh)
    eng.load(packed)
    reqs = [eng.submit(p, 6) for p in prompts]
    metrics = eng.run()
    return [[int(t) for t in r.tokens] for r in reqs], metrics

out = {}
mesh = tp_lib.replica_meshes(1, 4)[0]
for cache in ("dense", "paged"):
    ref, _ = serve(None, cache)
    got, m = serve(mesh, cache)
    assert all(len(t) == 6 for t in ref)
    out[cache] = {"exact": got == ref, "mesh": m["mesh"]}
print(json.dumps(out))
""")
    for cache in ("dense", "paged"):
        assert res[cache]["exact"], f"{cache}: tp=4 tokens diverged"
        assert res[cache]["mesh"]["tp"] == 4
        assert res[cache]["mesh"]["axes"] == {"model": 4}
        assert res[cache]["mesh"]["collective_plans"] > 0


@pytest.mark.slow
def test_tp_spec_serving_token_exact():
    """Speculative decoding under tp=4: draft replicated, target sharded,
    tokens still exact vs the single-device spec engine."""
    res = _run_sub(_SERVE_SETUP + """
from repro.spec.draft import SpecConfig
prompts = [rng.integers(1, cfg.vocab_size, size=12).astype(np.int32)
           for _ in range(3)]
spec = SpecConfig(draft="resparsify", k=2)

def serve(mesh, cache):
    eng = ContinuousScheduler(pcfg, 2, 32, cache=cache, spec=spec,
                              mesh=mesh)
    eng.load(packed)
    reqs = [eng.submit(p, 6) for p in prompts]
    eng.run()
    return [[int(t) for t in r.tokens] for r in reqs]

mesh = tp_lib.replica_meshes(1, 4)[0]
out = {cache: serve(None, cache) == serve(mesh, cache)
       for cache in ("dense", "paged")}
print(json.dumps(out))
""")
    assert res["dense"], "spec dense: tp=4 tokens diverged"
    assert res["paged"], "spec paged: tp=4 tokens diverged"


@pytest.mark.slow
def test_router_dp2_tp4_affinity_and_exactness():
    """2 replicas x tp=4: warm each replica with a distinct prefix, then
    route 10 repeated-prefix requests — every one should land on the
    replica holding its prefix pages (affinity rate 1.0 >= the 0.8 gate)
    and every token must match the single-device reference."""
    res = _run_sub(_SERVE_SETUP + """
from repro.distributed.router import Router

def make_prompt(prefix, seed):
    tail = np.random.default_rng(seed).integers(
        1, cfg.vocab_size, size=4).astype(np.int32)
    return np.concatenate([prefix, tail])

pa = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
pb = rng.integers(1, cfg.vocab_size, size=8).astype(np.int32)
warm = [make_prompt(pa, 100), make_prompt(pb, 101)]
hot = [make_prompt(pa if i % 2 == 0 else pb, i) for i in range(10)]

def build(mesh):
    eng = ContinuousScheduler(pcfg, 2, 32, cache="paged", page_size=4,
                              mesh=mesh)
    eng.load(packed)
    return eng

meshes = tp_lib.replica_meshes(2, 4)
router = Router([build(m) for m in meshes])
warm_reqs = [router.submit(p, 6) for p in warm]
router.run()                                  # registers prefix pages
hot_reqs = [router.submit(p, 6) for p in hot]
metrics = router.run()

ref = build(None)
ref_reqs = [ref.submit(p, 6) for p in warm + hot]
ref.run()

got = [[int(t) for t in r.tokens] for r in warm_reqs + hot_reqs]
want = [[int(t) for t in r.tokens] for r in ref_reqs]
print(json.dumps({
    "exact": got == want,
    "affinity": metrics["affinity"],
    "spills": metrics["spills"],
    "placements": metrics["placements"],
    "drained": [r["drained"] for r in metrics["per_replica"]],
    "meshes": [r["mesh"] for r in metrics["per_replica"]],
}))
""")
    assert res["exact"], "routed tokens diverged from single-device"
    assert res["affinity"]["candidates"] == 10
    assert res["affinity"]["rate"] >= 0.8
    assert res["spills"] == 0
    assert sorted(res["drained"]) == [6, 6]   # both replicas worked
    assert all(m == {"axes": {"model": 4}} for m in res["meshes"])
