"""Checkpoint save/restore: roundtrip, latest-step discovery, atomicity,
dtype restoration, and mesh-agnostic restore targets."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _state():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "opt": {"m": {"w": jnp.zeros((3, 4)), "b": jnp.zeros((4,))},
                "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    state = _state()
    path = ckpt.save(str(tmp_path), 7, state)
    assert os.path.isdir(path)
    step, restored = ckpt.restore(str(tmp_path), target=jax.eval_shape(_state))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_step(tmp_path):
    assert ckpt.latest_step(str(tmp_path)) is None
    for s in (5, 20, 10):
        ckpt.save(str(tmp_path), s, {"x": jnp.zeros(())})
    assert ckpt.latest_step(str(tmp_path)) == 20


def test_restore_specific_step(tmp_path):
    for s in (1, 2):
        ckpt.save(str(tmp_path), s, {"x": jnp.asarray(float(s))})
    step, st = ckpt.restore(str(tmp_path), step=1,
                            target={"x": jnp.zeros(())})
    assert step == 1 and float(st["x"]) == 1.0


def test_missing_leaf_raises(tmp_path):
    ckpt.save(str(tmp_path), 0, {"x": jnp.zeros(())})
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), target={"x": jnp.zeros(()),
                                            "y": jnp.zeros(())})


def test_no_torn_checkpoints(tmp_path):
    """tmp dirs from interrupted saves are not picked up as checkpoints."""
    os.makedirs(tmp_path / ".tmp_abc")
    ckpt.save(str(tmp_path), 3, {"x": jnp.zeros(())})
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_overwrite_same_step(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.asarray(1.0)})
    ckpt.save(str(tmp_path), 1, {"x": jnp.asarray(2.0)})
    _, st = ckpt.restore(str(tmp_path), target={"x": jnp.zeros(())})
    assert float(st["x"]) == 2.0
