"""Checkpoint save/restore: roundtrip, latest-step discovery, atomicity,
dtype restoration, and mesh-agnostic restore targets."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt


def _state():
    return {
        "params": {"w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
                   "b": jnp.ones((4,), jnp.bfloat16)},
        "opt": {"m": {"w": jnp.zeros((3, 4)), "b": jnp.zeros((4,))},
                "step": jnp.asarray(7, jnp.int32)},
    }


def test_roundtrip(tmp_path):
    state = _state()
    path = ckpt.save(str(tmp_path), 7, state)
    assert os.path.isdir(path)
    step, restored = ckpt.restore(str(tmp_path), target=jax.eval_shape(_state))
    assert step == 7
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
        assert a.dtype == b.dtype


def test_latest_step(tmp_path):
    assert ckpt.latest_step(str(tmp_path)) is None
    for s in (5, 20, 10):
        ckpt.save(str(tmp_path), s, {"x": jnp.zeros(())})
    assert ckpt.latest_step(str(tmp_path)) == 20


def test_restore_specific_step(tmp_path):
    for s in (1, 2):
        ckpt.save(str(tmp_path), s, {"x": jnp.asarray(float(s))})
    step, st = ckpt.restore(str(tmp_path), step=1,
                            target={"x": jnp.zeros(())})
    assert step == 1 and float(st["x"]) == 1.0


def test_missing_leaf_raises(tmp_path):
    ckpt.save(str(tmp_path), 0, {"x": jnp.zeros(())})
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), target={"x": jnp.zeros(()),
                                            "y": jnp.zeros(())})


def test_no_torn_checkpoints(tmp_path):
    """tmp dirs from interrupted saves are not picked up as checkpoints."""
    os.makedirs(tmp_path / ".tmp_abc")
    ckpt.save(str(tmp_path), 3, {"x": jnp.zeros(())})
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_overwrite_same_step(tmp_path):
    ckpt.save(str(tmp_path), 1, {"x": jnp.asarray(1.0)})
    ckpt.save(str(tmp_path), 1, {"x": jnp.asarray(2.0)})
    _, st = ckpt.restore(str(tmp_path), target={"x": jnp.zeros(())})
    assert float(st["x"]) == 2.0


def _corrupt_leaf(tmp_path, step, key, value):
    """Rewrite one stored array behind the manifest's back (np.savez stores
    uncompressed, so this is exactly silent on-disk corruption: shapes and
    dtypes still match, only the bytes changed)."""
    path = os.path.join(str(tmp_path), f"step_{step:08d}", "state.npz")
    data = dict(np.load(path).items())
    data[key] = value
    np.savez(path, **data)


def test_restore_detects_corruption(tmp_path):
    """A flipped array fails restore with the file and leaf named."""
    ckpt.save(str(tmp_path), 3, _state())
    _corrupt_leaf(tmp_path, 3, "params|w",
                  np.full((3, 4), 99.0, np.float32))
    with pytest.raises(ckpt.CheckpointCorruptError) as ei:
        ckpt.restore(str(tmp_path), target=jax.eval_shape(_state))
    assert "state.npz" in str(ei.value) and "params/w" in str(ei.value)
    assert ei.value.key == "params/w"


def test_corruption_detected_on_nonfloat_and_bf16_leaves(tmp_path):
    """Checksums cover the stored (viewed) bytes, so int and bfloat16
    leaves are protected too."""
    ckpt.save(str(tmp_path), 1, _state())
    _corrupt_leaf(tmp_path, 1, "opt|step", np.asarray(8, np.int32))
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.restore(str(tmp_path), step=1)
    ckpt.save(str(tmp_path), 2, _state())
    _corrupt_leaf(tmp_path, 2, "params|b",
                  np.zeros((4,), np.uint16))      # stored view of bf16
    with pytest.raises(ckpt.CheckpointCorruptError):
        ckpt.restore(str(tmp_path), step=2)


def test_latest_step_verify_skips_corrupt(tmp_path):
    """verify=True returns the newest *intact* step; plain latest_step
    keeps its cheap no-IO behavior."""
    for s in (5, 10, 20):
        ckpt.save(str(tmp_path), s, _state())
    _corrupt_leaf(tmp_path, 20, "params|w", np.zeros((3, 4), np.float32))
    assert ckpt.latest_step(str(tmp_path)) == 20
    assert ckpt.latest_step(str(tmp_path), verify=True) == 10
    os.remove(os.path.join(str(tmp_path), "step_00000010", "manifest.json"))
    assert ckpt.latest_step(str(tmp_path), verify=True) == 5


def test_clean_checkpoints_verify(tmp_path):
    ckpt.save(str(tmp_path), 4, _state())
    assert ckpt.latest_step(str(tmp_path), verify=True) == 4
    step, _ = ckpt.restore(str(tmp_path), target=jax.eval_shape(_state))
    assert step == 4


def test_pre_checksum_checkpoints_still_restore(tmp_path):
    """Manifests without crc32 fields (older saves) restore and verify
    without complaint — missing checksum means unverifiable, not corrupt."""
    import json
    ckpt.save(str(tmp_path), 2, {"x": jnp.asarray(3.0)})
    man = os.path.join(str(tmp_path), "step_00000002", "manifest.json")
    with open(man) as f:
        m = json.load(f)
    for leaf in m["leaves"].values():
        leaf.pop("crc32", None)
    with open(man, "w") as f:
        json.dump(m, f)
    assert ckpt.latest_step(str(tmp_path), verify=True) == 2
    _, st = ckpt.restore(str(tmp_path), target={"x": jnp.zeros(())})
    assert float(st["x"]) == 3.0
