"""Sparsity-adaptive path: TiledTernary occupancy metadata, the
scalar-prefetch tile-skipping kernel (interpret-mode parity + bit-exactness
vs the dense-decode kernel), the plane-factorized bitplane path, the
dispatcher, and the block-shape autotuner's JSON cache."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import formats, weights
from repro.kernels import ops, ref
from repro.kernels.autotune import Autotuner, BlockConfig, cache_key

SPARSITIES = [0.5, 0.25, 0.125, 0.0625]


def _tile_setup(m, k, n, s, tile_k=32, tile_n=16, seed=0):
    rng = np.random.default_rng(seed)
    kp = -(-k // tile_k) * tile_k
    npad = -(-n // tile_n) * tile_n
    w = formats.random_tile_ternary(rng, kp, npad, tile_k, tile_n, s)[:k, :n]
    tt = weights.pack(w, "tiled", tile_k=tile_k, tile_n=tile_n)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    return x, w, tt


# ---------------------------------------------------------------------------
# TiledTernary metadata
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", SPARSITIES)
@pytest.mark.parametrize("k,n", [(128, 64), (96, 40), (200, 33)])
def test_tiled_occupancy_matches_count_nonzero(k, n, s):
    _, w, _ = _tile_setup(4, k, n, s)
    tt = formats.TiledTernary.from_dense(w, tile_k=32, tile_n=16)
    kp = tt.n_ktiles * tt.tile_k
    npad = tt.n_ntiles * tt.tile_n
    wp = np.zeros((kp, npad), np.int8)
    wp[:k, :n] = w
    for kt in range(tt.n_ktiles):
        for nt in range(tt.n_ntiles):
            tile = wp[kt * tt.tile_k:(kt + 1) * tt.tile_k,
                      nt * tt.tile_n:(nt + 1) * tt.tile_n]
            assert tt.tile_nnz[kt, nt] == np.count_nonzero(tile)
    # kt_indices prefix = sorted occupied ids; padding points at empty tiles
    occ = tt.occupancy()
    for j in range(tt.n_ntiles):
        cnt = int(tt.kt_counts[j])
        np.testing.assert_array_equal(tt.kt_indices[j, :cnt],
                                      np.nonzero(occ[:, j])[0])
        for pad_id in tt.kt_indices[j, cnt:]:
            assert not occ[pad_id, j] or cnt == tt.n_ktiles
    assert (tt.to_dense() == w).all()


def test_tiled_roundtrip_and_counts():
    _, w, wc = _tile_setup(4, 96, 48, 0.25)
    tt = formats.TiledTernary.from_dense(w, tile_k=32, tile_n=16)
    assert tt.occupied_tiles() == int((tt.tile_nnz > 0).sum())
    assert tt.total_tiles() == tt.n_ktiles * tt.n_ntiles
    assert 0.0 < tt.occupancy_fraction() <= 1.0
    assert tt.visited_tiles() >= tt.occupied_tiles() // tt.n_ntiles
    # the container wrapper mirrors the raw format's static geometry
    assert wc.occupied_tiles == tt.occupied_tiles()
    assert wc.total_tiles() == tt.total_tiles()
    assert wc.occupancy() == tt.occupancy_fraction()
    assert wc.visited_tiles() == tt.visited_tiles()
    assert (np.asarray(wc.materialize(jnp.int8)) == w).all()


# ---------------------------------------------------------------------------
# Skipping kernel parity (interpret mode)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("s", SPARSITIES)
@pytest.mark.parametrize("m,k,n", [(8, 128, 64), (12, 96, 40), (32, 256, 96)])
def test_skip_kernel_matches_reference(m, k, n, s):
    x, w, tt = _tile_setup(m, k, n, s)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w))
    y = ops.ternary_gemm(x, tt, impl="skip")
    assert y.shape == (m, n)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("s", SPARSITIES)
def test_skip_kernel_bit_exact_vs_dense(s):
    """Same accumulation order and f32 arithmetic -> identical bits: the
    skipped tiles are exactly the ones that contribute f32 zeros densely."""
    m, k, n = 16, 256, 64
    x, w, tt = _tile_setup(m, k, n, s, tile_k=64, tile_n=32, seed=7)
    y_skip = ops.ternary_gemm(x, tt, impl="skip")
    y_dense = ops.ternary_gemm(x, tt, block_n=32, block_k=64, impl="dense")
    assert np.array_equal(np.asarray(y_skip), np.asarray(y_dense))


def test_skip_kernel_epilogue_and_empty_columns():
    m, k, n = 8, 128, 64
    rng = np.random.default_rng(5)
    w = formats.random_tile_ternary(rng, k, n, 32, 16, 0.25)
    w[:, 16:32] = 0                       # a fully-empty N-tile column
    tt = weights.pack(w, "tiled", tile_k=32, tile_n=16)
    assert int(tt.kt_counts[1]) == 0
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    alpha = jnp.asarray(rng.standard_normal(n) ** 2 + 0.1, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(n), jnp.float32)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w), alpha, bias,
                                  prelu_alpha=0.25)
    y = ops.ternary_gemm(x, tt, alpha, bias, fuse_prelu=True, impl="skip")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_skip_kernel_grad():
    m, k, n = 8, 96, 48
    x, w, tt = _tile_setup(m, k, n, 0.25, seed=9)
    g = jax.grad(lambda xx: jnp.sum(ops.ternary_gemm(xx, tt) ** 2))(x)
    g0 = jax.grad(lambda xx: jnp.sum(
        ref.ternary_matmul_dense(xx, jnp.asarray(w)) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0),
                               rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Dispatcher
# ---------------------------------------------------------------------------

def test_planner_auto_picks_skip_for_sparse():
    _, _, tt = _tile_setup(4, 128, 64, 0.0625)
    # the double-buffered variant outranks plain skip under auto dispatch
    assert ops.ternary_gemm_plan(tt, 4).impl == "skip_db"
    assert ops.ternary_gemm_plan(tt, 4, impl="skip").impl == "skip"
    dense_w = formats.random_ternary(np.random.default_rng(0), 64, 32, 0.5)
    tt_dense = weights.pack(dense_w, "tiled", tile_k=16, tile_n=16)
    # unstructured 1/2-sparse weights occupy every tile -> dense fallback
    assert tt_dense.occupancy() == 1.0
    assert ops.ternary_gemm_plan(tt_dense, 4).impl == "dense"
    assert ops.ternary_gemm_plan(
        weights.Dense2Bit.from_packed(jnp.zeros((4, 8), jnp.uint32), k=64),
        4).impl == "dense"
    # dense fallback on a tiled operand still computes correctly
    x = jnp.asarray(np.random.default_rng(1).standard_normal((4, 64)),
                    jnp.float32)
    y = ops.ternary_gemm(x, tt_dense)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(dense_w))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_dispatcher_bitplane_paths():
    m, k, n = 8, 128, 64
    rng = np.random.default_rng(11)
    w = formats.random_ternary(rng, k, n, 0.25)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    planes = weights.pack(w, "bitplane")
    alpha = jnp.asarray(rng.standard_normal(n) ** 2 + 0.1, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(n), jnp.float32)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w), alpha, bias,
                                  prelu_alpha=0.25)
    assert ops.ternary_gemm_plan(planes, m).impl == "bitplane"
    for impl in ("bitplane", "bitplane_factorized"):
        y = ops.ternary_gemm(x, planes, alpha, bias, fuse_prelu=True,
                             impl=impl)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                                   rtol=1e-4, atol=1e-4, err_msg=impl)
    g = jax.grad(lambda xx: jnp.sum(
        ops.ternary_gemm(xx, planes, impl="bitplane_factorized") ** 2))(x)
    g0 = jax.grad(lambda xx: jnp.sum(
        ref.ternary_matmul_dense(xx, jnp.asarray(w)) ** 2))(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g0),
                               rtol=1e-3, atol=1e-3)


def test_dispatcher_ref_impl():
    m, k, n = 4, 64, 32
    rng = np.random.default_rng(12)
    w = formats.random_ternary(rng, k, n, 0.25)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    packed = weights.pack(w, "dense2bit")
    y = ops.ternary_gemm(x, packed, impl="ref")
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------

def test_autotune_cache_json_roundtrip(tmp_path):
    path = str(tmp_path / "cache.json")
    tuner = Autotuner(path=path, mode="model")
    cfg = tuner.lookup(256, 4096, 4096, sparsity=0.25)
    assert isinstance(cfg, BlockConfig)
    assert cfg.vmem_bytes() < 16 * 2**20
    # second tuner instance reads the same pick from disk
    reloaded = Autotuner(path=path, mode="model")
    key = cache_key(256, 4096, 4096, 0.25)
    assert reloaded.entries()[key] == cfg
    assert reloaded.lookup(256, 4096, 4096, sparsity=0.25) == cfg
    # and the pick is deterministic in model mode
    assert Autotuner(path=str(tmp_path / "other.json"),
                     mode="model").lookup(256, 4096, 4096,
                                          sparsity=0.25) == cfg


def test_dense_fallback_with_large_pack_tile():
    """Regression: a TiledTernary packed with tile_k larger than the
    resolved dense block_k must still route through the dense kernel (x is
    padded to the pack's K, not just a block_k multiple)."""
    m, k, n = 8, 200, 64
    rng = np.random.default_rng(21)
    w = formats.random_ternary(rng, k, n, 0.5)       # occupancy 1.0 -> dense
    tt = weights.pack(w, "tiled", tile_k=512, tile_n=32)
    assert ops.ternary_gemm_plan(tt, m).impl == "dense"
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    y = ops.ternary_gemm(x, tt, block_m=8, block_n=32, block_k=64)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_autotune_key_includes_fixed_tiles(tmp_path):
    """Regression: two packs of the same logical shape with different tile
    shapes get distinct cache entries (no per-call re-tune/rewrite thrash)."""
    assert cache_key(64, 2048, 2048, 0.125, "skip", fixed_n=128,
                     fixed_k=256) != cache_key(64, 2048, 2048, 0.125, "skip",
                                               fixed_n=32, fixed_k=256)
    tuner = Autotuner(path=str(tmp_path / "c.json"), mode="model")
    a = tuner.lookup(64, 2048, 2048, 0.125, "skip", fixed_n=128, fixed_k=256)
    b = tuner.lookup(64, 2048, 2048, 0.125, "skip", fixed_n=32, fixed_k=256)
    assert a.block_n == 128 and b.block_n == 32
    assert len(tuner.entries()) == 2
    # both survive alternating lookups (cache hits, no overwrite)
    assert tuner.lookup(64, 2048, 2048, 0.125, "skip",
                        fixed_n=128, fixed_k=256) == a
    assert tuner.lookup(64, 2048, 2048, 0.125, "skip",
                        fixed_n=32, fixed_k=256) == b


def test_autotune_key_bucketing():
    assert cache_key(100, 1024, 1024) == cache_key(128, 1024, 1024)
    assert cache_key(8, 1024, 1024) != cache_key(128, 1024, 1024)
    assert cache_key(8, 1024, 1024, 0.24) == cache_key(8, 1024, 1024, 0.25)
    assert cache_key(8, 1024, 1024, 0.25, "skip") != \
        cache_key(8, 1024, 1024, 0.25, "dense")


def test_autotune_respects_fixed_tile_shapes(tmp_path):
    tuner = Autotuner(path=str(tmp_path / "c.json"), mode="model")
    cfg = tuner.lookup(64, 2048, 2048, sparsity=0.125, impl="skip",
                       fixed_n=128, fixed_k=256)
    assert cfg.block_n == 128 and cfg.block_k == 256


def test_autotuned_blocks_give_same_numerics():
    """Dispatcher with blocks=None (autotuned) agrees with explicit blocks."""
    m, k, n = 16, 128, 64
    rng = np.random.default_rng(13)
    w = formats.random_ternary(rng, k, n, 0.25)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    packed = weights.pack(w, "dense2bit")
    y_auto = ops.ternary_gemm(x, packed)
    y_explicit = ops.ternary_gemm(x, packed, block_m=8, block_n=32,
                                  block_k=32)
    np.testing.assert_allclose(np.asarray(y_auto), np.asarray(y_explicit),
                               rtol=1e-5, atol=1e-5)
