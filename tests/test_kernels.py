"""Pallas ternary GEMM kernel vs the pure-jnp oracle: shape/dtype/sparsity
sweeps in interpret mode, fused epilogue, custom VJP, and agreement of every
reference algorithm variant (the paper's TCSC family)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro.core import formats, weights
from repro.kernels import ops, ref
from repro.kernels.autotune import BlockConfig


def _setup(m, k, n, s, dtype=jnp.float32, seed=0):
    rng = np.random.default_rng(seed)
    w = formats.random_ternary(rng, k, n, s)
    x = jnp.asarray(rng.standard_normal((m, k)), dtype)
    packed = weights.pack(w, "dense2bit")
    return x, w, packed


@pytest.mark.parametrize("s", [0.5, 0.25, 0.125, 0.0625])
@pytest.mark.parametrize("m,k,n", [(8, 128, 64), (12, 96, 40), (128, 512, 256)])
def test_kernel_matches_oracle(m, k, n, s):
    x, w, packed = _setup(m, k, n, s)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w))
    y = ops.ternary_gemm(x, packed, block_n=64, block_k=64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_dtypes(dtype):
    x, w, packed = _setup(16, 256, 128, 0.25, dtype)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w))
    y = ops.ternary_gemm(x, packed, block_n=128, block_k=128)
    assert y.dtype == dtype
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(y0, np.float32), rtol=tol, atol=tol)


@pytest.mark.parametrize("block_m,block_n,block_k",
                         [(8, 32, 32), (64, 128, 256), (128, 64, 512)])
def test_kernel_block_shapes(block_m, block_n, block_k):
    """The TPU analogue of the paper's unroll-factor sweep: every BlockSpec
    shape must give identical results."""
    x, w, packed = _setup(32, 512, 128, 0.25)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w))
    y = ops.ternary_gemm(x, packed, block_m=block_m,
                         block_n=block_n, block_k=block_k)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_kernel_fused_epilogue():
    x, w, packed = _setup(16, 128, 96, 0.5)
    rng = np.random.default_rng(1)
    alpha = jnp.asarray(rng.standard_normal(96) ** 2, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(96), jnp.float32)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w), alpha, bias,
                                  prelu_alpha=0.25)
    y = ops.ternary_gemm(x, packed, alpha, bias, block_n=32,
                         block_k=64, fuse_prelu=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_kernel_vjp():
    x, w, packed = _setup(8, 64, 48, 0.5)
    alpha = jnp.ones((48,), jnp.float32) * 2.0
    bias = jnp.zeros((48,), jnp.float32)

    def f(xx):
        return jnp.sum(ops.ternary_gemm(xx, packed, alpha, bias,
                                        block_n=16, block_k=32) ** 2)

    def f_ref(xx):
        return jnp.sum(ref.ternary_matmul_dense(xx, jnp.asarray(w), alpha,
                                                bias) ** 2)

    g = jax.grad(f)(x)
    g_ref = jax.grad(f_ref)(x)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("use_scale", [False, True])
@pytest.mark.parametrize("use_bias", [False, True])
def test_kernel_vjp_scale_bias_combos(use_scale, use_bias):
    """All four scale/bias presence combinations must differentiate
    correctly — the bias grad must exist iff a bias operand exists (the
    old _bwd keyed the bias grad off scale's presence)."""
    x, w, packed = _setup(8, 64, 48, 0.5, seed=3)
    rng = np.random.default_rng(4)
    alpha = jnp.asarray(rng.standard_normal(48) ** 2 + 0.1, jnp.float32) \
        if use_scale else None
    bias = jnp.asarray(rng.standard_normal(48), jnp.float32) \
        if use_bias else None

    def f(xx):
        return jnp.sum(ops.ternary_gemm(xx, packed, alpha, bias,
                                        block_n=16, block_k=32) ** 2)

    def f_ref(xx):
        return jnp.sum(ref.ternary_matmul_dense(xx, jnp.asarray(w), alpha,
                                                bias) ** 2)

    np.testing.assert_allclose(np.asarray(jax.grad(f)(x)),
                               np.asarray(jax.grad(f_ref)(x)),
                               rtol=1e-3, atol=1e-3)
    if use_scale:
        gs = jax.grad(lambda a: jnp.sum(
            ops.ternary_gemm(x, packed, a, bias, block_n=16,
                             block_k=32) ** 2))(alpha)
        gs_ref = jax.grad(lambda a: jnp.sum(
            ref.ternary_matmul_dense(x, jnp.asarray(w), a, bias) ** 2))(alpha)
        np.testing.assert_allclose(np.asarray(gs), np.asarray(gs_ref),
                                   rtol=1e-3, atol=1e-3)
    if use_bias:
        gb = jax.grad(lambda b: jnp.sum(
            ops.ternary_gemm(x, packed, alpha, b, block_n=16,
                             block_k=32) ** 2))(bias)
        gb_ref = jax.grad(lambda b: jnp.sum(
            ref.ternary_matmul_dense(x, jnp.asarray(w), alpha, b) ** 2))(bias)
        np.testing.assert_allclose(np.asarray(gb), np.asarray(gb_ref),
                                   rtol=1e-3, atol=1e-3)


def test_all_reference_variants_agree():
    """BaseTCSC / Blocked / Interleaved / bitplane / 2-bit / base-3 all
    compute the same Y (the paper's Table of variants)."""
    rng = np.random.default_rng(2)
    m, k, n, s = 16, 160, 48, 0.25
    w = formats.random_ternary(rng, k, n, s)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    alpha = jnp.asarray(rng.standard_normal(n) ** 2, jnp.float32)
    bias = jnp.asarray(rng.standard_normal(n), jnp.float32)
    y0 = np.asarray(ref.ternary_matmul_dense(x, jnp.asarray(w), alpha, bias))
    p, mneg = formats.pack_bitplanes(w)
    variants = {
        "tcsc": ref.tcsc_matmul(x, formats.TCSC.from_dense(w), alpha, bias),
        "blocked": ref.tcsc_matmul_blocked(
            x, formats.BlockedTCSC.from_dense(w, 64), alpha, bias),
        "interleaved": ref.tcsc_matmul_interleaved(
            x, formats.InterleavedTCSC.from_dense(w, 2), alpha, bias),
        "packed2bit": ref.packed2bit_matmul(
            x, jnp.asarray(formats.pack_2bit(w)), k, alpha, bias),
        "bitplane": ref.bitplane_matmul(
            x, jnp.asarray(p), jnp.asarray(mneg), k, alpha, bias),
        "base3": ref.base3_matmul(
            x, jnp.asarray(formats.pack_base3(w)), k, alpha, bias),
    }
    for name, y in variants.items():
        np.testing.assert_allclose(np.asarray(y), y0, rtol=1e-4, atol=1e-4,
                                   err_msg=name)


@settings(max_examples=10, deadline=None)
@given(m=st.integers(1, 40), k=st.integers(16, 200), n=st.integers(1, 90),
       s=st.sampled_from([0.5, 0.25, 0.0625]), seed=st.integers(0, 10**6))
def test_kernel_property_random_shapes(m, k, n, s, seed):
    """Property: the kernel handles arbitrary (unaligned) shapes via padding."""
    x, w, packed = _setup(m, k, n, s, seed=seed)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w))
    y = ops.ternary_gemm(x, packed, block_n=32, block_k=32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y0),
                               rtol=1e-4, atol=1e-4)


def test_vmem_budget():
    """BlockSpec working set must fit VMEM (16 MB v5e) for default blocks."""
    cfg = BlockConfig(128, 128, 512)
    assert cfg.vmem_bytes() < 16 * 2**20
