"""Paged KV-cache subsystem tests (DESIGN.md §9).

Pins the three correctness contracts:

1. the Pallas paged decode-attention kernel is **bit-exact** vs its
   pure-JAX reference across page-size / window / GQA / kv-dtype variants;
2. the ``jax`` lowering (the engine's off-TPU path) is **bit-identical**
   to ``models.attention.naive_attention`` on the gathered cache — the
   foundation of the paged-vs-dense token-exactness guarantee;
3. the same request stream through ``cache="dense"`` and ``cache="paged"``
   produces **identical tokens**, including page evict→reuse churn,
   shared-prefix admissions with copy-on-write, and OOM-pressure
   defer/preempt recovery.

Plus host-side unit tests for PagePool/PrefixCache/SlotPool bookkeeping.
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.kernels import ops as kops
from repro.launch import serve
from repro.models import LM, attention
from repro.paging import Int8Pages, PagePool, PrefixCache, page_keys
from repro.paging.kernels import (paged_decode_attention_jax,
                                  paged_decode_attention_pallas,
                                  paged_decode_attention_ref)
from repro.serving import ContinuousScheduler, SlotPool


def _cfg(**overrides):
    return get_config("ternary-paper", reduced=True, num_layers=2,
                      **overrides)


def _workload(cfg, n, prompt_len=16, seed=0, lens=(2, 9)):
    rng = np.random.default_rng(seed)
    prompts = rng.integers(0, cfg.vocab_size,
                           size=(n, prompt_len)).astype(np.int32)
    gens = [int(g) for g in rng.integers(lens[0], lens[1], size=n)]
    return prompts, gens


def _run_engine(cfg, params, prompts, gens, **engine_kw):
    eng = ContinuousScheduler(cfg, **engine_kw)
    eng.load(params)
    reqs = [eng.submit(p, g) for p, g in zip(prompts, gens)]
    metrics = eng.run()
    return [list(r.tokens) for r in reqs], metrics


# ---------------------------------------------------------------------------
# Kernel-level exactness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("heads,kv_heads", [(4, 2), (4, 4)])
@pytest.mark.parametrize("page_size", [4, 8])
@pytest.mark.parametrize("window", [0, 5])
@pytest.mark.parametrize("kv_dtype", ["f32", "int8"])
def test_paged_kernel_bitexact_vs_ref(heads, kv_heads, page_size, window,
                                      kv_dtype):
    """Pallas kernel (interpret off-TPU) == pure-JAX reference, bitwise,
    with garbage padding entries in the block table masked by lengths."""
    rng = np.random.default_rng(0)
    b, p, t, hd = 3, 10, 4, 16
    q = jnp.asarray(rng.standard_normal((b, heads, hd)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((p, page_size, kv_heads, hd)),
                     jnp.float32)
    vp = jnp.asarray(rng.standard_normal((p, page_size, kv_heads, hd)),
                     jnp.float32)
    if kv_dtype == "int8":
        kp, vp = Int8Pages.quantize(kp), Int8Pages.quantize(vp)
    table = jnp.asarray(rng.integers(0, p, size=(b, t)), jnp.int32)
    lengths = jnp.asarray(rng.integers(1, t * page_size + 1, size=(b,)),
                          jnp.int32)
    out_kernel = paged_decode_attention_pallas(q, kp, vp, table, lengths,
                                               window=window)
    out_ref = paged_decode_attention_ref(q, kp, vp, table, lengths,
                                         window=window)
    np.testing.assert_array_equal(np.asarray(out_kernel),
                                  np.asarray(out_ref))
    # and the ref agrees with the batched jax lowering numerically
    out_jax = paged_decode_attention_jax(q, kp, vp, table, lengths,
                                         window=window)
    np.testing.assert_allclose(np.asarray(out_jax), np.asarray(out_ref),
                               rtol=2e-5, atol=2e-5)


def test_paged_jax_impl_bitexact_vs_naive():
    """The engine's paged attention must be line-identical math to the
    dense decode path: gather + ``naive_attention`` == the jax lowering,
    bitwise (this is what makes paged serving token-exact vs dense)."""
    rng = np.random.default_rng(1)
    b, p, t, ps, h, kv, hd = 2, 6, 3, 8, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, h, hd)), jnp.bfloat16)
    kp = jnp.asarray(rng.standard_normal((p, ps, kv, hd)), jnp.bfloat16)
    vp = jnp.asarray(rng.standard_normal((p, ps, kv, hd)), jnp.bfloat16)
    table = jnp.asarray(rng.integers(0, p, size=(b, t)), jnp.int32)
    lengths = jnp.asarray([10, 20], jnp.int32)
    out = paged_decode_attention_jax(q, kp, vp, table, lengths)
    ks = kp[table].reshape(b, t * ps, kv, hd)
    vs = vp[table].reshape(b, t * ps, kv, hd)
    ref = attention.naive_attention(
        q[:, None], ks, vs, causal=False, window=0,
        q_offset=lengths - 1, kv_valid_len=lengths)[:, 0]
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_paged_attn_registry_dispatch():
    reg = kops.paged_attention_registry()
    assert {"jax", "pallas"} <= set(reg)
    with pytest.raises(ValueError, match="no paged-attention impl"):
        kops.paged_decode_attention(jnp.zeros((1, 2, 4)),
                                    jnp.zeros((2, 2, 1, 4)),
                                    jnp.zeros((2, 2, 1, 4)),
                                    jnp.zeros((1, 1), jnp.int32),
                                    jnp.ones((1,), jnp.int32),
                                    impl="nope")
    # off-TPU, auto must resolve to the dense-bit-identical jax lowering
    if jax.default_backend() != "tpu":
        cands = sorted(reg.values(), key=lambda pi: -pi.priority)
        chosen = next(pi for pi in cands
                      if pi.predicate(None, None, None, None, None))
        assert chosen.impl == "jax"


# ---------------------------------------------------------------------------
# Engine-level token exactness
# ---------------------------------------------------------------------------

def test_paged_vs_dense_token_exact_with_page_churn():
    """Same stream through both cache modes: identical tokens. More
    requests than slots and a pool sized near the working set force
    evict→reuse of pages across requests."""
    cfg = _cfg()
    prompts, gens = _workload(cfg, 8, prompt_len=12, seed=3, lens=(2, 12))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dense, md = _run_engine(cfg, params, prompts, gens,
                            max_slots=3, max_len=32)
    paged, mp = _run_engine(cfg, params, prompts, gens,
                            max_slots=3, max_len=32,
                            cache="paged", page_size=8, n_pages=13)
    assert mp["drained"] == md["drained"] == 8
    for i, (a, b) in enumerate(zip(dense, paged)):
        assert a == b, f"request {i} diverged under paging"
    # pool smaller than total demand -> pages must have been reused
    total_pages_needed = sum(-(-(p.shape[0] + g) // 8)
                             for p, g in zip(prompts, gens))
    assert total_pages_needed > mp["cache"]["pages_total"]


def test_paged_shared_prefix_and_cow_token_exact():
    """A batch sharing a long prompt prefix (and two *identical* prompts,
    which share their partial tail page) must hit the prefix cache, COW on
    first divergence, and stay token-exact vs dense."""
    cfg = _cfg()
    rng = np.random.default_rng(5)
    common = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    tail_a = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    tail_b = rng.integers(0, cfg.vocab_size, size=4).astype(np.int32)
    # 20-token prompts on 8-token pages: 2 full pages shared by everyone,
    # plus a *partial* tail page shared only within each identical pair —
    # the first decode append into a shared tail must copy-on-write
    prompts = np.stack([np.concatenate([common, tail_a]),
                        np.concatenate([common, tail_a]),
                        np.concatenate([common, tail_b]),
                        np.concatenate([common, tail_b])])
    gens = [6, 4, 5, 3]
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(1))
    dense, _ = _run_engine(cfg, params, prompts, gens,
                           max_slots=2, max_len=40)
    paged, mp = _run_engine(cfg, params, prompts, gens,
                            max_slots=2, max_len=40,
                            cache="paged", page_size=8)
    for i, (a, b) in enumerate(zip(dense, paged)):
        assert a == b, f"request {i} diverged under prefix sharing"
    prefix = mp["cache"]["prefix"]
    assert prefix["hits"] > 0 and prefix["hit_rate"] > 0
    assert mp["cache"]["cow_copies"] > 0


def test_paged_oom_defers_preempts_and_stays_exact():
    """A pool far smaller than the workload's working set must defer
    admissions and preempt+replay mid-decode — and still drain everything
    with dense-identical tokens (greedy replay is deterministic)."""
    cfg = _cfg()
    prompts, gens = _workload(cfg, 8, prompt_len=12, seed=2, lens=(6, 21))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dense, _ = _run_engine(cfg, params, prompts, gens,
                           max_slots=4, max_len=36)
    paged, mp = _run_engine(cfg, params, prompts, gens,
                            max_slots=4, max_len=36,
                            cache="paged", page_size=8, n_pages=9)
    assert mp["drained"] == 8
    for i, (a, b) in enumerate(zip(dense, paged)):
        assert a == b, f"request {i} diverged under OOM pressure"
    assert mp["cache"]["deferrals"] > 0 or mp["cache"]["preemptions"] > 0


@pytest.mark.parametrize("arch", ["mamba2-130m"])
def test_paged_cross_family_ssm_rows(arch):
    """Non-attention layers keep dense per-slot rows inside the paged
    tree; an SSM model must stay token-exact through paged mode."""
    cfg = get_config(arch, reduced=True)
    prompts, gens = _workload(cfg, 4, prompt_len=16, seed=0, lens=(2, 8))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    dense, _ = _run_engine(cfg, params, prompts, gens,
                           max_slots=2, max_len=32)
    paged, _ = _run_engine(cfg, params, prompts, gens,
                           max_slots=2, max_len=32,
                           cache="paged", page_size=8)
    for a, b in zip(dense, paged):
        assert a == b


def test_paged_rejects_unsupported_layouts():
    cfg = _cfg(cache_layout="opt")
    with pytest.raises(ValueError, match="bshd"):
        ContinuousScheduler(cfg, max_slots=2, max_len=16, cache="paged")
    cfg = get_config("mixtral-8x22b", reduced=True)   # sliding window
    with pytest.raises(ValueError, match="sliding-window"):
        ContinuousScheduler(cfg, max_slots=2, max_len=16, cache="paged")


# ---------------------------------------------------------------------------
# int8 pages
# ---------------------------------------------------------------------------

def test_int8_quant_roundtrip_and_pytree():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((5, 8, 2, 32)) * 3, jnp.float32)
    pages = Int8Pages.quantize(x)
    back = pages.dequantize(jnp.float32)
    err = np.abs(np.asarray(back) - np.asarray(x)).max()
    scale = np.abs(np.asarray(x)).max()
    assert err <= scale / 127 + 1e-6          # half-ulp of the int8 grid
    assert pages.nbytes < x.nbytes // 2 + pages.scales.nbytes + 1
    # pytree: flatten/unflatten and jit-arg round trips preserve structure
    leaves, treedef = jax.tree_util.tree_flatten(pages)
    assert len(leaves) == 2
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert isinstance(rebuilt, Int8Pages)
    out = jax.jit(lambda p: p.dequantize(jnp.float32))(pages)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(back))
    # zero rows dequantize exactly
    z = Int8Pages.quantize(jnp.zeros((2, 4, 1, 8)))
    assert np.all(np.asarray(z.dequantize()) == 0)


def test_paged_int8_engine_runs_and_halves_cache():
    cfg = _cfg()
    prompts, gens = _workload(cfg, 5, prompt_len=16, seed=1, lens=(2, 6))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _, m16 = _run_engine(cfg, params, prompts, gens, max_slots=2,
                         max_len=24, cache="paged", page_size=8)
    toks8, m8 = _run_engine(cfg, params, prompts, gens, max_slots=2,
                            max_len=24, cache="paged", page_size=8,
                            kv_dtype="int8")
    assert m8["drained"] == 5 and all(len(t) == g
                                      for t, g in zip(toks8, gens))
    assert m8["cache"]["kv_dtype"] == "int8"
    # int8 codes are half of bf16; per-page scale tensors add f32/(KV row)
    assert m8["cache"]["nbytes"] < m16["cache"]["nbytes"]


# ---------------------------------------------------------------------------
# Host-side bookkeeping units
# ---------------------------------------------------------------------------

def test_page_pool_admission_refcounts_and_release():
    cfg = _cfg()
    pool = PagePool(LM(cfg), max_slots=2, max_len=32, page_size=8,
                    n_pages=9)
    assert pool.usable_pages == 8
    prompt = np.arange(20, dtype=np.int32)       # 3 pages
    adm = pool.admit(prompt)
    assert adm is not None and adm.n_shared == 0
    assert len(adm.page_ids) == 3 and 0 not in adm.page_ids  # trash page
    assert pool.pages_used == 3
    # identical prompt: all three pages shared, refcounts bump
    adm2 = pool.admit(prompt)
    assert adm2 is not None and adm2.n_shared == 3
    assert adm2.page_ids == adm.page_ids
    assert pool.pages_used == 3                  # no new allocation
    assert pool.n_free == 0
    assert pool.admit(prompt) is None            # no slot left
    pool.release(adm.slot)
    pool.release(adm2.slot)
    # registered pages stay pinned for future prefix hits
    assert pool.pages_used == 3 and pool.n_free == 2


def test_page_pool_oom_rollback_and_reclaim():
    cfg = _cfg()
    pool = PagePool(LM(cfg), max_slots=4, max_len=32, page_size=8,
                    n_pages=5)                   # 4 usable pages
    a = pool.admit(np.arange(24, dtype=np.int32))          # 3 pages
    assert a is not None
    # 3 pages needed, 1 free -> all-or-nothing failure, state rolled back
    used_before = pool.pages_used
    assert pool.admit(np.arange(100, 124, dtype=np.int32)) is None
    assert pool.pages_used == used_before
    pool.release(a.slot)
    # pinned-but-unreferenced prefix pages are reclaimed under pressure
    b = pool.admit(np.arange(200, 232, dtype=np.int32))    # 4 pages
    assert b is not None and pool.pages_used == 4


def test_page_pool_ensure_append_grows_and_cows():
    cfg = _cfg()
    pool = PagePool(LM(cfg), max_slots=2, max_len=32, page_size=8,
                    n_pages=9)
    prompt = np.arange(12, dtype=np.int32)       # 1 full + 1 partial page
    adm = pool.admit(prompt)
    tail = adm.page_ids[-1]
    # sole owner appends into its registered tail *in place* (no copy —
    # prompt rows stay immutable; appends only touch rows >= prompt tail)
    assert pool.ensure_append(adm.slot, 12)
    assert pool.cow_count == 0
    assert pool.slot_pages[adm.slot][-1] == tail
    # a live sharer makes the tail refcount 2 -> the next append must COW
    adm2 = pool.admit(prompt)
    assert adm2 is not None and adm2.page_ids[-1] == tail
    assert pool.ensure_append(adm2.slot, 12)
    assert pool.cow_count == 1
    assert pool.slot_pages[adm2.slot][-1] != tail
    # crossing into a fresh page allocates
    used = pool.pages_used
    assert pool.ensure_append(adm.slot, 16)
    assert pool.pages_used == used + 1


def test_prefix_cache_chaining_semantics():
    ps = 8
    a = np.arange(20, dtype=np.int32)
    b = np.arange(20, dtype=np.int32)
    b[0] = 99                                             # diverges early
    c = np.arange(24, dtype=np.int32)                     # longer, same head
    keys_a = page_keys(a, ps)
    assert len(keys_a) == 3
    # chained: a divergence in page 0 changes every downstream key
    keys_b = page_keys(b, ps)
    assert all(x != y for x, y in zip(keys_a, keys_b))
    # partial-tail key (4 tokens) differs from the full-page key of the
    # longer prompt covering the same positions
    keys_c = page_keys(c, ps)
    assert keys_a[:2] == keys_c[:2] and keys_a[2] != keys_c[2]
    cache = PrefixCache(ps)
    for i, key in enumerate(keys_a):
        cache.register(key, i + 1)
    _, matched = cache.lookup(a)
    assert matched == [1, 2, 3]
    _, matched = cache.lookup(c)
    assert matched == [1, 2]                     # stops at the tail
    assert cache.hit_rate is not None and 0 < cache.hit_rate < 1
    cache.unregister_page(2)
    _, matched = cache.lookup(a)
    assert matched == [1]                        # chain broken at page 1


def test_slotpool_liveness_is_o1_and_lifo():
    cfg = _cfg()
    pool = SlotPool(LM(cfg), max_slots=4, max_len=8)
    s0 = pool.alloc()
    pool.free(s0)
    with pytest.raises(AssertionError):
        pool.free(s0)                            # double free caught in O(1)
    assert pool.alloc() == s0                    # LIFO order preserved
    assert pool.nbytes > 0


# ---------------------------------------------------------------------------
# Metrics + CLI
# ---------------------------------------------------------------------------

def test_cache_metrics_sections():
    cfg = _cfg()
    prompts, gens = _workload(cfg, 4, prompt_len=8, seed=0, lens=(1, 4))
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    _, md = _run_engine(cfg, params, prompts, gens, max_slots=2, max_len=16)
    assert md["cache"]["mode"] == "dense" and md["cache"]["nbytes"] > 0
    assert md["concurrency"]["peak"] >= 1
    _, mp = _run_engine(cfg, params, prompts, gens, max_slots=2, max_len=16,
                        cache="paged", page_size=8)
    cm = mp["cache"]
    assert cm["mode"] == "paged" and cm["nbytes"] > 0
    assert cm["pages_total"] > 0 and cm["pages_used_peak"] >= 1
    assert 0 < cm["occupancy_peak"] <= 1
    assert cm["prefix"]["lookups"] > 0
    json.dumps(md), json.dumps(mp)               # JSON-serializable


def test_serve_cli_paged(capsys):
    metrics = serve.main(["--arch", "ternary-paper", "--reduced",
                          "--requests", "5", "--slots", "2",
                          "--prompt-len", "8", "--gen-lens", "2,5",
                          "--cache", "paged", "--page-size", "8"])
    out = json.loads(capsys.readouterr().out.strip().splitlines()[-1])
    assert out["submitted"] == out["drained"] == 5
    assert out["cache"]["mode"] == "paged"
    assert metrics["cache"]["page_size"] == 8
