"""Offline quantize-and-pack: convert a trained checkpoint's dense weights
into the 2-bit ternary serving format and report per-layer stats — the
deployment-side half of the paper's pipeline.

Run:  PYTHONPATH=src python examples/quantize_and_pack.py
"""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import weights
from repro.models import LM, layers as L


def main():
    cfg = get_config("ternary-paper", reduced=True, ternary_min_dim=64)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # the one pack entry point converts linears and MoE banks alike
    packed_params = L.pack_params(params, cfg)

    rows = []

    def stats(latent, packed, path=""):
        # walk the latent and packed trees in parallel: every container the
        # conversion produced becomes one row of the report — packed
        # linears ({"w_packed": ...} nodes) and MoE expert banks
        # (w_in/w_gate/w_out containers) alike
        if not isinstance(packed, dict):
            return
        wc = packed.get("w_packed")
        if isinstance(wc, weights.TernaryWeight):
            before = sum(v.nbytes for v in jax.tree.leaves(latent))
            after = sum(v.nbytes for v in jax.tree.leaves(packed))
            rows.append((path, tuple(latent["w"].shape), wc.occupancy(),
                         before, after))
            return
        for k, v in packed.items():
            if isinstance(v, weights.TernaryWeight):     # MoE expert bank
                rows.append((f"{path}/{k}", tuple(latent[k].shape),
                             v.occupancy(), latent[k].nbytes, v.nbytes))
            else:
                stats(latent[k], v, f"{path}/{k}")

    stats(params, packed_params)
    print(f"{'layer':34s} {'shape':>18s} {'nnz':>6s} {'before':>10s} "
          f"{'after':>9s} {'ratio':>6s}")
    tot_b = tot_a = 0
    for path, shape, s, before, after in rows:
        tot_b += before
        tot_a += after
        print(f"{path:34s} {str(shape):>18s} {s:6.1%} {before:10,} "
              f"{after:9,} {before / after:5.1f}x")
    print(f"\ntotal packed: {tot_b:,} -> {tot_a:,} "
          f"({tot_b / tot_a:.1f}x weight-memory reduction)")

    # verify the packed model still runs
    import dataclasses
    m2 = LM(dataclasses.replace(cfg, quantization="ternary_packed"))
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(1, 32)}
    x, _, _ = m2.forward(packed_params, batch)
    logits = m2._logits(packed_params, x)
    assert bool(jnp.all(jnp.isfinite(logits)))
    print("packed model forward: OK")


if __name__ == "__main__":
    main()
