"""Offline quantize-and-pack: convert a trained checkpoint's dense weights
into the 2-bit ternary serving format and report per-layer stats — the
deployment-side half of the paper's pipeline.

Run:  PYTHONPATH=src python examples/quantize_and_pack.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import quantize
from repro.models import LM, layers as L


def main():
    cfg = get_config("ternary-paper", reduced=True, ternary_min_dim=64)
    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))

    rows = []

    def walk(p, path=""):
        if isinstance(p, dict):
            if "w" in p and getattr(p["w"], "ndim", 0) in (2, 3) \
                    and min(p["w"].shape[-2:]) >= cfg.ternary_min_dim:
                w = p["w"]
                t, alpha = quantize.ternarize(
                    w.reshape(-1, w.shape[-1]), cfg.ternary_threshold)
                s = float((np.asarray(t) != 0).mean())
                packed = L.pack_linear(p, cfg)
                before = w.nbytes
                after = sum(v.nbytes for v in jax.tree.leaves(packed))
                rows.append((path, tuple(w.shape), s, before, after))
                return packed
            return {k: walk(v, f"{path}/{k}") for k, v in p.items()}
        return p

    packed_params = walk(params)
    print(f"{'layer':34s} {'shape':>18s} {'nnz':>6s} {'before':>10s} "
          f"{'after':>9s} {'ratio':>6s}")
    tot_b = tot_a = 0
    for path, shape, s, before, after in rows:
        tot_b += before
        tot_a += after
        print(f"{path:34s} {str(shape):>18s} {s:6.1%} {before:10,} "
              f"{after:9,} {before / after:5.1f}x")
    print(f"\ntotal packed: {tot_b:,} -> {tot_a:,} "
          f"({tot_b / tot_a:.1f}x weight-memory reduction)")

    # verify the packed model still runs
    import dataclasses
    m2 = LM(dataclasses.replace(cfg, quantization="ternary_packed"))
    batch = {"tokens": jnp.arange(32, dtype=jnp.int32).reshape(1, 32)}
    x, _, _ = m2.forward(packed_params, batch)
    logits = m2._logits(packed_params, x)
    assert bool(jnp.all(jnp.isfinite(logits)))
    print("packed model forward: OK")


if __name__ == "__main__":
    main()
