"""End-to-end driver: train a small LM with ternary QAT (the paper's weight
format in the forward pass, straight-through gradients), periodically
checkpointing, then quantize-pack-serve and compare perplexity.

This is the paper's deployment story in one script:
    train (QAT) -> ternarize + pack (2-bit) -> serve with the packed kernel.

Run:  PYTHONPATH=src python examples/train_ternary_lm.py [--steps 300]
(~100M-param config by default on real hardware; --small for CPU demo.)
"""
import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch import steps as steps_lib
from repro.models import LM, layers as L
from repro.optim import constant
from repro import checkpoint as ckpt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--small", action="store_true",
                    help="tiny config for CPU smoke runs")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/ternary_lm_ckpt")
    args = ap.parse_args()

    # ~100M params full; reduced for CPU demo
    if args.small:
        cfg = get_config("ternary-paper", reduced=True, ternary_min_dim=64,
                         num_layers=2, vocab_size=512)
    else:
        cfg = get_config("ternary-paper")          # 12L x 1024d, QAT on
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"quantization={cfg.quantization}")

    model = LM(cfg)
    params = model.init(jax.random.PRNGKey(0))
    step_fn, opt_init = steps_lib.make_train_step(model, cfg,
                                                  constant(args.lr))
    opt = opt_init(params)
    jitted = jax.jit(step_fn, donate_argnums=(0, 1))
    data = SyntheticLM(cfg, args.batch, args.seq, noise=0.02)

    losses = []
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.global_batch(i).items()}
        params, opt, metrics = jitted(params, opt, batch)
        losses.append(float(metrics["loss"]))
        if i % 20 == 0:
            print(f"step {i:4d} loss {losses[-1]:.4f}")
        if (i + 1) % 100 == 0:
            ckpt.save(args.ckpt_dir, i + 1, {"params": params, "opt": opt})

    # ---- quantize + pack for serving -------------------------------------
    packed_params = L.pack_params(params, cfg)
    import dataclasses
    cfg_packed = dataclasses.replace(cfg, quantization="ternary_packed")
    m2 = LM(cfg_packed)

    eval_batch = {k: jnp.asarray(v) for k, v in data.global_batch(10_000).items()}
    loss_qat, _ = jax.jit(model.loss)(params, eval_batch)
    loss_packed, _ = jax.jit(m2.loss)(packed_params, eval_batch)
    n_packed = sum(v.nbytes for v in jax.tree.leaves(packed_params))
    n_dense = sum(v.nbytes for v in jax.tree.leaves(params))
    print(json.dumps({
        "first_loss": losses[0], "last_loss": losses[-1],
        "eval_loss_qat": float(loss_qat),
        "eval_loss_packed_2bit": float(loss_packed),
        "serving_bytes": n_packed, "train_bytes": n_dense,
        "compression": round(n_dense / n_packed, 2),
    }, indent=1))
    assert losses[-1] < losses[0], "training must reduce loss"
    assert abs(float(loss_packed) - float(loss_qat)) < 0.05, \
        "packed serving must match QAT"


if __name__ == "__main__":
    main()
