"""Continuous-batching serving example: submit a stream of mixed-length
requests to ``repro.serving.ContinuousScheduler`` (queue -> slot pool ->
interleaved prefill/decode) and print per-request TTFT/latency plus engine
throughput. Pass ``--static`` to run the same workload through the legacy
static-batch server for an A/B comparison.

Pass ``--spec`` to run the same engine with self-speculative decoding
(DESIGN.md §10): a layer-skip draft proposes ``--spec-k`` tokens per slot
per round and the target verifies them in one multi-token forward —
outputs are token-exact vs the plain engine, and the printed spec block
shows the acceptance rate the draft achieved.

Pass ``--traffic poisson`` (or ``bursty``) to drive the engine open-loop
from a seeded arrival schedule with chunked prefill + SLO-aware admission
(DESIGN.md §14): requests split between an interactive class (tight TTFT
target, priority 0) and a batch class, prompts stream in ``--chunk-tokens``
per step alongside decode, and the printed report shows per-class
p50/p99 TTFT.

Run:  PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x22b
      PYTHONPATH=src python examples/serve_batched.py \
          --arch ternary-paper --spec --spec-k 4
      PYTHONPATH=src python examples/serve_batched.py \
          --arch ternary-paper --traffic poisson --rate 12
"""
import argparse
import json

import jax
import numpy as np

from repro.configs import get_config
from repro.launch.serve import (BatchedServer, build_workload, run_continuous,
                                run_static)
from repro.serving import (ContinuousScheduler, SchedConfig, SLOClass,
                           TrafficConfig, make_schedule, run_open_loop)


def serve_traffic(args):
    """Open-loop demo (DESIGN.md §14): chunked prefill + SLO admission
    under a seeded Poisson/bursty arrival schedule, with a per-class
    latency-percentile report."""
    cfg = get_config(args.arch, reduced=True)
    gen_lens = [int(g) for g in args.gen_lens.split(",")]
    interactive = SLOClass("interactive", ttft_target_s=0.5,
                           tpot_target_s=0.1, priority=0)
    batch = SLOClass("batch", ttft_target_s=None, priority=1)
    engine = ContinuousScheduler(
        cfg, max_slots=args.slots,
        max_len=args.prompt_len + max(gen_lens) + 1,
        sched=SchedConfig(chunk_tokens=args.chunk_tokens))
    engine.load(engine.model.init(jax.random.PRNGKey(0)))
    tc = TrafficConfig(kind=args.traffic, rate=args.rate,
                       n_requests=args.requests,
                       prompt_lens=(args.prompt_len,),
                       gen_lens=tuple(gen_lens), seed=0)
    schedule = make_schedule(tc, cfg.vocab_size,
                             classes=(interactive, batch),
                             class_weights=(0.75, 0.25))
    reqs, metrics = run_open_loop(engine, schedule)
    for name in ("interactive", "batch"):
        ttfts = [r.ttft_s for r in reqs
                 if r.slo is not None and r.slo.name == name
                 and r.ttft_s is not None]
        if ttfts:
            print(f"# {name}: n={len(ttfts)} "
                  f"p50_ttft={np.percentile(ttfts, 50) * 1e3:.1f}ms "
                  f"p99_ttft={np.percentile(ttfts, 99) * 1e3:.1f}ms")
    t = metrics["traffic"]
    print(f"# {args.traffic} rate={args.rate}/s offered={t['offered_rate']} "
          f"makespan={t['makespan_s']}s "
          f"chunk_steps={metrics['sched']['chunk_steps']}")
    print(json.dumps({k: v for k, v in metrics.items()
                      if k != "per_request"}))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=10)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-lens", default="4,16")
    ap.add_argument("--static", action="store_true")
    ap.add_argument("--spec", action="store_true",
                    help="speculative decoding (layer-skip draft; "
                         "token-exact vs the plain engine)")
    ap.add_argument("--spec-k", type=int, default=4)
    ap.add_argument("--traffic", default="off",
                    choices=("poisson", "bursty", "off"),
                    help="open-loop arrival schedule + chunked prefill "
                         "with SLO classes (DESIGN.md §14)")
    ap.add_argument("--rate", type=float, default=12.0,
                    help="--traffic: offered load, requests/second")
    ap.add_argument("--chunk-tokens", type=int, default=16,
                    help="--traffic: prefill chunk size per step")
    args = ap.parse_args()

    if args.traffic != "off":
        serve_traffic(args)
        return

    cfg = get_config(args.arch, reduced=True)
    gen_lens = [int(g) for g in args.gen_lens.split(",")]
    max_len = args.prompt_len + max(gen_lens) + 1 \
        + (args.spec_k if args.spec else 0)
    prompts, gens, extras = build_workload(cfg, args.requests,
                                           args.prompt_len, gen_lens)

    if not args.static and (cfg.is_encdec or cfg.family == "vlm"):
        print(f"# {args.arch} needs per-request encoder/frontend state; "
              "falling back to the static server")
        args.static = True
    if args.static:
        server = BatchedServer(cfg, max_len)
        server.load(server.model.init(jax.random.PRNGKey(0)))
        outs, metrics = run_static(server, prompts, gens, args.batch,
                                   extras=extras)
        for i, out in enumerate(outs):
            print(f"req {i}: {len(out)} tokens; sample: {out[:8].tolist()}")
    else:
        spec = None
        if args.spec:
            from repro.spec import SpecConfig
            spec = SpecConfig(draft="layer_skip", k=args.spec_k)
        try:
            engine = ContinuousScheduler(cfg, max_slots=args.slots,
                                         max_len=max_len, spec=spec)
        except ValueError as e:
            # the engine owns the spec-support predicate (rolling-SWA /
            # SSM / opt-layout caches cannot roll back) — fall back rather
            # than duplicating its rules here
            if spec is None:
                raise
            print(f"# --spec unsupported for {args.arch}: {e}")
            spec = None
            engine = ContinuousScheduler(cfg, max_slots=args.slots,
                                         max_len=max_len)
        engine.load(engine.model.init(jax.random.PRNGKey(0)))
        outs, metrics = run_continuous(engine, prompts, gens)
        for r in sorted(metrics["per_request"], key=lambda r: r["rid"]):
            out = outs[r["rid"]]        # outs is in submit (rid) order
            print(f"req {r['rid']}: {r['gen_len']} tokens, "
                  f"ttft {r['ttft_s']:.3f}s, latency {r['latency_s']:.3f}s; "
                  f"sample: {out[:8].tolist()}")
        if metrics["spec"] is not None:
            s = metrics["spec"]
            print(f"# spec: draft={s['draft']} k={s['k']} "
                  f"acceptance={s['acceptance_rate']} "
                  f"mean_accepted_len={s['mean_accepted_len']}")
    print(json.dumps({k: v for k, v in metrics.items()
                      if k != "per_request"}))


if __name__ == "__main__":
    main()
