"""Batched serving example: load an arch (reduced for CPU), run batched
prefill+decode over a stream of requests with the continuous-batching server
from launch/serve.py, using ternary-packed weights when configured.

Run:  PYTHONPATH=src python examples/serve_batched.py --arch mixtral-8x22b
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch.serve import BatchedServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mixtral-8x22b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=16)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    server = BatchedServer(cfg, max_len=args.prompt_len + args.gen_len + 1)
    server.load(server.model.init(jax.random.PRNGKey(0)))

    data = SyntheticLM(cfg, args.batch, args.prompt_len)
    total_tokens, t0 = 0, time.monotonic()
    for i in range(args.requests // args.batch):
        b = data.global_batch(i)
        extras = {k: v for k, v in b.items()
                  if k in ("vision_embeds", "enc_embeds")}
        out = server.generate(b["tokens"][:, :args.prompt_len],
                              args.gen_len, extras)
        total_tokens += out.size
        print(f"batch {i}: generated {out.shape} tokens; "
              f"sample: {out[0][:8].tolist()}")
    dt = time.monotonic() - t0
    print(f"{total_tokens} tokens in {dt:.2f}s "
          f"({total_tokens / dt:.1f} tok/s on CPU-reduced config)")


if __name__ == "__main__":
    main()
