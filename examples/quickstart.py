"""Quickstart: the paper's sparse ternary GEMM, end to end.

1. quantize a dense weight matrix to ternary (TWN absmean),
2. build the paper's TCSC / BlockedTCSC / InterleavedTCSC formats,
3. pack into a typed ``weights.TernaryWeight`` container (2-bit kernel
   format, scale/bias metadata riding along),
4. inspect the registry's ``GemmPlan``, run the Pallas kernel (interpret
   mode on CPU) and every reference algorithm, checking they all agree.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import formats, quantize, weights
from repro.kernels import ops, ref


def main():
    rng = np.random.default_rng(0)
    m, k, n = 32, 2048, 1024

    # --- 1. quantize dense weights to ternary (the paper's input) --------
    w_dense = jnp.asarray(rng.standard_normal((k, n)) * 0.05, jnp.float32)
    t, alpha = quantize.ternarize(w_dense)          # T in {-1,0,1}, scales
    t_np = np.asarray(t)
    sparsity = (t_np != 0).mean()
    print(f"ternarized: {sparsity:.1%} nonzero (paper's 's')")

    # --- 2. the paper's sparse formats ------------------------------------
    tcsc = formats.TCSC.from_dense(t_np)
    blocked = formats.BlockedTCSC.from_dense(t_np, block_size=4096)
    inter = formats.InterleavedTCSC.from_dense(t_np, group=4)
    print(f"TCSC bytes: {tcsc.nbytes():,} "
          f"(dense f32 would be {t_np.size * 4:,})")

    # --- 3. typed kernel containers: 2 bits/weight, 16 per u32 word ------
    bias = jnp.asarray(rng.standard_normal(n) * 0.1, jnp.float32)
    alpha_v = alpha.reshape(-1)
    wc = weights.pack(t_np, "dense2bit", scale=alpha_v, bias=bias)
    print(f"{type(wc).__name__} payload bytes: {wc.nbytes:,} "
          f"({t_np.size * 4 / wc.nbytes:.0f}x smaller than f32; "
          f"occupancy {wc.occupancy():.1%})")

    # --- 4. plan, run everything and compare ------------------------------
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    plan = ops.ternary_gemm_plan(wc, m)
    print(f"GemmPlan: {plan.format}/{plan.impl} blocks="
          f"{plan.block_m}x{plan.block_n}x{plan.block_k}")

    y_oracle = ref.ternary_matmul_dense(x, t, alpha_v, bias)
    y_kernel = ops.ternary_gemm(x, wc)     # scale/bias ride in the container
    y_tcsc = ref.tcsc_matmul(x, tcsc, alpha_v, bias)
    y_blocked = ref.tcsc_matmul_blocked(x, blocked, alpha_v, bias)
    y_inter = ref.tcsc_matmul_interleaved(x, inter, alpha_v, bias)
    y_base3 = ops.ternary_gemm(
        x, weights.pack(t_np, "base3", scale=alpha_v, bias=bias))

    for name, y in [("pallas-kernel", y_kernel), ("TCSC", y_tcsc),
                    ("BlockedTCSC", y_blocked), ("InterleavedTCSC", y_inter),
                    ("Base3 (ref)", y_base3)]:
        err = float(jnp.max(jnp.abs(y - y_oracle)))
        print(f"{name:18s} max|err| = {err:.2e}")
        assert err < 1e-3

    print("all variants agree — the paper's algorithm family is consistent")


if __name__ == "__main__":
    main()
