from repro.models.transformer import LM, set_mesh

__all__ = ["LM", "set_mesh"]
