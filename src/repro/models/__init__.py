from repro.models.transformer import LM, set_mesh, tree_nbytes

__all__ = ["LM", "set_mesh", "tree_nbytes"]
