"""Core layers (functional, no framework): linear (dense / ternary-QAT /
ternary-packed), norms, embeddings, RoPE, gated MLP.

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
params pytree with ``jax.sharding.PartitionSpec`` leaves — keeping shardings
structurally in sync with parameters (the distributed layer consumes them).

Axis-name conventions used in specs (resolved to mesh axes in
``repro.distributed.sharding``): "fsdp" (data axes when cfg.fsdp), "model".
We store specs directly as PartitionSpec with logical names; resolution
replaces names with mesh axes or None.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import formats, quantize
from repro.kernels import ref as kref

# Logical axis names (resolved in distributed/sharding.py)
FSDP = "fsdp"      # -> data axes if cfg.fsdp else None
MODEL = "model"    # -> tensor-parallel axis
EMPTY = None


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Linear — the layer the paper's technique lives in
# ---------------------------------------------------------------------------

def linear_init(key, cfg: ModelConfig, d_in: int, d_out: int,
                in_axis=FSDP, out_axis=MODEL, use_bias: Optional[bool] = None,
                scale: Optional[float] = None):
    """A (d_in, d_out) projection. Under ``quantization='ternary_packed'``
    the parameter is the packed 2-bit word matrix + per-channel scale
    (serving format); otherwise a latent dense matrix (QAT applies STE)."""
    use_bias = cfg.use_bias if use_bias is None else use_bias
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    ternary = _is_ternary(cfg, d_in, d_out)
    params, specs = {}, {}
    if cfg.quantization == "ternary_packed" and ternary:
        kw = (d_in + 15) // 16
        params["w_packed"] = jnp.zeros((kw, d_out), jnp.uint32)
        params["w_scale"] = jnp.ones((d_out,), jnp.float32)
        specs["w_packed"] = P(in_axis, out_axis)
        specs["w_scale"] = P(out_axis)
    else:
        params["w"] = jax.random.normal(key, (d_in, d_out), _pdtype(cfg)) * std
        specs["w"] = P(in_axis, out_axis)
    if use_bias:
        params["b"] = jnp.zeros((d_out,), _pdtype(cfg))
        specs["b"] = P(out_axis)
    return params, specs


def _is_ternary(cfg: ModelConfig, d_in: int, d_out: int) -> bool:
    return (cfg.quantization != "none"
            and min(d_in, d_out) >= cfg.ternary_min_dim)


def _use_pallas_gemm(cfg: ModelConfig) -> bool:
    if cfg.ternary_kernel == "pallas":
        return True
    if cfg.ternary_kernel == "xla":
        return False
    return jax.default_backend() == "tpu"


def linear_apply(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (..., d_in) -> (..., d_out)."""
    if "w_packed" in params:
        k = x.shape[-1]
        lead = x.shape[:-1]
        x2 = x.reshape(-1, k)
        if _use_pallas_gemm(cfg):
            # Autotuned Pallas kernel (blocks=None -> kernels.autotune pick);
            # on CPU the XLA dense-decode path below is the faster oracle.
            from repro.kernels import ops as kops
            y = kops.ternary_gemm(x2, params["w_packed"],
                                  scale=params["w_scale"], k=k)
        else:
            y = kref.packed2bit_matmul(x2, params["w_packed"], k,
                                       alpha=params["w_scale"])
        y = y.reshape(*lead, -1)
    else:
        w = params["w"]
        if cfg.quantization == "ternary" and _is_ternary(cfg, *w.shape):
            w = quantize.ste_ternarize(w, cfg.ternary_threshold)
        y = jnp.dot(x, w.astype(x.dtype))
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def pack_linear(params: dict, cfg: ModelConfig) -> dict:
    """Convert a latent-weight linear into the packed serving format
    (host-side; used by examples/quantize_and_pack.py and serve path).
    Handles scan-stacked weights: a (L, K, N) stack packs to
    (L, ceil(K/16), N) + per-layer scales — scan slicing hands the kernel
    2-D blocks at apply time."""
    import numpy as np
    if "w" not in params:
        return params
    w = params["w"]
    if not _is_ternary(cfg, *w.shape[-2:]):
        return params
    if w.ndim == 2:
        t, alpha = quantize.ternarize(w, cfg.ternary_threshold)
        out = {"w_packed": jnp.asarray(formats.pack_2bit(np.asarray(t))),
               "w_scale": jnp.asarray(alpha.reshape(-1))}
    else:
        packs, scales = [], []
        for i in range(w.shape[0]):
            t, alpha = quantize.ternarize(w[i], cfg.ternary_threshold)
            packs.append(formats.pack_2bit(np.asarray(t)))
            scales.append(np.asarray(alpha).reshape(-1))
        out = {"w_packed": jnp.asarray(np.stack(packs)),
               "w_scale": jnp.asarray(np.stack(scales))}
    if "b" in params:
        out["b"] = params["b"]
    return out


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(key, cfg: ModelConfig, d: int):
    del key
    params = {"scale": jnp.ones((d,), _pdtype(cfg))}
    specs = {"scale": P(EMPTY)}
    if cfg.norm_type == "layernorm":
        params["bias"] = jnp.zeros((d,), _pdtype(cfg))
        specs["bias"] = P(EMPTY)
    return params, specs


def norm_apply(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        xf = xf - jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig):
    v, d = cfg.padded_vocab(), cfg.d_model
    params = {"table": jax.random.normal(key, (v, d), _pdtype(cfg)) * 0.02}
    specs = {"table": P(MODEL, FSDP)}
    return params, specs


def embed_apply(params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return params["table"].astype(_dtype(cfg))[tokens]


def unembed_init(key, cfg: ModelConfig):
    # The vocab head is a plain linear layer -> the paper's ternary format
    # applies to it like any other projection.
    return linear_init(key, cfg, cfg.d_model, cfg.padded_vocab(),
                       FSDP, MODEL, use_bias=False)


def unembed_apply(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return linear_apply(params, x, cfg)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd), positions: (B, S) -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    w_in, s_in = linear_init(k1, cfg, cfg.d_model, d_ff, FSDP, MODEL)
    w_gate, s_gate = linear_init(k2, cfg, cfg.d_model, d_ff, FSDP, MODEL)
    w_out, s_out = linear_init(k3, cfg, d_ff, cfg.d_model, MODEL, FSDP)
    return ({"in": w_in, "gate": w_gate, "out": w_out},
            {"in": s_in, "gate": s_gate, "out": s_out})


def mlp_apply(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    h = jax.nn.silu(linear_apply(params["gate"], x, cfg)) \
        * linear_apply(params["in"], x, cfg)
    return linear_apply(params["out"], h, cfg)
