"""Core layers (functional, no framework): linear (dense / ternary-QAT /
ternary-packed), norms, embeddings, RoPE, gated MLP.

Every ``init_*`` returns ``(params, specs)`` where ``specs`` mirrors the
params pytree with ``jax.sharding.PartitionSpec`` leaves — keeping shardings
structurally in sync with parameters (the distributed layer consumes them).

Axis-name conventions used in specs (resolved to mesh axes in
``repro.distributed.sharding``): "fsdp" (data axes when cfg.fsdp), "model".
We store specs directly as PartitionSpec with logical names; resolution
replaces names with mesh axes or None.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import quantize, weights

# Logical axis names (resolved in distributed/sharding.py)
FSDP = "fsdp"      # -> data axes if cfg.fsdp else None
MODEL = "model"    # -> tensor-parallel axis
EMPTY = None


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def _pdtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# Linear — the layer the paper's technique lives in
# ---------------------------------------------------------------------------

def linear_init(key, cfg: ModelConfig, d_in: int, d_out: int,
                in_axis=FSDP, out_axis=MODEL, use_bias: Optional[bool] = None,
                scale: Optional[float] = None):
    """A (d_in, d_out) projection. Under ``quantization='ternary_packed'``
    the parameter is the packed 2-bit word matrix + per-channel scale
    (serving format); otherwise a latent dense matrix (QAT applies STE)."""
    use_bias = cfg.use_bias if use_bias is None else use_bias
    std = scale if scale is not None else 1.0 / math.sqrt(d_in)
    ternary = _is_ternary(cfg, d_in, d_out)
    params, specs = {}, {}
    if cfg.quantization == "ternary_packed" and ternary:
        # Serving format: a TernaryWeight container is the parameter (its
        # array leaves flow through stacking/scan/sharding like any other
        # leaf; the spec twin mirrors it with PartitionSpec leaves).
        kw = (d_in + 15) // 16
        wc = weights.Dense2Bit(
            packed=jnp.zeros((kw, d_out), jnp.uint32),
            scale=jnp.ones((d_out,), jnp.float32),
            bias=jnp.zeros((d_out,), jnp.float32) if use_bias else None,
            shape=(d_in, d_out))
        params["w_packed"] = wc
        specs["w_packed"] = wc.replace(
            packed=P(in_axis, out_axis), scale=P(out_axis),
            bias=P(out_axis) if use_bias else None)
        return params, specs
    params["w"] = jax.random.normal(key, (d_in, d_out), _pdtype(cfg)) * std
    specs["w"] = P(in_axis, out_axis)
    if use_bias:
        params["b"] = jnp.zeros((d_out,), _pdtype(cfg))
        specs["b"] = P(out_axis)
    return params, specs


def _is_ternary(cfg: ModelConfig, d_in: int, d_out: int) -> bool:
    return (cfg.quantization != "none"
            and min(d_in, d_out) >= cfg.ternary_min_dim)


def _use_pallas_gemm(cfg: ModelConfig) -> bool:
    if cfg.ternary_kernel == "pallas":
        return True
    if cfg.ternary_kernel == "xla":
        return False
    return jax.default_backend() == "tpu"


def gemm_impl(cfg: ModelConfig) -> str:
    """The ``ternary_gemm`` impl the packed-linear apply path dispatches
    for this config: ``"auto"`` (registry + autotuner, Pallas) when the
    Pallas path is active, else the XLA dense-decode ``"ref"`` oracle.
    Single source of truth — the serving engine warms GemmPlans for
    exactly this impl."""
    return "auto" if _use_pallas_gemm(cfg) else "ref"


def linear_apply(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """x: (..., d_in) -> (..., d_out)."""
    wc = params.get("w_packed")
    if wc is not None and not isinstance(wc, weights.TernaryWeight):
        raise TypeError(
            "params['w_packed'] is a raw array (the pre-container packed "
            "format); re-pack from the latent (unpacked) weights with "
            "models.layers.pack_params, or wrap the buffer directly via "
            "weights.Dense2Bit.from_packed(words, k=d_in, scale=...)")
    if isinstance(wc, weights.TernaryWeight):
        lead = x.shape[:-1]
        x2 = x.reshape(-1, x.shape[-1])
        from repro.kernels import ops as kops
        y = kops.ternary_gemm(x2, wc, impl=gemm_impl(cfg))
        y = y.reshape(*lead, -1)
    else:
        w = params["w"]
        if cfg.quantization == "ternary" and _is_ternary(cfg, *w.shape):
            w = quantize.ste_ternarize(w, cfg.ternary_threshold)
        y = jnp.dot(x, w.astype(x.dtype))
    if "b" in params:
        y = y + params["b"].astype(y.dtype)
    return y


def pack_linear(params: dict, cfg: ModelConfig) -> dict:
    """Convert a latent-weight linear into the packed serving format
    (host-side): the parameter becomes a ``weights.Dense2Bit`` container
    carrying per-channel ternarization scales (and the bias, when present).
    Handles scan-stacked weights: a (L, K, N) stack packs to
    (L, ceil(K/16), N) leaves — scan slicing hands the kernel 2-D blocks
    at apply time."""
    if "w" not in params:
        return params
    w = params["w"]
    if not _is_ternary(cfg, *w.shape[-2:]):
        return params
    return {"w_packed": weights.pack(w, "dense2bit", bias=params.get("b"),
                                     threshold=cfg.ternary_threshold)}


def pack_params(params, cfg: ModelConfig):
    """Walk a model param tree, converting every ternarizable projection
    (plain or scan-stacked linears, MoE expert banks) into the packed
    ``TernaryWeight`` serving format. The single pack entry point for
    serving / checkpointing (examples, launch.serve, tests)."""
    from repro.models import moe as moe_lib

    def walk(p):
        if isinstance(p, dict):
            if "router" in p and "w_in" in p:
                return moe_lib.pack_moe(p, cfg)
            if "w" in p and getattr(p["w"], "ndim", 0) in (2, 3) \
                    and min(p["w"].shape[-2:]) >= cfg.ternary_min_dim:
                return pack_linear(p, cfg)
            return {k: walk(v) for k, v in p.items()}
        return p

    return walk(params)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def norm_init(key, cfg: ModelConfig, d: int):
    del key
    params = {"scale": jnp.ones((d,), _pdtype(cfg))}
    specs = {"scale": P(EMPTY)}
    if cfg.norm_type == "layernorm":
        params["bias"] = jnp.zeros((d,), _pdtype(cfg))
        specs["bias"] = P(EMPTY)
    return params, specs


def norm_apply(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm":
        xf = xf - jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + cfg.norm_eps)
    y = y * params["scale"].astype(jnp.float32)
    if "bias" in params:
        y = y + params["bias"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embed_init(key, cfg: ModelConfig):
    v, d = cfg.padded_vocab(), cfg.d_model
    params = {"table": jax.random.normal(key, (v, d), _pdtype(cfg)) * 0.02}
    specs = {"table": P(MODEL, FSDP)}
    return params, specs


def embed_apply(params, tokens: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return params["table"].astype(_dtype(cfg))[tokens]


def unembed_init(key, cfg: ModelConfig):
    # The vocab head is a plain linear layer -> the paper's ternary format
    # applies to it like any other projection.
    return linear_init(key, cfg, cfg.d_model, cfg.padded_vocab(),
                       FSDP, MODEL, use_bias=False)


def unembed_apply(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    return linear_apply(params, x, cfg)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, hd), positions: (B, S) -> rotated x."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------

def mlp_init(key, cfg: ModelConfig, d_ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    w_in, s_in = linear_init(k1, cfg, cfg.d_model, d_ff, FSDP, MODEL)
    w_gate, s_gate = linear_init(k2, cfg, cfg.d_model, d_ff, FSDP, MODEL)
    w_out, s_out = linear_init(k3, cfg, d_ff, cfg.d_model, MODEL, FSDP)
    return ({"in": w_in, "gate": w_gate, "out": w_out},
            {"in": s_in, "gate": s_gate, "out": s_out})


def _fused_mlp_weights(params, cfg: ModelConfig):
    """The (w_in, w_out, w_gate) containers when this MLP can dispatch the
    fused lowering: every projection packed (bias inside the container),
    the Pallas path active, and fusion not configured off."""
    if getattr(cfg, "fused_mlp", "auto") == "off" or not _use_pallas_gemm(cfg):
        return None
    ws = []
    for name in ("in", "out", "gate"):
        p = params.get(name, {})
        wc = p.get("w_packed") if isinstance(p, dict) else None
        if not isinstance(wc, weights.TernaryWeight) or "b" in p:
            return None
        ws.append(wc)
    return tuple(ws)


def mlp_apply(params, x: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    fused = _fused_mlp_weights(params, cfg)
    if fused is not None:
        w_in, w_out, w_gate = fused
        from repro.kernels import ops as kops
        lead = x.shape[:-1]
        y = kops.fused_mlp(x.reshape(-1, x.shape[-1]), w_in, w_out, w_gate)
        return y.reshape(*lead, -1)
    h = jax.nn.silu(linear_apply(params["gate"], x, cfg)) \
        * linear_apply(params["in"], x, cfg)
    return linear_apply(params["out"], h, cfg)
