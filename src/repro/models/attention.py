"""GQA attention: flash (blockwise, lax-native) + naive paths, RoPE, sliding
window, KV-cache decode, and cross-attention for enc-dec models.

All projections are 2-D ``layers.linear`` layers, so the paper's ternary
weight format applies to QKV/O directly. Flash attention is implemented as a
python-unrolled loop over query blocks with a ``lax.scan`` over key blocks
whose *static trip count is shortened* by causality and the sliding window —
i.e. masked-out blocks are genuinely skipped in the HLO, not just masked
(this is what makes SWA sub-quadratic here, and is a §Perf lever).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models.layers import FSDP, MODEL, linear_apply, linear_init, rope

NEG_INF = -1e30


def attn_init(key, cfg: ModelConfig, cross: bool = False):
    d, kv, hd = cfg.d_model, cfg.num_kv_heads, cfg.head_dim
    h = cfg.num_heads + cfg.head_pad   # §Perf B1: TP-divisible head padding
    ks = jax.random.split(key, 4)
    wq, sq = linear_init(ks[0], cfg, d, h * hd, FSDP, MODEL)
    wk, sk = linear_init(ks[1], cfg, d, kv * hd, FSDP, MODEL)
    wv, sv = linear_init(ks[2], cfg, d, kv * hd, FSDP, MODEL)
    wo, so = linear_init(ks[3], cfg, h * hd, d, MODEL, FSDP)
    return ({"q": wq, "k": wk, "v": wv, "o": wo},
            {"q": sq, "k": sk, "v": sv, "o": so})


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _qk_scale(hd):
    return 1.0 / math.sqrt(hd)


# ---------------------------------------------------------------------------
# Blockwise (flash) attention over full sequences — train / prefill
# ---------------------------------------------------------------------------

def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool, window: int, block_q: int, block_kv: int,
                    q_offset: int = 0) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd) with H % KV == 0.
    Returns (B, Sq, H, hd). f32 softmax accumulation."""
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    pad_q = (-sq) % bq
    pad_kv = (-skv) % bkv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
    sq_p, skv_p = sq + pad_q, skv + pad_kv
    nkv = skv_p // bkv
    # (B, nkv, bkv, KV, hd) blocked K/V for scan
    kb = k.reshape(b, nkv, bkv, kvh, hd)
    vb = v.reshape(b, nkv, bkv, kvh, hd)
    scale = _qk_scale(hd)

    outs = []
    for i in range(sq_p // bq):
        q_blk = q[:, i * bq:(i + 1) * bq]                      # (B,bq,H,hd)
        q_blk = q_blk.reshape(b, bq, kvh, g, hd)
        q_lo = q_offset + i * bq
        q_hi = q_lo + bq
        # static KV range this q block can see
        hi_blk = nkv if not causal else min(nkv, -(-min(q_hi, skv) // bkv))
        lo_blk = 0
        if window:
            lo_blk = max(0, (q_lo - window) // bkv)
        hi_blk = max(hi_blk, lo_blk + 1)
        q_pos = q_lo + jnp.arange(bq)

        def step(carry, blk_idx):
            m_prev, l_prev, acc = carry
            # dynamic-index the block from the full blocked K/V (a sliced
            # xs copy per q-block would materialize O(S^2/bq) bytes)
            kc = jax.lax.dynamic_index_in_dim(kb, blk_idx, axis=1,
                                              keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(vb, blk_idx, axis=1,
                                              keepdims=False)
            k_pos = blk_idx * bkv + jnp.arange(bkv)
            # scores: (B, KV, G, bq, bkv), f32
            s = jnp.einsum("bqkgd,bskd->bkgqs", q_blk, kc,
                           preferred_element_type=jnp.float32) * scale
            mask = jnp.ones((bq, bkv), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            mask &= (k_pos < skv)[None, :]
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_cur = jnp.max(s, axis=-1)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m_prev - m_new)
            l_new = l_prev * corr + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqs,bskd->bkgqd", p.astype(vc.dtype), vc,
                            preferred_element_type=jnp.float32)
            acc = acc * corr[..., None] + pv
            return (m_new, l_new, acc), None

        init = (jnp.full((b, kvh, g, bq), NEG_INF, jnp.float32),
                jnp.zeros((b, kvh, g, bq), jnp.float32),
                jnp.zeros((b, kvh, g, bq, hd), jnp.float32))
        blk_ids = jnp.arange(lo_blk, hi_blk)
        (m_f, l_f, acc), _ = jax.lax.scan(step, init, blk_ids)
        o = acc / jnp.maximum(l_f, 1e-30)[..., None]
        o = o.reshape(b, kvh * g, bq, hd).transpose(0, 2, 1, 3)
        outs.append(o.astype(q.dtype))
    out = jnp.concatenate(outs, axis=1) if len(outs) > 1 else outs[0]
    return out[:, :sq].reshape(b, sq, h, hd)


def naive_attention(q, k, v, *, causal, window, q_offset=0,
                    kv_valid_len=None):
    """Reference full-materialization attention (and the decode path).
    q: (B, Sq, H, hd); k/v: (B, Skv, KV, hd).

    ``q_offset`` / ``kv_valid_len`` may be scalars (classic decode: every
    row at the same position) or (B,) vectors (continuous batching: each
    slot at its own position/valid length)."""
    b, sq, h, hd = q.shape
    _, skv, kvh, _ = k.shape
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, k,
                   preferred_element_type=jnp.float32) * _qk_scale(hd)
    q_off = jnp.asarray(q_offset)
    q_pos = q_off[..., None] + jnp.arange(sq)    # (sq,) or (B, sq)
    if q_pos.ndim == 1:
        q_pos = q_pos[None]                      # (1, sq): shared offsets
    k_pos = jnp.arange(skv)
    mask = jnp.ones((1, sq, skv), bool)
    if causal:
        mask &= q_pos[:, :, None] >= k_pos
    if window:
        mask &= q_pos[:, :, None] - k_pos < window
    if kv_valid_len is not None:
        valid = jnp.asarray(kv_valid_len)
        valid = valid[:, None, None] if valid.ndim else valid
        mask = mask & (k_pos < valid)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def opt_decode_attention(q, k_cache, v_cache, *, kv_valid_len, window=0,
                         q_offset=0):
    """Decode attention on the transpose-free layouts:
    q (B,1,H,hd); k_cache (B,KV,S,hd); v_cache (B,KV,hd,S). Both dots have
    their contracting dim minor-most — no relayout traffic (§Perf A6)."""
    b, sq, h, hd = q.shape
    kvh, s = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bksd->bkgqs", qg, k_cache,
                        preferred_element_type=jnp.float32) * _qk_scale(hd)
    k_pos = jnp.arange(s)
    mask = k_pos < kv_valid_len
    if window:
        mask &= (q_offset - k_pos) < window
    scores = jnp.where(mask[None, None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bkgqs,bkds->bqkgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, sq, h, hd).astype(q.dtype)


def delta_decode_attention(q, k_cache, v_cache, k_tok, v_tok, *, cache_pos,
                           rolling: bool, window=0):
    """Decode attention WITHOUT writing the cache in-loop (§Perf A7): attend
    over the stale cache (current token masked out) plus the fresh token's
    self-attention term, concatenated before the softmax — mathematically
    identical to attending over the updated cache. The layer scan then emits
    only (k_tok, v_tok) and one batched DUS outside the loop commits all
    layers' tokens: per-step cache write drops from L x full-layer-slice to
    L x one token.

    q (B,1,H,hd); k_cache (B,KV,S,hd); v_cache (B,KV,hd,S);
    k_tok (B,1,KV,hd); v_tok (B,1,KV,hd).

    ``cache_pos`` may be a scalar (all rows at one position) or a (B,)
    vector (continuous batching: per-slot positions)."""
    b, sq, h, hd = q.shape
    kvh, s = k_cache.shape[1], k_cache.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, hd)
    scores = jnp.einsum("bqkgd,bksd->bkgqs", qg, k_cache,
                        preferred_element_type=jnp.float32) * _qk_scale(hd)
    cp = jnp.asarray(cache_pos).reshape(-1, 1)   # (1,1) scalar / (B,1) vector
    idx = jnp.arange(s)[None]                    # (1, S)
    if rolling:
        slot = cp % s
        mask = jnp.where(cp >= s, idx != slot, idx < cp)
    else:
        mask = idx < cp
        if window:
            mask &= (cp - idx) < window
    scores = jnp.where(mask[:, None, None, None], scores, NEG_INF)
    self_score = jnp.einsum("bqkgd,bqkd->bkgq", qg, k_tok,
                            preferred_element_type=jnp.float32) \
        * _qk_scale(hd)
    # two-part softmax without concatenating on the (sharded) S axis —
    # concat on a sharded dim forces a GSPMD full regather
    m = jnp.maximum(jnp.max(scores, axis=-1), self_score)   # (B,KV,G,1)
    p_cache = jnp.exp(scores - m[..., None])
    p_self = jnp.exp(self_score - m)
    denom = jnp.sum(p_cache, axis=-1) + p_self              # (B,KV,G,1)
    o = jnp.einsum("bkgqs,bkds->bqkgd", p_cache.astype(v_cache.dtype),
                   v_cache, preferred_element_type=jnp.float32)
    o = o + jnp.einsum("bkgq,bqkd->bqkgd", p_self.astype(q.dtype),
                       v_tok, preferred_element_type=jnp.float32)
    o = o / denom.transpose(0, 3, 1, 2)[..., None]
    return o.reshape(b, sq, h, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# Full attention layer (projections + rope + attention + output)
# ---------------------------------------------------------------------------

def attn_apply(params, x: jnp.ndarray, cfg: ModelConfig, *,
               positions: jnp.ndarray, causal: bool = True,
               cache: Optional[dict] = None,
               cache_pos: Optional[jnp.ndarray] = None,
               kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
               block_table: Optional[jnp.ndarray] = None,
               ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """One attention layer.

    * train/prefill: cache=None (or a cache dict to fill at positions 0..S).
    * decode: cache given + cache_pos scalar; x is (B, 1, d).
    * verify window (speculative decoding, DESIGN.md §10): x is (B, S>1, d)
      with cache_pos scalar or (B,) — the S tokens sit at positions
      cache_pos..cache_pos+S-1, their K/V are scattered before attending,
      and causal masking within the window plus the committed prefix makes
      each window token's logits equal to what sequential decode at its
      position would produce. Non-rolling caches only (the caller —
      ``LM.decode_step`` — unrolls rolling-SWA layouts per token instead).
    * paged decode: cache = {"k_pages", "v_pages"} + block_table (B, T) +
      cache_pos (B,) vector (DESIGN.md §9); prefill never sees a paged
      cache — the page pool scatters prefilled dense rows into pages.
      Multi-token verify windows flatten to a (B·S) row batch.
    * cross-attention: kv_override = (k, v) precomputed from the encoder.
    """
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    h = cfg.num_heads + cfg.head_pad
    q = _split_heads(linear_apply(params["q"], x, cfg), h, hd)
    if kv_override is None:
        k = _split_heads(linear_apply(params["k"], x, cfg), kv, hd)
        v = _split_heads(linear_apply(params["v"], x, cfg), kv, hd)
        if cfg.rope_theta:
            q = rope(q, positions, cfg.rope_theta)
            k = rope(k, positions, cfg.rope_theta)
    else:
        k, v = kv_override
        causal = False

    if cache is not None and "k_pages" in cache:
        assert cache_pos is not None and block_table is not None, (
            "paged caches are decode-only and need a block table")
        y, new_cache = _paged_decode(params, x, cfg, q, k, v, cache,
                                     cache_pos, block_table)
        return y, new_cache

    new_cache = cache
    opt = cache is not None and cfg.cache_layout == "opt"
    attend_view = False   # prefill-into-cache: attend the stored view
    if cache is not None and kv_override is None:
        flat = cache["k"].ndim == 3
        cache_len = cache["k"].shape[2] if opt else cache["k"].shape[1]
        if cache_pos is not None and k.shape[1] > 1:
            # verify window (DESIGN.md §10): scatter all S tokens' K/V at
            # positions cache_pos..cache_pos+S-1 before attending. Rolling
            # SWA caches never reach here (write-then-attend would let a
            # wrapped write clobber an entry an earlier window token still
            # attends to — decode_step unrolls those per token).
            assert not (cfg.sliding_window and cache_len <= cfg.sliding_window)
            assert not opt, "verify windows need cache_layout='bshd'"
            sq = k.shape[1]
            base = cache_pos[:, None] if jnp.ndim(cache_pos) else cache_pos
            slots2d = jnp.broadcast_to(base + jnp.arange(sq),
                                       (k.shape[0], sq))
            rows = jnp.arange(k.shape[0])[:, None]
            k_c = cache["k"].at[rows, slots2d].set(
                _store_view(k, cfg, flat).astype(cache["k"].dtype))
            v_c = cache["v"].at[rows, slots2d].set(
                _store_view(v, cfg, flat).astype(cache["v"].dtype))
            new_cache = {"k": k_c, "v": v_c}
            k, v = _cache_view(k_c, cfg), _cache_view(v_c, cfg)
        elif cache_pos is not None:  # decode: insert this step's K/V
            if cfg.sliding_window and cache_len <= cfg.sliding_window:
                slot = cache_pos % cache_len            # rolling SWA cache
            else:
                slot = cache_pos
            if opt:
                # delta mode (§Perf A7): the scan emits just this token's
                # K/V; decode_step commits all layers in one batched DUS
                new_cache = {
                    "k_tok": k.transpose(0, 2, 1, 3).astype(cache["k"].dtype),
                    "v_tok": v.transpose(0, 2, 3, 1).astype(cache["v"].dtype),
                }
            elif jnp.ndim(slot):
                # per-slot positions (continuous batching): scatter each
                # row's token K/V at that row's own cache offset
                rows = jnp.arange(k.shape[0])
                k_c = cache["k"].at[rows, slot].set(
                    _store_view(k, cfg, flat)[:, 0].astype(cache["k"].dtype))
                v_c = cache["v"].at[rows, slot].set(
                    _store_view(v, cfg, flat)[:, 0].astype(cache["v"].dtype))
                new_cache = {"k": k_c, "v": v_c}
                k, v = _cache_view(k_c, cfg), _cache_view(v_c, cfg)
            else:
                zeros = (0, 0, 0) if flat else (0, 0, 0, 0)
                k_c = jax.lax.dynamic_update_slice(
                    cache["k"],
                    _store_view(k, cfg, flat).astype(cache["k"].dtype),
                    (0, slot) + zeros[2:])
                v_c = jax.lax.dynamic_update_slice(
                    cache["v"],
                    _store_view(v, cfg, flat).astype(cache["v"].dtype),
                    (0, slot) + zeros[2:])
                new_cache = {"k": k_c, "v": v_c}
                k, v = _cache_view(k_c, cfg), _cache_view(v_c, cfg)
        else:                       # prefill: write whole K/V
            # Attend the same cache-dtype-rounded K/V the cache will hold
            # (a no-op when the cache is full precision). Every other
            # consumer of these positions — sequential decode, spec verify
            # windows, chunked-prefill windows — reads the *stored*
            # values, so rounding at production makes prefill->decode
            # bitwise-consistent with windowed admission (DESIGN.md §14)
            # instead of agreeing only up to greedy near-ties.
            k = k.astype(cache["k"].dtype).astype(k.dtype)
            v = v.astype(cache["v"].dtype).astype(v.dtype)
            s = k.shape[1]
            if opt:
                ks = k.transpose(0, 2, 1, 3)            # (B,KV,S,hd)
                vs = v.transpose(0, 2, 3, 1)            # (B,KV,hd,S)
                if s > cache_len:
                    shift = (s - cache_len) % cache_len
                    k_c = jnp.roll(ks[:, :, -cache_len:], shift, axis=2
                                   ).astype(cache["k"].dtype)
                    v_c = jnp.roll(vs[..., -cache_len:], shift, axis=3
                                   ).astype(cache["v"].dtype)
                else:
                    k_c = jax.lax.dynamic_update_slice(
                        cache["k"], ks.astype(cache["k"].dtype), (0, 0, 0, 0))
                    v_c = jax.lax.dynamic_update_slice(
                        cache["v"], vs.astype(cache["v"].dtype), (0, 0, 0, 0))
            else:
                ks = _store_view(k, cfg, flat)
                vs = _store_view(v, cfg, flat)
                if s > cache_len:
                    # rolling SWA cache: keep last `cache_len` tokens at
                    # their (pos % cache_len) slots
                    shift = (s - cache_len) % cache_len
                    k_c = jnp.roll(ks[:, -cache_len:], shift, axis=1
                                   ).astype(cache["k"].dtype)
                    v_c = jnp.roll(vs[:, -cache_len:], shift, axis=1
                                   ).astype(cache["v"].dtype)
                else:
                    zeros = (0, 0, 0) if flat else (0, 0, 0, 0)
                    k_c = jax.lax.dynamic_update_slice(
                        cache["k"], ks.astype(cache["k"].dtype), zeros)
                    v_c = jax.lax.dynamic_update_slice(
                        cache["v"], vs.astype(cache["v"].dtype), zeros)
                    # Attend through the *written cache view*, not the
                    # S-wide fresh K/V: sequential decode, spec verify and
                    # chunked-prefill windows all reduce attention over the
                    # full cache axis (naive, max_len-wide, stale tail
                    # masked as future by causality), and both the reducer
                    # width and the kernel choice change f32 accumulation
                    # grouping — an S-wide (or flash-blocked) prefill
                    # disagrees with the windowed paths by ~1 ULP on
                    # layer>=1 K/V, enough to flip greedy near-ties.
                    # Attending the view makes whole-prompt admission
                    # bitwise-equal to windowed admission (DESIGN.md §14).
                    k = _cache_view(k_c, cfg)
                    v = _cache_view(v_c, cfg)
                    attend_view = True
            new_cache = {"k": k_c, "v": v_c}

    if cache_pos is not None and q.shape[1] > 1:
        # verify window: causal masking gives token j of the window exactly
        # the prefix+window-causal view sequential decode at position
        # cache_pos+j would see (its own K/V at that slot included; stale
        # rows beyond the window are masked as "future" by causality)
        o = naive_attention(q, k, v, causal=True, window=cfg.sliding_window,
                            q_offset=cache_pos)
    elif cache_pos is not None:
        # decode: 1-token query against the cache (plain attention)
        cache_len = (cache["k"].shape[2] if opt
                     else cache["k"].shape[1]) if cache is not None else 0
        rolling = (cfg.sliding_window and cache is not None
                   and cache_len <= cfg.sliding_window)
        if rolling:
            valid = jnp.minimum(cache_pos + 1, cache_len)
            win, q_off = 0, 0
        else:
            valid = cache_pos + 1
            win, q_off = cfg.sliding_window, cache_pos
        if opt:
            o = delta_decode_attention(
                q, cache["k"], cache["v"],
                k.astype(cache["k"].dtype), v.astype(cache["v"].dtype),
                cache_pos=cache_pos, rolling=bool(rolling),
                window=cfg.sliding_window)
        else:
            o = naive_attention(q, k, v, causal=False, window=win,
                                q_offset=q_off, kv_valid_len=valid)
    elif attend_view:
        # prefill into a cache: same kernel + reduction width as the
        # decode/verify/chunk consumers of these positions (see above) —
        # never flash/pallas, whose blockwise accumulation differs
        o = naive_attention(q, k, v, causal=True,
                            window=cfg.sliding_window)
    else:
        if (cfg.gqa_repeat_kv or cfg.attn_impl == "pallas") \
                and k.shape[2] < h:
            # §Perf B1: repeat K/V to full MHA so every attention einsum
            # shards cleanly on the head axis (kv=8 cannot shard over a
            # 16-way TP axis). Repeat along a sharded dim is comm-free.
            k = jnp.repeat(k, h // k.shape[2], axis=2)
            v = jnp.repeat(v, h // v.shape[2], axis=2)
        if cfg.attn_impl == "pallas" and kv_override is None \
                and not cfg.sliding_window:
            # TPU runtime path: VMEM-resident flash kernel (§Perf B — kills
            # the XLA score/accumulator HBM round-trips). interpret=True on
            # non-TPU backends.
            import jax as _jax
            from repro.kernels.flash_attention import flash_attention_pallas
            b, s, _, hd2 = q.shape
            qf = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd2)
            kf = k.transpose(0, 2, 1, 3).reshape(b * h, s, hd2)
            vf = v.transpose(0, 2, 1, 3).reshape(b * h, s, hd2)
            of = flash_attention_pallas(
                qf, kf, vf, causal=causal,
                block_q=min(cfg.attn_block_q, 512),
                block_kv=min(cfg.attn_block_kv, 512),
                interpret=_jax.default_backend() != "tpu")
            o = of.reshape(b, h, s, hd2).transpose(0, 2, 1, 3)
        elif cfg.attn_impl == "flash" and kv_override is None:
            o = flash_attention(q, k, v, causal=causal,
                                window=cfg.sliding_window,
                                block_q=cfg.attn_block_q,
                                block_kv=cfg.attn_block_kv)
        else:
            o = naive_attention(q, k, v, causal=causal,
                                window=cfg.sliding_window)
    y = linear_apply(params["o"], o.reshape(*x.shape[:-1], h * hd), cfg)
    return y, new_cache


def _paged_decode(params, x, cfg: ModelConfig, q, k, v, cache,
                  cache_pos, block_table):
    """Paged decode step (DESIGN.md §9): scatter the token's K/V into its
    row's current page, then attend over the block-table-indexed pages.

    Every live row writes to a page it privately owns (COW in the page pool
    guarantees this); free slots' block tables are all-zero, so their
    garbage writes land in the reserved trash page 0 and are never read.

    A multi-token verify window (S > 1, DESIGN.md §10) scatters all S
    tokens first — the engine's ``ensure_append`` horizon made every page
    in positions cache_pos..cache_pos+S-1 privately owned — then flattens
    the window into a (B·S) row batch whose per-row ``lengths`` encode
    causality within the window (token j sees valid tokens < pos+j+1).
    """
    from repro.paging.quant import Int8Pages, quantize_rows

    k_pages, v_pages = cache["k_pages"], cache["v_pages"]
    quantized = isinstance(k_pages, Int8Pages)
    ps = (k_pages.codes if quantized else k_pages).shape[-3]
    pos = jnp.asarray(cache_pos)
    b, sq = k.shape[0], k.shape[1]
    h = cfg.num_heads + cfg.head_pad
    if sq == 1:
        rows = jnp.arange(k.shape[0])
        pids = block_table[rows, pos // ps]       # (B,) page of this token
        offs = pos % ps
        k_tok, v_tok = k[:, 0], v[:, 0]           # (B, KV, hd)
        if quantized:
            kc, ks = quantize_rows(k_tok)
            vc, vs = quantize_rows(v_tok)
            k_pages = Int8Pages(k_pages.codes.at[pids, offs].set(kc),
                                k_pages.scales.at[pids, offs].set(ks))
            v_pages = Int8Pages(v_pages.codes.at[pids, offs].set(vc),
                                v_pages.scales.at[pids, offs].set(vs))
        else:
            k_pages = k_pages.at[pids, offs].set(k_tok.astype(k_pages.dtype))
            v_pages = v_pages.at[pids, offs].set(v_tok.astype(v_pages.dtype))
        o = kops.paged_decode_attention(
            q[:, 0], k_pages, v_pages, block_table, pos + 1,
            window=cfg.sliding_window, impl=cfg.paged_attn_impl)
        o_seq = o[:, None]                        # (B, 1, H, hd)
    else:
        base = pos[:, None] if pos.ndim else pos
        pos2d = jnp.broadcast_to(base + jnp.arange(sq), (b, sq))
        rows = jnp.arange(b)[:, None]
        pids = block_table[rows, pos2d // ps]     # (B, S)
        offs = pos2d % ps
        if quantized:
            kc, ks = quantize_rows(k)             # (B,S,KV,hd)/(B,S,KV)
            vc, vs = quantize_rows(v)
            k_pages = Int8Pages(k_pages.codes.at[pids, offs].set(kc),
                                k_pages.scales.at[pids, offs].set(ks))
            v_pages = Int8Pages(v_pages.codes.at[pids, offs].set(vc),
                                v_pages.scales.at[pids, offs].set(vs))
        else:
            k_pages = k_pages.at[pids, offs].set(k.astype(k_pages.dtype))
            v_pages = v_pages.at[pids, offs].set(v.astype(v_pages.dtype))
        o = kops.paged_decode_attention(
            q.reshape(b * sq, h, cfg.head_dim), k_pages, v_pages,
            jnp.repeat(block_table, sq, axis=0), (pos2d + 1).reshape(-1),
            window=cfg.sliding_window, impl=cfg.paged_attn_impl)
        o_seq = o.reshape(b, sq, h, cfg.head_dim)
    y = linear_apply(params["o"],
                     o_seq.reshape(*x.shape[:-1], h * cfg.head_dim),
                     cfg)
    return y, {"k_pages": k_pages, "v_pages": v_pages}


def init_paged_kv_cache(cfg: ModelConfig, n_pages: int, page_size: int,
                        dtype=jnp.bfloat16, kv_dtype: Optional[str] = None,
                        ) -> dict:
    """Per-layer page arrays for the paged KV cache (DESIGN.md §9): K and V
    as (n_pages, page_size, KV, hd), either dense ``dtype`` buffers or
    int8 ``Int8Pages`` containers (``kv_dtype="int8"``). Page id 0 is the
    pool's reserved trash page for free-slot garbage writes."""
    shape = (n_pages, page_size, cfg.num_kv_heads, cfg.head_dim)
    if kv_dtype in ("int8", "i8"):
        from repro.paging.quant import Int8Pages
        return {"k_pages": Int8Pages.zeros(shape),
                "v_pages": Int8Pages.zeros(shape)}
    return {"k_pages": jnp.zeros(shape, dtype),
            "v_pages": jnp.zeros(shape, dtype)}


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  dtype=jnp.bfloat16) -> dict:
    """Per-layer KV cache. SWA models keep a rolling window-sized cache —
    that boundedness is what makes SWA decode sub-quadratic.

    decode_cache_shard == "flat": store (B, S, kv*hd) with the channel dim
    TP-sharded — the seq axis stays local (in-place one-token DUS) and
    GSPMD propagates the channel sharding to the natural (kv x hd) split
    through the reshape at the attention einsum (§Perf iteration A4)."""
    s = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    if cfg.cache_layout == "opt":
        # transpose-free dot layouts (§Perf A6): contracting dims minor-most
        return {"k": jnp.zeros((batch, cfg.num_kv_heads, s, cfg.head_dim),
                               dtype),
                "v": jnp.zeros((batch, cfg.num_kv_heads, cfg.head_dim, s),
                               dtype)}
    if cfg.decode_cache_shard == "flat":
        shape = (batch, s, cfg.num_kv_heads * cfg.head_dim)
    else:
        shape = (batch, s, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def _cache_view(c: jnp.ndarray, cfg: ModelConfig) -> jnp.ndarray:
    """(B, S, kv*hd) storage -> (B, S, kv, hd) compute view."""
    if c.ndim == 3:
        return c.reshape(c.shape[0], c.shape[1], cfg.num_kv_heads,
                         cfg.head_dim)
    return c


def _store_view(k: jnp.ndarray, cfg: ModelConfig, flat: bool) -> jnp.ndarray:
    if flat:
        return k.reshape(k.shape[0], k.shape[1], -1)
    return k
