"""Mamba2 (SSD — state-space duality) mixer: chunked training scan +
O(1)-state decode step. Used by the `ssm` family (mamba2-130m) and the
`hybrid` family (jamba; see DESIGN.md — we use SSD for jamba's Mamba layers).

The in/out projections are `layers.linear` layers (ternary-quantizable — the
paper's technique applies); the SSD state updates are activation-activation
einsums with no weights to ternarize (DESIGN.md §4).
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models.layers import FSDP, MODEL, _pdtype, linear_apply, linear_init

NEG_INF = -1e30


def ssm_init(key, cfg: ModelConfig):
    d, di = cfg.d_model, cfg.d_inner
    g, s, h = cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * g * s
    ks = jax.random.split(key, 4)
    # in_proj emits [z, x, B, C, dt]
    d_proj = 2 * di + 2 * g * s + h
    w_in, s_in = linear_init(ks[0], cfg, d, d_proj, FSDP, MODEL)
    w_out, s_out = linear_init(ks[1], cfg, di, d, MODEL, FSDP)
    params = {
        "in_proj": w_in,
        "out_proj": w_out,
        "conv_w": jax.random.normal(ks[2], (cfg.ssm_conv, conv_dim),
                                    _pdtype(cfg)) / math.sqrt(cfg.ssm_conv),
        "conv_b": jnp.zeros((conv_dim,), _pdtype(cfg)),
        "a_log": jnp.log(jnp.arange(1, h + 1, dtype=_pdtype(cfg))),
        "dt_bias": jnp.zeros((h,), _pdtype(cfg)),
        "d_skip": jnp.ones((h,), _pdtype(cfg)),
        "norm_scale": jnp.ones((di,), _pdtype(cfg)),
    }
    specs = {
        "in_proj": s_in,
        "out_proj": s_out,
        "conv_w": P(None, MODEL),
        "conv_b": P(MODEL),
        "a_log": P(None),
        "dt_bias": P(None),
        "d_skip": P(None),
        "norm_scale": P(MODEL),
    }
    return params, specs


def _split_proj(proj, cfg: ModelConfig):
    di, g, s, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xbc = proj[..., di:di + di + 2 * g * s]
    dt = proj[..., -h:]
    return z, xbc, dt


def _causal_conv(xbc: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 ) -> jnp.ndarray:
    """Depthwise causal conv over (B, L, C) via shifted adds (width small)."""
    width = w.shape[0]
    out = xbc * w[-1]
    for i in range(1, width):
        shifted = jnp.pad(xbc, ((0, 0), (i, 0), (0, 0)))[:, :-i]
        out = out + shifted * w[width - 1 - i]
    return jax.nn.silu(out + b)


def _segsum(a: jnp.ndarray) -> jnp.ndarray:
    """a: (..., q) -> (..., q, q); out[t, s] = sum_{s < r <= t} a[r]."""
    q = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((q, q), bool), 0)
    return jnp.where(mask, d, NEG_INF)


def ssd_chunked(x_dt: jnp.ndarray, a_dt: jnp.ndarray, bm: jnp.ndarray,
                cm: jnp.ndarray, chunk: int,
                init_state: Optional[jnp.ndarray] = None,
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """SSD chunked algorithm (Mamba2 paper, minimal form).

    x_dt: (B, L, H, P) inputs pre-multiplied by dt
    a_dt: (B, L, H)   log-decay per step (A * dt, negative)
    bm, cm: (B, L, H, S) input/output projections (groups pre-broadcast)
    Returns (y (B, L, H, P), final_state (B, H, P, S)).
    """
    b, l, h, p = x_dt.shape
    s = bm.shape[-1]
    q = min(chunk, l)
    assert l % q == 0, (l, q)
    nc = l // q

    def ch(t):  # (B, L, ...) -> (B, nc, q, ...)
        return t.reshape(b, nc, q, *t.shape[2:])

    xc, bc, cc = ch(x_dt), ch(bm), ch(cm)
    ac = ch(a_dt).transpose(0, 3, 1, 2)                     # (B, H, nc, q)
    a_cum = jnp.cumsum(ac, axis=-1)

    # 1) intra-chunk (the "quadratic attention-like" term)
    l_mat = jnp.exp(_segsum(ac))                            # (B,H,nc,q,q)
    y_diag = jnp.einsum("bcqhs,bckhs,bhcqk,bckhp->bcqhp",
                        cc, bc, l_mat.astype(cc.dtype), xc,
                        preferred_element_type=jnp.float32)

    # 2) per-chunk output states
    decay_states = jnp.exp(a_cum[..., -1:] - a_cum)         # (B,H,nc,q)
    states = jnp.einsum("bcqhs,bhcq,bcqhp->bchps",
                        bc, decay_states.astype(bc.dtype), xc,
                        preferred_element_type=jnp.float32)  # (B,nc,H,P,S)

    # 3) inter-chunk recurrence over chunk boundaries
    if init_state is None:
        init_state = jnp.zeros((b, h, p, s), jnp.float32)
    a_chunk = a_cum[..., -1]                                # (B,H,nc)
    decay_chunk = jnp.exp(_segsum(jnp.pad(a_chunk, ((0, 0), (0, 0), (1, 0)))))
    all_states = jnp.concatenate(
        [init_state[:, None].astype(jnp.float32),
         states.astype(jnp.float32)], axis=1)               # (B,nc+1,H,P,S)
    states_in = jnp.einsum("bhzc,bchps->bzhps", decay_chunk, all_states)
    final_state = states_in[:, -1]
    states_in = states_in[:, :-1]                           # entering each chunk

    # 4) inter-chunk contribution to outputs
    state_decay = jnp.exp(a_cum)                            # (B,H,nc,q)
    y_off = jnp.einsum("bcqhs,bchps,bhcq->bcqhp",
                       cc, states_in.astype(cc.dtype),
                       state_decay.astype(cc.dtype),
                       preferred_element_type=jnp.float32)
    y = (y_diag + y_off).reshape(b, l, h, p)
    return y.astype(x_dt.dtype), final_state


def _gated_norm(y, z, scale, eps):
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
            ).astype(y.dtype)


def ssm_apply(params, x: jnp.ndarray, cfg: ModelConfig, *,
              cache: Optional[dict] = None,
              cache_pos: Optional[jnp.ndarray] = None,
              ) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Full-sequence (train/prefill) or single-step (decode) Mamba2 mixer.

    cache = {"state": (B,H,P,S) f32, "conv": (B, conv-1, conv_dim)}.
    """
    di, g, s, h = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state, cfg.ssm_heads
    p = cfg.ssm_head_dim
    b = x.shape[0]
    proj = linear_apply(params["in_proj"], x, cfg)
    z, xbc, dt = _split_proj(proj, cfg)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))        # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))

    if cache_pos is None:
        # ---- full sequence ----
        xbc = _causal_conv(xbc, params["conv_w"].astype(x.dtype),
                           params["conv_b"].astype(x.dtype))
        xi = xbc[..., :di].reshape(b, -1, h, p)
        bm = xbc[..., di:di + g * s].reshape(b, -1, g, 1, s)
        cm = xbc[..., di + g * s:].reshape(b, -1, g, 1, s)
        bm = jnp.broadcast_to(bm, (b, bm.shape[1], g, h // g, s)
                              ).reshape(b, -1, h, s)
        cm = jnp.broadcast_to(cm, (b, cm.shape[1], g, h // g, s)
                              ).reshape(b, -1, h, s)
        x_dt = xi * dt[..., None].astype(xi.dtype)
        a_dt = a[None, None, :] * dt                          # (B,L,H)
        init_state = cache["state"] if cache is not None else None
        y, final_state = ssd_chunked(x_dt, a_dt, bm, cm, cfg.ssm_chunk,
                                     init_state)
        y = y + xi * params["d_skip"].astype(xi.dtype)[None, None, :, None]
        y = y.reshape(b, -1, di)
        new_cache = None
        if cache is not None:
            conv_tail = xbc_raw_tail(x, proj, cfg)
            new_cache = {"state": final_state,
                         "conv": conv_tail}
    else:
        # ---- single-token decode ----
        conv_cache = cache["conv"]                            # (B, w-1, conv_dim)
        xbc_new = xbc[:, 0]                                   # (B, conv_dim)
        window = jnp.concatenate([conv_cache, xbc_new[:, None]], axis=1)
        w = params["conv_w"].astype(x.dtype)                  # (w, conv_dim)
        conv_out = jnp.sum(window * w[None], axis=1) \
            + params["conv_b"].astype(x.dtype)
        xbc1 = jax.nn.silu(conv_out)                          # (B, conv_dim)
        xi = xbc1[..., :di].reshape(b, h, p)
        bm = xbc1[..., di:di + g * s].reshape(b, g, 1, s)
        bm = jnp.broadcast_to(bm, (b, g, h // g, s)).reshape(b, h, s)
        cm = xbc1[..., di + g * s:].reshape(b, g, 1, s)
        cm = jnp.broadcast_to(cm, (b, g, h // g, s)).reshape(b, h, s)
        dt1 = dt[:, 0]                                        # (B, H)
        # match the chunked path's numerics: decay factors and B/C/x*dt
        # round through the compute dtype, state accumulates in f32
        decay = jnp.exp(dt1 * a[None]).astype(x.dtype).astype(jnp.float32)
        state = cache["state"]                                # (B,H,P,S) f32
        xdt = (xi * dt1[..., None].astype(x.dtype)).astype(x.dtype)
        state = state * decay[..., None, None] \
            + jnp.einsum("bhp,bhs->bhps", xdt, bm,
                         preferred_element_type=jnp.float32)
        y = jnp.einsum("bhps,bhs->bhp", state.astype(x.dtype), cm,
                       preferred_element_type=jnp.float32)
        y = y.astype(x.dtype) + xi * params["d_skip"].astype(x.dtype)[None, :, None]
        y = y.reshape(b, 1, di)
        z = z[:, :1]
        new_cache = {"state": state, "conv": window[:, 1:]}

    y = _gated_norm(y, z.reshape(y.shape), params["norm_scale"], cfg.norm_eps)
    return linear_apply(params["out_proj"], y, cfg), new_cache


def xbc_raw_tail(x, proj, cfg: ModelConfig):
    """Last (conv-1) pre-conv xbc inputs — the decode conv cache."""
    di, g, s = cfg.d_inner, cfg.ssm_groups, cfg.ssm_state
    xbc = proj[..., di:di + di + 2 * g * s]
    return xbc[:, -(cfg.ssm_conv - 1):]


def init_ssm_cache(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    conv_dim = cfg.d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim,
                            cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_dim), dtype),
    }
