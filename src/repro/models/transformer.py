"""Model assembly: decoder-only / encoder-decoder / hybrid / VLM / audio LMs
from a ``ModelConfig``, with layers stacked under ``jax.lax.scan`` (HLO size
and compile time are O(1) in depth — required for 61-80 layer dry-runs).

Heterogeneous stacks (jamba's 1:7 attn:mamba interleave with MoE every 2nd
layer) scan over *period groups*: the layer pattern repeats with period p
(jamba: 8), params are stacked over L/p groups, and the scan body unrolls the
p distinct blocks.

Public API (all functional):
    m = LM(cfg)
    params, specs = m.init_with_specs(key)
    loss, metrics = m.loss(params, batch)
    cache = m.init_cache(batch_size, max_len)
    cache, logits = m.prefill(params, batch, max_len)
    logits, cache = m.decode_step(params, cache, tokens)
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention, layers, moe, ssm
from repro.models.layers import MODEL


def tree_nbytes(tree) -> int:
    """Total payload bytes across a cache/param tree's array leaves — the
    serving-memory figure of merit (dense slot pools and paged pools
    alike report it in the engine metrics)."""
    import numpy as np
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        nb = getattr(leaf, "nbytes", None)
        if nb is None:
            nb = int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        total += int(nb)
    return total


def _stack_tree(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _stack_specs(spec_tree):
    return jax.tree.map(
        lambda s: P(None, *s) if isinstance(s, P) else s, spec_tree,
        is_leaf=lambda x: isinstance(x, P))


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        kinds = [(cfg.layer_kind(i), cfg.layer_ffn(i))
                 for i in range(cfg.num_layers)]
        # smallest period p with L % p == 0 and kinds periodic
        p = 1
        while p <= cfg.num_layers:
            if cfg.num_layers % p == 0 and all(
                    kinds[i] == kinds[i % p] for i in range(cfg.num_layers)):
                break
            p += 1
        self.period = p
        self.n_groups = cfg.num_layers // p
        self.block_kinds = kinds[:p]          # [(mixer, ffn)] * period

    # ------------------------------------------------------------------
    # Init
    # ------------------------------------------------------------------
    def _block_init(self, key, kind: str, ffn: str, cross: bool):
        cfg = self.cfg
        ks = jax.random.split(key, 6)
        params: Dict[str, Any] = {}
        specs: Dict[str, Any] = {}
        params["norm1"], specs["norm1"] = layers.norm_init(ks[0], cfg, cfg.d_model)
        if kind == "attn":
            params["mixer"], specs["mixer"] = attention.attn_init(ks[1], cfg)
        else:
            params["mixer"], specs["mixer"] = ssm.ssm_init(ks[1], cfg)
        if cross:
            params["norm_cross"], specs["norm_cross"] = layers.norm_init(
                ks[2], cfg, cfg.d_model)
            params["cross"], specs["cross"] = attention.attn_init(ks[3], cfg)
        if ffn != "none":
            params["norm2"], specs["norm2"] = layers.norm_init(ks[4], cfg, cfg.d_model)
            if ffn == "moe":
                params["ffn"], specs["ffn"] = moe.moe_init(ks[5], cfg)
            else:
                params["ffn"], specs["ffn"] = layers.mlp_init(ks[5], cfg, cfg.d_ff)
        return params, specs

    def init_with_specs(self, key):
        cfg = self.cfg
        keys = jax.random.split(key, 8)
        params, specs = {}, {}
        params["embed"], specs["embed"] = layers.embed_init(keys[0], cfg)
        cross = cfg.is_encdec

        # decoder blocks, stacked over groups per period-offset
        for j, (kind, ffn) in enumerate(self.block_kinds):
            ps, ss = [], None
            for g in range(self.n_groups):
                k = jax.random.fold_in(keys[1], g * self.period + j)
                p_, s_ = self._block_init(k, kind, ffn, cross)
                ps.append(p_)
                ss = s_
            params[f"block{j}"] = _stack_tree(ps)
            specs[f"block{j}"] = _stack_specs(ss)

        if cfg.is_encdec:
            enc_ps, enc_ss = [], None
            for g in range(cfg.enc_layers):
                k = jax.random.fold_in(keys[2], g)
                p_, s_ = self._block_init(k, "attn", "mlp", cross=False)
                enc_ps.append(p_)
                enc_ss = s_
            params["enc_block"] = _stack_tree(enc_ps)
            specs["enc_block"] = _stack_specs(enc_ss)
            params["enc_norm"], specs["enc_norm"] = layers.norm_init(
                keys[3], cfg, cfg.d_model)

        params["final_norm"], specs["final_norm"] = layers.norm_init(
            keys[4], cfg, cfg.d_model)
        if not cfg.tie_embeddings:
            params["unembed"], specs["unembed"] = layers.unembed_init(keys[5], cfg)
        return params, specs

    def init(self, key):
        return self.init_with_specs(key)[0]

    def init_with_specs_abstract(self):
        """(param ShapeDtypeStructs, PartitionSpec tree) — no allocation.
        Specs are static python objects built during tracing; capture them
        through a side channel since eval_shape only maps array outputs."""
        captured = {}

        def f(key):
            params, specs = self.init_with_specs(key)
            captured["specs"] = specs
            return params

        shapes = jax.eval_shape(f, jax.ShapeDtypeStruct((2,), jnp.uint32))
        return shapes, captured["specs"]

    # ------------------------------------------------------------------
    # One block
    # ------------------------------------------------------------------
    def _apply_block(self, bparams, x, kind: str, ffn: str, *,
                     positions, causal=True, cache=None, cache_pos=None,
                     enc_out=None, block_table=None):
        cfg = self.cfg
        h = layers.norm_apply(bparams["norm1"], x, cfg)
        if kind == "attn":
            h, new_cache = attention.attn_apply(
                bparams["mixer"], h, cfg, positions=positions, causal=causal,
                cache=cache, cache_pos=cache_pos, block_table=block_table)
        else:
            h, new_cache = ssm.ssm_apply(
                bparams["mixer"], h, cfg, cache=cache, cache_pos=cache_pos)
        x = x + h
        if enc_out is not None and "cross" in bparams:
            hc = layers.norm_apply(bparams["norm_cross"], x, cfg)
            kv, hd = cfg.num_kv_heads, cfg.head_dim
            ek = layers.linear_apply(bparams["cross"]["k"], enc_out, cfg)
            ev = layers.linear_apply(bparams["cross"]["v"], enc_out, cfg)
            ek = ek.reshape(*enc_out.shape[:-1], kv, hd)
            ev = ev.reshape(*enc_out.shape[:-1], kv, hd)
            hc, _ = attention.attn_apply(
                bparams["cross"], hc, cfg, positions=positions,
                kv_override=(ek, ev))
            x = x + hc
        aux = jnp.zeros((), jnp.float32)
        if ffn != "none":
            h2 = layers.norm_apply(bparams["norm2"], x, cfg)
            if ffn == "moe":
                h2, aux = moe.moe_apply(bparams["ffn"], h2, cfg)
            else:
                h2 = layers.mlp_apply(bparams["ffn"], h2, cfg)
            x = x + h2
        return x, new_cache, aux

    # ------------------------------------------------------------------
    # Stacked decoder
    # ------------------------------------------------------------------
    def _run_stack(self, params, x, *, positions, causal=True,
                   caches=None, cache_pos=None, enc_out=None,
                   block_table=None):
        """caches: dict block{j} -> stacked (n_groups, ...) cache trees.
        ``block_table`` (paged decode) is layer-invariant, so it rides into
        the scan body as a closure constant rather than a sliced xs leaf."""
        cfg = self.cfg

        def body(carry, xs):
            x = carry
            aux_total = jnp.zeros((), jnp.float32)
            new_caches = {}
            for j, (kind, ffn) in enumerate(self.block_kinds):
                c = xs[f"cache{j}"] if caches is not None else None
                x, nc, aux = self._apply_block(
                    xs[f"block{j}"], x, kind, ffn, positions=positions,
                    causal=causal, cache=c, cache_pos=cache_pos,
                    enc_out=enc_out, block_table=block_table)
                aux_total += aux
                if nc is not None:
                    new_caches[f"cache{j}"] = nc
            return x, (new_caches, aux_total)

        # Remat only where there is a backward pass to save memory for —
        # wrapping the serving scans in jax.checkpoint makes XLA route the
        # full stacked KV cache through f32 select/convert chains every
        # layer step (measured +150 GB/chip/step on decode_32k; see
        # EXPERIMENTS.md §Perf iteration A1).
        if cfg.remat == "full" and caches is None:
            body = jax.checkpoint(body, prevent_cse=False)

        xs = {f"block{j}": params[f"block{j}"]
              for j in range(len(self.block_kinds))}
        if caches is not None:
            xs.update({f"cache{j}": caches[f"cache{j}"]
                       for j in range(len(self.block_kinds))
                       if f"cache{j}" in caches})
        x, (new_caches, auxs) = jax.lax.scan(body, x, xs)
        return x, new_caches, jnp.sum(auxs)

    def _run_encoder(self, params, enc_x):
        cfg = self.cfg
        positions = jnp.broadcast_to(
            jnp.arange(enc_x.shape[1]), enc_x.shape[:2])

        def body(x, bp):
            x, _, _ = self._apply_block(bp, x, "attn", "mlp",
                                        positions=positions, causal=False)
            return x, None

        if cfg.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, enc_x, params["enc_block"])
        return layers.norm_apply(params["enc_norm"], x, cfg)

    # ------------------------------------------------------------------
    # Forward / loss
    # ------------------------------------------------------------------
    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = layers.embed_apply(params["embed"], batch["tokens"], cfg)
        n_front = 0
        if "vision_embeds" in batch:
            ve = batch["vision_embeds"].astype(x.dtype)
            x = jnp.concatenate([ve, x], axis=1)
            n_front = ve.shape[1]
        return x, n_front

    def _logits(self, params, x):
        cfg = self.cfg
        if cfg.tie_embeddings:
            return jnp.dot(x, params["embed"]["table"].astype(x.dtype).T)
        return layers.unembed_apply(params["unembed"], x, cfg)

    def forward(self, params, batch):
        """Full-sequence forward -> (hidden (B,S,D), n_frontend, aux)."""
        cfg = self.cfg
        x, n_front = self._embed_inputs(params, batch)
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._run_encoder(params, batch["enc_embeds"].astype(x.dtype))
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x = _shard_act(x, P(("pod", "data"), None, None))
        x, _, aux = self._run_stack(params, x, positions=positions,
                                    causal=True, enc_out=enc_out)
        x = layers.norm_apply(params["final_norm"], x, cfg)
        return x, n_front, aux

    def loss(self, params, batch):
        """Causal-LM cross-entropy (chunked over seq if cfg.logits_chunk)."""
        cfg = self.cfg
        x, n_front, aux = self.forward(params, batch)
        x_text = x[:, n_front:]
        targets = batch["targets"]
        v = cfg.padded_vocab()

        def ce_of(xc, tc):
            logits = self._logits(params, xc).astype(jnp.float32)
            logz = jax.nn.logsumexp(logits, axis=-1)
            gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
            return logz - gold

        if cfg.logits_chunk and x_text.shape[1] % cfg.logits_chunk == 0:
            b, s, d = x_text.shape
            nc = s // cfg.logits_chunk
            xc = jnp.moveaxis(x_text.reshape(b, nc, cfg.logits_chunk, d), 1, 0)
            tc = jnp.moveaxis(targets.reshape(b, nc, cfg.logits_chunk), 1, 0)
            ce = jax.lax.map(lambda args: ce_of(*args), (xc, tc))
            ce = jnp.moveaxis(ce, 0, 1).reshape(b, s)
        else:
            ce = ce_of(x_text, targets)
        loss = jnp.mean(ce) + 0.01 * aux
        return loss, {"loss": loss, "ce": jnp.mean(ce), "aux": aux}

    # ------------------------------------------------------------------
    # Serving
    # ------------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int, dtype=None):
        cfg = self.cfg
        dtype = jnp.dtype(cfg.cache_dtype) if dtype is None else dtype
        caches = {}
        for j, (kind, _) in enumerate(self.block_kinds):
            if kind == "attn":
                one = attention.init_kv_cache(cfg, batch, max_len, dtype)
            else:
                one = ssm.init_ssm_cache(cfg, batch, dtype)
            caches[f"cache{j}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.n_groups, *x.shape)), one)
        return {"layers": caches, "pos": jnp.zeros((), jnp.int32)}

    def init_paged_cache(self, n_pages: int, page_size: int, batch: int,
                         dtype=None, kv_dtype=None):
        """Paged-cache layer tree (DESIGN.md §9): attention layers hold
        global page arrays (``n_pages`` shared across all slots, indexed
        through per-slot block tables), while SSM layers keep their O(1)
        per-slot rows — state paging buys nothing for constant-size state.
        Owned and indexed by ``repro.paging.PagePool``."""
        cfg = self.cfg
        dtype = jnp.dtype(cfg.cache_dtype) if dtype is None else dtype
        caches = {}
        for j, (kind, _) in enumerate(self.block_kinds):
            if kind == "attn":
                one = attention.init_paged_kv_cache(
                    cfg, n_pages, page_size, dtype, kv_dtype)
            else:
                one = ssm.init_ssm_cache(cfg, batch, dtype)
            caches[f"cache{j}"] = jax.tree.map(
                lambda x: jnp.broadcast_to(x, (self.n_groups, *x.shape)), one)
        return {"layers": caches}

    @staticmethod
    def insert_cache(pool_layers, req_layers, slots):
        """Slot-pool cache contract: write a freshly prefilled k-request
        cache (batch dim k, same max_len) into the batch rows ``slots``
        (scalar or (k,) vector) of a pool cache (batch dim max_slots).
        Every cache leaf is stacked as (n_groups, B, ...), so one tree-wide
        row scatter covers attention K/V and SSM state/conv alike. Used by
        ``repro.serving.SlotPool``."""
        slots = jnp.atleast_1d(slots)
        return jax.tree.map(
            lambda big, small: big.at[:, slots].set(
                small.astype(big.dtype)), pool_layers, req_layers)

    def cache_specs(self, decode_seq_sharded: bool = True):
        """PartitionSpec tree matching init_cache output."""
        cfg = self.cfg
        caches = {}
        seq_ax = MODEL if decode_seq_sharded else None
        hd_ax = None
        if cfg.decode_cache_shard == "heads":
            # shard head_dim: the seq axis stays local -> the per-token DUS
            # is an in-place write instead of a GSPMD select over the whole
            # cache shard (§Perf iteration A2)
            seq_ax, hd_ax = None, MODEL
        for j, (kind, _) in enumerate(self.block_kinds):
            if kind == "attn":
                if cfg.cache_layout == "opt":
                    # K (.., B, KV, S, hd) / V (.., B, KV, hd, S): seq
                    # TP-sharded on its new position (§Perf A6)
                    one = {"k": P(None, ("pod", "data"), None, seq_ax, None),
                           "v": P(None, ("pod", "data"), None, None, seq_ax)}
                elif cfg.decode_cache_shard == "flat":
                    # (n_groups, B, S, kv*hd): channel dim TP-sharded,
                    # seq local (§Perf iteration A4)
                    one = {"k": P(None, ("pod", "data"), None, MODEL),
                           "v": P(None, ("pod", "data"), None, MODEL)}
                else:
                    one = {"k": P(None, ("pod", "data"), seq_ax, None, hd_ax),
                           "v": P(None, ("pod", "data"), seq_ax, None, hd_ax)}
            else:
                one = {"state": P(None, ("pod", "data"), None, None, None),
                       "conv": P(None, ("pod", "data"), None, MODEL)}
            caches[f"cache{j}"] = one
        return {"layers": caches, "pos": P()}

    def prefill(self, params, batch, max_len: int, cache_dtype=jnp.bfloat16):
        """Run the prompt, fill caches, return (cache, last-position logits)."""
        cfg = self.cfg
        x, n_front = self._embed_inputs(params, batch)
        enc_out = None
        if cfg.is_encdec:
            enc_out = self._run_encoder(params, batch["enc_embeds"].astype(x.dtype))
        positions = jnp.broadcast_to(jnp.arange(x.shape[1]), x.shape[:2])
        x = _shard_act(x, P(("pod", "data"), None, None))
        cache0 = self.init_cache(x.shape[0], max_len, cache_dtype)
        x, new_caches, _ = self._run_stack(
            params, x, positions=positions, causal=True,
            caches=cache0["layers"], cache_pos=None, enc_out=enc_out)
        x = layers.norm_apply(params["final_norm"], x, cfg)
        logits = self._logits(params, x[:, -1:])
        cache = {"layers": new_caches,
                 "pos": jnp.asarray(x.shape[1], jnp.int32)}
        if cfg.is_encdec:
            cache["enc_out"] = enc_out
        return cache, logits

    def _decode_window_unrolled(self, cache) -> bool:
        """Whether a multi-token verify window must unroll per token.

        The batched window path (scatter all S tokens' K/V, then attend
        with causal-within-window masking) equals sequential decode only
        when a token's cache write cannot clobber state an earlier window
        token still reads: non-rolling attention caches in the bshd/flat
        layouts, paged included. Rolling SWA caches (a wrapped write
        overwrites the oldest live entry), the 'opt' delta-commit layout
        and SSM recurrences instead unroll inside the same call — bitwise
        equal to sequential decode by construction."""
        cfg = self.cfg
        if cfg.cache_layout == "opt":
            return True
        if any(kind != "attn" for kind, _ in self.block_kinds):
            return True
        if "block_table" in cache:        # paged pools reject SWA models
            return False
        if cfg.sliding_window:
            cache_len = cache["layers"]["cache0"]["k"].shape[2]
            return cache_len <= cfg.sliding_window      # rolling cache
        return False

    def decode_step(self, params, cache, tokens):
        """tokens: (B, S) -> (logits (B,S,V), updated cache). S is 1 for
        plain decode; S > 1 is a speculative-decoding *verify window*
        (DESIGN.md §10): the S tokens sit at consecutive positions
        pos..pos+S-1 and each position's logits equal what S sequential
        single-token calls would produce.

        ``cache["pos"]`` may be a scalar (classic batched decode: all rows
        at the same position) or a (B,) vector (continuous batching: each
        slot decodes at its own position; K/V writes scatter per slot).
        A ``cache["block_table"]`` entry switches attention layers to the
        paged cache path (pages + block tables, DESIGN.md §9)."""
        cfg = self.cfg
        pos = cache["pos"]
        sq = tokens.shape[1]
        if sq > 1 and self._decode_window_unrolled(cache):
            lgs, cur = [], cache
            for j in range(sq):
                lg, cur = self.decode_step(params, cur, tokens[:, j:j + 1])
                lgs.append(lg)
            return jnp.concatenate(lgs, axis=1), cur
        positions_src = pos[:, None] if jnp.ndim(pos) else pos
        x = layers.embed_apply(params["embed"], tokens, cfg)
        if sq == 1:
            positions = jnp.broadcast_to(positions_src, tokens.shape)
        else:
            positions = jnp.broadcast_to(positions_src + jnp.arange(sq),
                                         tokens.shape)
        x, new_caches, _ = self._run_stack(
            params, x, positions=positions, causal=True,
            caches=cache["layers"], cache_pos=pos,
            enc_out=cache.get("enc_out"),
            block_table=cache.get("block_table"))
        x = layers.norm_apply(params["final_norm"], x, cfg)
        logits = self._logits(params, x)
        # delta-mode commit (§Perf A7): the scan emitted per-layer K/V
        # tokens; write them all with one batched DUS per cache tensor.
        committed = {}
        for key, nc in new_caches.items():
            if isinstance(nc, dict) and "k_tok" in nc:
                old = cache["layers"][key]
                s_len = old["k"].shape[3]
                if cfg.sliding_window and s_len <= cfg.sliding_window:
                    slot = pos % s_len
                else:
                    slot = pos
                if jnp.ndim(slot):
                    # per-slot positions: scatter each batch row's token at
                    # its own offset (k_tok (L,B,KV,1,hd), v_tok (L,B,KV,hd,1))
                    rows = jnp.arange(slot.shape[0])
                    committed[key] = {
                        "k": old["k"].at[:, rows, :, slot, :].set(
                            nc["k_tok"][:, :, :, 0].transpose(1, 0, 2, 3)),
                        "v": old["v"].at[:, rows, :, :, slot].set(
                            nc["v_tok"][..., 0].transpose(1, 0, 2, 3)),
                    }
                else:
                    committed[key] = {
                        "k": jax.lax.dynamic_update_slice(
                            old["k"], nc["k_tok"], (0, 0, 0, slot, 0)),
                        "v": jax.lax.dynamic_update_slice(
                            old["v"], nc["v_tok"], (0, 0, 0, 0, slot)),
                    }
            else:
                committed[key] = nc
        new_cache = dict(cache, layers=committed, pos=pos + sq)
        return logits, new_cache


# ---------------------------------------------------------------------------
# Activation sharding constraint helper (no-op outside a mesh context)
# ---------------------------------------------------------------------------

_MESH = None


def set_mesh(mesh):
    """Install the mesh used to resolve activation sharding constraints."""
    global _MESH
    _MESH = mesh


def _shard_act(x, spec: P):
    if _MESH is None:
        return x
    names = set(_MESH.axis_names)

    def size_of(axes):
        n = 1
        for a in (axes if isinstance(axes, (tuple, list)) else (axes,)):
            n *= _MESH.shape[a]
        return n

    def fix(axes, dim):
        if axes is None:
            return None
        if isinstance(axes, (tuple, list)):
            kept = tuple(a for a in axes if a in names)
            if not kept or dim % size_of(kept) != 0:
                return None
            return kept
        if axes not in names or dim % size_of(axes) != 0:
            return None
        return axes

    entries = tuple(spec) + (None,) * (x.ndim - len(tuple(spec)))
    resolved = P(*(fix(a, d) for a, d in zip(entries, x.shape)))
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(_MESH, resolved))
