"""Mixture-of-Experts with token-choice top-k routing and capacity-bounded
expert-side dispatch (GShard-style dropping).

Dispatch is gather/scatter based — the expert matmuls are real
``(E, C, d) x (E, d, f)`` batched GEMMs whose FLOP count equals
``top_k * tokens * capacity_factor`` active-expert FLOPs, so the dry-run
``cost_analysis()`` reflects genuine MoE compute (a one-hot einsum dispatch
would quadratically over-count and poison the roofline).

Expert FFNs are ``layers.linear`` stacks, so ternary quantization (the
paper's technique) applies to every expert weight — with 384-expert models
(kimi-k2) the 16x weight compression is at its most valuable, since expert
weights dominate bytes moved.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import quantize, weights
from repro.models.layers import FSDP, MODEL, _is_ternary, _pdtype

EXPERT = "expert"   # logical axis: resolved to "model" when E % model == 0


def moe_init(key, cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)
    params = {
        "router": jax.random.normal(ks[0], (d, e), _pdtype(cfg)) * std,
    }
    specs = {
        "router": P(None, None),
    }
    if cfg.quantization == "ternary_packed":
        # serving format: TernaryWeight containers of 2-bit packed expert
        # banks + per-channel scales — 16x less weight bandwidth where it
        # matters most (expert weights dominate MoE bytes; the paper's
        # technique at its highest leverage)
        kw_d, kw_f = (d + 15) // 16, (f + 15) // 16

        def bank(kw, n, kdim):
            return weights.Dense2Bit(
                packed=jnp.zeros((e, kw, n), jnp.uint32),
                scale=jnp.ones((e, n), jnp.float32), bias=None,
                shape=(kdim, n))

        params.update({
            "w_in": bank(kw_d, f, d),
            "w_gate": bank(kw_d, f, d),
            "w_out": bank(kw_f, d, f),
        })
        specs.update({
            "w_in": params["w_in"].replace(
                packed=P(EXPERT, FSDP, MODEL), scale=P(EXPERT, MODEL)),
            "w_gate": params["w_gate"].replace(
                packed=P(EXPERT, FSDP, MODEL), scale=P(EXPERT, MODEL)),
            "w_out": params["w_out"].replace(
                packed=P(EXPERT, MODEL, FSDP), scale=P(EXPERT, FSDP)),
        })
    else:
        params.update({
            "w_in": jax.random.normal(ks[1], (e, d, f), _pdtype(cfg)) * std,
            "w_gate": jax.random.normal(ks[2], (e, d, f), _pdtype(cfg)) * std,
            "w_out": jax.random.normal(ks[3], (e, f, d), _pdtype(cfg))
            / math.sqrt(f),
        })
        specs.update({
            "w_in": P(EXPERT, FSDP, MODEL),
            "w_gate": P(EXPERT, FSDP, MODEL),
            "w_out": P(EXPERT, MODEL, FSDP),
        })
    if cfg.n_shared_experts:
        fs = cfg.n_shared_experts * f
        params["shared_in"] = jax.random.normal(ks[4], (d, fs), _pdtype(cfg)) * std
        params["shared_gate"] = jax.random.normal(ks[4], (d, fs), _pdtype(cfg)) * std
        params["shared_out"] = jax.random.normal(ks[4], (fs, d), _pdtype(cfg)) / math.sqrt(fs)
        specs["shared_in"] = P(FSDP, MODEL)
        specs["shared_gate"] = P(FSDP, MODEL)
        specs["shared_out"] = P(MODEL, FSDP)
    return params, specs


def _expert_weight(w, cfg: ModelConfig):
    if cfg.quantization == "ternary":
        # per-expert per-channel ternarization (vmapped STE)
        return jax.vmap(lambda wi: quantize.ste_ternarize(
            wi, cfg.ternary_threshold))(w)
    return w


def moe_apply(params, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, d) -> (y, aux_loss). Capacity C = ceil(T*k/E * cf).

    With ``cfg.moe_route_blocks = nb`` (aligned to the DP shard count),
    routing/capacity/gather/scatter are per token-block: every data-movement
    op stays shard-local and the only cross-shard communication is the
    dispatched (nb, E, C/nb, d) tensor meeting the model-sharded experts —
    an all-to-all of the *active* tokens instead of global-token all-reduces
    (§Perf D1: measured 488x f32[81936,7168] all-reduces on kimi train)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.num_experts_per_tok
    t = b * s
    nb = max(cfg.moe_route_blocks, 1)
    if t % nb != 0:
        nb = 1
    tb = t // nb
    xb = x.reshape(nb, tb, d)

    logits = jnp.einsum("ntd,de->nte", xb, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)  # (nb,Tb,E)
    top_p, top_ids = jax.lax.top_k(probs, k)                     # (nb,Tb,k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)      # renormalize

    # token-side sparse gate matrix (nb, Tb, E), built shard-locally
    gates = jnp.zeros((nb, tb, e), jnp.float32)
    gates = jax.vmap(jax.vmap(lambda g, i, p: g.at[i].set(p)))(
        gates, top_ids, top_p)

    # expert-side capacity truncation per block: top-C/nb tokens by gate
    cap = int(math.ceil(tb * k / e * cfg.capacity_factor))
    cap = min(max(cap, 1), tb)
    g_sel, tok_sel = jax.lax.top_k(
        jnp.swapaxes(gates, 1, 2), cap)                          # (nb,E,C)

    xe = jnp.take_along_axis(
        xb[:, None], tok_sel[..., None], axis=2)                 # (nb,E,C,d)
    # (§Perf D2 tried pinning the dispatch sharding here; measured: it
    # fights GSPMD propagation — t_coll 165 -> 436 s. Refuted; see
    # EXPERIMENTS.md §Perf cell D.)
    if isinstance(params["w_in"], weights.TernaryWeight):
        # packed expert banks: decode + scale into the compute dtype
        w_in = params["w_in"].materialize(x.dtype, with_scale=True)
        w_gate = params["w_gate"].materialize(x.dtype, with_scale=True)
        w_out = params["w_out"].materialize(x.dtype, with_scale=True)
    else:
        w_in = _expert_weight(params["w_in"], cfg).astype(x.dtype)
        w_gate = _expert_weight(params["w_gate"], cfg).astype(x.dtype)
        w_out = _expert_weight(params["w_out"], cfg).astype(x.dtype)
    h = jax.nn.silu(jnp.einsum("necd,edf->necf", xe, w_gate)) \
        * jnp.einsum("necd,edf->necf", xe, w_in)
    ye = jnp.einsum("necf,efd->necd", h, w_out)                  # (nb,E,C,d)
    ye = ye * g_sel[..., None].astype(ye.dtype)

    # per-block scatter-add back to token order (shard-local when nb == DP)
    y = jnp.zeros((nb, tb, d), ye.dtype)
    y = y.at[jnp.arange(nb)[:, None], tok_sel.reshape(nb, -1)].add(
        ye.reshape(nb, -1, d), mode="drop")

    if cfg.n_shared_experts:
        xt = xb.reshape(t, d)
        hs = jax.nn.silu(jnp.dot(xt, params["shared_gate"].astype(x.dtype))) \
            * jnp.dot(xt, params["shared_in"].astype(x.dtype))
        y = y + jnp.dot(hs, params["shared_out"].astype(x.dtype)
                        ).reshape(nb, tb, d)

    # Switch-style load-balancing auxiliary loss
    me = jnp.mean(probs, axis=(0, 1))                            # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(top_ids[..., 0], e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return y.reshape(b, s, d).astype(x.dtype), aux


def pack_moe(params: dict, cfg: ModelConfig) -> dict:
    """Host-side: convert a latent MoE node's expert banks ((E, K, N) or
    scan-stacked (L, E, K, N)) into packed ``Dense2Bit`` containers, each
    expert matrix ternarized per-channel. Router / shared-expert weights
    stay latent (they are small and always-on). Gated like
    ``layers.pack_linear``: an unquantized config (or experts below
    ``ternary_min_dim``) passes through untouched — packing is lossy and
    must never be applied unrequested."""
    if isinstance(params.get("w_in"), weights.TernaryWeight) \
            or "w_in" not in params \
            or not _is_ternary(cfg, *params["w_in"].shape[-2:]):
        return params
    out = {k: v for k, v in params.items()
           if k not in ("w_in", "w_gate", "w_out")}
    for name in ("w_in", "w_gate", "w_out"):
        out[name] = weights.pack(params[name], "dense2bit",
                                 threshold=cfg.ternary_threshold)
    return out
