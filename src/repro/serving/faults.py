"""Serving-side fault tolerance (DESIGN.md §11).

The continuous-batching engine's failure model, host-side. Two config
objects and one injector:

* ``FaultConfig`` + ``FaultInjector`` — a *deterministic, seeded* chaos
  schedule. Each scheduler step the injector draws a fixed number of
  uniforms from its own ``np.random.default_rng(seed)`` stream (the draw
  count never depends on engine state, so the schedule is reproducible
  run-to-run) and decides which faults fire: NaN-corrupted decode/verify
  logits for one live slot, forced page-pool allocation failures, an
  artificially slow step, or a draft-model failure. Explicit ``*_at`` step
  lists give tests an exact schedule; rates give soak runs a storm.
* ``ResilienceConfig`` — the engine's response policy: per-request
  deadlines, bounded retry-with-backoff on quarantines, and the graceful
  degradation ladder (auto-disable speculative decoding below a rolling
  acceptance floor; pause admission under page-pool pressure before the
  preemption storm). Every default is inert — an engine built without an
  explicit config behaves exactly as before, and the always-on numerical
  guard (a jit'd finite check on decode/verify logits) is bitwise-neutral
  on clean logits.

Failure semantics (the contract ``benchmarks/chaos_bench.py`` soaks):
every submitted request reaches a terminal state (``done`` or ``failed``
with a reason code), a quarantined/retried request replays to the *exact*
tokens an undisturbed run produces (greedy decode is deterministic), and
faults in one slot never perturb another slot's output.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["FaultConfig", "ResilienceConfig", "FaultInjector", "StepFaults",
           "FAIL_DEADLINE", "FAIL_NUMERIC", "FAIL_CANCELLED"]

# Terminal failure reason codes (``Request.fail_reason``).
FAIL_DEADLINE = "deadline"            # wall-clock deadline exceeded
FAIL_NUMERIC = "nan_logits"           # non-finite logits, retries exhausted
FAIL_CANCELLED = "cancelled"          # explicit user cancellation


@dataclasses.dataclass(frozen=True)
class FaultConfig:
    """Seeded chaos schedule for ``ContinuousScheduler(faults=...)``.

    Rates are per-scheduler-step probabilities; ``*_at`` tuples name exact
    step numbers (1-based, matching the engine's step counter) that fire
    unconditionally — the deterministic handle tests use. A NaN fault
    corrupts every logit of one seeded-randomly-chosen live slot inside
    the decode/verify jit (upstream of the finite guard, so the guard is
    exercised for real); an OOM fault makes the next ``oom_burst`` page
    allocations fail (paged cache only — the engine's defer/preempt
    machinery absorbs them); a slow fault sleeps ``slow_s`` (what pushes
    requests past their deadlines); a draft fault fails the speculative
    draft round, forcing a plain-decode fallback step."""

    seed: int = 0
    nan_rate: float = 0.0
    oom_rate: float = 0.0
    oom_burst: int = 2
    slow_rate: float = 0.0
    slow_s: float = 0.02
    draft_fail_rate: float = 0.0
    nan_at: Tuple[int, ...] = ()
    oom_at: Tuple[int, ...] = ()
    slow_at: Tuple[int, ...] = ()
    draft_fail_at: Tuple[int, ...] = ()


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Lifecycle-hardening policy for ``ContinuousScheduler(resilience=...)``.

    * ``deadline_s`` — default wall-clock budget per request measured from
      submit (``None``: no deadline). A request past its deadline is
      cancelled wherever it is (queued or mid-decode), its slot/pages are
      released, and it terminates ``failed`` with reason ``"deadline"``.
    * ``max_retries`` — quarantine replays allowed per request before it
      terminates ``failed`` (reason ``"nan_logits"``). Retries re-enqueue
      through the same replay machinery as paged preemption; greedy
      determinism makes a successful retry token-exact.
    * ``retry_backoff_s`` — base of the exponential re-admission backoff
      (attempt ``n`` waits ``retry_backoff_s * 2**(n-1)``); 0 retries
      immediately.
    * ``spec_accept_floor`` / ``spec_floor_window`` — degradation ladder
      rung 1: when the mean acceptance rate over the last ``window``
      speculative rounds drops below the floor, speculative decoding is
      disabled for the rest of the run (drafting a stream the draft cannot
      predict costs more than plain decode). 0.0 never disables.
    * ``admission_pause_frac`` — ladder rung 2 (paged cache): while the
      free-page fraction is below this and requests are live, admission
      pauses — live requests drain and release pages instead of new
      admissions triggering a preempt/replay storm. 0.0 never pauses.
    """

    deadline_s: Optional[float] = None
    max_retries: int = 2
    retry_backoff_s: float = 0.0
    spec_accept_floor: float = 0.0
    spec_floor_window: int = 16
    admission_pause_frac: float = 0.0


@dataclasses.dataclass
class StepFaults:
    """One step's fired faults (``FaultInjector.plan``)."""

    nan: bool = False
    oom: bool = False
    slow: bool = False
    draft_fail: bool = False


class FaultInjector:
    """Deterministic seeded fault scheduler + injection counters.

    ``plan(step)`` draws exactly four uniforms per call whatever fires, so
    the schedule depends only on the seed and the step sequence. Victim
    slots for NaN faults are drawn from the same stream at application
    time (``choose_slot``). ``injected`` counts faults actually applied —
    a NaN fault with no live slot, or an OOM fault on a dense cache,
    fizzles and is not counted."""

    def __init__(self, cfg: FaultConfig):
        self.cfg = cfg
        self._rng = np.random.default_rng(cfg.seed)
        self.injected: Dict[str, int] = {
            "nan_logits": 0, "page_oom": 0, "slow_step": 0, "draft_fail": 0}

    def plan(self, step: int) -> StepFaults:
        c = self.cfg
        u = self._rng.random(4)
        return StepFaults(
            nan=step in c.nan_at or u[0] < c.nan_rate,
            oom=step in c.oom_at or u[1] < c.oom_rate,
            slow=step in c.slow_at or u[2] < c.slow_rate,
            draft_fail=step in c.draft_fail_at or u[3] < c.draft_fail_rate)

    def choose_slot(self, live_slots: List[int]) -> Optional[int]:
        """Pick (and count) the NaN victim among the live slots, in slot
        order so the choice is independent of dict iteration history."""
        if not live_slots:
            return None
        victims = sorted(live_slots)
        slot = victims[int(self._rng.integers(len(victims)))]
        self.injected["nan_logits"] += 1
        return slot

    def count(self, kind: str) -> None:
        self.injected[kind] += 1

    @property
    def total_injected(self) -> int:
        return sum(self.injected.values())
