"""SLO-aware admission ordering and per-step chunk budgeting
(DESIGN.md §14).

``SLOQueue`` keeps every behavioural contract of the FIFO
``RequestQueue`` the engine already depends on — preempted requests
re-enter at the *absolute* head (they hold no cache but must win the
next admission to preserve drain progress), quarantine retries re-enter
at the tail, ``not_before`` backoff windows are honoured — but orders
ordinary admission by ``(priority, TTFT deadline, submit order)``
instead of arrival alone. Best-effort requests (no SLO class) get
priority 0 and an infinite deadline, so a workload with no classes
behaves exactly like FIFO.

``plan_chunks`` is the pure per-step token budgeter: given the
mid-prefill slots, the decode batch's token charge, and the step
budget, it decides how many prompt tokens each prefill advances this
step. Pure and host-only, so unit tests pin its policy without an
engine.
"""
from __future__ import annotations

import collections
import math
from typing import Deque, Dict, List, Optional, Sequence, Tuple

from repro.obs import clock as obs_clock
from repro.serving.queue import Request, RequestQueue
from repro.serving.sched.config import SchedConfig

_NO_DEADLINE = math.inf


def slo_key(req: Request) -> Tuple[int, float, int]:
    """Admission ordering key: (priority, TTFT deadline, enqueue seq).

    Priority strictly dominates (a priority-1 batch request never
    overtakes a priority-0 request, even when its deadline is nearer);
    within a priority level, earliest TTFT deadline first; submit order
    breaks ties. ``seq`` is re-stamped by ``requeue`` so retries fall to
    the tail of their (priority, deadline) cohort.
    """
    slo = req.slo
    pr = getattr(slo, "priority", 0) if slo is not None else 0
    ttft = getattr(slo, "ttft_target_s", None) if slo is not None else None
    dl = req.submit_t + ttft if ttft is not None else _NO_DEADLINE
    return (pr, dl, req.seq)


def ttft_deadline(req: Request) -> float:
    """Absolute TTFT deadline (monotonic clock), inf when untargeted."""
    return slo_key(req)[1]


class SLOQueue(RequestQueue):
    """Priority + earliest-deadline admission queue.

    Storage is an unordered list ordered on demand (queue depths here
    are tens-to-thousands; an O(n log n) sort per admission round is
    noise next to a model forward). Replays live in a separate deque
    that always wins ``peek``/``pop`` — preempt-at-head semantics are
    absolute, matching the FIFO queue.
    """

    def __init__(self):
        super().__init__()
        self._q: List[Request] = []                  # unordered
        self._replays: Deque[Request] = collections.deque()
        self._peeked: Optional[Request] = None

    # -- ordering core ----------------------------------------------------
    def _best(self, now: float) -> Optional[Request]:
        if self._replays:
            return self._replays[0]
        if not self._q:
            return None
        order = sorted(self._q, key=slo_key)
        for r in order:
            if r.not_before <= now:
                return r
        # Everything is inside its retry-backoff window: surface the
        # best-ranked request so the engine's not_before gate idles
        # (exactly what the FIFO head would do).
        return order[0]

    # -- RequestQueue surface ---------------------------------------------
    def pop(self) -> Request:
        req = self.peek()
        if req is None:
            raise IndexError(
                "pop from an empty SLOQueue — admission must guard on "
                ".empty() (or depth()) before popping")
        if self._replays and self._replays[0] is req:
            self._replays.popleft()
        else:
            self._q.remove(req)
        self._peeked = None
        return req

    def push_front(self, req: Request) -> None:
        req.state = "queued"
        self._replays.appendleft(req)
        self._peeked = None

    def requeue(self, req: Request) -> None:
        # Re-stamp the enqueue seq: a retried request re-enters behind
        # every already-waiting request of equal (priority, deadline) —
        # retry-at-tail, so a faulty request cannot camp on the head.
        req.state = "queued"
        req.seq = self.submitted + len(self._replays) + len(self._q)
        self.submitted = max(self.submitted, req.seq)  # keep seqs fresh
        self._q.append(req)
        self._peeked = None

    def submit(self, *args, **kwargs) -> Request:
        self._peeked = None
        return super().submit(*args, **kwargs)

    def peek(self) -> Optional[Request]:
        # Memoized so an engine's peek-then-pop (and grouped admission's
        # repeated peeks) see one consistent choice even as the clock
        # advances between calls.
        if self._peeked is not None and (
                (self._replays and self._replays[0] is self._peeked)
                or self._peeked in self._q):
            return self._peeked
        self._peeked = self._best(obs_clock.now())
        return self._peeked

    def empty(self) -> bool:
        return not (self._q or self._replays)

    def take_expired(self, now: float) -> List[Request]:
        dead = {r.rid for r in self._q if r.expired(now)}
        dead |= {r.rid for r in self._replays if r.expired(now)}
        if not dead:
            return []
        expired = [r for r in self._q if r.rid in dead]
        expired += [r for r in self._replays if r.rid in dead]
        self._q = [r for r in self._q if r.rid not in dead]
        self._replays = collections.deque(
            r for r in self._replays if r.rid not in dead)
        self._peeked = None
        return sorted(expired, key=lambda r: r.rid)

    def depth(self) -> int:
        return len(self._q) + len(self._replays)

    def __len__(self) -> int:
        return self.depth()

    def __bool__(self) -> bool:
        return bool(self._q or self._replays)


def plan_chunks(
    prefills: Sequence[Tuple[int, Request]],
    *,
    cfg: SchedConfig,
    budget: int,
    n_decode_tokens: int,
    max_len: int,
    now: float,
    step_s: float = 0.0,
    tpot_floor: Optional[float] = None,
) -> Tuple[List[Tuple[int, Request, int]], Dict[str, int]]:
    """Split this step's token budget across mid-prefill requests.

    prefills: (slot, request) pairs currently mid-prefill.
    budget: the step's total forward-token budget
        (``SchedConfig.budget_for``).
    n_decode_tokens: tokens the decode batch charges this step (live
        slots, times ``k + 1`` under spec).
    max_len: cache capacity per slot — bounds the rectangular chunk
        window so padded rows never write past the cache (DESIGN.md §14
        in-bounds cap).
    now / step_s: monotonic clock and recent per-step wall time, for
        deadline-pressure boosting.
    tpot_floor: tightest TPOT target among *live decode* requests, or
        None. When recent steps already exceed it, the prefill residual
        is halved — decode slots keep their TPOT, prefill absorbs the
        slack.

    Returns ``(jobs, meta)``: jobs are ``(slot, request, chunk_len)``
    with ``chunk_len >= 1``, ordered by ``slo_key`` (the order rows are
    packed into the chunk window), and meta records the budget split.
    """
    residual = budget - n_decode_tokens
    if tpot_floor is not None and step_s > tpot_floor and residual > 1:
        residual //= 2
    if residual <= 0 and prefills:
        # Liveness floor: a mid-prefill slot pins cache memory; under a
        # budget the decode batch alone saturates, still trickle one
        # token per step so held slots eventually reach decode.
        residual = 1
    meta = {"budget": budget, "decode_tokens": n_decode_tokens,
            "residual": residual, "assigned": 0, "window": 0}
    if not prefills or residual <= 0:
        return [], meta

    ordered = sorted(prefills, key=lambda sr: slo_key(sr[1]))
    jobs: List[Tuple[int, Request, int]] = []
    left = residual
    for slot, req in ordered:
        if left <= 0:
            break
        remaining = req.prompt_len - req.prefill_pos
        assert remaining > 0, (req.rid, req.prefill_pos, req.prompt_len)
        cap = cfg.chunk_tokens if cfg.chunk_tokens else remaining
        dl = ttft_deadline(req)
        if dl <= now + 2.0 * step_s:
            # Deadline-pressed (or already past): let it claim the whole
            # residual instead of one polite chunk.
            cap = remaining
        c = min(cap, remaining, left)
        if c <= 0:
            break
        jobs.append((slot, req, c))
        left -= c

    if jobs:
        # Rectangular-window in-bounds cap: every packed row writes S
        # positions starting at its prefill_pos (short rows are padded);
        # shrink S so no row's window crosses max_len. submit() asserts
        # prompt + max_new (+ spec headroom) <= max_len, so the cap
        # always leaves S >= 1.
        s = max(c for _, _, c in jobs)
        s = min(s, min(max_len - r.prefill_pos for _, r, _ in jobs))
        assert s >= 1, (s, [(r.rid, r.prefill_pos) for _, r, _ in jobs])
        # Round the window down to a power of two: the chunk forward is
        # jit-compiled per (rows, S) shape, and budget leftovers would
        # otherwise produce an unbounded set of odd widths (a fresh XLA
        # compile mid-traffic costs more than the tokens it carries).
        # Rounding down keeps the in-bounds cap intact; the remainder
        # just lands in the next round's window.
        s = 1 << (s.bit_length() - 1)
        jobs = [(slot, r, min(c, s)) for slot, r, c in jobs]
        meta["window"] = s
    meta["assigned"] = sum(c for _, _, c in jobs)
    return jobs, meta
