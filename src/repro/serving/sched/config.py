"""Scheduling configuration: request SLO classes and the chunked-prefill
token budget (DESIGN.md §14).

``SLOClass`` names a request class and its latency targets. Targets are
*objectives*, not guarantees: the scheduler orders admission by
(priority, TTFT deadline) and boosts chunk allocations for
deadline-pressed prefills, then reports per-class violation counts in
``run()``'s metrics so an operator can see how far reality landed from
the targets at a given offered load.

``SchedConfig`` switches the engine from grouped whole-prompt prefill to
chunked prefill: each step spends at most ``step_token_budget`` tokens of
model forward work — the decode batch is charged first (one token per
live slot; ``k + 1`` under speculative decoding), and mid-prefill
requests split the residual in chunks of at most ``chunk_tokens``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class SLOClass:
    """A request class with latency objectives.

    priority: lower runs first (ties broken by TTFT deadline, then
        submit order). Best-effort requests (``Request.slo is None``)
        get priority 0 and an infinite deadline, so an all-default
        workload degenerates to plain FIFO.
    ttft_target_s: time-to-first-token objective from submit; drives the
        admission deadline (``submit_t + ttft_target_s``) and the
        deadline-pressed chunk boost.
    tpot_target_s: decode time-per-output-token objective; classes with
        a TPOT target shrink the prefill residual when the engine's
        recent step time is already above the tightest live target.
    """
    name: str
    ttft_target_s: Optional[float] = None
    tpot_target_s: Optional[float] = None
    priority: int = 0


# A reasonable interactive/batch split for demos and the serve CLI;
# real deployments define their own.
DEFAULT_SLO_CLASSES: Tuple[SLOClass, ...] = (
    SLOClass("interactive", ttft_target_s=0.5, tpot_target_s=0.1,
             priority=0),
    SLOClass("batch", ttft_target_s=10.0, tpot_target_s=None, priority=1),
)


@dataclasses.dataclass(frozen=True)
class SchedConfig:
    """Chunked-prefill + admission policy knobs.

    chunk_tokens: max prompt tokens a request prefills per step. 0
        disables chunking (whole-prompt prefill with SLO-ordered
        admission only).
    step_token_budget: max model-forward tokens per engine step (decode
        charged first, prefill chunks fill the residual). 0 = automatic:
        ``max_slots + chunk_tokens``, i.e. a full decode batch plus one
        chunk.
    admission: "slo" orders the queue by (priority, TTFT deadline,
        submit order); "fifo" keeps plain FIFO admission (chunking
        still applies).
    """
    chunk_tokens: int = 64
    step_token_budget: int = 0
    admission: str = "slo"

    def __post_init__(self):
        assert self.chunk_tokens >= 0, self.chunk_tokens
        assert self.step_token_budget >= 0, self.step_token_budget
        assert self.admission in ("slo", "fifo"), self.admission

    @property
    def chunked(self) -> bool:
        return self.chunk_tokens > 0

    def budget_for(self, max_slots: int, spec_k: int = 0) -> int:
        """Effective per-step token budget for an engine with
        ``max_slots`` decode slots (each costing ``1 + spec_k`` verify
        tokens per step under speculative decoding)."""
        if self.step_token_budget:
            return self.step_token_budget
        return max_slots * (1 + spec_k) + max(self.chunk_tokens, 1)
