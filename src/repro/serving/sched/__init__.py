"""SLO-aware chunked-prefill scheduling (DESIGN.md §14).

Three cooperating pieces layered on the continuous-batching engine:

- ``config``: ``SLOClass`` (per-request-class TTFT/TPOT targets +
  priority) and ``SchedConfig`` (chunk size, per-step token budget,
  admission policy).
- ``slo``: ``SLOQueue`` — priority + earliest-TTFT-deadline admission
  ordering over ``RequestQueue`` semantics (preempt-at-head replays,
  retry-at-tail, ``not_before`` backoff) — and ``plan_chunks``, the pure
  deadline-aware token budgeter that splits each step's budget between
  the decode batch and prefill chunks.
- ``chunker``: ``ChunkRunner`` — the jit'd windowed forward that advances
  every mid-prefill slot by its planned chunk in one batched call,
  reusing the (B, S) decode window (bitwise-equal to sequential decode,
  DESIGN.md §10) over dense slot rows or paged block tables.
"""
from repro.serving.sched.chunker import ChunkRunner
from repro.serving.sched.config import DEFAULT_SLO_CLASSES, SchedConfig, SLOClass
from repro.serving.sched.slo import SLOQueue, plan_chunks

__all__ = [
    "ChunkRunner",
    "DEFAULT_SLO_CLASSES",
    "SLOClass",
    "SLOQueue",
    "SchedConfig",
    "plan_chunks",
]
