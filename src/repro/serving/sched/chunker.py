"""Chunked-prefill forward pass (DESIGN.md §14).

One engine step advances every mid-prefill slot by its planned chunk in
a single batched forward, reusing the (B, S) decode window that
speculative verify proved bitwise-equal to sequential decode
(DESIGN.md §10): the window scatters each row's S token K/V entries at
its own position offset *before* any query attends, and causal masking
keeps queries off positions at or beyond their own — so prefilling a
prompt 64 tokens at a time commits exactly the same cache bytes and
logits as the one-shot whole-prompt prefill. Token exactness vs
whole-prompt admission follows by greedy determinism.

Window packing: jobs are rectangularized to ``S = max(chunk)``; shorter
rows pad by repeating their last real token. Padded positions write
garbage K/V *inside the row's own slot/pages* at positions the row's
next chunk (or its first decode steps) overwrites before any real query
can attend there — the same overwrite-before-read invariant free-slot
garbage lanes already rely on. ``plan_chunks`` caps S so no padded row
writes past ``max_len`` (no reliance on XLA out-of-bounds scatter
semantics).

Compile discipline: a fresh XLA compile mid-traffic costs seconds — a
p99 disaster — so the window shape space is pinned small and warmed
ahead of time. The row dimension is always padded to the full slot
count (pad rows are write-discarded: dense rows live only in the
gathered copy that is never inserted back; paged pad rows carry an
all-zeros block table, routing every write to the trash page), and
``plan_chunks`` rounds S down to a power of two — so the only shapes
that exist are (max_slots, pow2), and ``warmup`` compiles them all at
``load()`` time.

The forward traces under ``ops.serving_phase("chunk")``: flattened GEMM
M = P·S rows — bigger than decode's GEMV, smaller than a grouped
prefill — gets its own autotune phase so chunk plans never thrash the
decode or prefill entries.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops as kops

__all__ = ["ChunkRunner"]


class ChunkRunner:
    """Jit'd chunk forward over dense slot rows or paged block tables,
    at a fixed row count (``rows`` = the engine's slot count)."""

    def __init__(self, model, max_len: int, paged: bool, rows: int):
        self.model = model
        self.max_len = max_len
        self.paged = paged
        self.rows = rows

        def fwd(params, layers, pos, toks, table=None):
            cache = {"layers": layers, "pos": pos}
            if table is not None:
                cache["block_table"] = table
            logits, new_cache = model.decode_step(params, cache, toks)
            greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            ok = jnp.all(jnp.isfinite(logits), axis=(1, 2))
            return new_cache["layers"], greedy, ok

        if paged:
            self._fwd = jax.jit(
                lambda p, layers, table, pos, toks:
                fwd(p, layers, pos, toks, table),
                donate_argnums=(1,))
        else:
            # donates the *gathered* P-row copy, never the pool tree
            self._fwd = jax.jit(
                lambda p, layers, pos, toks: fwd(p, layers, pos, toks),
                donate_argnums=(1,))
            self._gather = jax.jit(
                lambda layers, idx:
                jax.tree.map(lambda x: x[:, idx], layers))

    # ------------------------------------------------------------------
    def pack_window(self, jobs) -> Tuple[List[int], np.ndarray, np.ndarray]:
        """Rectangularize ``[(slot, req, c)]`` into the fixed-row batched
        window: real slot list, (rows,) start positions, (rows, S) tokens
        with repeat-last padding. Rows beyond ``len(jobs)`` are pad lanes
        (position 0, token 0) whose writes the caller discards."""
        slots = [s for s, _, _ in jobs]
        pos = np.zeros(self.rows, np.int32)
        s_max = max(c for _, _, c in jobs)
        toks = np.zeros((self.rows, s_max), np.int32)
        for i, (_, req, c) in enumerate(jobs):
            a = req.prefill_pos
            pos[i] = a
            toks[i, :c] = req.prompt[a:a + c]
            toks[i, c:] = req.prompt[a + c - 1]
        return slots, pos, toks

    def _pad_table(self, pool, slots) -> jnp.ndarray:
        """(rows, T) block table: real rows from the pool, pad rows all
        zeros — page 0 is the trash page, so pad-lane writes vanish by
        the same mechanism shared-prefix COW relies on."""
        table = np.zeros((self.rows, pool.table.shape[1]),
                         pool.table.dtype)
        if slots:
            table[:len(slots)] = pool.table[slots]
        return jnp.asarray(table)

    def advance(self, params, pool, jobs) -> Tuple[np.ndarray, np.ndarray]:
        """Run one chunk window over ``pool`` (mutating its cache tree in
        place) and return ``(greedy, ok)`` as host arrays aligned with
        ``jobs`` order: greedy[i, j] is the argmax after job i's token j
        (a completing row reads its first output token at its last real
        chunk position), ok[i] the per-row finite-logits guard."""
        slots, pos, toks = self.pack_window(jobs)
        dev_pos = jnp.asarray(pos)
        dev_toks = jnp.asarray(toks)
        if self.paged:
            with kops.serving_phase("chunk"):
                pool.layers, greedy, ok = self._fwd(
                    params, pool.layers, self._pad_table(pool, slots),
                    dev_pos, dev_toks)
        else:
            # pad lanes gather slot 0's rows; the garbage they compute
            # stays in the gathered copy, which is inserted back only at
            # the real slots
            idx = np.zeros(self.rows, np.int32)
            idx[:len(slots)] = slots
            gathered = self._gather(pool.layers, jnp.asarray(idx))
            with kops.serving_phase("chunk"):
                gathered, greedy, ok = self._fwd(
                    params, gathered, dev_pos, dev_toks)
            pool.insert(slots, jax.tree.map(lambda x: x[:, :len(slots)],
                                            gathered))
        n = len(jobs)
        return np.asarray(greedy)[:n], np.asarray(ok)[:n]

    def warmup(self, params, pool, windows) -> None:
        """Compile every (rows, S) window shape ahead of traffic: one
        all-pad forward per S in ``windows``. Pad-lane writes are
        discarded (dense) or routed to the trash page (paged), so the
        pool's cache content is untouched."""
        for s in windows:
            pos = jnp.zeros(self.rows, jnp.int32)
            toks = jnp.zeros((self.rows, int(s)), jnp.int32)
            with kops.serving_phase("chunk"):
                if self.paged:
                    pool.layers, _, _ = self._fwd(
                        params, pool.layers, self._pad_table(pool, []),
                        pos, toks)
                else:
                    gathered = self._gather(
                        pool.layers, jnp.zeros(self.rows, jnp.int32))
                    self._fwd(params, gathered, pos, toks)
