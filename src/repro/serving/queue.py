"""Request bookkeeping for the continuous-batching engine: one ``Request``
per user call (prompt, token budget, per-request timing/metrics) and a FIFO
``RequestQueue`` feeding the scheduler.

Metrics captured per request (emitted by ``engine.ContinuousScheduler`` as
JSON): time-to-first-token (queue wait + prefill), end-to-end latency, and
decode throughput. All timestamps are ``time.monotonic`` floats.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Deque, List, Optional

import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (prompt_len,) int32 token ids
    max_new: int                     # generation budget (tokens)
    eos_id: Optional[int] = None     # early-stop token (None: budget only)

    # scheduler-owned state / metrics
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    submit_t: float = 0.0
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None
    # speculative decoding (DESIGN.md §10): per-request draft stats
    spec_proposed: int = 0
    spec_accepted: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        if len(self.tokens) >= self.max_new:
            return True
        return bool(self.tokens and self.eos_id is not None
                    and self.tokens[-1] == self.eos_id)

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def latency_s(self) -> Optional[float]:
        if self.done_t is None:
            return None
        return self.done_t - self.submit_t

    def metrics(self) -> dict:
        return {
            "rid": self.rid,
            "prompt_len": self.prompt_len,
            "gen_len": len(self.tokens),
            "ttft_s": self.ttft_s,
            "latency_s": self.latency_s,
        }


class RequestQueue:
    """FIFO admission queue. ``submit`` stamps the enqueue time (so TTFT
    includes queue wait); the scheduler ``pop``s at admission."""

    def __init__(self):
        self._q: Deque[Request] = collections.deque()
        self._next_rid = 0
        self.submitted = 0

    def submit(self, prompt: np.ndarray, max_new: int,
               eos_id: Optional[int] = None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size > 0, "empty prompt"
        assert max_new >= 1, max_new
        req = Request(rid=self._next_rid, prompt=prompt, max_new=max_new,
                      eos_id=eos_id, submit_t=time.monotonic())
        self._next_rid += 1
        self.submitted += 1
        self._q.append(req)
        return req

    def pop(self) -> Request:
        return self._q.popleft()

    def push_front(self, req: Request) -> None:
        """Re-queue a preempted request at the head (it keeps its original
        ``submit_t`` and rid; ``submitted`` is not re-counted)."""
        self._q.appendleft(req)

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def depth(self) -> int:
        return len(self._q)

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
