"""Request bookkeeping for the continuous-batching engine: one ``Request``
per user call (prompt, token budget, per-request timing/metrics) and a FIFO
``RequestQueue`` feeding the scheduler.

Lifecycle (DESIGN.md §11): ``queued -> live -> done | failed``. A request
re-enters ``queued`` on preemption (paged OOM) or quarantine retry (numeric
fault); both replay the request from its original prompt, which greedy
determinism makes token-exact. ``failed`` is terminal and carries a reason
code (``serving.faults.FAIL_*``) so one bad request never wedges the pool —
it drains like any other, just without a full token stream.

Metrics captured per request (emitted by ``engine.ContinuousScheduler`` as
JSON): time-to-first-token (queue wait + prefill), end-to-end latency,
decode throughput, terminal state + failure reason, and retry attempts.
All timestamps are monotonic floats from ``repro.obs.clock`` — the one
clock source shared with the engine, the SLO queue, the traffic harness,
and the tracer, so deadlines, backoff windows, trace spans, and latency
metrics stay mutually comparable (and fake-able together in tests).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Deque, List, Optional

import numpy as np

from repro.obs import clock as obs_clock


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (prompt_len,) int32 token ids
    max_new: int                     # generation budget (tokens)
    eos_id: Optional[int] = None     # early-stop token (None: budget only)

    # lifecycle hardening (DESIGN.md §11)
    deadline_s: Optional[float] = None   # wall-clock budget from submit
    max_retries: Optional[int] = None    # None: engine's ResilienceConfig
    attempts: int = 0                    # quarantine replays so far
    not_before: float = 0.0              # retry-backoff re-admission gate
    state: str = "queued"                # queued | live | done | failed
    fail_reason: Optional[str] = None    # faults.FAIL_* when state=="failed"

    # SLO-aware scheduling (DESIGN.md §14): the request's class — a
    # ``serving.sched.SLOClass`` (duck-typed here to keep queue.py free of
    # the sched import) with ``priority`` / ``ttft_target_s`` /
    # ``tpot_target_s``. ``None`` = best-effort (FIFO-equivalent ordering
    # under the SLO queue). ``seq`` is the queue's enqueue counter —
    # re-stamped on retry so a retried request re-enters behind
    # equal-priority/equal-deadline waiters (retry-at-tail under EDF).
    slo: Optional[object] = None
    seq: int = 0

    # scheduler-owned state / metrics
    tokens: List[int] = dataclasses.field(default_factory=list)
    slot: Optional[int] = None
    submit_t: float = 0.0
    admit_t: Optional[float] = None      # slot granted / prefill started
    first_token_t: Optional[float] = None
    done_t: Optional[float] = None
    # chunked prefill (DESIGN.md §14): prompt tokens committed so far and
    # the number of chunk forwards this request rode in
    prefill_pos: int = 0
    chunks: int = 0
    # speculative decoding (DESIGN.md §10): per-request draft stats
    spec_proposed: int = 0
    spec_accepted: int = 0

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    @property
    def done(self) -> bool:
        if len(self.tokens) >= self.max_new:
            return True
        return bool(self.tokens and self.eos_id is not None
                    and self.tokens[-1] == self.eos_id)

    @property
    def terminal(self) -> bool:
        return self.state in ("done", "failed")

    def expired(self, now: float) -> bool:
        return (self.deadline_s is not None
                and now - self.submit_t > self.deadline_s)

    @property
    def ttft_s(self) -> Optional[float]:
        if self.first_token_t is None:
            return None
        return self.first_token_t - self.submit_t

    @property
    def queue_wait_s(self) -> Optional[float]:
        """Time from submit until a slot was granted (admission latency).
        TTFT == queue_wait_s + prefill_s by construction; ``admit_t`` is
        re-stamped on replay, so after preemption this reports the wait
        before the *successful* admission (matching ttft_s, which keeps
        the original submit_t)."""
        if self.admit_t is None:
            return None
        return self.admit_t - self.submit_t

    @property
    def prefill_s(self) -> Optional[float]:
        """Admission-to-first-token time (whole-prompt: one forward;
        chunked: all chunk forwards plus any steps spent waiting for
        token budget)."""
        if self.first_token_t is None or self.admit_t is None:
            return None
        return self.first_token_t - self.admit_t

    @property
    def tpot_s(self) -> Optional[float]:
        """Decode-phase time-per-output-token: (done - first token) over
        the tokens generated after the first. None until terminal or when
        only one token was generated (no decode phase to measure)."""
        if self.done_t is None or self.first_token_t is None:
            return None
        n = len(self.tokens) - 1
        if n <= 0:
            return None
        return (self.done_t - self.first_token_t) / n

    @property
    def latency_s(self) -> Optional[float]:
        if self.done_t is None:
            return None
        return self.done_t - self.submit_t

    def metrics(self) -> dict:
        return {
            "rid": self.rid,
            "prompt_len": self.prompt_len,
            "gen_len": len(self.tokens),
            "ttft_s": self.ttft_s,
            "queue_wait_s": self.queue_wait_s,
            "prefill_s": self.prefill_s,
            "tpot_s": self.tpot_s,
            "latency_s": self.latency_s,
            "state": self.state,
            "fail_reason": self.fail_reason,
            "attempts": self.attempts,
            "chunks": self.chunks,
            "slo": self.slo.name if self.slo is not None else None,
        }


class RequestQueue:
    """FIFO admission queue. ``submit`` stamps the enqueue time (so TTFT
    includes queue wait); the scheduler ``pop``s at admission. Replays
    (preemption) re-enter at the head; retries (quarantine) re-enter at the
    tail so a repeatedly-faulting request cannot starve the queue behind
    it."""

    def __init__(self):
        self._q: Deque[Request] = collections.deque()
        self._next_rid = 0
        self.submitted = 0

    def submit(self, prompt: np.ndarray, max_new: int,
               eos_id: Optional[int] = None,
               deadline_s: Optional[float] = None,
               max_retries: Optional[int] = None,
               slo: Optional[object] = None,
               submit_t: Optional[float] = None) -> Request:
        # submit_t (monotonic float) lets an open-loop driver stamp the
        # *intended* arrival instant rather than the moment this call ran:
        # a blocking engine step delays the submit() call itself, and
        # stamping late would silently erase exactly the queueing delay
        # TTFT exists to measure (DESIGN.md §14).
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size > 0, "empty prompt"
        assert max_new >= 1, max_new
        req = Request(rid=self._next_rid, prompt=prompt, max_new=max_new,
                      eos_id=eos_id, deadline_s=deadline_s,
                      max_retries=max_retries, slo=slo,
                      seq=self.submitted,
                      submit_t=(obs_clock.now() if submit_t is None
                                else submit_t))
        self._next_rid += 1
        self.submitted += 1
        self._q.append(req)
        return req

    def pop(self) -> Request:
        if not self._q:
            raise IndexError(
                "pop from an empty RequestQueue — admission must guard on "
                ".empty() (or depth()) before popping")
        return self._q.popleft()

    def push_front(self, req: Request) -> None:
        """Re-queue a preempted request at the head (it keeps its original
        ``submit_t`` and rid; ``submitted`` is not re-counted)."""
        req.state = "queued"
        self._q.appendleft(req)

    def requeue(self, req: Request) -> None:
        """Re-queue a quarantined request at the tail for its retry —
        behind already-waiting work, so a faulty request cannot hold the
        head across its backoff window."""
        req.state = "queued"
        self._q.append(req)

    def peek(self) -> Optional[Request]:
        return self._q[0] if self._q else None

    def empty(self) -> bool:
        return not self._q

    def take_expired(self, now: float) -> List[Request]:
        """Remove and return every queued request past its deadline (the
        engine fails them without wasting a prefill), in submit order
        (``rid`` order — rids are assigned monotonically at submit, and a
        ``push_front`` replay keeps its original rid, so ordering by rid
        is stable across preemption re-queues). O(depth); the engine only
        calls this when some request actually carries a deadline."""
        dead = {r.rid for r in self._q if r.expired(now)}
        if not dead:
            return []
        expired = sorted((r for r in self._q if r.rid in dead),
                         key=lambda r: r.rid)
        self._q = collections.deque(
            r for r in self._q if r.rid not in dead)
        return expired

    def depth(self) -> int:
        return len(self._q)

    def __len__(self) -> int:
        return len(self._q)

    def __bool__(self) -> bool:
        return bool(self._q)
