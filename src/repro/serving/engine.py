"""Continuous-batching scheduler over the slot pool.

Each scheduler step:

1. **admit** — while the queue is non-empty and a slot is free, pop a
   request, prefill it (B=1, its exact prompt length) and scatter the
   resulting cache into the allocated slot; the prefill's last-position
   argmax is the request's first token (TTFT stamps here).
2. **decode** — one jitted step over *all* ``max_slots`` rows with a
   per-slot position vector (``cache["pos"]`` as ``(B,)``): live slots each
   attend to their own valid prefix and scatter their token K/V at their own
   offset; free slots compute garbage that is never read and whose writes
   land in rows fully overwritten on the next admit.
3. **evict** — requests that hit their token budget (or EOS) release their
   slot back to the free list; the next step's admit refills it.

Short requests therefore drain and are replaced while long ones keep
decoding — no static-batch barrier. The decode jit compiles once (fixed
``max_slots`` batch); prefill compiles once per distinct (admission-group
size, prompt length) pair — bounded by ``max_slots`` sizes per length, a
deliberate trade against padding every admission to a full-pool prefill.

The decode hot loop is device-resident: cache, position and token vectors
stay on device, the greedy argmax runs inside the jit, and the only
per-step transfer is the ``(max_slots,)`` next-token vector the scheduler
needs for EOS/budget checks. Host state is pushed to the device only after
admit/evict events (O(requests), not O(tokens)).

Kernel selection: prefill traces under ``ops.serving_phase("prefill")``
(M=B·L GEMM-shaped) and decode under ``"decode"`` (M=slots GEMV-shaped), so
the block-shape autotuner keys the two phases separately.

Cache modes (DESIGN.md §9): ``cache="dense"`` is the original fixed
``max_slots x max_len`` slot pool (kept bit-exact as the A/B baseline);
``cache="paged"`` swaps in ``repro.paging.PagePool`` — per-request block
tables over a global page pool, on-demand page growth each decode step,
OOM-safe admission (requests defer instead of crashing), copy-on-write
prefix sharing, and preempt-and-replay (greedy decoding is deterministic,
so a preempted request replayed from its original prompt reproduces its
tokens exactly) when the pool runs dry mid-decode.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models import LM
from repro.serving.queue import Request, RequestQueue
from repro.serving.slots import SlotPool


class ContinuousScheduler:
    def __init__(self, cfg: ModelConfig, max_slots: int, max_len: int,
                 eos_id: Optional[int] = None, *, cache: str = "dense",
                 page_size: int = 16, n_pages: int = 0,
                 kv_dtype: Optional[str] = None, prefix_cache: bool = True,
                 paged_attn: Optional[str] = None):
        if cfg.is_encdec or cfg.family == "vlm":
            raise ValueError(
                f"family {cfg.family!r} needs per-request encoder/frontend "
                "state; use the static BatchedServer for it")
        assert cache in ("dense", "paged"), cache
        # paged_attn=None inherits cfg.paged_attn_impl; an explicit value
        # overrides it for this engine only
        if cache == "paged" and paged_attn is not None \
                and paged_attn != cfg.paged_attn_impl:
            cfg = dataclasses.replace(cfg, paged_attn_impl=paged_attn)
        self.cfg = cfg
        self.cache_mode = cache
        self.model = LM(cfg)
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.params = None
        self.queue = RequestQueue()
        if cache == "paged":
            from repro.paging import PagePool
            self.pool = PagePool(self.model, max_slots, max_len,
                                 page_size=page_size, n_pages=n_pages,
                                 kv_dtype=kv_dtype,
                                 prefix_cache=prefix_cache)
            self._dev_table = jnp.asarray(self.pool.table)
            self.pool.table_dirty = False
        else:
            self.pool = SlotPool(self.model, max_slots, max_len)
        self._live: Dict[int, Request] = {}          # slot -> request
        self._pos = np.zeros(max_slots, np.int32)    # host mirror
        self._tok = np.zeros(max_slots, np.int32)    # host mirror
        self._dev_pos = jnp.zeros(max_slots, jnp.int32)
        self._dev_tok = jnp.zeros(max_slots, jnp.int32)
        self._dirty = False           # host mirrors newer than device state
        self._finished: List[Request] = []
        self.total_drained = 0
        self.prefill_steps = 0
        self.decode_steps = 0
        self.preemptions = 0
        self.deferrals = 0
        self._depth_samples: List[int] = []
        self._live_samples: List[int] = []

        def prefill(params, toks):
            cache_, logits = self.model.prefill(params, {"tokens": toks},
                                                max_len)
            return cache_["layers"], jnp.argmax(logits[:, -1],
                                                axis=-1).astype(jnp.int32)

        def prefill_paged(params, toks):
            # page-aligned cache length: the pool scatters whole pages
            pad = -(-toks.shape[1] // page_size) * page_size
            cache_, logits = self.model.prefill(params, {"tokens": toks},
                                                pad)
            return cache_["layers"], jnp.argmax(logits[:, -1],
                                                axis=-1).astype(jnp.int32)

        def decode(params, layers, pos, toks):
            # free slots keep decoding garbage; clamp their write position
            # so it can never run past the cache (live rows are bounded by
            # the submit-time prompt+budget <= max_len assertion)
            cache_ = {"layers": layers,
                      "pos": jnp.minimum(pos, max_len - 1)}
            logits, new_cache = self.model.decode_step(params, cache_,
                                                       toks[:, None])
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            return new_cache["layers"], new_cache["pos"], nxt

        def decode_paged(params, layers, table, pos, toks):
            # free slots' block tables are all-zero, so their clamped
            # garbage writes land in the pool's reserved trash page 0
            cache_ = {"layers": layers,
                      "pos": jnp.minimum(pos, max_len - 1),
                      "block_table": table}
            logits, new_cache = self.model.decode_step(params, cache_,
                                                       toks[:, None])
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            return new_cache["layers"], new_cache["pos"], nxt

        self._prefill = jax.jit(prefill if cache == "dense"
                                else prefill_paged)
        self._decode = jax.jit(decode, donate_argnums=(1,))
        self._decode_paged = jax.jit(decode_paged, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def load(self, params) -> None:
        """Install params and precompute phase-keyed GEMM plans.

        Packed ``TernaryWeight`` containers live directly in the param
        pytree; for each of them every (M-bucket, phase) the hot loop can
        dispatch is planned *now* — prefill at the power-of-two M buckets
        up to ``max_slots * max_len`` (admission groups flatten to
        M = batch·prompt_len rows) and decode at M = ``max_slots`` — so the
        autotuner cache is warm before the first request and no serving
        step pays a first-call tune or cache write."""
        self.params = params
        top = max(self.max_slots * self.max_len, 1)
        # every pow2 bucket from M=1 up: a single short-prompt admission
        # (M = prompt_len < 8) must hit a warm entry too
        prefill_ms = [1 << i for i in range((top - 1).bit_length() + 1)]
        from repro.models.layers import gemm_impl
        self.gemm_plans = kops.precompute_plans(
            params, prefill_ms=prefill_ms, decode_ms=(self.max_slots,),
            # only packed linears dispatch through ternary_gemm; MoE expert
            # banks are materialized in moe_apply and need no GEMM plan
            select=lambda path, w: getattr(path[-1], "key", None)
            == "w_packed",
            # warm exactly the impl linear_apply will dispatch ("ref"
            # off-TPU touches no autotune state)
            impl=gemm_impl(self.cfg))

    def submit(self, prompt: np.ndarray, max_new: int) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size + max_new <= self.max_len, (
            f"prompt {prompt.size} + gen {max_new} exceeds max_len "
            f"{self.max_len}")
        return self.queue.submit(prompt, max_new, eos_id=self.eos_id)

    # ------------------------------------------------------------------
    def _prefill_group(self, group) -> None:
        """Prefill one admitted group and wire up per-request state.
        ``group`` is ``[(request, slot, Admission|None)]`` — the admission
        carries the paged pool's page plan, ``None`` in dense mode. Shared
        between both cache modes so their bookkeeping cannot diverge."""
        prompts = np.stack([r.prompt for r, _, _ in group])
        with kops.serving_phase("prefill"):
            req_layers, toks_dev = self._prefill(
                self.params, jnp.asarray(prompts))
        self.prefill_steps += 1
        if self.cache_mode == "paged":
            self.pool.insert([a for _, _, a in group], req_layers)
        else:
            self.pool.insert([s for _, s, _ in group], req_layers)
        toks = np.asarray(toks_dev)
        now = time.monotonic()
        for (req, slot, _), tok in zip(group, toks):
            req.slot = slot
            req.tokens.append(int(tok))
            req.first_token_t = now
            self._pos[slot] = req.prompt_len
            self._tok[slot] = tok
            self._live[slot] = req
            self._dirty = True
            if req.done:                 # max_new == 1 (or instant EOS)
                self._evict(slot)

    def _admit_paged(self) -> None:
        """Paged admission: a request is admitted only when the page pool
        can cover its whole prompt (shared prefix pages + fresh pages,
        reclaiming cold prefix pages under pressure). A request the pool
        cannot place right now *defers* — admission stops for this step and
        retries after the next round of evictions frees pages."""
        while self.queue and self.pool.n_free:
            adm = self.pool.admit(self.queue.peek().prompt)
            if adm is None:
                self.deferrals += 1
                return
            group = [(self.queue.pop(), adm.slot, adm)]
            plen = group[0][0].prompt_len
            deferred = False
            while (self.queue and self.pool.n_free
                   and self.queue.peek().prompt_len == plen):
                nxt = self.pool.admit(self.queue.peek().prompt)
                if nxt is None:
                    self.deferrals += 1
                    deferred = True
                    break
                group.append((self.queue.pop(), nxt.slot, nxt))
            self._prefill_group(group)
            if deferred:    # already counted — don't re-attempt this step
                return

    def _admit(self) -> None:
        if self.cache_mode == "paged":
            self._admit_paged()
            return
        while self.queue and self.pool.n_free:
            # grouped admission: prefill a FIFO run of equal-length prompts
            # (up to the free-slot count) as one batch — one kernel dispatch
            # and one pool scatter instead of k
            group = [self.queue.pop()]
            plen = group[0].prompt_len
            while (len(group) < self.pool.n_free and self.queue
                   and self.queue.peek().prompt_len == plen):
                group.append(self.queue.pop())
            self._prefill_group(
                [(req, self.pool.alloc(), None) for req in group])

    def _evict(self, slot: int) -> None:
        req = self._live.pop(slot)
        req.done_t = time.monotonic()
        req.slot = None
        self._pos[slot] = 0
        self._tok[slot] = 0
        self._dirty = True
        if self.cache_mode == "paged":
            self.pool.release(slot)
        else:
            self.pool.free(slot)
        self._finished.append(req)
        self.total_drained += 1

    def _preempt(self, slot: int) -> None:
        """Paged OOM recovery: release the slot's pages and replay the
        request from scratch later. Greedy decode is deterministic, so the
        replay regenerates the exact same tokens — preemption trades
        wasted compute for memory, never correctness."""
        req = self._live.pop(slot)
        self.pool.release(slot)
        self._pos[slot] = 0
        self._tok[slot] = 0
        self._dirty = True
        req.slot = None
        req.tokens.clear()
        req.first_token_t = None
        self.queue.push_front(req)
        self.preemptions += 1

    def _grow_paged(self) -> None:
        """Before each paged decode step, make every live row's write
        position appendable: allocate pages crossed into this step and COW
        shared pages about to be written. When the pool is dry, preempt the
        *youngest* live request and retry — the oldest request is never
        preempted while others live, which guarantees drain progress."""
        for slot in list(self._live):
            if slot not in self._live:       # preempted by an earlier turn
                continue
            while not self.pool.ensure_append(slot, int(self._pos[slot])):
                victim = next(reversed(self._live))
                self._preempt(victim)
                if victim == slot:
                    break

    def step(self) -> None:
        """One scheduler iteration: admit + prefill, decode, evict."""
        self._depth_samples.append(self.queue.depth())
        self._admit()
        if self.cache_mode == "paged":
            self._grow_paged()
        if not self._live:
            return
        self._live_samples.append(len(self._live))
        if self._dirty:
            self._dev_pos = jnp.asarray(self._pos)
            self._dev_tok = jnp.asarray(self._tok)
            self._dirty = False
        with kops.serving_phase("decode"):
            if self.cache_mode == "paged":
                if self.pool.table_dirty:
                    self._dev_table = jnp.asarray(self.pool.table)
                    self.pool.table_dirty = False
                self.pool.layers, self._dev_pos, self._dev_tok = \
                    self._decode_paged(self.params, self.pool.layers,
                                       self._dev_table, self._dev_pos,
                                       self._dev_tok)
            else:
                self.pool.layers, self._dev_pos, self._dev_tok = \
                    self._decode(self.params, self.pool.layers,
                                 self._dev_pos, self._dev_tok)
        self.decode_steps += 1
        toks = np.asarray(self._dev_tok)
        for slot in list(self._live):
            req = self._live[slot]
            req.tokens.append(int(toks[slot]))
            self._pos[slot] += 1
            self._tok[slot] = toks[slot]
            if req.done:
                self._evict(slot)

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        """Drain the queue completely; return the metrics JSON dict."""
        assert self.params is not None, "load(params) first"
        t0 = time.monotonic()
        n0 = self.total_drained
        p0, d0 = self.prefill_steps, self.decode_steps
        self._depth_samples = []
        self._live_samples = []
        budget = (self.queue.depth() + len(self._live)) * self.max_len + 1
        if self.cache_mode == "paged":
            # preempt-and-replay re-runs requests; each replay costs at most
            # max_len extra steps and the oldest-never-preempted rule bounds
            # the churn, but give the watchdog generous headroom
            budget *= 8
        while self.queue or self._live:
            assert budget > 0, "scheduler failed to make progress"
            budget -= 1
            self.step()
        wall = time.monotonic() - t0
        assert self.total_drained == self.queue.submitted, (
            "drained-request count != submitted count",
            self.total_drained, self.queue.submitted)
        done = self._finished[n0:]
        gen = sum(len(r.tokens) for r in done)
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        depths = self._depth_samples or [0]
        lives = self._live_samples or [0]
        cache_metrics: Dict[str, Any] = {
            "mode": self.cache_mode,
            "nbytes": int(self.pool.nbytes),
        }
        if self.cache_mode == "paged":
            cache_metrics.update(self.pool.stats())
            cache_metrics["preemptions"] = self.preemptions
            cache_metrics["deferrals"] = self.deferrals
        return {
            "engine": "continuous",
            "max_slots": self.max_slots,
            "max_len": self.max_len,
            "cache": cache_metrics,
            "concurrency": {"peak": int(np.max(lives)),
                            "mean": round(float(np.mean(lives)), 3)},
            "planned_gemms": len(getattr(self, "gemm_plans", {})),
            "per_request": [r.metrics() for r in done],
            "submitted": len(done),
            "drained": len(done),
            "generated_tokens": gen,
            "wall_s": round(wall, 4),
            "tok_per_s": round(gen / wall, 2) if wall > 0 else None,
            "prefill_steps": self.prefill_steps - p0,
            "decode_steps": self.decode_steps - d0,
            "ttft_s": {"mean": float(np.mean(ttfts)) if ttfts else None,
                       "max": float(np.max(ttfts)) if ttfts else None},
            "queue_depth": {"max": int(np.max(depths)),
                            "mean": float(np.mean(depths))},
        }
