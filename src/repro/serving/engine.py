"""Continuous-batching scheduler over the slot pool.

Each scheduler step:

1. **admit** — while the queue is non-empty and a slot is free, pop a
   request, prefill it (B=1, its exact prompt length) and scatter the
   resulting cache into the allocated slot; the prefill's last-position
   argmax is the request's first token (TTFT stamps here).
2. **decode** — one jitted step over *all* ``max_slots`` rows with a
   per-slot position vector (``cache["pos"]`` as ``(B,)``): live slots each
   attend to their own valid prefix and scatter their token K/V at their own
   offset; free slots compute garbage that is never read and whose writes
   land in rows fully overwritten on the next admit.
3. **evict** — requests that hit their token budget (or EOS) release their
   slot back to the free list; the next step's admit refills it.

Short requests therefore drain and are replaced while long ones keep
decoding — no static-batch barrier. The decode jit compiles once (fixed
``max_slots`` batch); prefill compiles once per distinct (admission-group
size, prompt length) pair — bounded by ``max_slots`` sizes per length, a
deliberate trade against padding every admission to a full-pool prefill.

The decode hot loop is device-resident: cache, position and token vectors
stay on device, the greedy argmax runs inside the jit, and the only
per-step transfer is the ``(max_slots,)`` next-token vector the scheduler
needs for EOS/budget checks. Host state is pushed to the device only after
admit/evict events (O(requests), not O(tokens)).

Kernel selection: prefill traces under ``ops.serving_phase("prefill")``
(M=B·L GEMM-shaped) and decode under ``"decode"`` (M=slots GEMV-shaped), so
the block-shape autotuner keys the two phases separately.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models import LM
from repro.serving.queue import Request, RequestQueue
from repro.serving.slots import SlotPool


class ContinuousScheduler:
    def __init__(self, cfg: ModelConfig, max_slots: int, max_len: int,
                 eos_id: Optional[int] = None):
        if cfg.is_encdec or cfg.family == "vlm":
            raise ValueError(
                f"family {cfg.family!r} needs per-request encoder/frontend "
                "state; use the static BatchedServer for it")
        self.cfg = cfg
        self.model = LM(cfg)
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.params = None
        self.queue = RequestQueue()
        self.pool = SlotPool(self.model, max_slots, max_len)
        self._live: Dict[int, Request] = {}          # slot -> request
        self._pos = np.zeros(max_slots, np.int32)    # host mirror
        self._tok = np.zeros(max_slots, np.int32)    # host mirror
        self._dev_pos = jnp.zeros(max_slots, jnp.int32)
        self._dev_tok = jnp.zeros(max_slots, jnp.int32)
        self._dirty = False           # host mirrors newer than device state
        self._finished: List[Request] = []
        self.total_drained = 0
        self.prefill_steps = 0
        self.decode_steps = 0
        self._depth_samples: List[int] = []

        def prefill(params, toks):
            cache, logits = self.model.prefill(params, {"tokens": toks},
                                               max_len)
            return cache["layers"], jnp.argmax(logits[:, -1],
                                               axis=-1).astype(jnp.int32)

        def decode(params, layers, pos, toks):
            # free slots keep decoding garbage; clamp their write position
            # so it can never run past the cache (live rows are bounded by
            # the submit-time prompt+budget <= max_len assertion)
            cache = {"layers": layers,
                     "pos": jnp.minimum(pos, max_len - 1)}
            logits, new_cache = self.model.decode_step(params, cache,
                                                       toks[:, None])
            nxt = jnp.argmax(logits[:, 0, :], axis=-1).astype(jnp.int32)
            return new_cache["layers"], new_cache["pos"], nxt

        self._prefill = jax.jit(prefill)
        self._decode = jax.jit(decode, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def load(self, params) -> None:
        """Install params and precompute phase-keyed GEMM plans.

        Packed ``TernaryWeight`` containers live directly in the param
        pytree; for each of them every (M-bucket, phase) the hot loop can
        dispatch is planned *now* — prefill at the power-of-two M buckets
        up to ``max_slots * max_len`` (admission groups flatten to
        M = batch·prompt_len rows) and decode at M = ``max_slots`` — so the
        autotuner cache is warm before the first request and no serving
        step pays a first-call tune or cache write."""
        self.params = params
        top = max(self.max_slots * self.max_len, 1)
        # every pow2 bucket from M=1 up: a single short-prompt admission
        # (M = prompt_len < 8) must hit a warm entry too
        prefill_ms = [1 << i for i in range((top - 1).bit_length() + 1)]
        from repro.models.layers import gemm_impl
        self.gemm_plans = kops.precompute_plans(
            params, prefill_ms=prefill_ms, decode_ms=(self.max_slots,),
            # only packed linears dispatch through ternary_gemm; MoE expert
            # banks are materialized in moe_apply and need no GEMM plan
            select=lambda path, w: getattr(path[-1], "key", None)
            == "w_packed",
            # warm exactly the impl linear_apply will dispatch ("ref"
            # off-TPU touches no autotune state)
            impl=gemm_impl(self.cfg))

    def submit(self, prompt: np.ndarray, max_new: int) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        assert prompt.size + max_new <= self.max_len, (
            f"prompt {prompt.size} + gen {max_new} exceeds max_len "
            f"{self.max_len}")
        return self.queue.submit(prompt, max_new, eos_id=self.eos_id)

    # ------------------------------------------------------------------
    def _admit(self) -> None:
        while self.queue and self.pool.n_free:
            # grouped admission: prefill a FIFO run of equal-length prompts
            # (up to the free-slot count) as one batch — one kernel dispatch
            # and one pool scatter instead of k
            group = [self.queue.pop()]
            plen = group[0].prompt_len
            while (len(group) < self.pool.n_free and self.queue
                   and self.queue.peek().prompt_len == plen):
                group.append(self.queue.pop())
            slots = [self.pool.alloc() for _ in group]
            prompts = np.stack([r.prompt for r in group])
            with kops.serving_phase("prefill"):
                req_layers, toks_dev = self._prefill(
                    self.params, jnp.asarray(prompts))
            self.prefill_steps += 1
            self.pool.insert(slots, req_layers)
            toks = np.asarray(toks_dev)
            now = time.monotonic()
            for req, slot, tok in zip(group, slots, toks):
                req.slot = slot
                req.tokens.append(int(tok))
                req.first_token_t = now
                self._pos[slot] = req.prompt_len
                self._tok[slot] = tok
                self._live[slot] = req
                self._dirty = True
                if req.done:                 # max_new == 1 (or instant EOS)
                    self._evict(slot)

    def _evict(self, slot: int) -> None:
        req = self._live.pop(slot)
        req.done_t = time.monotonic()
        req.slot = None
        self._pos[slot] = 0
        self._tok[slot] = 0
        self._dirty = True
        self.pool.free(slot)
        self._finished.append(req)
        self.total_drained += 1

    def step(self) -> None:
        """One scheduler iteration: admit + prefill, decode, evict."""
        self._depth_samples.append(self.queue.depth())
        self._admit()
        if not self._live:
            return
        if self._dirty:
            self._dev_pos = jnp.asarray(self._pos)
            self._dev_tok = jnp.asarray(self._tok)
            self._dirty = False
        with kops.serving_phase("decode"):
            self.pool.layers, self._dev_pos, self._dev_tok = self._decode(
                self.params, self.pool.layers, self._dev_pos, self._dev_tok)
        self.decode_steps += 1
        toks = np.asarray(self._dev_tok)
        for slot in list(self._live):
            req = self._live[slot]
            req.tokens.append(int(toks[slot]))
            self._pos[slot] += 1
            self._tok[slot] = toks[slot]
            if req.done:
                self._evict(slot)

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, Any]:
        """Drain the queue completely; return the metrics JSON dict."""
        assert self.params is not None, "load(params) first"
        t0 = time.monotonic()
        n0 = self.total_drained
        p0, d0 = self.prefill_steps, self.decode_steps
        self._depth_samples = []
        budget = (self.queue.depth() + len(self._live)) * self.max_len + 1
        while self.queue or self._live:
            assert budget > 0, "scheduler failed to make progress"
            budget -= 1
            self.step()
        wall = time.monotonic() - t0
        assert self.total_drained == self.queue.submitted, (
            "drained-request count != submitted count",
            self.total_drained, self.queue.submitted)
        done = self._finished[n0:]
        gen = sum(len(r.tokens) for r in done)
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        depths = self._depth_samples or [0]
        return {
            "engine": "continuous",
            "max_slots": self.max_slots,
            "max_len": self.max_len,
            "planned_gemms": len(getattr(self, "gemm_plans", {})),
            "per_request": [r.metrics() for r in done],
            "submitted": len(done),
            "drained": len(done),
            "generated_tokens": gen,
            "wall_s": round(wall, 4),
            "tok_per_s": round(gen / wall, 2) if wall > 0 else None,
            "prefill_steps": self.prefill_steps - p0,
            "decode_steps": self.decode_steps - d0,
            "ttft_s": {"mean": float(np.mean(ttfts)) if ttfts else None,
                       "max": float(np.max(ttfts)) if ttfts else None},
            "queue_depth": {"max": int(np.max(depths)),
                            "mean": float(np.mean(depths))},
        }
