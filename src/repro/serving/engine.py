"""Continuous-batching scheduler over the slot pool.

Each scheduler step:

1. **admit** — while the queue is non-empty and a slot is free, pop a
   request, prefill it (B=1, its exact prompt length) and scatter the
   resulting cache into the allocated slot; the prefill's last-position
   argmax is the request's first token (TTFT stamps here).
2. **decode** — one jitted step over *all* ``max_slots`` rows with a
   per-slot position vector (``cache["pos"]`` as ``(B,)``): live slots each
   attend to their own valid prefix and scatter their token K/V at their own
   offset; free slots compute garbage that is never read and whose writes
   land in rows fully overwritten on the next admit.
3. **evict** — requests that hit their token budget (or EOS) release their
   slot back to the free list; the next step's admit refills it.

Short requests therefore drain and are replaced while long ones keep
decoding — no static-batch barrier. The decode jit compiles once (fixed
``max_slots`` batch); prefill compiles once per distinct (admission-group
size, prompt length) pair — bounded by ``max_slots`` sizes per length, a
deliberate trade against padding every admission to a full-pool prefill.

The decode hot loop is device-resident: cache, position and token vectors
stay on device, the greedy argmax runs inside the jit, and the only
per-step transfer is the ``(max_slots,)`` next-token vector the scheduler
needs for EOS/budget checks. Host state is pushed to the device only after
admit/evict events (O(requests), not O(tokens)).

Kernel selection: prefill traces under ``ops.serving_phase("prefill")``
(M=B·L GEMM-shaped) and decode under ``"decode"`` (M=slots GEMV-shaped), so
the block-shape autotuner keys the two phases separately.

Cache modes (DESIGN.md §9): ``cache="dense"`` is the original fixed
``max_slots x max_len`` slot pool (kept bit-exact as the A/B baseline);
``cache="paged"`` swaps in ``repro.paging.PagePool`` — per-request block
tables over a global page pool, on-demand page growth each decode step,
OOM-safe admission (requests defer instead of crashing), copy-on-write
prefix sharing, and preempt-and-replay (greedy decoding is deterministic,
so a preempted request replayed from its original prompt reproduces its
tokens exactly) when the pool runs dry mid-decode.

Speculative decoding (DESIGN.md §10): ``spec=SpecConfig(...)`` replaces
the one-token decode with a draft -> verify -> rollback round. A cheap
draft model (``repro.spec.draft``) proposes ``k`` tokens per slot from its
own dense KV cache; the target verifies the whole ``(slots, k+1)`` window
in one forward traced under ``serving_phase("verify")`` (M = slots·(k+1)
GEMM-shaped — the regime the sparse ternary kernels are built for) and
accepts the longest exactly-matching prefix plus one bonus token. The
window forward is bitwise-equal to sequential decode, so spec serving is
token-exact vs the non-spec engine; rejected tokens roll back by length
bookkeeping (dense) plus O(1) tail-page reclamation (paged). Each round
emits 1..k+1 tokens per live slot.

Chunked prefill + SLO scheduling (DESIGN.md §14): ``sched=SchedConfig``
replaces grouped whole-prompt admission with chunked prefill — a request
is admitted the moment a slot (and, paged, its prompt's pages) is free,
then its prompt streams into the cache ``chunk_tokens`` at a time,
co-scheduled with the decode batch under a per-step token budget: the
decode batch is charged first, mid-prefill requests split the residual
(earliest TTFT deadline first, deadline-pressed requests claiming the
whole residual). The chunk forward reuses the (B, S) decode window that
speculative verify proved bitwise-equal to sequential decode, so chunked
output streams are token-exact vs whole-prompt admission. Admission
ordering comes from ``sched.SLOQueue`` (priority + earliest deadline,
preserving preempt-at-head / retry-at-tail / backoff semantics), and
``run()`` grows exact p50/p90/p99 TTFT/TPOT aggregates plus per-class
SLO violation counts. Chunked prefill shares spec's model restrictions
(attention-only, ``cache_layout='bshd'``, no sliding window): the
prefilling slots ride the decode batch as garbage lanes, which only the
overwrite-before-read attention argument makes safe.

Fault tolerance (DESIGN.md §11): every decode/verify step runs a jit'd
finite check over each slot's logits; a slot with non-finite logits is
*quarantined* — its uncommitted token is dropped, its slot/pages released,
and the request replays from its prompt (greedy determinism makes the
retry token-exact) up to ``ResilienceConfig.max_retries`` attempts before
terminating ``failed`` with a reason code. Per-request wall-clock
deadlines cancel requests wherever they are (queued or mid-decode). A
``FaultConfig`` arms the seeded chaos injector (NaN logits, forced page
OOM, slow steps, draft failures); the degradation ladder auto-disables
speculation below a rolling acceptance floor and pauses admission under
page-pool pressure. All of it surfaces in ``run()`` under ``faults{...}``.
"""
from __future__ import annotations

import collections
import dataclasses
import logging
import time
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels import ops as kops
from repro.models import LM
from repro.obs import clock as obs_clock
from repro.obs.metrics import MetricsRegistry, RunningStat, percentiles
from repro.serving.faults import (FAIL_DEADLINE, FAIL_NUMERIC, FaultConfig,
                                  FaultInjector, ResilienceConfig)
from repro.serving.queue import Request, RequestQueue
from repro.serving.sched import ChunkRunner, SchedConfig, SLOQueue
from repro.serving.sched.slo import plan_chunks
from repro.serving.slots import SlotPool

log = logging.getLogger("repro.serving")

# both primitives moved to repro.obs.metrics (DESIGN.md §15); the old
# private names stay importable for anything that grew against them
_RunningStat = RunningStat
_pcts = percentiles

# a step this many times slower than the step-time EWMA is a straggler —
# generous because serving steps legitimately vary (whole-prompt prefill
# vs GEMV decode); the signal targets pathological stalls, not phase mix
_STRAGGLER_FACTOR = 8.0


class ContinuousScheduler:
    def __init__(self, cfg: ModelConfig, max_slots: int, max_len: int,
                 eos_id: Optional[int] = None, *, cache: str = "dense",
                 page_size: int = 16, n_pages: int = 0,
                 kv_dtype: Optional[str] = None, prefix_cache: bool = True,
                 paged_attn: Optional[str] = None, spec=None,
                 faults: Optional[FaultConfig] = None,
                 resilience: Optional[ResilienceConfig] = None,
                 mesh=None, sched: Optional[SchedConfig] = None,
                 tracer=None):
        if cfg.is_encdec or cfg.family == "vlm":
            raise ValueError(
                f"family {cfg.family!r} needs per-request encoder/frontend "
                "state; use the static BatchedServer for it")
        assert cache in ("dense", "paged"), cache
        # paged_attn=None inherits cfg.paged_attn_impl; an explicit value
        # overrides it for this engine only
        if cache == "paged" and paged_attn is not None \
                and paged_attn != cfg.paged_attn_impl:
            cfg = dataclasses.replace(cfg, paged_attn_impl=paged_attn)
        self.cfg = cfg
        self.cache_mode = cache
        # every ad-hoc `self.x = 0; self.x += 1` counter below is
        # registry-backed (DESIGN.md §15) behind unchanged attribute
        # names — see the property block after the class body
        self.metrics = MetricsRegistry()
        # obs.trace.Tracer or None; None is the zero-cost path (one
        # attribute test per site, no clock read, no event)
        self.tracer = tracer
        self._trace_pid = tracer.new_pid("engine") if tracer is not None else 0
        if tracer is not None:
            tracer.thread_name(self._trace_pid, 0, "scheduler")
        # mesh != None = tensor-parallel engine (DESIGN.md §13): params
        # shard over the mesh's "model" axis at load(), the KV cache over
        # its head dim, and every jit below runs under GSPMD on the
        # mesh's devices. mesh=None is the unchanged single-device path.
        self.mesh = mesh
        self.model = LM(cfg)
        self.max_slots = max_slots
        self.max_len = max_len
        self.eos_id = eos_id
        if spec is not None:
            if spec.k < 1:
                raise ValueError(f"spec.k must be >= 1, got {spec.k}")
            if max_len < spec.k + 2:
                raise ValueError(f"max_len={max_len} leaves no room for a "
                                 f"k={spec.k} verify window")
            if any(kind != "attn" for kind, _ in self.model.block_kinds):
                raise ValueError(
                    "speculative decoding needs an attention-only stack: "
                    "SSM recurrent state advanced past a rejected token "
                    "cannot be rolled back by position bookkeeping")
            if cfg.cache_layout == "opt":
                raise ValueError("speculative decoding needs "
                                 "cache_layout='bshd' (the 'opt' "
                                 "delta-commit layout is one-token-only)")
            if cfg.sliding_window:
                raise ValueError(
                    "speculative decoding does not support rolling "
                    "sliding-window caches: a rejected window write "
                    "overwrites the oldest live entry, which rollback "
                    "cannot restore")
        self.spec = spec
        # ---- chunked prefill + SLO admission (DESIGN.md §14) ----
        if sched is not None and sched.chunked:
            if any(kind != "attn" for kind, _ in self.model.block_kinds):
                raise ValueError(
                    "chunked prefill needs an attention-only stack: "
                    "mid-prefill slots ride the decode batch as garbage "
                    "lanes, and SSM recurrent state advanced on garbage "
                    "tokens cannot be overwritten later")
            if cfg.cache_layout == "opt":
                raise ValueError("chunked prefill needs "
                                 "cache_layout='bshd' (the 'opt' "
                                 "delta-commit layout is one-token-only)")
            if cfg.sliding_window:
                raise ValueError(
                    "chunked prefill does not support rolling "
                    "sliding-window caches: padded chunk-window writes "
                    "would overwrite live rolled entries")
        self.sched = sched
        self.params = None
        self.queue = (SLOQueue() if sched is not None
                      and sched.admission == "slo" else RequestQueue())
        self._chunker = (ChunkRunner(self.model, max_len,
                                     paged=cache == "paged",
                                     rows=max_slots)
                         if sched is not None and sched.chunked else None)
        self._prefills: Dict[int, Request] = {}      # slot -> mid-prefill
        self.chunk_steps = 0
        self.chunk_tokens_committed = 0
        self.prefill_completions = 0
        self._chunk_meta = None       # last plan_chunks meta, for tracing
        # recent per-step wall time (EWMA) — drives the budgeter's
        # deadline-pressure and TPOT-protection heuristics, and (with
        # _STRAGGLER_FACTOR) flags anomalous steps through the same
        # registry mechanism the train supervisor's watchdog uses
        self._step_time = self.metrics.ewma("step_time_s", alpha=0.3)
        if cache == "paged":
            from repro.paging import PagePool
            self.pool = PagePool(self.model, max_slots, max_len,
                                 page_size=page_size, n_pages=n_pages,
                                 kv_dtype=kv_dtype,
                                 prefix_cache=prefix_cache)
            self._dev_table = jnp.asarray(self.pool.table)
            self.pool.table_dirty = False
        else:
            self.pool = SlotPool(self.model, max_slots, max_len)
        self._live: Dict[int, Request] = {}          # slot -> request
        self._pos = np.zeros(max_slots, np.int32)    # host mirror
        self._tok = np.zeros(max_slots, np.int32)    # host mirror
        # spec: second-newest committed token per slot (the draft round's
        # re-sync feed; see repro.spec.draft.make_draft_round)
        self._prev_tok = np.zeros(max_slots, np.int32)
        self._dev_pos = jnp.zeros(max_slots, jnp.int32)
        self._dev_tok = jnp.zeros(max_slots, jnp.int32)
        self._dev_prev = jnp.zeros(max_slots, jnp.int32)
        self._dirty = False           # host mirrors newer than device state
        self._finished: List[Request] = []
        self.total_drained = 0
        self.prefill_steps = 0
        self.decode_steps = 0
        self.preemptions = 0
        self.deferrals = 0
        self.spec_rounds = 0
        self.spec_slot_rounds = 0
        self.spec_proposed = 0
        self.spec_accepted = 0
        self.spec_emitted = 0
        self.spec_page_reclaims = 0
        self._depth_stat = RunningStat("queue_depth")
        self._live_stat = RunningStat("live_slots")

        # ---- fault tolerance (DESIGN.md §11) ----
        self.resilience = resilience or ResilienceConfig()
        self.injector = FaultInjector(faults) if faults is not None else None
        self._step_no = 0
        self._any_deadline = self.resilience.deadline_s is not None
        self.quarantines = 0
        self.fault_retries = 0
        self.failed_requests = 0
        self.admission_pauses = 0
        self.deadline_cancels = 0
        self.spec_disabled = False
        self.spec_disables = 0
        self.draft_fallbacks = 0
        self._accept_ring = collections.deque(
            maxlen=max(self.resilience.spec_floor_window, 1))
        # all-false NaN mask: the fault-free guard input (where() with an
        # all-false mask is bitwise-neutral on the logits)
        self._no_nan = jnp.zeros((max_slots,), jnp.bool_)

        if self.mesh is not None:
            # Commit every persistent device buffer onto the mesh now:
            # freshly created arrays are committed to the default device,
            # and mixing those with mesh-committed params in one jit is a
            # placement error. The KV cache shards on the head dim
            # (matching the column-split K/V projections); the small
            # scheduler vectors replicate. Host pushes inside step()
            # (jnp.asarray of a numpy mirror) stay uncommitted and follow
            # the computation, so only the init-time buffers need this.
            from repro.distributed import tp as tp_lib
            self.pool.layers = tp_lib.device_put_cache(
                self.pool.layers, cfg, self.mesh)
            (self._dev_pos, self._dev_tok, self._dev_prev,
             self._no_nan) = jax.device_put(
                (self._dev_pos, self._dev_tok, self._dev_prev,
                 self._no_nan),
                tp_lib.replicated_sharding(
                    (self._dev_pos, self._dev_tok, self._dev_prev,
                     self._no_nan), self.mesh))
            if cache == "paged":
                self._dev_table = jax.device_put(
                    self._dev_table,
                    tp_lib.replicated_sharding(self._dev_table, self.mesh))

        def prefill(params, toks):
            cache_, logits = self.model.prefill(params, {"tokens": toks},
                                                max_len)
            return cache_["layers"], jnp.argmax(logits[:, -1],
                                                axis=-1).astype(jnp.int32)

        def prefill_paged(params, toks):
            # page-aligned cache length: the pool scatters whole pages
            pad = -(-toks.shape[1] // page_size) * page_size
            cache_, logits = self.model.prefill(params, {"tokens": toks},
                                                pad)
            return cache_["layers"], jnp.argmax(logits[:, -1],
                                                axis=-1).astype(jnp.int32)

        def decode(params, layers, pos, toks, nan_mask):
            # free slots keep decoding garbage; clamp their write position
            # so it can never run past the cache (live rows are bounded by
            # the submit-time prompt+budget <= max_len assertion)
            cache_ = {"layers": layers,
                      "pos": jnp.minimum(pos, max_len - 1)}
            logits, new_cache = self.model.decode_step(params, cache_,
                                                       toks[:, None])
            # §11 numerical guard: fault injection corrupts masked rows
            # *before* the finite check (all-false mask = bitwise no-op);
            # a non-finite row quarantines its slot instead of committing
            row = jnp.where(nan_mask[:, None], jnp.nan, logits[:, 0, :])
            ok = jnp.all(jnp.isfinite(row), axis=-1)
            nxt = jnp.argmax(row, axis=-1).astype(jnp.int32)
            return new_cache["layers"], new_cache["pos"], nxt, ok

        def decode_paged(params, layers, table, pos, toks, nan_mask):
            # free slots' block tables are all-zero, so their clamped
            # garbage writes land in the pool's reserved trash page 0
            cache_ = {"layers": layers,
                      "pos": jnp.minimum(pos, max_len - 1),
                      "block_table": table}
            logits, new_cache = self.model.decode_step(params, cache_,
                                                       toks[:, None])
            row = jnp.where(nan_mask[:, None], jnp.nan, logits[:, 0, :])
            ok = jnp.all(jnp.isfinite(row), axis=-1)
            nxt = jnp.argmax(row, axis=-1).astype(jnp.int32)
            return new_cache["layers"], new_cache["pos"], nxt, ok

        self._prefill = jax.jit(prefill if cache == "dense"
                                else prefill_paged)
        self._decode = jax.jit(decode, donate_argnums=(1,))
        self._decode_paged = jax.jit(decode_paged, donate_argnums=(1,))

    # ------------------------------------------------------------------
    def load(self, params) -> None:
        """Install params and precompute phase-keyed GEMM plans.

        Packed ``TernaryWeight`` containers live directly in the param
        pytree; for each of them every (M-bucket, phase) the hot loop can
        dispatch is planned *now* — prefill at the power-of-two M buckets
        up to ``max_slots * max_len`` (admission groups flatten to
        M = batch·prompt_len rows) and decode at M = ``max_slots`` — so the
        autotuner cache is warm before the first request and no serving
        step pays a first-call tune or cache write.

        With a mesh, params are placed first: the model's logical
        PartitionSpecs resolve against the mesh (packed spec twins
        validated for pack-multiple shard boundaries) and the tree is
        ``device_put`` accordingly, so every jit below runs GSPMD-sharded.
        The precomputed plans then read each placed array's sharding and
        record the per-shard problem plus its collective (DESIGN.md §13)."""
        shard_fn = None
        if self.mesh is not None:
            from repro.distributed import tp as tp_lib
            _, spec_tree = self.model.init_with_specs_abstract()
            params = tp_lib.shard_params(params, spec_tree, self.mesh)
            shard_fn = tp_lib.gemm_shard_fn(self.mesh)
        self.params = params
        top = max(self.max_slots * self.max_len, 1)
        # every pow2 bucket from M=1 up: a single short-prompt admission
        # (M = prompt_len < 8) must hit a warm entry too
        prefill_ms = [1 << i for i in range((top - 1).bit_length() + 1)]
        from repro.models.layers import gemm_impl
        is_packed_linear = (lambda path, w:
                            getattr(path[-1], "key", None) == "w_packed")
        # chunk windows flatten to M = P·S rows, P <= max_slots rows of at
        # most chunk_tokens each (a deadline-pressed row can claim the
        # whole step budget) — warm every pow2 bucket up to that ceiling
        # under the "chunk" phase (DESIGN.md §14)
        chunk_ms = ()
        if self._chunker is not None:
            ctop = max(self.max_slots * self.sched.budget_for(
                self.max_slots, self.spec.k if self.spec else 0), 1)
            ctop = min(ctop, top)
            chunk_ms = [1 << i for i in range((ctop - 1).bit_length() + 1)]
        self.gemm_plans = kops.precompute_plans(
            params, prefill_ms=prefill_ms, decode_ms=(self.max_slots,),
            # verify windows flatten to M = slots·(k+1) rows; their plans
            # key under the "verify" phase so they never thrash the GEMV
            # decode entries (DESIGN.md §10)
            verify_ms=((self.max_slots * (self.spec.k + 1),)
                       if self.spec else ()),
            chunk_ms=chunk_ms,
            # only packed linears dispatch through ternary_gemm; MoE expert
            # banks are materialized in moe_apply and need no GEMM plan
            select=is_packed_linear,
            # warm exactly the impl linear_apply will dispatch ("ref"
            # off-TPU touches no autotune state)
            impl=gemm_impl(self.cfg),
            shard=shard_fn)
        # fused-MLP plans warm alongside (mlp_apply dispatches the fused
        # lowering for fully-packed MLP blocks when the Pallas path is on —
        # the fused autotune keys must be resolved before the hot loop too)
        if getattr(self.cfg, "fused_mlp", "auto") != "off" \
                and gemm_impl(self.cfg) != "ref":
            self.fused_plans = kops.precompute_fused_plans(
                params, prefill_ms=prefill_ms, decode_ms=(self.max_slots,),
                verify_ms=((self.max_slots * (self.spec.k + 1),)
                           if self.spec else ()),
                chunk_ms=chunk_ms)
        else:
            self.fused_plans = {}
        if self.spec is not None:
            from repro import spec as spec_lib
            self.draft = spec_lib.build_draft(self.spec, self.model, params)
            dlm = self.draft.model
            self._draft_layers = dlm.init_cache(self.max_slots,
                                                self.max_len)["layers"]
            if self.mesh is not None:
                # the draft is cheap: replicate it (and its cache) on the
                # mesh rather than TP-sharding it — token exactness needs
                # only the target's shards, and a replicated draft keeps
                # the draft round free of collectives
                from repro.distributed import tp as tp_lib
                dparams = jax.device_put(
                    self.draft.params, tp_lib.replicated_sharding(
                        self.draft.params, self.mesh))
                if dataclasses.is_dataclass(self.draft):
                    self.draft = dataclasses.replace(self.draft,
                                                     params=dparams)
                else:
                    self.draft.params = dparams
                self._draft_layers = jax.device_put(
                    self._draft_layers, tp_lib.replicated_sharding(
                        self._draft_layers, self.mesh))
            self._draft_insert = jax.jit(dlm.insert_cache,
                                         donate_argnums=(0,))

            def draft_prefill(dp, toks):
                c, _ = dlm.prefill(dp, {"tokens": toks}, self.max_len)
                return c["layers"]

            self._draft_prefill = jax.jit(draft_prefill)
            self._draft_round = spec_lib.make_draft_round(
                self.draft, self.max_len, self.spec.k)
            self._verify = spec_lib.make_verify_step(
                self.model, self.max_len, self.spec.k,
                paged=self.cache_mode == "paged", guard=True)
            # the draft's own packed GEMV decodes warm under "decode" too
            self.gemm_plans.update(
                (("draft",) + key, plan) for key, plan in
                kops.precompute_plans(
                    self.draft.params, decode_ms=(self.max_slots,),
                    select=is_packed_linear,
                    impl=gemm_impl(dlm.cfg)).items())
        if self._chunker is not None:
            # XLA-compile every chunk-window shape before traffic: rows
            # are always padded to max_slots and plan_chunks quantizes S
            # to powers of two <= min(budget, max_len), so the shape set
            # is small and closed — a mid-traffic compile costs seconds
            # and would wreck the p99 the scheduler exists to protect
            smax = min(self.sched.budget_for(
                self.max_slots, self.spec.k if self.spec else 0),
                self.max_len)
            self._chunker.warmup(
                self.params, self.pool,
                [1 << i for i in range(smax.bit_length())])
        # per-(phase, M-bucket) modeled roofline aggregates over the
        # warmed plans — attached to this engine's measured kernel-phase
        # trace spans so a trace carries measured-vs-modeled utilization
        # side by side (DESIGN.md §15)
        self._phase_model: Dict[tuple, Dict[str, float]] = {}
        self._modeled_memo: Dict[tuple, Optional[Dict[str, float]]] = {}
        for key, plan in self.gemm_plans.items():
            if key[0] == "draft":
                continue
            _, m, phase = key
            agg = self._phase_model.setdefault(
                (phase, m), {"gemms": 0, "modeled_flops": 0.0,
                             "modeled_bytes": 0.0, "model_time_s": 0.0})
            rl = plan.roofline()
            agg["gemms"] += 1
            agg["modeled_flops"] += rl["flops"]
            agg["modeled_bytes"] += rl["bytes"]
            agg["model_time_s"] += rl["model_time_s"]

    def _modeled(self, phase: str, m: int) -> Optional[Dict[str, float]]:
        """Modeled roofline aggregate for one kernel-phase span: the
        warmed plan bucket that dispatch would hit for ``m`` rows (the
        smallest planned bucket >= m, or the largest available).
        Memoized — the decode path asks the same (phase, m) every step
        and the answer is fixed once ``load()`` builds the buckets."""
        memo = getattr(self, "_modeled_memo", None)
        if memo is not None and (phase, m) in memo:
            return memo[(phase, m)]
        buckets = sorted(mb for ph, mb in
                         getattr(self, "_phase_model", {}) if ph == phase)
        if not buckets:
            out = None
        else:
            mb = next((b for b in buckets if b >= m), buckets[-1])
            out = dict(self._phase_model[(phase, mb)], m_bucket=mb)
        if memo is not None:
            memo[(phase, m)] = out
        return out

    def submit(self, prompt: np.ndarray, max_new: int, *,
               deadline_s: Optional[float] = None,
               max_retries: Optional[int] = None,
               slo=None, submit_t: Optional[float] = None) -> Request:
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        # spec mode reserves k positions of headroom: the last emitted
        # token's verify window writes up to position prompt+gen-1+k
        headroom = self.spec.k if self.spec is not None else 0
        assert prompt.size + max_new + headroom <= self.max_len, (
            f"prompt {prompt.size} + gen {max_new} + spec headroom "
            f"{headroom} exceeds max_len {self.max_len}")
        if deadline_s is None:
            deadline_s = self.resilience.deadline_s
        if deadline_s is not None:
            self._any_deadline = True
        req = self.queue.submit(prompt, max_new, eos_id=self.eos_id,
                                deadline_s=deadline_s,
                                max_retries=max_retries, slo=slo,
                                submit_t=submit_t)
        tr = self.tracer
        if tr is not None:
            tr.thread_name(self._trace_pid, req.rid + 1, f"req {req.rid}")
            tr.instant("submit", t=req.submit_t, cat="request",
                       pid=self._trace_pid, tid=req.rid + 1,
                       args={"rid": req.rid, "prompt_len": req.prompt_len,
                             "max_new": max_new,
                             "slo": slo.name if slo is not None else None})
        return req

    # ------------------------------------------------------------------
    # tracing helpers (DESIGN.md §15). Callers on hot paths guard with
    # `if self.tracer is not None` so the disabled engine pays exactly
    # one attribute test per site.
    def _trace_first_token(self, req: Request) -> None:
        """Retrospective TTFT components on the request's track, from the
        same clock stamps the metrics use: queue_wait (submit → admit)
        and prefill (admit → first token) sum to ``Request.ttft_s`` up
        to microsecond rounding."""
        tr, pid, tid = self.tracer, self._trace_pid, req.rid + 1
        tr.complete("queue_wait", req.submit_t, req.admit_t,
                    cat="request", pid=pid, tid=tid,
                    args={"rid": req.rid, "attempts": req.attempts})
        tr.complete("prefill", req.admit_t, req.first_token_t,
                    cat="request", pid=pid, tid=tid,
                    args={"rid": req.rid, "chunks": req.chunks})
        tr.instant("first_token", t=req.first_token_t, cat="request",
                   pid=pid, tid=tid, args={"rid": req.rid})

    def _trace_req(self, req: Request, name: str,
                   t: Optional[float] = None, **extra) -> None:
        tr = self.tracer
        if tr is not None:
            tr.instant(name, t=t, cat="request", pid=self._trace_pid,
                       tid=req.rid + 1, args={"rid": req.rid, **extra})

    # ------------------------------------------------------------------
    def _prefill_group(self, group) -> None:
        """Prefill one admitted group and wire up per-request state.
        ``group`` is ``[(request, slot, Admission|None)]`` — the admission
        carries the paged pool's page plan, ``None`` in dense mode. Shared
        between both cache modes so their bookkeeping cannot diverge."""
        t_admit = obs_clock.now()
        for req, _, _ in group:
            req.admit_t = t_admit       # slot granted; prefill starts now
        prompts = np.stack([r.prompt for r, _, _ in group])
        with kops.serving_phase("prefill"):
            req_layers, toks_dev = self._prefill(
                self.params, jnp.asarray(prompts))
        self.prefill_steps += 1
        tr = self.tracer
        if tr is not None:
            # host wall time of the dispatched (async) forward; the
            # np.asarray(toks_dev) below is the sync point, so the span
            # closes there — measured next to the plans' modeled roofline
            args = {"batch": len(group),
                    "prompt_len": int(prompts.shape[1]),
                    "m": int(prompts.size)}
            model = self._modeled("prefill", prompts.size)
            if model:
                args.update(model)
            np.asarray(toks_dev)
            tr.complete("prefill", t_admit, obs_clock.now(), cat="kernel",
                        pid=self._trace_pid, args=args)
        if self.cache_mode == "paged":
            self.pool.insert([a for _, _, a in group], req_layers)
        else:
            self.pool.insert([s for _, s, _ in group], req_layers)
        if self.spec is not None:
            # the draft keeps its own dense KV cache of the same stream
            with kops.serving_phase("prefill"):
                draft_layers = self._draft_prefill(self.draft.params,
                                                   jnp.asarray(prompts))
            self._draft_layers = self._draft_insert(
                self._draft_layers, draft_layers,
                jnp.asarray([s for _, s, _ in group]))
        toks = np.asarray(toks_dev)
        now = obs_clock.now()
        for (req, slot, _), tok in zip(group, toks):
            req.slot = slot
            req.state = "live"
            req.tokens.append(int(tok))
            req.first_token_t = now
            self._pos[slot] = req.prompt_len
            self._tok[slot] = tok
            self._prev_tok[slot] = req.prompt[-1]
            self._live[slot] = req
            self._dirty = True
            if tr is not None:
                self._trace_first_token(req)
            if req.done:                 # max_new == 1 (or instant EOS)
                self._evict(slot)

    def _head_ready(self, now: float) -> bool:
        """Admission gate: queue non-empty and the head request past its
        retry-backoff window. FIFO order is preserved — a backing-off head
        stalls admission for this step rather than being skipped."""
        if self.queue.empty():
            return False
        return self.queue.peek().not_before <= now

    def _admission_paused(self) -> bool:
        """Degradation ladder rung 2 (DESIGN.md §11): under page-pool
        pressure, pause admission while live requests drain — shedding
        load *before* the preempt-and-replay storm rather than during."""
        frac = self.resilience.admission_pause_frac
        if (not frac or self.cache_mode != "paged"
                or not (self._live or self._prefills)
                or self.queue.empty()):
            return False
        if self.pool.n_free_pages / self.pool.usable_pages < frac:
            self.admission_pauses += 1
            tr = self.tracer
            if tr is not None:
                tr.instant("admission_pause", pid=self._trace_pid,
                           args={"free_page_frac": round(
                               self.pool.n_free_pages
                               / self.pool.usable_pages, 4)})
            return True
        return False

    def _admit_paged(self, now: float) -> None:
        """Paged admission: a request is admitted only when the page pool
        can cover its whole prompt (shared prefix pages + fresh pages,
        reclaiming cold prefix pages under pressure). A request the pool
        cannot place right now *defers* — admission stops for this step and
        retries after the next round of evictions frees pages."""
        while self._head_ready(now) and self.pool.n_free:
            adm = self.pool.admit(self.queue.peek().prompt)
            if adm is None:
                self.deferrals += 1
                self._trace_req(self.queue.peek(), "defer")
                return
            group = [(self.queue.pop(), adm.slot, adm)]
            plen = group[0][0].prompt_len
            deferred = False
            while (self._head_ready(now) and self.pool.n_free
                   and self.queue.peek().prompt_len == plen):
                nxt = self.pool.admit(self.queue.peek().prompt)
                if nxt is None:
                    self.deferrals += 1
                    deferred = True
                    break
                group.append((self.queue.pop(), nxt.slot, nxt))
            self._prefill_group(group)
            if deferred:    # already counted — don't re-attempt this step
                return

    def _admit_chunked(self, now: float) -> None:
        """Chunked admission (DESIGN.md §14): grant a slot (and, paged,
        the prompt's pages — private ones only, see ``PagePool.admit``'s
        ``use_prefix``) the moment one is free; no prefill forward runs
        here. The request enters ``_prefills`` at ``prefill_pos=0`` and
        streams its prompt in via ``_run_chunks`` over subsequent
        steps."""
        while self._head_ready(now) and self.pool.n_free:
            req = self.queue.peek()
            if self.cache_mode == "paged":
                adm = self.pool.admit(req.prompt, use_prefix=False)
                if adm is None:
                    self.deferrals += 1
                    self._trace_req(req, "defer")
                    return
                slot = adm.slot
            else:
                slot = self.pool.alloc()
            popped = self.queue.pop()
            assert popped is req, (popped.rid, req.rid)
            req.slot = slot
            req.state = "live"
            req.prefill_pos = 0
            req.admit_t = obs_clock.now()
            self._prefills[slot] = req
            self._trace_req(req, "admit", t=req.admit_t, slot=slot)

    def _admit(self) -> None:
        now = obs_clock.now()
        if self._admission_paused():
            return
        if self._chunker is not None:
            self._admit_chunked(now)
            return
        if self.cache_mode == "paged":
            self._admit_paged(now)
            return
        while self._head_ready(now) and self.pool.n_free:
            # grouped admission: prefill a FIFO run of equal-length prompts
            # (up to the free-slot count) as one batch — one kernel dispatch
            # and one pool scatter instead of k
            group = [self.queue.pop()]
            plen = group[0].prompt_len
            while (len(group) < self.pool.n_free and self._head_ready(now)
                   and self.queue.peek().prompt_len == plen):
                group.append(self.queue.pop())
            self._prefill_group(
                [(req, self.pool.alloc(), None) for req in group])

    def _release_slot(self, slot: int) -> Request:
        """Common tail of every live-slot exit: pop the request (from the
        decode batch or the mid-prefill set), return the slot's cache
        (pages or dense row) to its pool, zero the host mirrors. Shared
        by evict/preempt/quarantine/fail so slot accounting cannot
        diverge between the happy and failure paths."""
        req = self._live.pop(slot, None)
        if req is None:
            req = self._prefills.pop(slot)
        req.slot = None
        self._pos[slot] = 0
        self._tok[slot] = 0
        self._prev_tok[slot] = 0
        self._dirty = True
        if self.cache_mode == "paged":
            self.pool.release(slot)
        else:
            self.pool.free(slot)
        return req

    def _evict(self, slot: int) -> None:
        req = self._release_slot(slot)
        req.state = "done"
        req.done_t = obs_clock.now()
        self._finished.append(req)
        self.total_drained += 1
        tr = self.tracer
        if tr is not None and req.first_token_t is not None:
            # the decode phase as one retrospective span: its dur over
            # (gen_len - 1) tokens is exactly Request.tpot_s
            tr.complete("decode", req.first_token_t, req.done_t,
                        cat="request", pid=self._trace_pid,
                        tid=req.rid + 1,
                        args={"rid": req.rid, "tokens": len(req.tokens)})
            self._trace_req(req, "done", t=req.done_t,
                            tokens=len(req.tokens))

    def _replay(self, slot: int) -> Request:
        """Reset a live request for a from-scratch replay (preemption or
        quarantine retry). Greedy decode is deterministic, so the replay
        regenerates the exact same tokens — replays trade wasted compute
        for memory/robustness, never correctness."""
        req = self._release_slot(slot)
        req.tokens.clear()
        req.first_token_t = None
        req.admit_t = None            # re-stamped at the retry admission
        req.prefill_pos = 0           # chunked prefill restarts from 0
        req.spec_proposed = 0         # replay re-counts draft stats
        req.spec_accepted = 0
        return req

    def _preempt(self, slot: int) -> None:
        """Paged OOM recovery: release the slot's pages and replay the
        request from scratch later; it re-enters at the queue *head* (the
        oldest-never-preempted rule in ``_grow_paged`` guarantees drain
        progress)."""
        req = self._replay(slot)
        self.queue.push_front(req)
        self.preemptions += 1
        self._trace_req(req, "preempt", slot=slot)

    def _fail_live(self, slot: int, reason: str) -> None:
        """Terminal failure of an in-flight request: slot and pages are
        reclaimed exactly as on eviction (refcount-clean), the request
        drains with ``state="failed"`` + reason code instead of wedging
        the pool."""
        req = self._release_slot(slot)
        self._fail(req, reason)

    def _fail(self, req: Request, reason: str) -> None:
        req.state = "failed"
        req.fail_reason = reason
        req.done_t = obs_clock.now()
        self._finished.append(req)
        self.total_drained += 1
        self.failed_requests += 1
        self._trace_req(req, "failed", t=req.done_t, reason=reason,
                        attempts=req.attempts)
        log.warning("request %d failed: %s (attempts=%d, %d tokens in)",
                    req.rid, reason, req.attempts, len(req.tokens))

    def _quarantine(self, slot: int) -> None:
        """Numerical-guard response (DESIGN.md §11): the slot produced
        non-finite logits this step. Its uncommitted token is dropped and
        the request retries from scratch (token-exact by greedy
        determinism) with exponential backoff, up to its retry budget;
        other slots are untouched — one poisoned row never kills the
        batch."""
        req = self._live.get(slot) or self._prefills[slot]
        self.quarantines += 1
        req.attempts += 1
        retries = (req.max_retries if req.max_retries is not None
                   else self.resilience.max_retries)
        if req.attempts > retries:
            self._fail_live(slot, FAIL_NUMERIC)
            return
        self.fault_retries += 1
        backoff = self.resilience.retry_backoff_s
        req.not_before = (obs_clock.now()
                          + backoff * (2 ** (req.attempts - 1))
                          if backoff else 0.0)
        self._trace_req(req, "quarantine", slot=slot,
                        attempts=req.attempts)
        self.queue.requeue(self._replay(slot))
        log.warning("quarantined slot %d (request %d): non-finite logits; "
                    "retry %d/%d", slot, req.rid, req.attempts, retries)

    def _expire_deadlines(self) -> None:
        """Cancel every request past its wall-clock deadline — queued
        requests before they waste a prefill, live ones mid-decode (their
        slot/pages are reclaimed refcount-clean)."""
        if not self._any_deadline:
            return
        now = obs_clock.now()
        for req in self.queue.take_expired(now):
            self._fail(req, FAIL_DEADLINE)
            self.deadline_cancels += 1
        for slot in list(self._live):
            if self._live[slot].expired(now):
                self._fail_live(slot, FAIL_DEADLINE)
                self.deadline_cancels += 1
        for slot in list(self._prefills):
            if self._prefills[slot].expired(now):
                self._fail_live(slot, FAIL_DEADLINE)
                self.deadline_cancels += 1

    def _grow_paged(self, horizon: int = 1) -> None:
        """Before each paged decode step, make every live row's next
        ``horizon`` write positions appendable: allocate pages crossed into
        this step and COW shared pages about to be written (spec mode
        grows the whole k+1 verify window, so every speculative write
        lands in a privately owned page). When the pool is dry, preempt
        the *youngest* live request and retry — the oldest request is
        never preempted while others live, which guarantees drain
        progress."""
        for slot in list(self._live):
            if slot not in self._live:       # preempted by an earlier turn
                continue
            p = 0
            while p < horizon:
                if self.pool.ensure_append(slot, int(self._pos[slot]) + p):
                    p += 1
                    continue
                # preempt mid-prefill slots before decoding ones: they
                # have produced no tokens yet, so replaying them wastes
                # the least work — and the oldest-never-preempted rule
                # still holds (a decode slot outranks every prefill)
                victim = (next(reversed(self._prefills))
                          if self._prefills
                          else next(reversed(self._live)))
                self._preempt(victim)
                if victim == slot:
                    break

    def _run_chunks(self) -> None:
        """Advance every mid-prefill slot by its planned chunk
        (DESIGN.md §14): budget the step's residual tokens across
        ``_prefills`` (earliest TTFT deadline first), run one batched
        chunk window, then commit — a request whose prompt completes this
        step reads its first token from the window's last real position
        and joins the decode batch immediately (spec mode additionally
        catches the draft cache up with a B=1 whole-prompt draft
        prefill)."""
        if not self._prefills:
            self._chunk_meta = None
            return
        spec_active = self.spec is not None and not self.spec_disabled
        k = self.spec.k if spec_active else 0
        tpots = [r.slo.tpot_target_s for r in self._live.values()
                 if r.slo is not None
                 and getattr(r.slo, "tpot_target_s", None) is not None]
        jobs, meta = plan_chunks(
            list(self._prefills.items()), cfg=self.sched,
            budget=self.sched.budget_for(self.max_slots, k),
            n_decode_tokens=len(self._live) * (1 + k),
            max_len=self.max_len, now=obs_clock.now(),
            step_s=self._step_ema,
            tpot_floor=min(tpots) if tpots else None)
        self._chunk_meta = meta
        if not jobs:
            return
        t_window = obs_clock.now()
        greedy, ok = self._chunker.advance(self.params, self.pool, jobs)
        self.chunk_steps += 1
        now = obs_clock.now()
        tr = self.tracer
        if tr is not None:
            args = {"rows": len(jobs),
                    "tokens": sum(c for _, _, c in jobs)}
            args.update(meta)
            model = self._modeled("chunk", len(jobs) * max(
                c for _, _, c in jobs))
            if model:
                args.update(model)
            tr.complete("chunk_window", t_window, now, cat="kernel",
                        pid=self._trace_pid, args=args)
        completed = []
        for i, (slot, req, c) in enumerate(jobs):
            if not ok[i]:
                self._quarantine(slot)
                continue
            if tr is not None:
                tr.complete("chunk", t_window, now, cat="request",
                            pid=self._trace_pid, tid=req.rid + 1,
                            args={"rid": req.rid, "tokens": c,
                                  "pos": req.prefill_pos})
            req.prefill_pos += c
            req.chunks += 1
            self.chunk_tokens_committed += c
            # the slot's garbage decode lane follows the prefill frontier;
            # its writes land at positions the next chunk (or the first
            # real decode) overwrites before any query attends there
            self._pos[slot] = req.prefill_pos
            self._dirty = True
            if req.prefill_pos >= req.prompt_len:
                tok = int(greedy[i, c - 1])
                del self._prefills[slot]
                self._live[slot] = req
                req.tokens.append(tok)
                req.first_token_t = now
                self._tok[slot] = tok
                self._prev_tok[slot] = int(req.prompt[-1])
                self.prefill_completions += 1
                if tr is not None:
                    self._trace_first_token(req)
                if req.done:             # max_new == 1 (or instant EOS)
                    self._evict(slot)
                elif self.spec is not None:
                    completed.append((slot, req))
        for slot, req in completed:
            # the draft runs its own dense whole-prompt prefill — cheap
            # (draft-sized), and chunking it would buy nothing since the
            # draft cache is not the serving-latency bottleneck
            with kops.serving_phase("prefill"):
                dl = self._draft_prefill(self.draft.params,
                                         jnp.asarray(req.prompt[None]))
            self._draft_layers = self._draft_insert(
                self._draft_layers, dl, jnp.asarray([slot]))

    def _plan_faults(self):
        """Draw this step's chaos schedule and apply the engine-external
        faults (sleep, armed page-OOM) immediately; NaN/draft faults are
        returned for the decode path to apply."""
        if self.injector is None:
            return None
        f = self.injector.plan(self._step_no)
        if f.slow:
            self.injector.count("slow_step")
            time.sleep(self.injector.cfg.slow_s)
        if f.oom and self.cache_mode == "paged":
            self.injector.count("page_oom")
            self.pool.inject_alloc_failures(self.injector.cfg.oom_burst)
        return f

    def _nan_mask(self, faults):
        """Device mask of the slots whose logits this step's schedule
        corrupts (all-false — a cached constant — when nothing fires)."""
        if faults is None or not faults.nan or not self._live:
            return self._no_nan
        victim = self.injector.choose_slot(list(self._live))
        mask = np.zeros(self.max_slots, bool)
        mask[victim] = True
        return jnp.asarray(mask)

    def step(self) -> None:
        """One scheduler iteration: inject scheduled faults, expire
        deadlines, admit (+ prefill, or advance chunked prefills), decode
        (or the spec draft -> verify -> rollback round) under the
        numerical guard, evict/quarantine."""
        self._step_no += 1
        t_step = obs_clock.now()
        faults = self._plan_faults()
        self._expire_deadlines()
        self._depth_stat.push(self.queue.depth())
        self._admit()
        if self._chunker is not None:
            self._run_chunks()
        # a draft fault (or the acceptance-floor ladder) downgrades this
        # step to plain one-token decode; growth only needs horizon 1 then
        spec_active = self.spec is not None and not self.spec_disabled
        draft_down = (spec_active and faults is not None
                      and faults.draft_fail)
        if draft_down:
            self.injector.count("draft_fail")
            self.draft_fallbacks += 1
            if self.tracer is not None:
                self.tracer.instant("draft_fallback", pid=self._trace_pid,
                                    args={"step": self._step_no})
        if self.cache_mode == "paged":
            self._grow_paged(1 + (self.spec.k
                                  if spec_active and not draft_down else 0))
        if not self._live:
            if self._prefills:       # chunk-only step: still real work
                self._note_step_time(t_step)
            return
        self._live_stat.push(len(self._live) + len(self._prefills))
        if self._dirty:
            self._dev_pos = jnp.asarray(self._pos)
            self._dev_tok = jnp.asarray(self._tok)
            if self.spec is not None:
                self._dev_prev = jnp.asarray(self._prev_tok)
            self._dirty = False
        if spec_active and not draft_down:
            self._step_spec(faults)
            self._note_step_time(t_step)
            return
        mask = self._nan_mask(faults)
        t_decode = obs_clock.now()
        with kops.serving_phase("decode"):
            if self.cache_mode == "paged":
                if self.pool.table_dirty:
                    self._dev_table = jnp.asarray(self.pool.table)
                    self.pool.table_dirty = False
                self.pool.layers, self._dev_pos, self._dev_tok, ok_dev = \
                    self._decode_paged(self.params, self.pool.layers,
                                       self._dev_table, self._dev_pos,
                                       self._dev_tok, mask)
            else:
                self.pool.layers, self._dev_pos, self._dev_tok, ok_dev = \
                    self._decode(self.params, self.pool.layers,
                                 self._dev_pos, self._dev_tok, mask)
        self.decode_steps += 1
        toks = np.asarray(self._dev_tok)
        ok = np.asarray(ok_dev)
        tr = self.tracer
        if tr is not None:
            # the np.asarray reads above are the sync point, so this span
            # covers dispatch + device execution of the decode forward
            args = {"live": len(self._live), "m": self.max_slots}
            model = self._modeled("decode", self.max_slots)
            if model:
                args.update(model)
            tr.complete("decode_step", t_decode, obs_clock.now(),
                        cat="kernel", pid=self._trace_pid, args=args)
        for slot in list(self._live):
            req = self._live[slot]
            if not ok[slot]:
                self._quarantine(slot)
                continue
            if self.spec is not None:
                # keep the draft-round re-sync feed consistent across
                # plain-decode fallback rounds (spec.draft docstring)
                self._prev_tok[slot] = self._tok[slot]
                self._dirty = True
            req.tokens.append(int(toks[slot]))
            self._pos[slot] += 1
            self._tok[slot] = toks[slot]
            if req.done:
                self._evict(slot)
        self._note_step_time(t_step)

    @property
    def _step_ema(self) -> float:
        """Registry-backed EWMA of recent step wall time — the
        budgeter's clock for deadline pressure (how many steps fit
        before a TTFT deadline) and TPOT protection (is the step already
        slower than the tightest live target)."""
        return self._step_time.value or 0.0

    def _note_step_time(self, t0: float) -> None:
        """Feed the step-time EWMA, flag stragglers (same registry
        mechanism as the train supervisor's ``StragglerWatchdog``), and
        emit the per-step timeline counters."""
        dt = obs_clock.now() - t0
        prev = self._step_time.value
        self._step_time.update(dt)
        straggler = prev is not None and dt > _STRAGGLER_FACTOR * prev
        if straggler:
            self.metrics.counter("straggler_steps").inc()
        tr = self.tracer
        if tr is None:
            return
        if straggler:
            tr.instant("straggler_step", pid=self._trace_pid,
                       args={"dt_s": round(dt, 6),
                             "ewma_s": round(prev, 6)})
        tr.counter("sched", {"queue_depth": self.queue.depth(),
                             "live_slots": len(self._live),
                             "prefilling": len(self._prefills)},
                   pid=self._trace_pid)
        util = {"step_ms": round(dt * 1e3, 3)}
        if self.cache_mode == "paged":
            util["free_page_frac"] = round(
                self.pool.n_free_pages / self.pool.usable_pages, 4)
        meta = self._chunk_meta
        if meta is not None:
            util["token_budget_util"] = round(min(1.0, (
                meta["assigned"] + meta["decode_tokens"])
                / max(meta["budget"], 1)), 4)
        tr.counter("util", util, pid=self._trace_pid)

    def _step_spec(self, faults=None) -> None:
        """One speculative round (DESIGN.md §10): draft k tokens per slot
        from the draft's own cache, verify the (slots, k+1) window in one
        target forward, emit the accepted prefix + bonus token, roll the
        target cache back past the rejected tail."""
        from repro.spec import rollback as rb
        k = self.spec.k
        tr = self.tracer
        t_draft = obs_clock.now()
        with kops.serving_phase("decode"):       # draft GEMMs are M=slots
            self._draft_layers, drafts = self._draft_round(
                self.draft.params, self._draft_layers, self._dev_pos,
                self._dev_prev, self._dev_tok)
        if tr is not None:
            # draft plans are keyed separately (("draft",)+key) and are
            # excluded from _phase_model, so this span carries measured
            # shape args only — no modeled roofline
            jax.block_until_ready(drafts)
            tr.complete("draft", t_draft, obs_clock.now(), cat="kernel",
                        pid=self._trace_pid,
                        args={"live": len(self._live), "k": k,
                              "m": self.max_slots})
        window = jnp.concatenate([self._dev_tok[:, None], drafts], axis=1)
        mask = self._nan_mask(faults)
        t_verify = obs_clock.now()
        with kops.serving_phase("verify"):
            if self.cache_mode == "paged":
                if self.pool.table_dirty:
                    self._dev_table = jnp.asarray(self.pool.table)
                    self.pool.table_dirty = False
                self.pool.layers, greedy, n_acc, _, ok_dev = self._verify(
                    self.params, self.pool.layers, self._dev_table,
                    self._dev_pos, window, mask)
            else:
                self.pool.layers, greedy, n_acc, _, ok_dev = self._verify(
                    self.params, self.pool.layers, self._dev_pos, window,
                    mask)
        self.decode_steps += 1
        self.spec_rounds += 1
        greedy = np.asarray(greedy)
        n_acc = np.asarray(n_acc)
        ok = np.asarray(ok_dev)
        if tr is not None:
            # the np.asarray reads above are the sync point
            args = {"live": len(self._live), "k": k,
                    "m": self.max_slots * (k + 1)}
            model = self._modeled("verify", self.max_slots * (k + 1))
            if model:
                args.update(model)
            tr.complete("verify", t_verify, obs_clock.now(), cat="kernel",
                        pid=self._trace_pid, args=args)
        round_slots = 0
        round_accepted = 0
        for slot in list(self._live):
            req = self._live[slot]
            if not ok[slot]:
                # corrupted window: commit nothing from it — quarantine
                # replays the request from its prompt (token-exact under
                # greedy decode), so the NaN never reaches the output
                self._quarantine(slot)
                continue
            na = int(n_acc[slot])
            round_slots += 1
            round_accepted += na
            self.spec_slot_rounds += 1
            self.spec_proposed += k
            self.spec_accepted += na
            req.spec_proposed += k
            req.spec_accepted += na
            old_tok = int(self._tok[slot])
            emitted = 0
            for j in range(na + 1):               # accepted drafts + bonus
                req.tokens.append(int(greedy[slot, j]))
                emitted += 1
                if req.done:                      # budget / EOS mid-window
                    break
            self.spec_emitted += emitted
            self._pos[slot] += emitted
            self._tok[slot] = int(greedy[slot, emitted - 1])
            self._prev_tok[slot] = (int(greedy[slot, emitted - 2])
                                    if emitted >= 2 else old_tok)
            self._dirty = True
            if req.done:
                self._evict(slot)                 # release() drops all pages
            elif self.cache_mode == "paged":
                self.spec_page_reclaims += rb.rollback_paged(
                    self.pool, slot, int(self._pos[slot]))
            else:
                # dense rollback is length bookkeeping only — the _pos
                # update above IS the rollback (see spec.rollback)
                rb.rollback_dense(self.pool, slot, int(self._pos[slot]))
        # degradation rung 1 (DESIGN.md §11): rolling acceptance floor.
        # A draft that stops agreeing with the target makes every round
        # cost a k+1-wide verify for ~1 emitted token — worse than plain
        # decode — so the engine sheds speculation instead of limping.
        floor = self.resilience.spec_accept_floor
        if floor > 0.0 and round_slots:
            self._accept_ring.append(round_accepted / (k * round_slots))
            if (len(self._accept_ring) == self._accept_ring.maxlen
                    and not self.spec_disabled):
                mean = sum(self._accept_ring) / len(self._accept_ring)
                if mean < floor:
                    self.spec_disabled = True
                    self.spec_disables += 1
                    if tr is not None:
                        tr.instant("spec_disabled", pid=self._trace_pid,
                                   args={"acceptance": round(mean, 4),
                                         "floor": floor})
                    log.warning(
                        "spec decoding disabled: rolling acceptance %.3f "
                        "< floor %.3f over %d rounds", mean, floor,
                        self._accept_ring.maxlen)

    # ------------------------------------------------------------------
    def has_work(self) -> bool:
        """Anything queued, mid-prefill, or decoding — the loop condition
        for external step drivers (``serving.traffic.run_open_loop``)."""
        return bool(self.queue) or bool(self._live) or bool(self._prefills)

    def begin_metrics(self) -> Dict[str, Any]:
        """Snapshot every cumulative counter and reset the windowed stats.
        ``run()`` calls this at entry; an external driver that steps the
        engine itself (the open-loop traffic harness) calls it before its
        own loop and ``collect_metrics`` after, so manually-driven spans
        report the same JSON ``run()`` would."""
        assert self.params is not None, "load(params) first"
        self._depth_stat = _RunningStat("queue_depth")
        self._live_stat = _RunningStat("live_slots")
        return {
            "t0": obs_clock.now(),
            "n0": self.total_drained,
            "p0": self.prefill_steps,
            "d0": self.decode_steps,
            "c0": (self.chunk_steps, self.chunk_tokens_committed,
                   self.prefill_completions),
            "s0": (self.spec_rounds, self.spec_proposed,
                   self.spec_accepted, self.spec_emitted,
                   self.spec_page_reclaims, self.spec_slot_rounds),
            "f0": {"quarantines": self.quarantines,
                   "retries": self.fault_retries,
                   "failed": self.failed_requests,
                   "pauses": self.admission_pauses,
                   "deadline_cancels": self.deadline_cancels,
                   "spec_disables": self.spec_disables,
                   "draft_fallbacks": self.draft_fallbacks,
                   "injected": (dict(self.injector.injected)
                                if self.injector else {})},
        }

    def run(self) -> Dict[str, Any]:
        """Drain the queue completely; return the metrics JSON dict."""
        snap = self.begin_metrics()
        budget = (self.queue.depth() + len(self._live)
                  + len(self._prefills)) * self.max_len + 1
        if self._chunker is not None:
            # chunked prefill spends up to prompt_len extra chunk steps
            # per request (worst case: the 1-token/step liveness trickle)
            budget *= 2
        if self.cache_mode == "paged":
            # preempt-and-replay re-runs requests; each replay costs at most
            # max_len extra steps and the oldest-never-preempted rule bounds
            # the churn, but give the watchdog generous headroom
            budget *= 8
        if self.injector is not None or self.resilience.max_retries > 0:
            # quarantine replays restart requests from the prompt, so each
            # of the max_retries attempts can cost another full generation
            budget *= 2 + self.resilience.max_retries
        idle = 0
        while self.queue or self._live or self._prefills:
            assert budget > 0, "scheduler failed to make progress"
            progress = (self.prefill_steps, self.decode_steps,
                        self.chunk_steps, self.total_drained)
            self.step()
            if (self.prefill_steps, self.decode_steps, self.chunk_steps,
                    self.total_drained) == progress:
                # idle tick — nothing live and the queue head is inside its
                # retry-backoff window. Waiting costs no work, so it must
                # not eat the progress budget; yield briefly instead.
                idle += 1
                assert idle < 1_000_000, "scheduler stuck on idle ticks"
                time.sleep(5e-4)
            else:
                idle = 0
                budget -= 1
        assert self.total_drained == self.queue.submitted, (
            "drained-request count != submitted count",
            self.total_drained, self.queue.submitted)
        return self.collect_metrics(snap)

    def _slo_report(self, done) -> Optional[Dict[str, Any]]:
        """Per-class SLO violation counts over a span's terminal
        requests. Targets are objectives, not guarantees — this is the
        honest scoreboard."""
        classes: Dict[str, Dict[str, Any]] = {}
        for r in done:
            if r.slo is None:
                continue
            ttft_t = getattr(r.slo, "ttft_target_s", None)
            tpot_t = getattr(r.slo, "tpot_target_s", None)
            c = classes.setdefault(r.slo.name, {
                "n": 0, "ttft_target_s": ttft_t, "tpot_target_s": tpot_t,
                "ttft_violations": 0, "tpot_violations": 0})
            c["n"] += 1
            if ttft_t is not None and r.ttft_s is not None \
                    and r.ttft_s > ttft_t:
                c["ttft_violations"] += 1
            if tpot_t is not None and r.tpot_s is not None \
                    and r.tpot_s > tpot_t:
                c["tpot_violations"] += 1
        return classes or None

    def collect_metrics(self, snap: Dict[str, Any]) -> Dict[str, Any]:
        """Build the metrics JSON for the span since ``begin_metrics``."""
        n0, p0, d0 = snap["n0"], snap["p0"], snap["d0"]
        s0, f0, c0 = snap["s0"], snap["f0"], snap["c0"]
        wall = obs_clock.now() - snap["t0"]
        done = self._finished[n0:]
        gen = sum(len(r.tokens) for r in done)
        ttfts = [r.ttft_s for r in done if r.ttft_s is not None]
        cache_metrics: Dict[str, Any] = {
            "mode": self.cache_mode,
            "nbytes": int(self.pool.nbytes),
        }
        if self.cache_mode == "paged":
            cache_metrics.update(self.pool.stats())
            cache_metrics["preemptions"] = self.preemptions
            cache_metrics["deferrals"] = self.deferrals
        spec_metrics = None
        if self.spec is not None:
            rounds = self.spec_rounds - s0[0]
            proposed = self.spec_proposed - s0[1]
            accepted = self.spec_accepted - s0[2]
            emitted = self.spec_emitted - s0[3]
            slot_rounds = self.spec_slot_rounds - s0[5]
            spec_metrics = {
                "draft": self.draft.name,
                "k": self.spec.k,
                "rounds": rounds,
                "draft_tokens_proposed": proposed,
                "draft_tokens_accepted": accepted,
                "acceptance_rate": (round(accepted / proposed, 4)
                                    if proposed else None),
                # emitted tokens per (slot, round): 1 (nothing accepted)
                # .. k+1 (whole window + bonus)
                "mean_accepted_len": (round(emitted / slot_rounds, 3)
                                      if slot_rounds else None),
                "rollback_page_reclaims": self.spec_page_reclaims - s0[4],
                "disabled": self.spec_disabled,
                "draft_fallbacks": (self.draft_fallbacks
                                    - f0["draft_fallbacks"]),
                "per_request": [
                    {"rid": r.rid, "proposed": r.spec_proposed,
                     "accepted": r.spec_accepted,
                     "rate": (round(r.spec_accepted / r.spec_proposed, 4)
                              if r.spec_proposed else None)}
                    for r in done],
            }
        return {
            "engine": "continuous",
            "max_slots": self.max_slots,
            "max_len": self.max_len,
            "mesh": (None if self.mesh is None else
                     {"tp": int(np.prod(list(dict(
                          self.mesh.shape).values()))),
                      "axes": dict(self.mesh.shape),
                      "collective_plans": sum(
                          1 for p in getattr(self, "gemm_plans",
                                             {}).values()
                          if getattr(p, "collective", None))}),
            "cache": cache_metrics,
            "spec": spec_metrics,
            "concurrency": {"peak": self._live_stat.peak,
                            "mean": round(self._live_stat.mean, 3)},
            "planned_gemms": len(getattr(self, "gemm_plans", {})),
            "per_request": [r.metrics() for r in done],
            "submitted": len(done),
            "drained": len(done),
            "generated_tokens": gen,
            "wall_s": round(wall, 4),
            "tok_per_s": round(gen / wall, 2) if wall > 0 else None,
            "prefill_steps": self.prefill_steps - p0,
            "decode_steps": self.decode_steps - d0,
            "ttft_s": {"mean": float(np.mean(ttfts)) if ttfts else None,
                       "max": float(np.max(ttfts)) if ttfts else None},
            # exact percentile aggregates over the span's terminal
            # requests (DESIGN.md §14) — no reservoir approximation at
            # our scales
            "latency": {
                "ttft_s": _pcts(r.ttft_s for r in done),
                "queue_wait_s": _pcts(r.queue_wait_s for r in done),
                "prefill_s": _pcts(r.prefill_s for r in done),
                "tpot_s": _pcts(r.tpot_s for r in done),
                "e2e_s": _pcts(r.latency_s for r in done),
            },
            "sched": (None if self.sched is None else {
                "chunked_prefill": self._chunker is not None,
                "chunk_tokens": self.sched.chunk_tokens,
                "step_token_budget": self.sched.budget_for(
                    self.max_slots,
                    self.spec.k if self.spec is not None else 0),
                "admission": self.sched.admission,
                "chunk_steps": self.chunk_steps - c0[0],
                "chunk_tokens_committed":
                    self.chunk_tokens_committed - c0[1],
                "prefill_completions": self.prefill_completions - c0[2],
                "slo": self._slo_report(done),
            }),
            "queue_depth": {"max": self._depth_stat.peak,
                            "mean": self._depth_stat.mean},
            "faults": {
                "injected": {k: v - f0["injected"].get(k, 0)
                             for k, v in (self.injector.injected.items()
                                          if self.injector else ())},
                "quarantines": self.quarantines - f0["quarantines"],
                "retries": self.fault_retries - f0["retries"],
                "failed_requests": self.failed_requests - f0["failed"],
                "degradations": {
                    "spec_disabled": self.spec_disabled,
                    "spec_disables": (self.spec_disables
                                      - f0["spec_disables"]),
                    "admission_pauses": (self.admission_pauses
                                         - f0["pauses"]),
                    "deadline_cancellations": (self.deadline_cancels
                                               - f0["deadline_cancels"]),
                },
            },
        }


# ---------------------------------------------------------------------------
# Registry-backed scheduler counters (DESIGN.md §15). Call sites — and
# external readers like distributed.router and the test suite — keep the
# bare attribute idiom (``eng.total_drained += 1``); these properties
# route every read/write through the engine's MetricsRegistry, so
# ``engine.metrics.snapshot()`` sees the full counter set without a
# second bookkeeping path. ``spec_disabled`` stays a plain bool flag.
_ENGINE_COUNTERS = (
    "total_drained", "prefill_steps", "decode_steps", "preemptions",
    "deferrals", "spec_rounds", "spec_slot_rounds", "spec_proposed",
    "spec_accepted", "spec_emitted", "spec_page_reclaims", "chunk_steps",
    "chunk_tokens_committed", "prefill_completions", "quarantines",
    "fault_retries", "failed_requests", "admission_pauses",
    "deadline_cancels", "spec_disables", "draft_fallbacks",
)


def _counter_property(name: str) -> property:
    def _get(self):
        return self.metrics.counter(name).value

    def _set(self, v):
        self.metrics.counter(name).value = int(v)

    return property(_get, _set, doc=f"registry-backed counter {name!r}")


for _cname in _ENGINE_COUNTERS:
    setattr(ContinuousScheduler, _cname, _counter_property(_cname))
del _cname
