"""Continuous-batching serving subsystem (DESIGN.md §7):

``RequestQueue`` (FIFO admission + per-request metrics) ->
``ContinuousScheduler`` (interleaved prefill/decode/evict) ->
``SlotPool`` (fixed ``max_slots x max_len`` KV/SSM cache, free-list reuse)
-> the ternary kernels, phase-tagged for the autotuner.

``ContinuousScheduler(..., cache="paged")`` swaps the slot pool for the
paged KV cache (``repro.paging.PagePool``: block tables, quantized pages,
prefix reuse with copy-on-write — DESIGN.md §9); the dense mode remains
the bit-exact A/B baseline.

Fault tolerance (DESIGN.md §11): ``FaultConfig`` arms the seeded chaos
injector (``FaultInjector``), ``ResilienceConfig`` sets the engine's
response policy — deadlines, quarantine retries, and the graceful
degradation ladder. Both default inert.
"""
from repro.serving.engine import ContinuousScheduler
from repro.serving.faults import FaultConfig, FaultInjector, ResilienceConfig
from repro.serving.queue import Request, RequestQueue
from repro.serving.slots import SlotPool

__all__ = ["ContinuousScheduler", "Request", "RequestQueue", "SlotPool",
           "FaultConfig", "FaultInjector", "ResilienceConfig"]
