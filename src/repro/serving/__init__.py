"""Continuous-batching serving subsystem (DESIGN.md §7):

``RequestQueue`` (FIFO admission + per-request metrics) ->
``ContinuousScheduler`` (interleaved prefill/decode/evict) ->
``SlotPool`` (fixed ``max_slots x max_len`` KV/SSM cache, free-list reuse)
-> the ternary kernels, phase-tagged for the autotuner.

``ContinuousScheduler(..., cache="paged")`` swaps the slot pool for the
paged KV cache (``repro.paging.PagePool``: block tables, quantized pages,
prefix reuse with copy-on-write — DESIGN.md §9); the dense mode remains
the bit-exact A/B baseline.
"""
from repro.serving.engine import ContinuousScheduler
from repro.serving.queue import Request, RequestQueue
from repro.serving.slots import SlotPool

__all__ = ["ContinuousScheduler", "Request", "RequestQueue", "SlotPool"]
