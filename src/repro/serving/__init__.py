"""Continuous-batching serving subsystem (DESIGN.md §7):

``RequestQueue`` (FIFO admission + per-request metrics) ->
``ContinuousScheduler`` (interleaved prefill/decode/evict) ->
``SlotPool`` (fixed ``max_slots x max_len`` KV/SSM cache, free-list reuse)
-> the ternary kernels, phase-tagged for the autotuner.
"""
from repro.serving.engine import ContinuousScheduler
from repro.serving.queue import Request, RequestQueue
from repro.serving.slots import SlotPool

__all__ = ["ContinuousScheduler", "Request", "RequestQueue", "SlotPool"]
