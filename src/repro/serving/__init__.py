"""Continuous-batching serving subsystem (DESIGN.md §7):

``RequestQueue`` (FIFO admission + per-request metrics) ->
``ContinuousScheduler`` (interleaved prefill/decode/evict) ->
``SlotPool`` (fixed ``max_slots x max_len`` KV/SSM cache, free-list reuse)
-> the ternary kernels, phase-tagged for the autotuner.

``ContinuousScheduler(..., cache="paged")`` swaps the slot pool for the
paged KV cache (``repro.paging.PagePool``: block tables, quantized pages,
prefix reuse with copy-on-write — DESIGN.md §9); the dense mode remains
the bit-exact A/B baseline.

Fault tolerance (DESIGN.md §11): ``FaultConfig`` arms the seeded chaos
injector (``FaultInjector``), ``ResilienceConfig`` sets the engine's
response policy — deadlines, quarantine retries, and the graceful
degradation ladder. Both default inert.

Scheduling under SLOs (DESIGN.md §14): ``SchedConfig`` switches the
engine to chunked prefill co-scheduled with the decode batch under a
per-step token budget, with ``SLOClass``-driven priority/deadline
admission (``SLOQueue``); ``TrafficConfig``/``make_schedule``/
``run_open_loop`` drive the engine from a seeded open-loop Poisson or
bursty arrival schedule for latency-percentile measurement.
"""
from repro.serving.engine import ContinuousScheduler
from repro.serving.faults import FaultConfig, FaultInjector, ResilienceConfig
from repro.serving.queue import Request, RequestQueue
from repro.serving.sched import SchedConfig, SLOClass, SLOQueue
from repro.serving.slots import SlotPool
from repro.serving.traffic import (Arrival, TrafficConfig, make_schedule,
                                   run_open_loop)

__all__ = ["ContinuousScheduler", "Request", "RequestQueue", "SlotPool",
           "FaultConfig", "FaultInjector", "ResilienceConfig",
           "SchedConfig", "SLOClass", "SLOQueue",
           "Arrival", "TrafficConfig", "make_schedule", "run_open_loop"]
