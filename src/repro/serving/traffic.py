"""Open-loop traffic harness (DESIGN.md §14).

Serving latency is only meaningful under *offered load*: a closed-loop
driver (submit everything, then drain — what ``run()`` does) lets the
system set its own arrival rate, hiding exactly the queueing behaviour
p99 TTFT exists to expose. This module generates a seeded wall-clock
arrival schedule (Poisson or bursty) ahead of time and drives the
engine from it **open-loop**: arrivals happen at their scheduled times
whether or not the engine has kept up, so saturation shows up as
growing queue wait — not as a silently stretched benchmark.

``make_schedule`` is pure and seeded (same config -> same schedule,
byte-for-byte), so an A/B comparison (chunked vs whole-prompt
admission, ``benchmarks/latency_bench.py``) replays the identical
workload against both engines. ``run_open_loop`` wraps the engine's
``begin_metrics``/``collect_metrics`` span, so it reports the same JSON
``run()`` would, plus a ``traffic`` block.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.obs import clock as obs_clock

__all__ = ["Arrival", "TrafficConfig", "make_schedule", "run_open_loop"]


@dataclasses.dataclass(frozen=True)
class TrafficConfig:
    """Arrival-process + workload-shape knobs.

    kind: "poisson" (independent exponential inter-arrivals at ``rate``)
        or "bursty" (bursts of ~``burst_size`` simultaneous arrivals,
        burst times Poisson at ``rate / burst_size`` — same mean offered
        rate, much worse tail behaviour).
    rate: mean offered load, requests/second.
    prompt_lens / prompt_weights: prompt-length distribution (weights
        default uniform). gen_lens: output-budget choices, sampled
        uniformly.
    """
    kind: str = "poisson"
    rate: float = 8.0
    n_requests: int = 64
    prompt_lens: Tuple[int, ...] = (16,)
    prompt_weights: Tuple[float, ...] = ()
    gen_lens: Tuple[int, ...] = (16,)
    burst_size: int = 8
    seed: int = 0

    def __post_init__(self):
        assert self.kind in ("poisson", "bursty"), self.kind
        assert self.rate > 0, self.rate
        assert self.n_requests >= 1, self.n_requests
        assert self.prompt_lens and self.gen_lens
        assert self.burst_size >= 1, self.burst_size
        if self.prompt_weights:
            assert len(self.prompt_weights) == len(self.prompt_lens)


@dataclasses.dataclass
class Arrival:
    """One scheduled request: wall-clock offset from harness start."""
    t: float
    prompt: np.ndarray
    max_new: int
    slo: Optional[object] = None


def make_schedule(tc: TrafficConfig, vocab_size: int,
                  classes: Sequence = (),
                  class_weights: Sequence[float] = ()) -> List[Arrival]:
    """Draw a deterministic arrival schedule. ``classes`` (SLOClass
    instances) are sampled per request by ``class_weights`` (uniform
    when omitted); empty = all best-effort."""
    rng = np.random.default_rng(tc.seed)
    n = tc.n_requests
    if tc.kind == "poisson":
        times = np.cumsum(rng.exponential(1.0 / tc.rate, size=n))
    else:
        # bursts arrive Poisson at rate/burst_size; members share the
        # burst instant (the scheduler sees them in one admission round)
        times_l: List[float] = []
        t = 0.0
        while len(times_l) < n:
            t += float(rng.exponential(tc.burst_size / tc.rate))
            size = int(rng.geometric(1.0 / tc.burst_size))
            times_l.extend([t] * min(size, n - len(times_l)))
        times = np.asarray(times_l)

    pw = None
    if tc.prompt_weights:
        pw = np.asarray(tc.prompt_weights, np.float64)
        pw = pw / pw.sum()
    plens = rng.choice(np.asarray(tc.prompt_lens), size=n, p=pw)
    glens = rng.choice(np.asarray(tc.gen_lens), size=n)
    cls: List[Optional[object]] = [None] * n
    if classes:
        cw = None
        if class_weights:
            cw = np.asarray(class_weights, np.float64)
            cw = cw / cw.sum()
        picks = rng.choice(len(classes), size=n, p=cw)
        cls = [classes[int(i)] for i in picks]
    return [Arrival(t=float(times[i]),
                    prompt=rng.integers(0, vocab_size, size=int(plens[i]),
                                        dtype=np.int32),
                    max_new=int(glens[i]), slo=cls[i])
            for i in range(n)]


def run_open_loop(engine, schedule: Sequence[Arrival], *,
                  time_scale: float = 1.0,
                  deadline_s: Optional[float] = None,
                  ) -> Tuple[List[Any], Dict[str, Any]]:
    """Drive ``engine`` from the wall-clock ``schedule``: submit each
    arrival at (or as soon as possible after) its scheduled time,
    stepping the engine in between, until the schedule is exhausted and
    the engine drains. ``time_scale`` compresses the schedule (0 =
    everything arrives at t=0: a closed-loop drain, useful for
    exactness tests). Returns ``(requests, metrics)`` where metrics is
    the engine's standard JSON plus a ``traffic`` block."""
    assert engine.params is not None, "load(params) first"
    snap = engine.begin_metrics()
    t0 = obs_clock.now()
    reqs: List[Any] = []
    i, n = 0, len(schedule)
    late = 0.0
    while i < n or engine.has_work():
        now = obs_clock.now() - t0
        while i < n and schedule[i].t * time_scale <= now:
            a = schedule[i]
            late = max(late, now - a.t * time_scale)
            # stamp the *intended* arrival instant, not the moment this
            # call ran: a blocking engine step (a long whole-prompt
            # prefill) delays the submit loop, and stamping late would
            # erase exactly the head-of-line queueing delay the open
            # loop exists to expose
            reqs.append(engine.submit(a.prompt, a.max_new, slo=a.slo,
                                      deadline_s=deadline_s,
                                      submit_t=t0 + a.t * time_scale))
            i += 1
        if engine.has_work():
            engine.step()
        elif i < n:
            # idle until the next arrival — short naps so a long gap
            # doesn't overshoot it
            time.sleep(min(max(schedule[i].t * time_scale - now, 0.0),
                           0.005))
    metrics = engine.collect_metrics(snap)
    makespan = obs_clock.now() - t0
    span = schedule[-1].t - schedule[0].t if n > 1 else 0.0
    # a one-arrival schedule (or a zero-span / time_scale=0 burst) has no
    # meaningful arrival rate: report 0.0 — numeric, so downstream
    # aggregation never trips over None — and flag the degeneracy
    # explicitly instead of leaving callers to infer it
    degenerate = n <= 1 or span <= 0 or time_scale <= 0
    metrics["traffic"] = {
        "n": n,
        "time_scale": time_scale,
        "offered_rate": (0.0 if degenerate
                         else round((n - 1) / span, 3)),
        "degenerate_schedule": degenerate,
        "makespan_s": round(makespan, 4),
        # how far submission lagged the schedule at worst (a large value
        # means the host couldn't keep the open loop open — the engine
        # step outran the arrival spacing)
        "max_submit_lag_s": round(late, 4),
    }
    return reqs, metrics
