"""Slot-allocated KV/SSM cache pool.

The pool owns one device-resident cache tree of fixed capacity
``max_slots x max_len`` (the model's ``init_cache(max_slots, max_len)``
layers tree — every leaf is ``(n_groups, max_slots, ...)``) plus a host-side
free list. Admission allocates a slot and scatters a freshly prefilled
single-request cache into that batch row (``LM.insert_cache``); eviction
just returns the slot id to the free list — the row's stale contents are
fully overwritten by the next insert, so reuse needs no zeroing.
"""
from __future__ import annotations

from typing import List, Optional

import jax
import numpy as np


class SlotPool:
    def __init__(self, model, max_slots: int, max_len: int,
                 cache_dtype=None):
        assert max_slots >= 1, max_slots
        self.model = model
        self.max_slots = max_slots
        self.max_len = max_len
        self.layers = model.init_cache(max_slots, max_len,
                                       dtype=cache_dtype)["layers"]
        # LIFO free list: reuse the most recently freed slot first (keeps
        # the touched working set small at low load). Liveness rides in a
        # boolean array so double-free detection is O(1), not an O(slots)
        # membership scan per eviction (O(slots²) at high churn).
        self._free: List[int] = list(range(max_slots))[::-1]
        self._live = np.zeros(max_slots, bool)
        self._insert = jax.jit(model.insert_cache, donate_argnums=(0,))

    @property
    def n_free(self) -> int:
        return len(self._free)

    @property
    def n_live(self) -> int:
        return self.max_slots - len(self._free)

    @property
    def all_free(self) -> bool:
        """Drain invariant: every slot back on the free list and none
        marked live — the leak check chaos tests assert after a soak
        (``benchmarks/chaos_bench.py``, ``tests/test_faults.py``)."""
        return len(self._free) == self.max_slots and not self._live.any()

    @property
    def nbytes(self) -> int:
        """Device bytes of the pool's cache tree (the serving-memory
        figure of merit reported in the engine metrics)."""
        from repro.models import tree_nbytes
        return tree_nbytes(self.layers)

    def alloc(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop()
        self._live[slot] = True
        return slot

    def free(self, slot: int) -> None:
        assert 0 <= slot < self.max_slots and self._live[slot], slot
        self._live[slot] = False
        self._free.append(slot)

    def insert(self, slots, req_layers) -> None:
        """Scatter a prefilled cache tree (batch dim k, same max_len) into
        the batch rows named by ``slots`` (scalar or (k,) vector — grouped
        admission inserts a whole prefill batch in one scatter)."""
        self.layers = self._insert(self.layers, req_layers,
                                   jax.numpy.asarray(slots))
