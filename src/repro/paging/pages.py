"""Page-granular KV/SSM cache pool (DESIGN.md §9).

``PagePool`` replaces the dense ``serving.SlotPool`` rows with fixed-size
pages owned globally: attention layers hold one ``(n_groups, n_pages,
page_size, KV, hd)`` array pair (or int8 ``Int8Pages`` containers) shared
by *all* slots, and each slot reads its own sequence through a host-side
block table pushed to the device when it changes. SSM layers keep their
O(1)-per-slot dense rows inside the same tree — paging buys nothing for
constant-size state.

Host-side ownership model:

* **free list** (LIFO) of page ids; page 0 is reserved as the *trash page*
  — free slots' block tables are all-zero, so the garbage K/V their decode
  lanes write lands there and is never read.
* **refcounts** count live-slot references; the prefix registry
  (``prefix.PrefixCache``) additionally *pins* pages holding registered
  prompt content. A page returns to the free list only at refcount 0 and
  unpinned; pinned refcount-0 pages are reclaimed LRU-first under
  pressure.
* **admission** (``admit``) is OOM-safe: it either finds every page the
  prompt needs (shared prefix hits + fresh allocations + reclamation) or
  returns ``None`` with all side effects rolled back — the engine defers
  the request instead of crashing.
* **growth** (``ensure_append``) allocates the next page on demand during
  decode and performs **copy-on-write** when the target page is shared
  (registered or multiply referenced): the page is copied to a private one
  and the block table repointed before the append. Returns ``False`` when
  the pool is dry — the engine preempts its youngest request and retries.

Device-side, the pool owns two jitted tree ops: ``insert`` (scatter a
prefilled dense cache into the prompt's pages — page chunks for attention
leaves, slot rows for SSM leaves) and the COW page copy.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import tree_nbytes
from repro.paging.prefix import PrefixCache
from repro.paging.quant import Int8Pages, quantize_rows

__all__ = ["PagePool", "Admission"]


@dataclasses.dataclass
class Admission:
    """One admitted request's page plan."""

    slot: int
    page_ids: List[int]          # prompt pages, in sequence order
    n_shared: int                # leading pages satisfied by the prefix cache


class PagePool:
    """Global paged KV/SSM cache pool with prefix sharing and COW."""

    def __init__(self, model, max_slots: int, max_len: int, *,
                 page_size: int = 16, n_pages: int = 0,
                 kv_dtype: Optional[str] = None, cache_dtype=None,
                 prefix_cache: bool = True):
        assert max_slots >= 1 and page_size >= 1
        cfg = model.cfg
        if cfg.cache_layout == "opt":
            raise ValueError("paged caches need cache_layout='bshd' "
                             "(the 'opt' delta-decode layout is dense-only)")
        if cfg.sliding_window:
            raise ValueError("paged caches do not support rolling "
                             "sliding-window models yet; use cache='dense'")
        self.model = model
        self.max_slots = max_slots
        self.max_len = max_len
        self.page_size = page_size
        self.pages_per_slot = -(-max_len // page_size)
        # +1: page 0 is the reserved trash page
        self.n_pages = n_pages or max_slots * self.pages_per_slot + 1
        if self.n_pages < self.pages_per_slot + 1:
            raise ValueError(
                f"n_pages={self.n_pages} cannot hold even one max-length "
                f"request ({self.pages_per_slot} pages + trash page)")
        self.kv_dtype = kv_dtype
        self.layers = model.init_paged_cache(
            self.n_pages, page_size, max_slots, dtype=cache_dtype,
            kv_dtype=kv_dtype)["layers"]

        # ---- host ownership state ----
        self._free_slots: List[int] = list(range(max_slots))[::-1]
        self._slot_live = np.zeros(max_slots, bool)
        self._free_pages: List[int] = list(range(1, self.n_pages))[::-1]
        self._refcount = np.zeros(self.n_pages, np.int32)
        # registered pages with no live references, in the order they went
        # cold — the O(1) reclaim pool (scanning the whole registry per
        # reclaimed page would make admission-under-pressure O(n_pages²))
        self._reclaimable: Dict[int, None] = {}
        self.slot_pages: Dict[int, List[int]] = {s: [] for s in range(max_slots)}
        self.table = np.zeros((max_slots, self.pages_per_slot), np.int32)
        self.table_dirty = True
        self.prefix = PrefixCache(page_size) if prefix_cache else None

        # ---- stats ----
        self.cow_count = 0
        self.pages_used_peak = 0
        # fault injection (DESIGN.md §11): while positive, each page
        # allocation attempt fails as if the pool were dry — exercising
        # the defer (admission) and preempt-and-replay (growth) paths on
        # demand. Decremented per failed _alloc_pages call.
        self.fault_alloc_failures = 0

        def copy_page(layers, src, dst):
            out = {}
            for key, entry in layers.items():
                if "k_pages" in entry:
                    out[key] = jax.tree.map(
                        lambda p: p.at[:, dst].set(p[:, src]), entry)
                else:
                    out[key] = entry
            return out

        self._copy_page_fn = jax.jit(copy_page, donate_argnums=(0,))
        self._insert_fn = jax.jit(self._insert_impl, donate_argnums=(0,))

    # ------------------------------------------------------------------
    # Geometry / accounting
    # ------------------------------------------------------------------
    @property
    def n_free(self) -> int:            # slots (SlotPool-compatible name)
        return len(self._free_slots)

    @property
    def n_live(self) -> int:
        return self.max_slots - len(self._free_slots)

    @property
    def usable_pages(self) -> int:
        return self.n_pages - 1

    @property
    def pages_used(self) -> int:
        """Pages not on the free list (live refs + pinned prefix pages)."""
        return self.usable_pages - len(self._free_pages)

    @property
    def n_free_pages(self) -> int:
        return len(self._free_pages)

    @property
    def all_reclaimed(self) -> bool:
        """Drain invariant: every slot free and every live reference
        dropped (pinned-but-cold prefix pages are *not* leaks — they hold
        refcount 0 and are reclaimable on demand). The leak check chaos
        tests assert after a soak."""
        return (len(self._free_slots) == self.max_slots
                and not self._slot_live.any()
                and int(self._refcount.sum()) == 0)

    @property
    def nbytes(self) -> int:
        """Device bytes of the cache tree + the block table."""
        return tree_nbytes(self.layers) + int(self.table.nbytes)

    def pages_needed(self, prompt_len: int) -> int:
        return -(-prompt_len // self.page_size)

    def _note_usage(self) -> None:
        self.pages_used_peak = max(self.pages_used_peak, self.pages_used)

    def _shared(self, pid: int) -> bool:
        """Copy-on-write trigger: more than one live slot references the
        page. A *registered* page with a single live referent appends in
        place — appends only touch rows at/after the registrant's prompt
        tail, which future prefix matchers mask until their own first
        append (when refcount > 1 forces them to COW), so the prompt rows
        the registry vouches for stay immutable without per-request
        copies."""
        return self._refcount[pid] > 1

    # ------------------------------------------------------------------
    # Page allocation / reclamation
    # ------------------------------------------------------------------
    def _reclaim_one(self) -> Optional[int]:
        """Unpin + take the coldest registered page with no live
        references, O(1). None when nothing is reclaimable."""
        if self.prefix is None or not self._reclaimable:
            return None
        pid = next(iter(self._reclaimable))
        del self._reclaimable[pid]
        assert self._refcount[pid] == 0, pid
        self.prefix.unregister_page(pid)
        return pid

    def inject_alloc_failures(self, n: int) -> None:
        """Arm ``n`` forced allocation failures (chaos testing — see
        ``serving.faults.FaultInjector``)."""
        assert n >= 0, n
        self.fault_alloc_failures += n

    def _alloc_pages(self, n: int) -> Optional[List[int]]:
        if n > 0 and self.fault_alloc_failures > 0:   # injected OOM (§11)
            self.fault_alloc_failures -= 1
            return None
        out: List[int] = []
        while len(out) < n:
            if self._free_pages:
                out.append(self._free_pages.pop())
            else:
                pid = self._reclaim_one()
                if pid is None:
                    self._free_pages.extend(reversed(out))  # rollback
                    return None
                out.append(pid)
        return out

    def _unref(self, pid: int) -> None:
        assert self._refcount[pid] > 0, pid
        self._refcount[pid] -= 1
        if self._refcount[pid] == 0:
            if self.prefix is not None and self.prefix.holds(pid):
                self._reclaimable[pid] = None      # cold prefix page
            else:
                self._free_pages.append(pid)

    # ------------------------------------------------------------------
    # Admission / growth / release
    # ------------------------------------------------------------------
    def admit(self, prompt: np.ndarray, *,
              use_prefix: bool = True) -> Optional[Admission]:
        """Reserve a slot + every page the prompt needs, reusing registered
        prefix pages. All-or-nothing: on failure every side effect is
        rolled back and ``None`` is returned (the engine defers).

        ``use_prefix=False`` skips prefix matching *and* registration for
        this admission. Chunked prefill (DESIGN.md §14) needs this: the
        registry's contract is that a registered page already holds its
        prompt content, but a chunked request writes its pages
        incrementally over several steps — registering them at admission
        would let a concurrent whole-prompt admission share a page whose
        K/V has not been written yet. Chunked requests therefore take
        private pages only (prefix sharing for chunked admissions is
        future work)."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        n_p = self.pages_needed(prompt.size)
        assert n_p <= self.pages_per_slot, (n_p, self.pages_per_slot)
        if not self._free_slots:
            return None
        prefix = self.prefix if use_prefix else None
        matched: List[int] = []
        keys: List[bytes] = []
        if prefix is not None:
            keys, matched = prefix.lookup(prompt)
            for pid in matched:          # pin before reclamation can run
                self._refcount[pid] += 1
                self._reclaimable.pop(pid, None)
        fresh = self._alloc_pages(n_p - len(matched))
        if fresh is None:
            for pid in matched:          # rollback
                self._unref(pid)
            return None
        for pid in fresh:
            self._refcount[pid] = 1
        if prefix is not None:
            for key, pid in zip(keys[len(matched):], fresh):
                self.prefix.register(key, pid)
        slot = self._free_slots.pop()
        self._slot_live[slot] = True
        pids = matched + fresh
        self.slot_pages[slot] = pids
        self.table[slot] = 0
        self.table[slot, :n_p] = pids
        self.table_dirty = True
        self._note_usage()
        return Admission(slot=slot, page_ids=pids, n_shared=len(matched))

    def ensure_append(self, slot: int, pos: int) -> bool:
        """Make position ``pos`` of ``slot`` writable before a decode step:
        allocate the next page when ``pos`` crosses a page boundary, and
        copy-on-write when the target page is shared. ``False`` = pool dry
        (caller preempts and retries)."""
        assert self._slot_live[slot], slot
        pi = pos // self.page_size
        pages = self.slot_pages[slot]
        if pi < len(pages):
            pid = pages[pi]
            if not self._shared(pid):
                return True
            new = self._alloc_pages(1)
            if new is None:
                return False
            new = new[0]
            self.layers = self._copy_page_fn(
                self.layers, jnp.asarray(pid), jnp.asarray(new))
            self._unref(pid)
            self._refcount[new] = 1
            pages[pi] = new
            self.table[slot, pi] = new
            self.table_dirty = True
            self.cow_count += 1
            self._note_usage()
            return True
        assert pi == len(pages) and pi < self.pages_per_slot, (slot, pos)
        new = self._alloc_pages(1)
        if new is None:
            return False
        new = new[0]
        self._refcount[new] = 1
        pages.append(new)
        self.table[slot, pi] = new
        self.table_dirty = True
        self._note_usage()
        return True

    def truncate(self, slot: int, n_tokens: int) -> int:
        """Speculative-decoding rollback (DESIGN.md §10): drop the slot's
        page references beyond what ``n_tokens`` committed tokens need and
        return how many pages went back to the pool, O(dropped).

        Only decode-grown tail pages can be dropped — ``n_tokens`` is never
        below the prompt length, so prefix-shared (registered) prompt pages
        stay in range — and a dropped page is either freshly allocated or
        the private side of a COW, i.e. refcount 1 and unregistered:
        ``_unref`` returns it to the free list immediately. Refcounts of
        shared pages are untouched, so prefix sharing/COW invariants hold.
        """
        assert self._slot_live[slot], slot
        keep = max(self.pages_needed(n_tokens), 1)
        pages = self.slot_pages[slot]
        if keep >= len(pages):
            return 0
        dropped = pages[keep:]
        del pages[keep:]
        for pid in dropped:
            self._unref(pid)
        self.table[slot, keep:keep + len(dropped)] = 0
        self.table_dirty = True
        return len(dropped)

    def release(self, slot: int) -> None:
        """Return a slot and its page references; registered prefix pages
        stay resident (pinned) for future shared-prefix admissions."""
        assert self._slot_live[slot], slot
        for pid in self.slot_pages[slot]:
            self._unref(pid)
        self.slot_pages[slot] = []
        self.table[slot] = 0
        self.table_dirty = True
        self._slot_live[slot] = False
        self._free_slots.append(slot)

    # ------------------------------------------------------------------
    # Device scatter: prefilled dense cache -> pages (+ SSM slot rows)
    # ------------------------------------------------------------------
    def _insert_impl(self, layers, req_layers, flat_pids, slots):
        ps = self.page_size
        out = {}
        for key, entry in layers.items():
            src = req_layers[key]
            if "k_pages" in entry:
                new = {}
                for pk, sk in (("k_pages", "k"), ("v_pages", "v")):
                    pages, seq = entry[pk], src[sk]
                    quant = isinstance(pages, Int8Pages)
                    kv, hd = (pages.codes if quant else pages).shape[-2:]
                    # (G, B, L, ...) -> (G, B*n_chunks, ps, KV, hd); L is
                    # page-aligned (the engine prefills at ceil(L/ps)*ps)
                    chunks = seq.reshape(seq.shape[0], -1, ps, kv, hd)
                    if quant:
                        codes, scales = quantize_rows(chunks)
                        new[pk] = Int8Pages(
                            pages.codes.at[:, flat_pids].set(codes),
                            pages.scales.at[:, flat_pids].set(scales))
                    else:
                        new[pk] = pages.at[:, flat_pids].set(
                            chunks.astype(pages.dtype))
                out[key] = new
            else:                         # SSM state/conv: dense slot rows
                out[key] = jax.tree.map(
                    lambda big, small: big.at[:, slots].set(
                        small.astype(big.dtype)), entry, src)
        return out

    def insert(self, admissions: List[Admission], req_layers) -> None:
        """Scatter a freshly prefilled batch (batch dim k, seq dim padded
        to a page multiple) into each request's pages. Prefix-matched
        pages already hold this content (written by the admission that
        registered them) and may be under concurrent read by live sharers,
        so their chunks are redirected to the trash page — never rewritten.
        The redirect keeps the scatter shape static per (k, prompt_len)."""
        flat = [0 if i < adm.n_shared else pid
                for adm in admissions
                for i, pid in enumerate(adm.page_ids)]
        slots = [adm.slot for adm in admissions]
        self.layers = self._insert_fn(
            self.layers, req_layers, jnp.asarray(flat, jnp.int32),
            jnp.asarray(slots, jnp.int32))

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        prefix = None
        if self.prefix is not None:
            hr = self.prefix.hit_rate
            prefix = {"lookups": self.prefix.lookups,
                      "hits": self.prefix.hits,
                      "hit_rate": round(hr, 4) if hr is not None else None,
                      "registered_pages": len(self.prefix)}
        return {
            "page_size": self.page_size,
            "pages_total": self.usable_pages,
            "pages_used": self.pages_used,
            "pages_used_peak": self.pages_used_peak,
            "occupancy_peak": round(
                self.pages_used_peak / max(self.usable_pages, 1), 4),
            "kv_dtype": self.kv_dtype or "cache_dtype",
            "cow_copies": self.cow_count,
            "prefix": prefix,
        }
