"""Paged KV-cache subsystem (DESIGN.md §9):

``PagePool`` (global fixed-size pages, free-list + refcounts, OOM-safe
admission, on-demand growth, copy-on-write) + ``PrefixCache`` (chained-hash
shared-prefix page reuse) + ``Int8Pages`` (quantized pages with per-page
scales) + the Pallas/JAX paged decode-attention lowerings
(``paging.kernels``, dispatched through
``repro.kernels.ops.paged_decode_attention``).

The serving engine selects it with ``ContinuousScheduler(...,
cache="paged")``; the dense slot pool remains the bit-exact A/B baseline.
"""
from repro.models import tree_nbytes
from repro.paging.pages import Admission, PagePool
from repro.paging.prefix import PrefixCache, page_keys
from repro.paging.quant import Int8Pages

__all__ = ["PagePool", "Admission", "PrefixCache", "Int8Pages",
           "page_keys", "tree_nbytes"]
