"""Quantized KV pages (DESIGN.md §9).

``Int8Pages`` is the paged-cache analogue of the ``core.weights``
containers: a frozen-dataclass JAX pytree whose array payloads (int8 codes +
per-page scale tensors) are leaves and whose treedef carries no dynamic
state, so a page pool built from ``Int8Pages`` containers passes through
``jit`` arguments, ``lax.scan`` layer-stacking (the leading ``n_groups`` dim
slices off both leaves together) and ``jax.device_put`` exactly like the
bf16 page arrays it replaces.

Quantization is symmetric per (token-row, kv-head): each row of each page
carries its own f32 scale (``amax / 127``), so appending one token during
decode re-quantizes only that token's row — existing codes and scales are
never rescaled. The scale payload is 4 bytes per (token, kv-head) against
``head_dim`` bytes of codes, so the cache footprint stays ~``head_dim/(
head_dim+4)`` of bf16's half — the scales *live with the page* (allocated,
shared, copied and freed at page granularity), which is what "per-page
scales" means operationally: COW and prefix sharing move codes and scales
as one unit.

Both the pure-JAX gather path and the Pallas paged-attention kernel
dequantize *after* the gather (``codes.astype(f32) * scale``), inside the
kernel for the Pallas path — HBM traffic is int8.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["Int8Pages", "quantize_rows", "dequantize_rows"]

INT8_MAX = 127.0


def quantize_rows(x: jnp.ndarray):
    """Symmetric int8 quantization over the trailing (head_dim) axis.

    x: (..., hd) float -> (codes (..., hd) int8, scales (...) f32).
    All-zero rows get scale 1.0 (codes 0) so dequantization is exact there.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = jnp.where(amax > 0, amax / INT8_MAX, 1.0)
    codes = jnp.clip(jnp.round(xf / scale[..., None]), -INT8_MAX, INT8_MAX)
    return codes.astype(jnp.int8), scale


def dequantize_rows(codes: jnp.ndarray, scales: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    """Inverse of ``quantize_rows``: (..., hd) int8 + (...) f32 -> float."""
    return (codes.astype(jnp.float32)
            * scales[..., None].astype(jnp.float32)).astype(dtype)


@dataclasses.dataclass(frozen=True)
class Int8Pages:
    """int8-quantized K or V pages with per-(token-row, kv-head) scales.

    codes:  (..., n_pages, page_size, KV, hd) int8
    scales: (..., n_pages, page_size, KV)     f32

    Leading dims (the layer-group stack) are arbitrary; the two leaves
    always share them, so tree-mapped page scatters/copies touch both.
    """

    codes: Any
    scales: Any

    @classmethod
    def zeros(cls, shape, *_ignored, **__ignored) -> "Int8Pages":
        """Allocate zeroed pages for a (..., n_pages, ps, KV, hd) shape."""
        return cls(codes=jnp.zeros(shape, jnp.int8),
                   scales=jnp.ones(shape[:-1], jnp.float32))

    @classmethod
    def quantize(cls, x: jnp.ndarray) -> "Int8Pages":
        codes, scales = quantize_rows(x)
        return cls(codes=codes, scales=scales)

    def dequantize(self, dtype=jnp.float32) -> jnp.ndarray:
        return dequantize_rows(self.codes, self.scales, dtype)

    @property
    def shape(self):
        return self.codes.shape

    @property
    def nbytes(self) -> int:
        n = 0
        for leaf in (self.codes, self.scales):
            nb = getattr(leaf, "nbytes", None)
            if nb is None:       # tracers / ShapeDtypeStructs
                import numpy as np
                nb = int(leaf.size) * np.dtype(leaf.dtype).itemsize
            n += int(nb)
        return n

    def __repr__(self) -> str:   # leaves may be tracers; keep repr static
        return f"Int8Pages(shape={tuple(self.codes.shape)})"


jax.tree_util.register_pytree_with_keys(
    Int8Pages,
    lambda p: ([(jax.tree_util.GetAttrKey("codes"), p.codes),
                (jax.tree_util.GetAttrKey("scales"), p.scales)], None),
    lambda aux, children: Int8Pages(*children),
    lambda p: ([p.codes, p.scales], None),
)
