"""Paged decode attention: Pallas kernel + references (DESIGN.md §9).

Decode attention where K/V live in fixed-size *pages* owned by a global
pool and each batch row reads its own sequence through a block table
(``block_table[b, t]`` = page id of the t-th page of row ``b``).

Three registered lowerings (``kernels.ops.register_paged_attn``):

* ``jax`` — batched page gather + exactly the dense decode's attention
  math (the einsum/mask/softmax lines mirror
  ``models.attention.naive_attention`` with ``causal=False``). Because the
  ops match the dense path line-for-line, a paged serving run is
  **bit-identical** in logits to the dense-cache run — this is what the
  paged-vs-dense token-exactness guarantee rests on, and it is the
  ``impl="auto"`` choice off-TPU.
* ``pallas`` — ``PrefetchScalarGridSpec`` kernel: the block table and
  per-row lengths ride in as scalar-prefetch operands so the page grid
  dimension's BlockSpec index maps DMA exactly the pages the row owns
  (same steering mechanism as the tile-skipping GEMM, DESIGN.md §3). Pages
  are staged (and int8-dequantized) into VMEM scratch; the final grid step
  runs the row's attention from VMEM. Bit-exact against ``..._ref``.
* the pure-JAX **reference** (``paged_decode_attention_ref``) mirrors the
  kernel's per-row compute (same ``_attend_one_row`` function, same casts)
  so kernel-vs-reference comparisons are bitwise, not approximate.

All three accept bf16 page arrays or ``quant.Int8Pages`` containers
(per-page scales dequantized after the gather — inside the kernel for the
Pallas path, so HBM reads stay int8).
"""
from __future__ import annotations

import functools
import math
from typing import Optional, Union

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ops import register_paged_attn
from repro.kernels.ternary_gemm import CompilerParams
from repro.paging.quant import Int8Pages, dequantize_rows

NEG_INF = -1e30

__all__ = ["paged_decode_attention_pallas", "paged_decode_attention_ref",
           "paged_decode_attention_jax"]

Pages = Union[jnp.ndarray, Int8Pages]


def _page_geometry(pages: Pages):
    """(n_pages, page_size, kv_heads, head_dim) of a page operand."""
    shape = pages.codes.shape if isinstance(pages, Int8Pages) else pages.shape
    assert len(shape) == 4, f"expected (P, ps, KV, hd) pages, got {shape}"
    return shape


def _attend_one_row(q, k, v, *, kv_heads: int, length, window: int):
    """One row's decode attention, f32 in/out.

    q (H, hd); k/v (S, KV, hd); ``length`` = valid tokens (traced scalar,
    includes the current token, whose position is ``length - 1``).
    Shared verbatim between the Pallas kernel body and the pure-JAX
    reference so the two are bit-exact by construction.
    """
    h, hd = q.shape
    s_len = k.shape[0]
    g = h // kv_heads
    qg = q.reshape(kv_heads, g, hd)
    scores = jnp.einsum("kgd,skd->kgs", qg, k,
                        preferred_element_type=jnp.float32) \
        * (1.0 / math.sqrt(hd))
    k_pos = jnp.arange(s_len)
    mask = k_pos < length
    if window:
        mask &= (length - 1 - k_pos) < window
    scores = jnp.where(mask[None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("kgs,skd->kgd", p, v,
                   preferred_element_type=jnp.float32)
    return o.reshape(h, hd)


def _gather(pages: Pages, block_table: jnp.ndarray, dtype) -> jnp.ndarray:
    """(B, T) block table -> (B, T*ps, KV, hd) gathered sequence view.
    int8 pages dequantize to ``dtype``; raw pages keep their storage dtype
    (it already equals the dense cache dtype, which the bit-exactness
    contract with the dense path requires)."""
    if isinstance(pages, Int8Pages):
        codes = pages.codes[block_table]          # (B, T, ps, KV, hd)
        scales = pages.scales[block_table]        # (B, T, ps, KV)
        seq = dequantize_rows(codes, scales, dtype)
    else:
        seq = pages[block_table]
    b, t, ps, kv, hd = seq.shape
    return seq.reshape(b, t * ps, kv, hd)


# ---------------------------------------------------------------------------
# Pure-JAX lowerings
# ---------------------------------------------------------------------------

@register_paged_attn("jax", priority=10)
def paged_decode_attention_jax(q, k_pages: Pages, v_pages: Pages,
                               block_table, lengths, *, window: int = 0,
                               interpret: Optional[bool] = None):
    """Gather + dense-identical attention (see module docstring).

    q (B, H, hd); returns (B, H, hd). The einsum/mask/softmax sequence
    below MUST stay line-identical to ``models.attention.naive_attention``
    (causal=False) — tests/test_paging.py pins the bitwise equality."""
    del interpret
    b, h, hd = q.shape
    ks = _gather(k_pages, block_table, q.dtype)
    vs = _gather(v_pages, block_table, q.dtype)
    kvh = ks.shape[2]
    qg = q.reshape(b, 1, kvh, h // kvh, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg, ks,
                   preferred_element_type=jnp.float32) \
        * (1.0 / math.sqrt(hd))
    lengths = jnp.asarray(lengths)
    k_pos = jnp.arange(ks.shape[1])
    mask = jnp.ones((b, 1, ks.shape[1]), bool)
    if window:
        q_pos = (lengths - 1)[:, None, None]
        mask &= q_pos - k_pos < window
    mask = mask & (k_pos < lengths[:, None, None])
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(vs.dtype), vs,
                   preferred_element_type=jnp.float32)
    return o.reshape(b, 1, h, hd)[:, 0].astype(q.dtype)


def paged_decode_attention_ref(q, k_pages: Pages, v_pages: Pages,
                               block_table, lengths, *, window: int = 0):
    """Bit-exact mirror of the Pallas kernel: per-row gather into an f32
    staging buffer, then the *same* ``_attend_one_row``. Reference only —
    O(B) python loop, used by tests to pin the kernel bitwise."""
    b = q.shape[0]
    outs = []
    for i in range(b):
        ks = _gather(k_pages, block_table[i][None],
                     jnp.float32)[0].astype(jnp.float32)
        vs = _gather(v_pages, block_table[i][None],
                     jnp.float32)[0].astype(jnp.float32)
        o = _attend_one_row(q[i].astype(jnp.float32), ks, vs,
                            kv_heads=ks.shape[1], length=lengths[i],
                            window=window)
        outs.append(o.astype(q.dtype))
    return jnp.stack(outs)


# ---------------------------------------------------------------------------
# Pallas kernel
# ---------------------------------------------------------------------------

def _kernel(bt_ref, len_ref, q_ref, *refs, n_pages_seq: int, page_size: int,
            kv_heads: int, window: int, quantized: bool):
    b = pl.program_id(0)
    t = pl.program_id(1)
    if quantized:
        kc_ref, ks_ref, vc_ref, vs_ref = refs[:4]
        o_ref, k_scr, v_scr = refs[4:]
        k_page = dequantize_rows(kc_ref[0], ks_ref[0], jnp.float32)
        v_page = dequantize_rows(vc_ref[0], vs_ref[0], jnp.float32)
    else:
        k_ref, v_ref = refs[:2]
        o_ref, k_scr, v_scr = refs[2:]
        k_page = k_ref[0].astype(jnp.float32)
        v_page = v_ref[0].astype(jnp.float32)
    # stage this row's t-th page into the VMEM sequence buffer
    idx = (pl.dslice(t * page_size, page_size), slice(None), slice(None))
    pl.store(k_scr, idx, k_page)
    pl.store(v_scr, idx, v_page)

    @pl.when(t == n_pages_seq - 1)
    def _attend():
        o = _attend_one_row(q_ref[0].astype(jnp.float32), k_scr[...],
                            v_scr[...], kv_heads=kv_heads,
                            length=len_ref[b], window=window)
        o_ref[0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "interpret"))
def paged_decode_attention_pallas(q, k_pages: Pages, v_pages: Pages,
                                  block_table, lengths, *, window: int = 0,
                                  interpret: Optional[bool] = None):
    """q (B, H, hd); pages (P, ps, KV, hd) (or ``Int8Pages``); block_table
    (B, T) int32 (pad unused entries with any valid page id, e.g. 0 — their
    keys are masked out by ``lengths``); lengths (B,) int32 valid-token
    counts including the current token. Returns (B, H, hd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, hd = q.shape
    _, ps, kv, _ = _page_geometry(k_pages)
    t = block_table.shape[1]
    quantized = isinstance(k_pages, Int8Pages)

    page_spec = pl.BlockSpec((1, ps, kv, hd),
                             lambda i, j, bt, ln: (bt[i, j], 0, 0, 0))
    scale_spec = pl.BlockSpec((1, ps, kv),
                              lambda i, j, bt, ln: (bt[i, j], 0, 0))
    in_specs = [pl.BlockSpec((1, h, hd), lambda i, j, bt, ln: (i, 0, 0))]
    if quantized:
        in_specs += [page_spec, scale_spec, page_spec, scale_spec]
        operands = [q, k_pages.codes, k_pages.scales,
                    v_pages.codes, v_pages.scales]
    else:
        in_specs += [page_spec, page_spec]
        operands = [q, k_pages, v_pages]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, t),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, h, hd), lambda i, j, bt, ln: (i, 0, 0)),
        scratch_shapes=[pltpu.VMEM((t * ps, kv, hd), jnp.float32),
                        pltpu.VMEM((t * ps, kv, hd), jnp.float32)],
    )
    return pl.pallas_call(
        functools.partial(_kernel, n_pages_seq=t, page_size=ps, kv_heads=kv,
                          window=window, quantized=quantized),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, hd), q.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(jnp.asarray(block_table, jnp.int32), jnp.asarray(lengths, jnp.int32),
      *operands)


# registered lowering: the kernel wants explicit interpret resolution
register_paged_attn(
    "pallas", priority=20,
    predicate=lambda *a, **k: jax.default_backend() == "tpu",
)(paged_decode_attention_pallas)
