"""Hash-based shared-prefix page reuse (DESIGN.md §9).

Pages are content-addressed by a *chained* hash (vLLM-style): page ``i``'s
key digests (parent key, the page's token ids, the token count), so a key
identifies both the tokens in the page and every token before it — two
prompts share page ``i`` iff they agree on all of positions ``[0, (i+1)·ps)``.
Because RoPE positions are absolute from 0 and prefill is deterministic,
equal token prefixes produce bitwise-equal K/V pages, so pointing a new
request's block table at a registered page is exact, not approximate.

Both *full* pages and the prompt's *partial tail* page are registered: the
tail key also covers the partial token count, so only a request with the
identical full prompt matches it. A matched tail is where copy-on-write
triggers — the first decode append into a registered (or multiply
referenced) page copies it to a private page first (``PagePool``).

The registry itself holds no reference counts; it pins pages (a page it
holds never returns to the free list directly) and the pool reclaims cold
registered pages coldest-first when it runs dry (``PagePool._reclaim_one``
over the pool's ``_reclaimable`` order). Hit/lookup counters feed the
engine's ``prefix`` metrics.
"""
from __future__ import annotations

import collections
import hashlib
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = ["PrefixCache", "page_keys"]


def page_keys(prompt: np.ndarray, page_size: int) -> List[bytes]:
    """Chained content keys for every page the prompt touches (the last one
    may be partial). Keys are order-, content- and length-sensitive."""
    toks = np.asarray(prompt, np.int32).reshape(-1)
    keys: List[bytes] = []
    parent = b"root"
    for lo in range(0, toks.size, page_size):
        chunk = toks[lo:lo + page_size]
        h = hashlib.sha1()
        h.update(parent)
        h.update(np.int64(chunk.size).tobytes())
        h.update(chunk.tobytes())
        parent = h.digest()
        keys.append(parent)
    return keys


class PrefixCache:
    """LRU map of chained page keys -> page ids, plus hit accounting."""

    def __init__(self, page_size: int):
        self.page_size = page_size
        self._entries: "collections.OrderedDict[bytes, int]" = \
            collections.OrderedDict()
        self._key_of_page: Dict[int, bytes] = {}
        self.lookups = 0
        self.hits = 0

    def __len__(self) -> int:
        return len(self._entries)

    # ------------------------------------------------------------------
    def lookup(self, prompt: np.ndarray) -> Tuple[List[bytes], List[int]]:
        """(all page keys for the prompt, page ids for the matched prefix).

        The match is the longest *leading* run of registered keys — prefix
        sharing stops at the first divergence. Counters update here."""
        keys = page_keys(prompt, self.page_size)
        matched: List[int] = []
        for key in keys:
            pid = self._entries.get(key)
            if pid is None:
                break
            self._entries.move_to_end(key)          # LRU touch
            matched.append(pid)
        self.lookups += len(keys)
        self.hits += len(matched)
        return keys, matched

    def probe(self, prompt: np.ndarray) -> int:
        """Number of leading prompt pages this cache holds — the router's
        placement signal (DESIGN.md §13). Unlike ``lookup`` it mutates
        nothing: no LRU touch, no hit/lookup counters — probing every
        replica to *place* a request must not skew the per-replica metrics
        or evict-ordering that the serving engine's real lookup drives."""
        matched = 0
        for key in page_keys(prompt, self.page_size):
            if key not in self._entries:
                break
            matched += 1
        return matched

    def register(self, key: bytes, page_id: int) -> None:
        """Pin ``page_id`` as the canonical holder of ``key``. The caller
        (PagePool) marks the page read-only; re-registering an existing key
        is a no-op (first writer wins — its content is identical anyway)."""
        if key in self._entries:
            return
        self._entries[key] = page_id
        self._key_of_page[page_id] = key

    def holds(self, page_id: int) -> bool:
        return page_id in self._key_of_page

    def unregister_page(self, page_id: int) -> None:
        key = self._key_of_page.pop(page_id, None)
        if key is not None:
            self._entries.pop(key, None)

    @property
    def hit_rate(self) -> Optional[float]:
        return self.hits / self.lookups if self.lookups else None
