"""Ring-buffer tracer exporting Chrome trace-event JSON (DESIGN.md §15).

Design constraints, in order:

1. **Low overhead when on.** One event = one tuple appended to a
   ``deque(maxlen=capacity)`` — no dict construction, no string
   formatting, no I/O until ``export()``. A long soak cannot OOM the
   host: the ring drops the *oldest* events (counted in ``dropped``)
   while track-naming metadata survives outside the ring.
2. **Zero cost when off.** There is no global "maybe-enabled" tracer to
   consult; call sites hold ``tracer=None`` and guard with a single
   attribute test, so the disabled path never reads the clock or builds
   an event.
3. **Perfetto-loadable output.** ``export()`` writes the Chrome
   trace-event JSON object format (``{"traceEvents": [...]}``) using
   complete ("X"), instant ("i"), counter ("C") and metadata ("M")
   events — load the file at https://ui.perfetto.dev or
   chrome://tracing. Timestamps are integer microseconds relative to the
   tracer's epoch.

Track layout: each engine registers a *process* (``new_pid``); its
scheduler-level spans (decode steps, chunk windows, kernel-phase spans
with modeled roofline attributes) live on ``tid=0`` and every request
gets its own thread track (``tid = rid + 1``) carrying the request's
whole lifecycle — submit → admit → prefill/chunks → first token →
decode → done/failed/preempted/quarantined — as one row. Spans whose
boundaries are only known after the fact (queue wait, TTFT components)
are emitted retrospectively via ``complete()`` from the same clock
stamps the metrics use, so trace-derived TTFT/TPOT agrees with
``Request.metrics()`` to microsecond rounding.
"""
from __future__ import annotations

import collections
import contextlib
import json
from typing import Any, Dict, List, Optional

from repro.obs import clock as obs_clock

__all__ = ["Tracer", "load_trace", "validate_events"]

# tuple layout of one ring entry: (ph, name, cat, ts_us, dur_us, pid,
# tid, args) — ph/dur/args semantics per trace-event phase
_COMPLETE, _INSTANT, _COUNTER = "X", "i", "C"


class Tracer:
    def __init__(self, capacity: int = 65536, clock=None):
        assert capacity >= 1, capacity
        self._clock = clock if clock is not None else obs_clock.now
        self.t0 = self._clock()
        self.capacity = capacity
        self._ring: collections.deque = collections.deque(maxlen=capacity)
        self.dropped = 0
        self._process_names: Dict[int, str] = {}
        self._thread_names: Dict[tuple, str] = {}
        self._next_pid = 0

    # -- track naming (survives ring overflow) -------------------------
    def new_pid(self, name: str) -> int:
        pid = self._next_pid
        self._next_pid += 1
        self._process_names[pid] = name
        return pid

    def thread_name(self, pid: int, tid: int, name: str) -> None:
        self._thread_names[(pid, tid)] = name

    # -- event emission ------------------------------------------------
    def _ts(self, t: Optional[float]) -> int:
        return round(((self._clock() if t is None else t) - self.t0) * 1e6)

    def _push(self, ev: tuple) -> None:
        if len(self._ring) == self.capacity:
            self.dropped += 1
        self._ring.append(ev)

    def complete(self, name: str, t_start: float, t_end: float, *,
                 cat: str = "engine", pid: int = 0, tid: int = 0,
                 args: Optional[dict] = None) -> None:
        """Retrospective span from two absolute clock stamps (the pattern
        for request-lifecycle phases, whose boundaries the engine already
        stamps on the Request)."""
        self._push((_COMPLETE, name, cat, self._ts(t_start),
                    max(self._ts(t_end) - self._ts(t_start), 0),
                    pid, tid, args))

    @contextlib.contextmanager
    def span(self, name: str, *, cat: str = "engine", pid: int = 0,
             tid: int = 0, args: Optional[dict] = None):
        """Measured span around a code region; ``args`` may be mutated
        inside the region (it is read at exit)."""
        t0 = self._clock()
        try:
            yield
        finally:
            self.complete(name, t0, self._clock(), cat=cat, pid=pid,
                          tid=tid, args=args)

    def instant(self, name: str, *, t: Optional[float] = None,
                cat: str = "engine", pid: int = 0, tid: int = 0,
                args: Optional[dict] = None) -> None:
        self._push((_INSTANT, name, cat, self._ts(t), 0, pid, tid, args))

    def counter(self, name: str, values: Dict[str, float], *,
                t: Optional[float] = None, pid: int = 0) -> None:
        """One multi-series counter sample (each key renders as a series
        in the counter track)."""
        self._push((_COUNTER, name, "counter", self._ts(t), 0, pid, 0,
                    dict(values)))

    # -- export --------------------------------------------------------
    def __len__(self) -> int:
        return len(self._ring)

    def __bool__(self) -> bool:
        # an empty tracer is still a tracer — guard sites test
        # `tracer is not None`, but don't let a plain truthiness test
        # silently flip on the first buffered event either
        return True

    def events(self) -> List[Dict[str, Any]]:
        """Ring contents as trace-event dicts (metadata excluded),
        sorted by timestamp — retrospective spans land out of emission
        order, and sorted output keeps validators simple."""
        out = []
        for ph, name, cat, ts, dur, pid, tid, args in self._ring:
            ev: Dict[str, Any] = {"ph": ph, "name": name, "cat": cat,
                                  "ts": ts, "pid": pid, "tid": tid}
            if ph == _COMPLETE:
                ev["dur"] = dur
            if ph == _INSTANT:
                ev["s"] = "t"          # thread-scoped instant
            if args is not None:
                ev["args"] = args
            out.append(ev)
        out.sort(key=lambda e: e["ts"])
        return out

    def to_dict(self) -> Dict[str, Any]:
        meta: List[Dict[str, Any]] = []
        for pid, name in sorted(self._process_names.items()):
            meta.append({"ph": "M", "name": "process_name", "pid": pid,
                         "tid": 0, "args": {"name": name}})
        for (pid, tid), name in sorted(self._thread_names.items()):
            meta.append({"ph": "M", "name": "thread_name", "pid": pid,
                         "tid": tid, "args": {"name": name}})
        return {"traceEvents": meta + self.events(),
                "displayTimeUnit": "ms",
                "otherData": {"dropped_events": self.dropped,
                              "capacity": self.capacity}}

    def export(self, path: str) -> int:
        """Write Perfetto-loadable JSON; returns the event count."""
        doc = self.to_dict()
        with open(path, "w") as f:
            json.dump(doc, f)
        return len(doc["traceEvents"])


def load_trace(path: str) -> Dict[str, Any]:
    with open(path) as f:
        doc = json.load(f)
    assert isinstance(doc.get("traceEvents"), list), (
        "not a Chrome trace-event object file")
    return doc


def validate_events(events: List[Dict[str, Any]]) -> None:
    """Schema conformance check used by tests and ``trace_report``:
    every event carries the required trace-event fields, complete spans
    have non-negative durations, and rid-tagged events sit on the track
    their rid names."""
    for ev in events:
        assert {"ph", "name", "pid", "tid"} <= set(ev), ev
        if ev["ph"] == "M":
            continue
        assert isinstance(ev["ts"], int), ev
        if ev["ph"] == _COMPLETE:
            assert isinstance(ev["dur"], int) and ev["dur"] >= 0, ev
        rid = (ev.get("args") or {}).get("rid")
        if rid is not None:
            assert ev["tid"] == rid + 1, (
                f"rid {rid} event on track tid={ev['tid']}", ev)
