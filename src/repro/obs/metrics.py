"""Counter/gauge/histogram/EWMA registry + the shared percentile helper
(DESIGN.md §15).

Before this module every subsystem grew its own metric plumbing: the
engine held ~20 bare ``self.x = 0`` counters, percentiles were computed
by a private ``_pcts`` in engine.py, step-time EWMAs existed twice (the
engine's budgeter and the train supervisor's ``StragglerWatchdog``) with
subtly different seeding. The registry is the one place those primitives
live now; the engine's counters are registry-backed behind unchanged
attribute names, and its metrics JSON is bit-for-bit what it was
(golden-locked by ``tests/test_obs.py``).

Everything here is bounded-memory by construction (``Histogram`` keeps a
capped sample list and says so in its output) and free of jax imports —
the registry must be importable from config-level code.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

__all__ = ["Counter", "Gauge", "Histogram", "Ewma", "RunningStat",
           "MetricsRegistry", "percentiles"]


def percentiles(values) -> Optional[Dict[str, float]]:
    """Exact p50/p90/p99 (+ mean/max/n) over the non-None values, or
    None when nothing was measured. This is the one percentile
    definition in the repo — the engine's latency aggregates, the
    traffic harness, and ``trace_report`` all call it, so their numbers
    are comparable by construction."""
    vals = [v for v in values if v is not None]
    if not vals:
        return None
    a = np.asarray(vals, np.float64)
    return {"p50": float(np.percentile(a, 50)),
            "p90": float(np.percentile(a, 90)),
            "p99": float(np.percentile(a, 99)),
            "mean": float(a.mean()), "max": float(a.max()),
            "n": int(a.size)}


class Counter:
    """Monotonically-growing event count. ``value`` is writable so
    legacy ``engine.<counter> = 0`` property setters keep working."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0

    def inc(self, n: int = 1) -> int:
        self.value += n
        return self.value


class Gauge:
    """Last-written level (queue depth, free-page fraction, ...)."""

    __slots__ = ("name", "value")

    def __init__(self, name: str):
        self.name = name
        self.value = 0.0

    def set(self, v: float) -> float:
        self.value = float(v)
        return self.value


class Histogram:
    """Value distribution with exact percentiles over a bounded sample
    window: the newest ``cap`` observations are retained (ring), the
    total count stays exact."""

    __slots__ = ("name", "n", "_ring", "_cap", "_i")

    def __init__(self, name: str, cap: int = 4096):
        self.name = name
        self.n = 0
        self._ring: List[float] = []
        self._cap = cap
        self._i = 0

    def observe(self, v: float) -> None:
        self.n += 1
        if len(self._ring) < self._cap:
            self._ring.append(float(v))
        else:
            self._ring[self._i] = float(v)
            self._i = (self._i + 1) % self._cap

    def percentiles(self) -> Optional[Dict[str, float]]:
        p = percentiles(self._ring)
        if p is not None:
            p["n"] = self.n            # exact count, windowed detail
        return p


class Ewma:
    """Exponentially-weighted moving average, seeded by the first
    observation (``value`` is None until then). The one step-time EWMA
    implementation shared by the serving engine's budgeter and the train
    supervisor's straggler watchdog."""

    __slots__ = ("name", "alpha", "value")

    def __init__(self, name: str, alpha: float = 0.1):
        # alpha=0 freezes the value at the seed (a deliberate test mode
        # for threshold logic); alpha=1 tracks the newest sample exactly
        assert 0.0 <= alpha <= 1.0, alpha
        self.name = name
        self.alpha = alpha
        self.value: Optional[float] = None

    def update(self, v: float) -> float:
        self.value = (float(v) if self.value is None
                      else (1.0 - self.alpha) * self.value
                      + self.alpha * float(v))
        return self.value


class RunningStat:
    """Bounded replacement for unbounded per-step sample lists:
    count/sum/peak accumulate in O(1) state — ``mean``/``peak`` are exact
    over *every* pushed sample, unlike a sampling reservoir — plus a
    small ring of the most recent samples for debugging long runs."""

    __slots__ = ("name", "n", "total", "peak", "ring", "_cap", "_i")

    def __init__(self, name: str = "", cap: int = 1024):
        self.name = name
        self.n = 0
        self.total = 0
        self.peak = 0
        self.ring: List[int] = []
        self._cap = cap
        self._i = 0

    def push(self, v: int) -> None:
        v = int(v)
        self.n += 1
        self.total += v
        if v > self.peak:
            self.peak = v
        if len(self.ring) < self._cap:
            self.ring.append(v)
        else:
            self.ring[self._i] = v
            self._i = (self._i + 1) % self._cap

    @property
    def mean(self) -> float:
        return self.total / self.n if self.n else 0.0


class MetricsRegistry:
    """Name-keyed get-or-create store for the primitives above. A name
    is bound to one kind for the registry's lifetime — asking for a
    counter where a gauge lives is a bug, not a coercion."""

    def __init__(self):
        self._metrics: Dict[str, object] = {}

    def _get(self, name: str, kind, **kw):
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = kind(name, **kw)
        assert type(m) is kind, (
            f"metric {name!r} is a {type(m).__name__}, not {kind.__name__}")
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, cap: int = 4096) -> Histogram:
        return self._get(name, Histogram, cap=cap)

    def ewma(self, name: str, alpha: float = 0.1) -> Ewma:
        return self._get(name, Ewma, alpha=alpha)

    def stat(self, name: str, cap: int = 1024) -> RunningStat:
        return self._get(name, RunningStat, cap=cap)

    def reset(self, name: str) -> None:
        """Drop a metric so the next get-or-create starts fresh (the
        engine's windowed stats reset at ``begin_metrics``)."""
        self._metrics.pop(name, None)

    def snapshot(self) -> Dict[str, object]:
        """Scalar view: counters/gauges by value, EWMAs by current
        value, histograms/stats by their summary dicts."""
        out: Dict[str, object] = {}
        for name, m in sorted(self._metrics.items()):
            if isinstance(m, (Counter, Gauge, Ewma)):
                out[name] = m.value
            elif isinstance(m, Histogram):
                out[name] = m.percentiles()
            elif isinstance(m, RunningStat):
                out[name] = {"n": m.n, "mean": m.mean, "peak": m.peak}
        return out

    def __contains__(self, name: str) -> bool:
        return name in self._metrics

    def __len__(self) -> int:
        return len(self._metrics)
