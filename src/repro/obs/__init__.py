"""Observability subsystem (DESIGN.md §15): one clock, one tracer, one
metrics registry.

``obs.clock``   — the single monotonic clock source every serving-path
                  timestamp (submit/admit/first-token/deadline/backoff)
                  reads from; fake-able in tests so trace and metrics
                  output is deterministic.
``obs.trace``   — a low-overhead ring-buffer ``Tracer`` emitting
                  span/instant/counter events and exporting Chrome
                  trace-event JSON (load the file in Perfetto or
                  chrome://tracing). Per-request events share one track,
                  so a request's lifecycle — submit → admit →
                  prefill-chunk(s) → first token → decode →
                  done/failed/preempted — renders as one row.
``obs.metrics`` — counter/gauge/histogram/EWMA registry plus the shared
                  exact-percentile helper behind the engine's metrics
                  JSON (whose shape is golden-locked by
                  ``tests/test_obs.py``).

The disabled path is zero-cost by construction: call sites hold
``tracer=None`` and guard with one attribute test — no event object is
built, no clock is read.
"""
from repro.obs import clock
from repro.obs.metrics import (Counter, Ewma, Gauge, Histogram,
                               MetricsRegistry, RunningStat, percentiles)
from repro.obs.trace import Tracer, load_trace, validate_events

__all__ = [
    "clock", "trace", "metrics",
    "Tracer", "load_trace", "validate_events",
    "MetricsRegistry", "Counter", "Gauge", "Histogram", "Ewma",
    "RunningStat", "percentiles",
]
