"""The single monotonic clock source for every serving-path timestamp.

Before this module the stack mixed bare ``time.monotonic()`` calls across
the engine, queue, SLO admission, traffic harness, and train supervisor —
individually correct, but impossible to fake coherently: a test that
wanted deterministic TTFT numbers (or a trace whose timestamps survive a
golden comparison) had no seam. Every timing site now reads ``clock.now()``
and tests swap the source with ``set_clock``/``fake_clock``.

``now()`` must stay *monotonic and mutually consistent*: deadlines
(``Request.expired``), retry backoff (``not_before``), trace timestamps,
and latency metrics are all compared against each other, so they must all
come from this one function. ``time.time()`` (wall clock, steppable by
NTP) is never an acceptable substitute for durations.
"""
from __future__ import annotations

import contextlib
import time
from typing import Callable

__all__ = ["now", "set_clock", "reset_clock", "FakeClock", "fake_clock"]

_clock: Callable[[], float] = time.monotonic


def now() -> float:
    """Seconds from the process-wide monotonic source (or the installed
    fake). The float is comparable across every module that uses it —
    that mutual consistency is the whole point."""
    return _clock()


def set_clock(fn: Callable[[], float]) -> Callable[[], float]:
    """Install ``fn`` as the clock source; returns the previous source so
    callers can restore it (prefer the ``fake_clock`` context manager)."""
    global _clock
    prev = _clock
    _clock = fn
    return prev


def reset_clock() -> None:
    """Restore the real ``time.monotonic`` source."""
    global _clock
    _clock = time.monotonic


class FakeClock:
    """Deterministic test clock: starts at ``t0`` and advances only via
    ``advance()`` — plus an optional ``tick`` added on every read so
    code that busy-waits on the clock (admission backoff, deadline
    sweeps) still observes progress under test."""

    def __init__(self, t0: float = 0.0, tick: float = 0.0):
        self.t = float(t0)
        self.tick = float(tick)

    def __call__(self) -> float:
        self.t += self.tick
        return self.t

    def advance(self, dt: float) -> float:
        assert dt >= 0.0, f"monotonic clocks cannot rewind ({dt})"
        self.t += dt
        return self.t


@contextlib.contextmanager
def fake_clock(clock: FakeClock = None, **kw):
    """``with fake_clock(tick=0.01) as fc: ...`` — installs a FakeClock
    for the scope and always restores the previous source."""
    fc = clock if clock is not None else FakeClock(**kw)
    prev = set_clock(fc)
    try:
        yield fc
    finally:
        set_clock(prev)
