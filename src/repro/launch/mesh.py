"""Production mesh construction.

A FUNCTION (not a module-level constant) so importing this module never
touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before first jax
init; smoke tests and benches see the 1 real device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: 16x16 = 256 chips (data, model). Multi-pod: 2 pods =
    512 chips (pod, data, model) — the pod axis extends data parallelism.

    With the dry-run's 512 placeholder devices, the single-pod mesh takes
    the first 256 (one pod's worth)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = 1
    for s in shape:
        n *= s
    devs = jax.devices()
    if len(devs) != n:
        devs = devs[:n]
    return jax.make_mesh(shape, axes, devices=devs)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / CPU runs)."""
    return jax.make_mesh((data, model), ("data", "model"))
