"""End-to-end training driver.

Runs on anything from 1 CPU (reduced configs, tests, examples) to the
production mesh (same code path — the mesh shape is the only difference).
Integrates: synthetic data pipeline, AdamW, checkpoint/restart via the
fault-tolerance supervisor, straggler watchdog, optional ternary QAT
(the paper's technique) and optional ternary gradient compression on the
data-parallel axes (shard_map DP trainer).

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch ternary-paper \
      --reduced --steps 200 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import json
import logging
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig
from repro.data import SyntheticLM
from repro.distributed import sharding as shlib
from repro.distributed.fault_tolerance import StragglerWatchdog, TrainSupervisor
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_local_mesh
from repro.obs import clock as obs_clock
from repro.models import LM, set_mesh
from repro.optim import warmup_cosine

log = logging.getLogger("repro.train")


def make_compressed_dp_step(model: LM, cfg: ModelConfig, mesh, lr_fn):
    """Pure-DP trainer with TernGrad-style ternary gradient sync (§DESIGN 8):
    the whole step runs under shard_map over the data axes; each shard
    computes local grads on its batch slice, gradients cross the wire as
    ternary codes + scales with error feedback, the optimizer update is
    replicated. The paper's {-1,0,+1} value system applied to the comm
    layer."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.distributed import compression
    from repro.optim import adamw, clip_by_global_norm

    opt_init, opt_update = adamw(state_dtype=cfg.opt_state_dtype)
    axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def local_step(params, opt_state, err, batch):
        def loss_fn(p):
            return model.loss(p, batch)

        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        synced, err = compression.compressed_psum(grads, err, axes[-1])
        synced, gnorm = clip_by_global_norm(synced, 1.0)
        lr = lr_fn(opt_state["step"] + 1)
        params, opt_state = opt_update(synced, opt_state, params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr,
                       loss=jax.lax.pmean(metrics["loss"], axes[-1]))
        return params, opt_state, err, metrics

    rep = P()
    bspec = jax.tree.map(lambda _: P(axes[-1]), {"tokens": 0, "targets": 0})

    def step(params, opt_state, err, batch):
        bs = {k: P(axes[-1]) for k in batch}
        f = shard_map(local_step, mesh=mesh,
                      in_specs=(rep, rep, rep, bs),
                      out_specs=(rep, rep, rep, rep),
                      check_rep=False)
        return f(params, opt_state, err, batch)

    return step, opt_init


def build(cfg: ModelConfig, batch: int, seq: int, mesh=None, lr: float = 3e-4,
          total_steps: int = 1000):
    model = LM(cfg)
    data = SyntheticLM(cfg, batch, seq)
    lr_fn = warmup_cosine(lr, min(100, total_steps // 10 + 1), total_steps)
    train_step, opt_init = steps_lib.make_train_step(model, cfg, lr_fn)

    if mesh is not None:
        set_mesh(mesh)
        p_shapes, p_shardings = steps_lib.model_shardings(model, cfg, mesh)
        opt_shapes = jax.eval_shape(opt_init, p_shapes)
        opt_sh = shlib.opt_state_shardings(p_shardings, opt_shapes, mesh)
        batch_sh = shlib.batch_sharding(
            jax.eval_shape(lambda: data.sharded_batch(0)), mesh)
        jitted = jax.jit(train_step,
                         in_shardings=(p_shardings, opt_sh, batch_sh),
                         donate_argnums=(0, 1))
    else:
        p_shardings = None
        jitted = jax.jit(train_step, donate_argnums=(0, 1))

    def init_state(key):
        params = model.init(key)
        if mesh is not None:
            params = jax.tree.map(
                lambda x, s: jax.device_put(x, s), params, p_shardings)
        return {"params": params, "opt": opt_init(params)}

    return model, data, jitted, init_state, p_shardings


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ternary-paper")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true",
                    help="sync gradients as ternary codes + scales with "
                         "error feedback (TernGrad-style shard_map DP "
                         "trainer; needs --data-parallel > 1 and "
                         "--model-parallel 1)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--set", action="append", default=[])
    args = ap.parse_args(argv)
    logging.basicConfig(level=logging.INFO)

    overrides: Dict[str, Any] = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        f = ModelConfig.__dataclass_fields__[k]
        typ = f.type if isinstance(f.type, type) else eval(f.type)  # noqa: S307
        overrides[k] = (v.lower() in ("1", "true")) if typ is bool else typ(v)
    cfg = get_config(args.arch, reduced=args.reduced, **overrides)

    mesh = None
    if args.data_parallel * args.model_parallel > 1:
        mesh = make_local_mesh(args.data_parallel, args.model_parallel)

    if args.compress_grads:
        # the pure-DP shard_map trainer: replicated params/opt/error state,
        # batch split on the data axis, ternary codes on the wire
        if mesh is None or "data" not in mesh.axis_names \
                or args.model_parallel > 1:
            raise SystemExit("--compress-grads needs a pure data-parallel "
                             "mesh: --data-parallel > 1 --model-parallel 1")
        from repro.distributed import compression
        model = LM(cfg)
        data = SyntheticLM(cfg, args.batch, args.seq)
        lr_fn = warmup_cosine(args.lr, min(100, args.steps // 10 + 1),
                              args.steps)
        cstep, c_opt_init = make_compressed_dp_step(model, cfg, mesh, lr_fn)
        jitted = jax.jit(cstep, donate_argnums=(0, 1, 2))

        def init_state(key):
            params = model.init(key)
            return {"params": params, "opt": c_opt_init(params),
                    "err": compression.init_error_state(params)}
    else:
        model, data, jitted, init_state, _ = build(
            cfg, args.batch, args.seq, mesh, args.lr, args.steps)

    def make_state(resume_step: Optional[int]):
        if resume_step is None:
            return 0, init_state(jax.random.PRNGKey(args.seed))
        from repro import checkpoint as ckpt
        target = jax.eval_shape(init_state, jax.ShapeDtypeStruct((2,), jnp.uint32))
        step, state = ckpt.restore(args.ckpt_dir, resume_step, target)
        log.info("restored step %d from %s", step, args.ckpt_dir)
        return step, state

    t_hist = []

    def step_fn(step: int, state):
        t0 = obs_clock.now()
        if args.compress_grads:
            # shard_map splits the global batch on the data axis itself
            batch = {k: jnp.asarray(v)
                     for k, v in data.global_batch(step).items()}
            params, opt, err, metrics = jitted(
                state["params"], state["opt"], state["err"], batch)
            state = {"params": params, "opt": opt, "err": err}
        else:
            batch = (data.sharded_batch(step, mesh)
                     if mesh is not None else data.sharded_batch(step))
            params, opt, metrics = jitted(state["params"], state["opt"],
                                          batch)
            state = {"params": params, "opt": opt}
        metrics = {k: float(v) for k, v in metrics.items()}
        dt = obs_clock.now() - t0
        t_hist.append(dt)
        if step % args.log_every == 0:
            log.info("step %d loss %.4f (%.3fs)", step, metrics["loss"], dt)
        return state, metrics

    sup = TrainSupervisor(args.ckpt_dir, make_state, step_fn,
                          ckpt_every=args.ckpt_every,
                          watchdog=StragglerWatchdog())
    state, history = sup.run(args.steps)
    losses = [m["loss"] for _, m in history]
    print(json.dumps({
        "steps": len(history),
        "first_loss": losses[0] if losses else None,
        "last_loss": losses[-1] if losses else None,
        "mean_step_s": float(np.mean(t_hist[1:])) if len(t_hist) > 1 else None,
        "stragglers": sup.watchdog.straggler_steps,
    }))


if __name__ == "__main__":
    main()
