import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):  # test hook: smaller fake fleet
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on placeholder devices; extract memory / cost / collective-bytes
for the roofline analysis (EXPERIMENTS.md §Dry-run, §Roofline).

The two lines above MUST stay first — jax locks the device count on first
init. Never set that flag globally (smoke tests and benches see 1 device).

Usage:
  python -m repro.launch.dryrun --arch mistral-nemo-12b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multi-pod] \
      [--quant ternary_packed] [--out experiments/dryrun]
"""
import argparse
import json
import re
import sys
import traceback
from typing import Any, Dict

import jax

from repro.configs import SHAPES, get_config, list_archs
from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed import sharding as shlib
from repro.launch import hlo_cost
from repro.launch import steps as steps_lib
from repro.launch.mesh import make_production_mesh
from repro.obs import clock as obs_clock
from repro.models import LM, set_mesh

# --- hardware constants (TPU v5e-class, per the assignment brief) ---
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # bytes/s per chip
ICI_BW = 50e9              # bytes/s per link (ring model, per chip)

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
                "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1}

_COLL_RE = re.compile(
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-chip link bytes by collective type, from the SPMD-partitioned HLO
    (shapes printed there are already per-device). Ring model:
    all-reduce = 2x operand (RS+AG), all-gather = result, others = operand."""
    out: Dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if m is None or "-done(" in line:
            continue
        op = m.group(1)
        eq = line.index(" = ") if " = " in line else 0
        paren = m.end()
        results = _SHAPE_RE.findall(line[eq:paren])
        operands = _SHAPE_RE.findall(line[paren:])
        res_b = sum(_shape_bytes(d, s) for d, s in results)
        opd_b = sum(_shape_bytes(d, s) for d, s in operands)
        if op == "all-reduce":
            byt = 2 * opd_b
        elif op == "all-gather":
            byt = res_b
        else:
            byt = opd_b
        out[op] = out.get(op, 0.0) + byt
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def _mem_dict(mem) -> Dict[str, Any]:
    keys = ["argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes"]
    d = {}
    for k in keys:
        try:
            d[k] = int(getattr(mem, k))
        except Exception:  # noqa: BLE001
            pass
    return d


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Analytic 'useful' FLOPs per step: 6*N_active*D train, 2*N_active*D
    inference (D = tokens processed in the step)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len
    return 2.0 * n_active * shape.global_batch  # decode: 1 token/seq


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             quant: str = "", overrides: Dict[str, Any] | None = None,
             mesh=None, reduced: bool = False) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    kw = dict(overrides or {})
    if quant:
        kw["quantization"] = quant
    cfg = get_config(arch, reduced=reduced, **kw)
    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "quant": quant or cfg.quantization,
        "overrides": {k: str(v) for k, v in (overrides or {}).items()},
    }
    ok, reason = cfg.supports_shape(shape_name)
    if not ok:
        rec.update(status="skipped", reason=reason)
        return rec

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    else:
        rec["mesh"] = "x".join(str(s) for s in mesh.devices.shape)
    chips = mesh.devices.size
    set_mesh(mesh)
    model = LM(cfg)
    t0 = obs_clock.now()

    p_shapes, p_shardings = steps_lib.model_shardings(model, cfg, mesh)
    batch = steps_lib.input_specs(cfg, shape)
    batch_sh = shlib.batch_sharding(batch, mesh)

    if shape.kind == "train":
        train_step, opt_init = steps_lib.make_train_step(model, cfg)
        opt_shapes = jax.eval_shape(opt_init, p_shapes)
        opt_sh = shlib.opt_state_shardings(p_shardings, opt_shapes, mesh)
        jitted = jax.jit(train_step,
                         in_shardings=(p_shardings, opt_sh, batch_sh),
                         donate_argnums=(0, 1))
        lowered = jitted.lower(p_shapes, opt_shapes, batch)
    elif shape.kind == "prefill":
        prefill_step = steps_lib.make_prefill_step(model, cfg, shape.seq_len)
        jitted = jax.jit(prefill_step, in_shardings=(p_shardings, batch_sh))
        lowered = jitted.lower(p_shapes, batch)
    else:  # decode
        decode_step = steps_lib.make_decode_step(model, cfg)
        cache_shapes, cache_pspec = steps_lib.cache_specs_shapes(
            model, cfg, shape)
        cache_sh = shlib.resolve_specs(cache_pspec, cache_shapes, mesh,
                                       fsdp=True)
        jitted = jax.jit(decode_step,
                         in_shardings=(p_shardings, cache_sh,
                                       batch_sh["tokens"]),
                         donate_argnums=(1,))
        lowered = jitted.lower(p_shapes, cache_shapes, batch["tokens"])

    t_lower = obs_clock.now() - t0
    compiled = lowered.compile()
    t_compile = obs_clock.now() - t0 - t_lower

    cost = hlo_cost.xla_cost(compiled)
    mem = _mem_dict(compiled.memory_analysis())
    hlo_text = compiled.as_text()
    # Trip-count-aware walk (XLA's cost_analysis counts scan bodies once —
    # see hlo_cost.py); shapes in the SPMD module are per-device.
    walked = hlo_cost.analyze(hlo_text)
    coll = dict(walked.collective_bytes)
    coll["total"] = walked.total_collective()

    hlo_flops = walked.flops
    hlo_bytes = walked.bytes
    mf = model_flops(cfg, shape)
    t_comp = hlo_flops / PEAK_FLOPS
    t_mem = hlo_bytes / HBM_BW
    t_coll = coll.get("total", 0.0) / ICI_BW
    dominant = max((("compute", t_comp), ("memory", t_mem),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    rec.update(
        status="ok",
        chips=chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        hlo_flops_per_chip=hlo_flops,
        hlo_bytes_per_chip=hlo_bytes,
        collective_bytes_per_chip=coll,
        xla_cost_analysis={"flops": float(cost.get("flops", 0.0)),
                           "bytes": float(cost.get("bytes accessed", 0.0))},
        memory=mem,
        t_compute_s=t_comp,
        t_memory_s=t_mem,
        t_collective_s=t_coll,
        dominant=dominant,
        model_flops_total=mf,
        model_flops_per_chip=mf / chips,
        useful_flops_ratio=(mf / chips) / hlo_flops if hlo_flops else None,
        params_total=cfg.param_count(),
        params_active=cfg.active_param_count(),
    )
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--quant", default="")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="config override key=value (repeatable)")
    ap.add_argument("--mesh", default="",
                    help="test hook: 'DxM' or 'PxDxM' mesh instead of production")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh:
        dims = tuple(int(x) for x in args.mesh.split("x"))
        names = ("data", "model") if len(dims) == 2 else ("pod", "data", "model")
        mesh = jax.make_mesh(dims, names)

    archs = [a for a in list_archs() if a != "ternary-paper"] \
        if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    overrides: Dict[str, Any] = {}
    for kv in args.set:
        k, v = kv.split("=", 1)
        f = ModelConfig.__dataclass_fields__[k]
        typ = f.type if isinstance(f.type, type) else eval(f.type)  # noqa: S307
        overrides[k] = (v.lower() in ("1", "true")) if typ is bool else typ(v)

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch in archs:
        for shape in shapes:
            tag = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
            if args.quant:
                tag += f"_{args.quant}"
            if overrides:
                tag += "_" + "_".join(f"{k}-{v}" for k, v in overrides.items())
            path = os.path.join(args.out, tag + ".json")
            try:
                rec = run_cell(arch, shape, multi_pod=args.multi_pod,
                               quant=args.quant, overrides=overrides,
                               mesh=mesh)
            except Exception as e:  # noqa: BLE001
                rec = {"arch": arch, "shape": shape, "status": "error",
                       "error": f"{type(e).__name__}: {e}",
                       "trace": traceback.format_exc()[-2000:]}
                failures += 1
            with open(path, "w") as f:
                json.dump(rec, f, indent=1, default=str)
            print(f"{tag}: {rec['status']} "
                  + (f"dom={rec.get('dominant')} "
                     f"t=({rec.get('t_compute_s', 0):.2e},"
                     f"{rec.get('t_memory_s', 0):.2e},"
                     f"{rec.get('t_collective_s', 0):.2e})s "
                     f"compile={rec.get('compile_s')}s"
                     if rec["status"] == "ok" else rec.get("reason", rec.get("error", ""))),
                  flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
