"""Serving CLI: continuous-batching engine (default) with a static-batch
fallback for A/B comparison.

The default mode drives ``repro.serving.ContinuousScheduler``: a request
queue feeding a slot-allocated KV/SSM cache pool, prefill of newly admitted
requests interleaved with decode steps of in-flight ones, per-request
TTFT/latency and queue-depth metrics emitted as JSON (DESIGN.md §7).
``--static`` runs the legacy whole-batch loop (a batch must fully finish its
generation budget before the next is admitted) on the *same* workload so the
two modes are directly comparable; both modes handle request counts that are
not a multiple of the batch/slot size.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch ternary-paper --reduced \
      --requests 32 --slots 8 --prompt-len 32 --gen-lens 8,64
  ... --static --batch 8     # legacy static-batch A/B reference
  ... --packed --ternary-min-dim 64   # TernaryWeight packed serving
                                      # (reduced configs need the override)
  ... --cache paged --page-size 16 --kv-dtype int8   # paged KV cache
                                      # (block tables + quantized pages +
                                      #  prefix reuse, DESIGN.md §9)
  ... --spec layer_skip --spec-k 4    # self-speculative decoding: draft k
                                      # tokens cheaply, verify all k+1 in
                                      # one small-M GEMM forward, roll back
                                      # rejects — token-exact (DESIGN.md
                                      # §10; resparsify needs --packed)
  ... --chaos --deadline-s 5 --max-retries 2   # seeded fault injection +
                                      # lifecycle hardening: NaN quarantine,
                                      # retry-with-replay, deadlines, the
                                      # degradation ladder (DESIGN.md §11)
  ... --chunked-prefill --chunk-tokens 32 \\
      --traffic poisson --arrival-rate 12 \\
      --slo-ttft-ms 200 --slo-tpot-ms 50   # SLO-aware chunked prefill
                                      # under open-loop offered load:
                                      # prompts stream in alongside decode
                                      # under a per-step token budget, and
                                      # the JSON reports p50/p90/p99 TTFT
                                      # (split queue-wait + prefill) and
                                      # TPOT per class (DESIGN.md §14)
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \\
  ... --mesh 2,4                      # dp x tp mesh serving: 2 engine
                                      # replicas, each tensor-parallel over
                                      # 4 devices, behind the prefix-
                                      # affinity router (DESIGN.md §13);
                                      # token-exact vs single device
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.obs import clock as obs_clock
from repro.models import LM


class BatchedServer:
    """Static-batch server: groups requests into batches of size B, runs one
    prefill + N decode steps per batch. (Decode-step jit is shared across
    batches; the cache is donated between steps.)"""

    def __init__(self, cfg, max_len: int):
        self.cfg = cfg
        self.model = LM(cfg)
        self.max_len = max_len
        self.params = None
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_len))
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))

    def load(self, params):
        self.params = params

    def generate(self, prompts: np.ndarray, gen_len: int,
                 extras: Dict[str, Any] | None = None) -> np.ndarray:
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        cache, logits = self._prefill(self.params, batch)
        out: List[np.ndarray] = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for _ in range(gen_len):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Workload + drivers (shared with benchmarks/serving_bench.py and tests)
# ---------------------------------------------------------------------------

def build_workload(cfg, requests: int, prompt_len: int,
                   gen_lens: Sequence[int], seed: int = 0,
                   ) -> Tuple[np.ndarray, List[int], Dict[str, np.ndarray]]:
    """(prompts (R, prompt_len) int32, per-request gen budgets, extras).
    Prompts come from the deterministic SyntheticLM stream; budgets are drawn
    uniformly from ``gen_lens`` — mixed lengths are what continuous batching
    exploits. ``extras`` carries per-request frontend rows (vision/encoder
    embeds) for the families that need them (static mode only)."""
    data = SyntheticLM(cfg, requests, max(prompt_len, 16), seed=seed)
    b = data.global_batch(0)
    prompts = b["tokens"][:, :prompt_len]
    extras = {k: v for k, v in b.items()
              if k in ("vision_embeds", "enc_embeds")}
    rng = np.random.default_rng(seed + 1)
    gens = [int(g) for g in rng.choice(list(gen_lens), size=requests)]
    return prompts.astype(np.int32), gens, extras


def run_continuous(engine, prompts: np.ndarray, gens: Sequence[int],
                   ) -> Tuple[List[np.ndarray], Dict[str, Any]]:
    """Submit the whole workload, drain it, return per-request token arrays
    (in submit order) + the engine metrics dict."""
    reqs = [engine.submit(p, g) for p, g in zip(prompts, gens)]
    metrics = engine.run()
    outs = [np.asarray(r.tokens, np.int32) for r in reqs]
    return outs, metrics


def run_static(server: BatchedServer, prompts: np.ndarray,
               gens: Sequence[int], batch: int,
               extras: Optional[Dict[str, np.ndarray]] = None,
               ) -> Tuple[List[np.ndarray], Dict[str, Any]]:
    """Static-batch A/B reference on the same workload. Requests are grouped
    in submit order; each batch decodes max(batch budgets) steps and every
    request keeps its own budget's prefix. A ragged final batch is padded by
    repeating its last row and the padding outputs dropped — no request is
    silently left unserved."""
    n = len(prompts)
    assert n == len(gens) and n > 0
    outs: List[np.ndarray] = []
    t0 = obs_clock.now()
    n_decode = 0
    for lo in range(0, n, batch):
        chunk = prompts[lo:lo + batch]
        ext = {k: v[lo:lo + batch] for k, v in (extras or {}).items()}
        budgets = list(gens[lo:lo + batch])
        real = len(chunk)
        if real < batch:        # ragged final batch: pad, serve, trim
            pad_rows = batch - real
            chunk = np.concatenate(
                [chunk, np.repeat(chunk[-1:], pad_rows, axis=0)], axis=0)
            ext = {k: np.concatenate(
                [v, np.repeat(v[-1:], pad_rows, axis=0)], axis=0)
                for k, v in ext.items()}
        gen = max(budgets)
        toks = server.generate(chunk, gen, ext or None)
        n_decode += gen
        for i, g in enumerate(budgets):
            outs.append(toks[i, :g].astype(np.int32))
    wall = obs_clock.now() - t0
    assert len(outs) == n, (len(outs), n)
    useful = sum(len(o) for o in outs)
    return outs, {
        "engine": "static",
        "batch": batch,
        "submitted": n,
        "drained": len(outs),
        "generated_tokens": useful,
        "wall_s": round(wall, 4),
        "tok_per_s": round(useful / wall, 2) if wall > 0 else None,
        "decode_steps": n_decode,
    }


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main(argv: Optional[Sequence[str]] = None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="ternary-paper")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4,
                    help="continuous mode: KV/SSM cache pool capacity")
    ap.add_argument("--batch", type=int, default=4,
                    help="--static mode: static batch size")
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-lens", default="32",
                    help="comma list; per-request budgets drawn uniformly")
    ap.add_argument("--max-len", type=int, default=0,
                    help="cache capacity (0: prompt+max(gen-lens)+1)")
    ap.add_argument("--static", action="store_true",
                    help="legacy static-batch loop (A/B reference)")
    ap.add_argument("--cache", default="dense", choices=("dense", "paged"),
                    help="continuous mode cache: dense slot rows, or the "
                         "paged block-table pool (DESIGN.md §9)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="--cache paged: tokens per KV page")
    ap.add_argument("--pages", type=int, default=0,
                    help="--cache paged: page-pool capacity incl. the "
                         "trash page (0: slots*ceil(max_len/page_size)+1)")
    ap.add_argument("--kv-dtype", default="", choices=("", "int8"),
                    help="--cache paged: int8-quantized pages with "
                         "per-page scales (default: cfg.cache_dtype)")
    ap.add_argument("--no-prefix-cache", action="store_true",
                    help="--cache paged: disable shared-prefix page reuse")
    ap.add_argument("--paged-attn", default=None,
                    choices=("auto", "jax", "pallas"),
                    help="--cache paged: decode-attention lowering "
                         "(default: inherit cfg.paged_attn_impl; auto = "
                         "pallas on TPU, dense-bit-identical jax gather "
                         "elsewhere)")
    ap.add_argument("--spec", default="off",
                    choices=("off", "resparsify", "layer_skip"),
                    help="speculative decoding draft strategy (DESIGN.md "
                         "§10): resparsify = re-ternarized packed weights "
                         "at --draft-sparsity (needs --packed), layer_skip "
                         "= a prefix of the stack + shared lm_head. "
                         "Outputs stay token-exact vs --spec off")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="--spec: draft tokens proposed (and verified) per "
                         "round; each slot emits 1..k+1 tokens per round")
    ap.add_argument("--draft-sparsity", type=float, default=0.125,
                    help="--spec resparsify: draft nnz fraction")
    ap.add_argument("--draft-layers", type=int, default=0,
                    help="--spec layer_skip: draft stack depth (0: half "
                         "the layers, rounded to the stack period)")
    ap.add_argument("--packed", action="store_true",
                    help="quantize+pack ternarizable projections into the "
                         "TernaryWeight serving format before load (the "
                         "engine precomputes phase-keyed GemmPlans)")
    ap.add_argument("--ternary-min-dim", type=int, default=0,
                    help=">0: override cfg.ternary_min_dim — reduced smoke "
                         "configs need ~64 for --packed to convert their "
                         "small projections")
    ap.add_argument("--mesh", default="",
                    help="continuous mode: 'dp,tp' (or bare 'tp') — dp "
                         "engine replicas, each TP-sharded over tp devices "
                         "of a ('model',) mesh, behind the prefix-affinity "
                         "Router (DESIGN.md §13). Needs dp*tp devices; on "
                         "CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--eos-id", type=int, default=-1,
                    help=">=0: stop a request early on this token")
    ap.add_argument("--chaos", action="store_true",
                    help="continuous mode: arm the seeded fault injector "
                         "(NaN logits, forced page OOM, slow steps, draft "
                         "failures at modest rates; seeded from --seed). "
                         "Outputs of surviving requests stay token-exact "
                         "vs a fault-free run (DESIGN.md §11)")
    ap.add_argument("--deadline-s", type=float, default=0.0,
                    help=">0: per-request wall-clock deadline; expired "
                         "requests are cancelled (queued or mid-decode) "
                         "and drain as failed with reason 'deadline'")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="quarantine replays allowed per request before "
                         "it terminates failed (reason 'nan_logits')")
    ap.add_argument("--chunked-prefill", action="store_true",
                    help="continuous mode: chunked prefill + SLO-aware "
                         "admission (DESIGN.md §14) — prompts stream in "
                         "--chunk-tokens per step alongside decode, so a "
                         "long prompt never monopolises a step. Token-"
                         "exact vs whole-prompt admission")
    ap.add_argument("--chunk-tokens", type=int, default=32,
                    help="--chunked-prefill: max prompt tokens one request "
                         "prefills per step (windows are rounded down to "
                         "powers of two for bounded jit shapes)")
    ap.add_argument("--step-token-budget", type=int, default=0,
                    help="--chunked-prefill: total model-forward tokens "
                         "per step, decode charged first (0 = auto: "
                         "slots*(1+spec_k) + chunk-tokens)")
    ap.add_argument("--slo-ttft-ms", type=float, default=0.0,
                    help=">0: tag interactive-class requests with this "
                         "TTFT objective; admission orders by (priority, "
                         "deadline) and boosts deadline-pressed prefills")
    ap.add_argument("--slo-tpot-ms", type=float, default=0.0,
                    help=">0: interactive-class decode time-per-token "
                         "objective; prefill residual shrinks when steps "
                         "run over it")
    ap.add_argument("--traffic", default="off",
                    choices=("poisson", "bursty", "off"),
                    help="continuous mode: drive the engine open-loop "
                         "from a seeded arrival schedule instead of "
                         "submit-all-then-drain; requests split between "
                         "the interactive and batch SLO classes "
                         "(DESIGN.md §14)")
    ap.add_argument("--arrival-rate", type=float, default=8.0,
                    help="--traffic: mean offered load, requests/second")
    ap.add_argument("--trace", default="",
                    help="continuous mode: write a Perfetto-loadable "
                         "Chrome trace-event JSON of the run — per-request "
                         "lifecycle tracks, kernel spans carrying modeled "
                         "roofline attributes, per-step scheduler counters "
                         "(DESIGN.md §15). Load at https://ui.perfetto.dev "
                         "or analyse with scripts/trace_report.py")
    ap.add_argument("--trace-buffer", type=int, default=65536,
                    help="--trace: ring capacity in events; the oldest "
                         "events drop first and the drop count is "
                         "recorded in the file")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    overrides = ({"ternary_min_dim": args.ternary_min_dim}
                 if args.ternary_min_dim > 0 else {})
    cfg = get_config(args.arch, reduced=args.reduced, **overrides)
    gen_lens = [int(g) for g in args.gen_lens.split(",")]
    spec_headroom = args.spec_k if args.spec != "off" else 0
    max_len = args.max_len or (args.prompt_len + max(gen_lens) + 1
                               + spec_headroom)
    prompts, gens, extras = build_workload(cfg, args.requests,
                                           args.prompt_len, gen_lens,
                                           seed=args.seed)

    params = LM(cfg).init(jax.random.PRNGKey(args.seed))
    if args.packed:
        import dataclasses
        from repro.core import weights
        from repro.models import layers as L
        params = L.pack_params(params, cfg)
        n_packed = sum(isinstance(w, weights.TernaryWeight)
                       for w in jax.tree_util.tree_leaves(
                           params, is_leaf=lambda v: isinstance(
                               v, weights.TernaryWeight)))
        if n_packed:
            cfg = dataclasses.replace(cfg, quantization="ternary_packed")
        else:
            print(f"warning: --packed converted nothing (quantization="
                  f"{cfg.quantization!r}, no projection meets "
                  f"ternary_min_dim={cfg.ternary_min_dim}); serving the "
                  f"dense model", file=sys.stderr)

    tracer = None
    if args.trace:
        from repro.obs import Tracer
        tracer = Tracer(capacity=args.trace_buffer)

    if args.static:
        if args.mesh:
            raise SystemExit("--mesh is a continuous-engine feature; "
                             "drop --static")
        if args.chunked_prefill or args.traffic != "off":
            raise SystemExit("--chunked-prefill/--traffic drive the "
                             "continuous engine; drop --static")
        if args.trace:
            raise SystemExit("--trace instruments the continuous engine; "
                             "drop --static")
        server = BatchedServer(cfg, max_len)
        server.load(params)
        _, metrics = run_static(server, prompts, gens, args.batch,
                                extras=extras)
    else:
        from repro.serving import (ContinuousScheduler, FaultConfig,
                                   ResilienceConfig, SchedConfig, SLOClass)
        eos = args.eos_id if args.eos_id >= 0 else None
        spec = None
        if args.spec != "off":
            from repro.spec import SpecConfig
            spec = SpecConfig(draft=args.spec, k=args.spec_k,
                              draft_sparsity=args.draft_sparsity,
                              draft_layers=args.draft_layers)
        faults = None
        if args.chaos:
            faults = FaultConfig(seed=args.seed, nan_rate=0.05,
                                 oom_rate=0.05, slow_rate=0.02,
                                 slow_s=0.01, draft_fail_rate=0.05)
        resilience = ResilienceConfig(
            deadline_s=args.deadline_s if args.deadline_s > 0 else None,
            max_retries=args.max_retries)
        # SLO classes (DESIGN.md §14): the interactive class carries the
        # CLI latency objectives; batch-class requests ride priority 1
        slo_on = (args.chunked_prefill or args.slo_ttft_ms > 0
                  or args.slo_tpot_ms > 0 or args.traffic != "off")
        interactive = SLOClass(
            "interactive",
            ttft_target_s=(args.slo_ttft_ms / 1e3
                           if args.slo_ttft_ms > 0 else 0.5),
            tpot_target_s=(args.slo_tpot_ms / 1e3
                           if args.slo_tpot_ms > 0 else 0.1),
            priority=0)
        batch_cls = SLOClass("batch", ttft_target_s=None,
                             tpot_target_s=None, priority=1)
        sched = None
        if slo_on:
            sched = SchedConfig(
                chunk_tokens=args.chunk_tokens if args.chunked_prefill
                else 0,
                step_token_budget=args.step_token_budget)

        def build_engine(mesh=None):
            eng = ContinuousScheduler(
                cfg, max_slots=args.slots, max_len=max_len, eos_id=eos,
                cache=args.cache, page_size=args.page_size,
                n_pages=args.pages, kv_dtype=args.kv_dtype or None,
                prefix_cache=not args.no_prefix_cache,
                paged_attn=args.paged_attn, spec=spec, faults=faults,
                resilience=resilience, sched=sched, mesh=mesh,
                tracer=tracer)
            eng.load(params)
            return eng

        if args.mesh:
            if args.traffic != "off":
                raise SystemExit("--traffic drives a single engine "
                                 "open-loop; drop --mesh")
            from repro.distributed import router as router_lib
            from repro.distributed import tp as tp_lib
            dp, tp = tp_lib.parse_mesh(args.mesh)
            meshes = tp_lib.replica_meshes(dp, tp)
            front = router_lib.Router([build_engine(m) for m in meshes])
        else:
            front = build_engine()
        if args.traffic != "off":
            from repro.serving import (TrafficConfig, make_schedule,
                                       run_open_loop)
            tc = TrafficConfig(kind=args.traffic, rate=args.arrival_rate,
                               n_requests=args.requests,
                               prompt_lens=(args.prompt_len,),
                               gen_lens=tuple(gen_lens), seed=args.seed)
            schedule = make_schedule(tc, cfg.vocab_size,
                                     classes=(interactive, batch_cls),
                                     class_weights=(0.75, 0.25))
            _, metrics = run_open_loop(front, schedule)
        else:
            slo = interactive if slo_on else None
            reqs = [front.submit(p, g, slo=slo)
                    for p, g in zip(prompts, gens)]
            metrics = front.run()
            del reqs
        if tracer is not None:
            # one file even under --mesh: every replica engine registered
            # its own pid on the shared tracer, so replica timelines load
            # as separate process groups in the same Perfetto view
            n_ev = tracer.export(args.trace)
            print(f"# trace: {args.trace} ({n_ev} events, "
                  f"{tracer.dropped} dropped)", file=sys.stderr)
    print(json.dumps(metrics))
    return metrics


if __name__ == "__main__":
    main()
