"""Batched serving driver: continuous-batching-style loop with prefill +
decode over a request queue, KV/SSM caches, and ternary-packed weights
(the paper's serving-side format) when the config enables them.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch ternary-paper --reduced \
      --requests 16 --batch 4 --prompt-len 32 --gen-len 32
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticLM
from repro.launch import steps as steps_lib
from repro.models import LM


class BatchedServer:
    """Static-batch server: groups requests into batches of size B, runs one
    prefill + N decode steps per batch. (Decode-step jit is shared across
    batches; the cache is donated between steps.)"""

    def __init__(self, cfg, max_len: int):
        self.cfg = cfg
        self.model = LM(cfg)
        self.max_len = max_len
        self.params = None
        self._prefill = jax.jit(
            lambda p, b: self.model.prefill(p, b, max_len))
        self._decode = jax.jit(self.model.decode_step, donate_argnums=(1,))

    def load(self, params):
        self.params = params

    def generate(self, prompts: np.ndarray, gen_len: int,
                 extras: Dict[str, Any] | None = None) -> np.ndarray:
        batch = {"tokens": jnp.asarray(prompts)}
        if extras:
            batch.update({k: jnp.asarray(v) for k, v in extras.items()})
        cache, logits = self._prefill(self.params, batch)
        out: List[np.ndarray] = []
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        for _ in range(gen_len):
            out.append(np.asarray(tok))
            logits, cache = self._decode(self.params, cache, tok)
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return np.concatenate(out, axis=1)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="ternary-paper")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    server = BatchedServer(cfg, args.prompt_len + args.gen_len + 1)
    params = server.model.init(jax.random.PRNGKey(args.seed))
    server.load(params)

    rng = np.random.default_rng(args.seed)
    data = SyntheticLM(cfg, args.batch, args.prompt_len, seed=args.seed)
    n_batches = args.requests // args.batch
    t0 = time.monotonic()
    n_tokens = 0
    for i in range(n_batches):
        b = data.global_batch(i)
        extras = {k: v for k, v in b.items()
                  if k in ("vision_embeds", "enc_embeds")}
        toks = server.generate(b["tokens"][:, :args.prompt_len],
                               args.gen_len, extras)
        n_tokens += toks.size
    dt = time.monotonic() - t0
    print(json.dumps({
        "requests": n_batches * args.batch,
        "generated_tokens": n_tokens,
        "wall_s": round(dt, 3),
        "tok_per_s": round(n_tokens / dt, 2),
    }))


if __name__ == "__main__":
    main()
