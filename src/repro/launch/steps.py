"""Step builders: train / prefill / decode steps + ShapeDtypeStruct input
specs for every (architecture x assigned shape) cell.

``input_specs(cfg, shape)`` is the single source of truth for what enters
each lowered program (the shannon/kernels pattern: weak-type-correct,
shardable stand-ins, no device allocation).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import LM
from repro.optim import adamw, clip_by_global_norm, warmup_cosine

F32, BF16, I32 = jnp.float32, jnp.bfloat16, jnp.int32


def sds(shape, dtype) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Input specs per cell
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for the batch of one step of this cell."""
    b, s = shape.global_batch, shape.seq_len
    d = cfg.d_model
    if shape.kind == "decode":
        batch = {"tokens": sds((b, 1), I32)}
        return batch
    n_front = cfg.frontend_seq if (cfg.frontend or cfg.is_encdec) else 0
    if cfg.is_encdec:
        s_dec = s - n_front
        batch = {"tokens": sds((b, s_dec), I32),
                 "enc_embeds": sds((b, n_front, d), BF16)}
        if shape.kind == "train":
            batch["targets"] = sds((b, s_dec), I32)
        return batch
    s_text = s - n_front if cfg.family == "vlm" else s
    batch = {"tokens": sds((b, s_text), I32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = sds((b, n_front, d), BF16)
    if shape.kind == "train":
        batch["targets"] = sds((b, s_text), I32)
    return batch


def cache_specs_shapes(model: LM, cfg: ModelConfig, shape: ShapeConfig
                       ) -> Tuple[Any, Any]:
    """(ShapeDtypeStruct cache tree, PartitionSpec cache tree) for decode."""
    b, s = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(lambda: model.init_cache(b, s))
    cache_pspec = model.cache_specs()
    if cfg.is_encdec:
        cache_shapes = dict(cache_shapes)
        cache_shapes["enc_out"] = sds((b, cfg.frontend_seq, cfg.d_model), BF16)
        cache_pspec = dict(cache_pspec)
        cache_pspec["enc_out"] = P(("pod", "data"), None, None)
    return cache_shapes, cache_pspec


# ---------------------------------------------------------------------------
# Steps
# ---------------------------------------------------------------------------

def make_train_step(model: LM, cfg: ModelConfig,
                    lr_fn: Optional[Callable] = None,
                    compute_dtype=BF16) -> Tuple[Callable, Callable]:
    """Returns (train_step(params, opt_state, batch) -> (params, opt_state,
    metrics), opt_init(params) -> opt_state). Gradient accumulation over
    cfg.grad_accum microbatches via lax.scan (activation memory /= accum;
    XLA's scheduler overlaps each microbatch's reduce-scatters with the next
    microbatch's backward — the compute/comm overlap lever)."""
    lr_fn = lr_fn or warmup_cosine(3e-4, 100, 10_000)
    opt_init, opt_update = adamw(state_dtype=cfg.opt_state_dtype)
    accum = max(cfg.grad_accum, 1)

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if accum == 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
        else:
            def micro(i, batch):
                return jax.tree.map(
                    lambda x: x.reshape(accum, x.shape[0] // accum,
                                        *x.shape[1:])[i], batch)

            def body(carry, i):
                gsum, msum = carry
                (loss, metrics), g = jax.value_and_grad(
                    loss_fn, has_aux=True)(params, micro(i, batch))
                gsum = jax.tree.map(lambda a, b: a + b, gsum, g)
                msum = jax.tree.map(lambda a, b: a + b, msum, metrics)
                return (gsum, msum), None

            gzero = jax.tree.map(jnp.zeros_like, params)
            mzero = {"loss": jnp.zeros((), F32), "ce": jnp.zeros((), F32),
                     "aux": jnp.zeros((), F32)}
            (grads, metrics), _ = jax.lax.scan(
                body, (gzero, mzero), jnp.arange(accum))
            grads = jax.tree.map(lambda g: g / accum, grads)
            metrics = jax.tree.map(lambda m: m / accum, metrics)

        grads, gnorm = clip_by_global_norm(grads, 1.0)
        lr = lr_fn(opt_state["step"] + 1)  # schedules start at step 1
        params, opt_state = opt_update(grads, opt_state, params, lr)
        metrics = dict(metrics, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics

    return train_step, opt_init


def make_prefill_step(model: LM, cfg: ModelConfig, max_len: int) -> Callable:
    def prefill_step(params, batch):
        cache, logits = model.prefill(params, batch, max_len)
        return cache, logits
    return prefill_step


def make_decode_step(model: LM, cfg: ModelConfig) -> Callable:
    def decode_step(params, cache, tokens):
        return model.decode_step(params, cache, tokens)
    return decode_step


# ---------------------------------------------------------------------------
# Sharding assembly for a (cfg, mesh) pair
# ---------------------------------------------------------------------------

def model_shardings(model: LM, cfg: ModelConfig, mesh):
    """(param ShapeDtypeStructs, param NamedShardings) — no allocation."""
    from repro.distributed import sharding as shlib
    p_shapes, p_specs = model.init_with_specs_abstract()
    shardings = shlib.resolve_specs(p_specs, p_shapes, mesh, cfg.fsdp)
    return p_shapes, shardings
