"""Trip-count-aware HLO cost analysis.

XLA's ``compiled.cost_analysis()`` counts every computation ONCE — a
``lax.scan`` over 61 layers contributes 1/61 of its true FLOPs (verified in
EXPERIMENTS.md §Dry-run methodology). Since the whole framework scans over
layers / microbatches / attention blocks, we walk the optimized HLO text
ourselves:

* computations are parsed into op lists + per-computation symbol tables
  (operands are name references in compiled HLO; shapes come from the
  defining line);
* a call graph is built from ``fusion(calls=)``, ``call(to_apply=)``,
  ``while(body=, condition=)`` and ``conditional(branch_computations=)``;
* each ``while`` gets a trip count parsed from its condition computation
  (the ``s32[] constant(N)`` fed into the LT compare that lax.scan emits);
* costs roll up through the graph with trip multipliers.

Cost model (mirrors XLA's HloCostAnalysis, with the loop fix):
* flops: dot = 2 * prod(result) * prod(lhs contracting dims); elementwise /
  reduce = element counts (transcendentals weighted). Counted inside fused
  computations via the call graph, so fusion does not hide compute.
* bytes: operands + results at fusion *boundaries* only — fused internal
  traffic stays on-chip, matching the TPU HBM<->VMEM fusion model.
* collective bytes: ring model — all-reduce 2x operand, all-gather result,
  reduce-scatter / all-to-all / collective-permute operand; shapes in the
  SPMD-partitioned module are already per-device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple


def xla_cost(compiled) -> dict:
    """``compiled.cost_analysis()`` normalized across jax versions (older
    releases return ``[dict]`` per device, newer ones a flat dict)."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca

_DTYPE_BYTES = {"f64": 8, "s64": 8, "u64": 8, "c64": 8, "f32": 4, "s32": 4,
                "u32": 4, "bf16": 2, "f16": 2, "s16": 2, "u16": 2, "s8": 1,
                "u8": 1, "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1,
                "u4": 1, "token": 0, "opaque": 0}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\(?[^=()]*?\)?)\s([a-z][\w\-]*)\(")
_COMP_HDR_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_TO_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)")
_BRANCHES_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_S32_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_LHS_CDIMS_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

ELEMENTWISE_1FLOP = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "compare", "select", "and", "or", "xor", "not",
    "shift-left", "shift-right-logical", "shift-right-arithmetic",
    "floor", "ceil", "round-nearest-afz", "sign", "clamp", "remainder",
}
ELEMENTWISE_XFLOP = {
    "exponential": 4, "log": 4, "rsqrt": 2, "sqrt": 2, "tanh": 6,
    "logistic": 6, "power": 6, "cosine": 6, "sine": 6, "expm1": 4,
    "log-plus-one": 4, "atan2": 8, "erf": 6, "cbrt": 6,
}
CONTROL_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "partition-id", "replica-id", "copy-start", "copy-done",
    "domain", "opt-barrier",
}
COLLECTIVES = {"all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute"}


def _elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


def _text_shapes(text: str) -> List[Tuple[str, str]]:
    return _SHAPE_RE.findall(text)


def _shapes_bytes(shapes: List[Tuple[str, str]]) -> float:
    return float(sum(_elems(dims) * _DTYPE_BYTES.get(dt, 4)
                     for dt, dims in shapes))


def _shapes_elems(shapes: List[Tuple[str, str]]) -> float:
    return float(sum(_elems(dims) for _, dims in shapes))


def _elems_of(shapes) -> float:
    return float(sum(_elems(dims) for _, dims in shapes))


@dataclasses.dataclass
class Costs:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: Dict[str, float] = dataclasses.field(default_factory=dict)
    # per-opcode [flops, bytes] — the §Perf hypothesis source
    by_op: Dict[str, List[float]] = dataclasses.field(default_factory=dict)

    def bump(self, opcode: str, flops: float, byt: float):
        e = self.by_op.setdefault(opcode, [0.0, 0.0])
        e[0] += flops
        e[1] += byt

    def add(self, other: "Costs", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        for k, v in other.collective_bytes.items():
            self.collective_bytes[k] = self.collective_bytes.get(k, 0.0) \
                + v * mult
        for k, (fl, by) in other.by_op.items():
            e = self.by_op.setdefault(k, [0.0, 0.0])
            e[0] += fl * mult
            e[1] += by * mult

    def total_collective(self) -> float:
        return sum(self.collective_bytes.values())

    def top_bytes(self, n: int = 12) -> List[Tuple[str, float, float]]:
        rows = [(k, v[1], v[0]) for k, v in self.by_op.items()]
        rows.sort(key=lambda r: -r[1])
        return rows[:n]


@dataclasses.dataclass
class _Op:
    name: str
    opcode: str
    result_shapes: List[Tuple[str, str]]
    operand_names: List[str]
    line: str


class HloCostWalker:
    def __init__(self, hlo_text: str, kernel_dequant: bool = False):
        """kernel_dequant=True models the repo's Pallas fused dequant-GEMM
        on the runtime path (kernels/ternary_gemm.py, validated in interpret
        mode): 2-bit weight blocks are decoded VMEM-tile-wise inside the
        kernel, so dots charge the *packed* operand bytes and the decode
        fusion's HBM round-trip disappears. Off by default — the plain XLA
        path materializes decoded weights."""
        self._entry = ""
        self.kernel_dequant = kernel_dequant
        self.comps: Dict[str, List[_Op]] = {}
        self.symtab: Dict[str, Dict[str, List[Tuple[str, str]]]] = {}
        self._parse(hlo_text)
        self._memo: Dict[Tuple[str, bool], Costs] = {}
        self._dequant_ext: Dict[Tuple[str, str], float] = {}

    def _dequant_bytes(self, comp: str, op: _Op) -> Optional[float]:
        """If op is a 2-bit-dequant fusion, return its packed external
        bytes; else None. Signature: fused computation uses shift/and bit
        ops and expands >=4x from its integer inputs."""
        key = (comp, op.name)
        if key in self._dequant_ext:
            return self._dequant_ext[key]
        val: Optional[float] = None
        m = _CALLS_RE.search(op.line)
        if m and m.group(1) in self.comps:
            fops = self.comps[m.group(1)]
            has_bits = any(f.opcode in ("shift-right-logical", "and")
                           for f in fops)
            if has_bits:
                ext = sum(_shapes_bytes(self.symtab[comp].get(n, ()))
                          for n in op.operand_names)
                res = _shapes_bytes(op.result_shapes)
                if ext and res >= 4 * ext:
                    val = float(ext)
        self._dequant_ext[key] = val
        return val

    # ------------------------------------------------------------------
    def _parse(self, text: str):
        cur: Optional[str] = None
        for line in text.splitlines():
            if cur is None:
                m = _COMP_HDR_RE.match(line)
                if m and line.rstrip().endswith("{"):
                    cur = m.group(1)
                    self.comps[cur] = []
                    self.symtab[cur] = {}
                    if line.startswith("ENTRY"):
                        self._entry = cur
            elif line.startswith("}"):
                cur = None
            else:
                line = re.sub(r"/\*.*?\*/", "", line)  # strip HLO comments
                m = _OP_RE.match(line)
                if m is None:
                    continue
                name, result_text, opcode = m.groups()
                # operand section: up to attributes (first "), " or ", x=")
                rest = line[m.end():]
                cut = len(rest)
                for marker in ("metadata=", "calls=", "to_apply=",
                               "condition=", "dimensions=", "sharding=",
                               "dynamic_slice_sizes=", "slice=",
                               "lhs_contracting_dims=", "replica_groups=",
                               "branch_computations=", "channel_id=",
                               "source_target_pairs=", "custom_call_target="):
                    i = rest.find(marker)
                    if i != -1:
                        cut = min(cut, i)
                operand_names = _OPERAND_RE.findall(rest[:cut])
                op = _Op(name, opcode, _text_shapes(result_text),
                         operand_names, line)
                self.comps[cur].append(op)
                self.symtab[cur][name] = op.result_shapes

    def entry_name(self) -> str:
        return self._entry

    # ------------------------------------------------------------------
    def _operand_shapes(self, comp: str, op: _Op) -> List[Tuple[str, str]]:
        tab = self.symtab[comp]
        out: List[Tuple[str, str]] = []
        for n in op.operand_names:
            out.extend(tab.get(n, ()))
        return out

    def _op_index(self, comp: str) -> Dict[str, str]:
        idx = getattr(self, "_opcode_idx", None)
        if idx is None:
            idx = self._opcode_idx = {}
        if comp not in idx:
            idx[comp] = {o.name: o.opcode for o in self.comps.get(comp, ())}
        return idx[comp]

    def _fusion_operand_bytes(self, comp: str, op: _Op,
                              fused_name: Optional[str]) -> float:
        """Operand bytes at a fusion boundary. Two TPU-fusion rules:
        * an operand consumed *only* by gather / dynamic-slice ops inside the
          fused computation is read sparsely — count the consumers' result
          bytes (embedding tables, KV-cache block reads);
        * an operand produced directly by a `dot` fuses as the dot's output
          epilogue on TPU (elementwise consumers of matmul results never
          round-trip HBM) — count zero for it. XLA:CPU materializes these,
          which would charge score-tensor traffic the TPU never pays."""
        opcode_of = self._op_index(comp)
        opd_shapes = [() if opcode_of.get(n) == "dot"
                      else self.symtab[comp].get(n, ())
                      for n in op.operand_names]
        if fused_name is None or fused_name not in self.comps:
            return float(sum(_shapes_bytes(s) for s in opd_shapes))
        fops = self.comps[fused_name]
        # parameter name by index
        param_name = {}
        for f in fops:
            if f.opcode == "parameter":
                m = re.search(r"parameter\((\d+)\)", f.line)
                if m:
                    param_name[int(m.group(1))] = f.name
        total = 0.0
        for i, shapes in enumerate(opd_shapes):
            pname = param_name.get(i)
            if pname is None:
                total += _shapes_bytes(shapes)
                continue
            consumers = [f for f in fops if pname in f.operand_names]
            sparse = {"gather", "dynamic-slice"}
            if consumers and all(f.opcode in sparse for f in consumers):
                total += sum(_shapes_bytes(f.result_shapes) for f in consumers)
            elif consumers and all(
                    f.opcode == "dynamic-update-slice"
                    and f.operand_names and f.operand_names[0] == pname
                    for f in consumers):
                # in-place destination: only the written region moves
                total += sum(
                    _shapes_bytes(self.symtab[fused_name].get(
                        f.operand_names[1], ())) if len(f.operand_names) > 1
                    else 0.0
                    for f in consumers)
            else:
                total += _shapes_bytes(shapes)
        return total

    _MIRROR_OPS = {"parameter", "constant", "convert", "bitcast", "copy",
                   "select", "broadcast", "compare", "iota", "reshape",
                   "dynamic-slice", "dynamic-update-slice", "tuple",
                   "get-tuple-element"}

    def _is_inplace_update_fusion(self, fused_name: Optional[str]) -> bool:
        """True for fusions that are pure cache-update machinery: converts /
        selects / in-place DUS with no real compute. On TPU these lower to a
        predicated in-place write (bf16 dots are MXU-native, so the f32
        mirror XLA:CPU maintains for such buffers does not exist); counting
        the full-buffer convert traffic would charge a CPU-backend artifact
        to the TPU roofline. Verified: compiling uniform-f32 (mirror-free)
        halves measured bytes on decode cells."""
        if fused_name is None or fused_name not in self.comps:
            return False
        has_dus = False
        for f in self.comps[fused_name]:
            if f.opcode == "dynamic-update-slice":
                has_dus = True
            elif f.opcode not in self._MIRROR_OPS:
                return False
        return has_dus

    # dynamic-slice included: "slice a layer from the carried stack +
    # convert" shims — the real read is charged at the consuming dot
    _SHIM_OPS = {"parameter", "constant", "convert", "bitcast", "copy",
                 "tuple", "get-tuple-element", "dynamic-slice"}

    def _is_dtype_shim_fusion(self, fused_name: Optional[str]) -> bool:
        """Pure dtype-conversion fusions (convert/bitcast/copy only). The
        XLA:CPU backend upcasts bf16 dot inputs to f32 through these shims;
        on TPU the MXU consumes bf16 natively and the shim does not exist —
        and the *real* operand read is already counted at the consuming dot.
        Charging the shim would double-count a backend artifact."""
        if fused_name is None or fused_name not in self.comps:
            return False
        has_convert = False
        for f in self.comps[fused_name]:
            if f.opcode == "convert":
                has_convert = True
            elif f.opcode not in self._SHIM_OPS:
                return False
        return has_convert

    def _fusion_result_bytes(self, op: _Op, fused_name: Optional[str],
                             res_b: float) -> float:
        """A fusion rooted in dynamic-update-slice writes only the update
        region — XLA aliases the destination buffer in place (the lax.scan
        ys pattern). Count the update bytes, not the full result."""
        if fused_name is None or fused_name not in self.comps:
            return res_b
        fops = self.comps[fused_name]
        root = None
        for f in fops:
            if "ROOT" in f.line:
                root = f
                break
        if root is None:
            return res_b
        # unwrap converts/bitcasts at the root
        tab = self.symtab[fused_name]
        seen = 0
        while root.opcode in ("convert", "bitcast", "copy") \
                and root.operand_names and seen < 4:
            nxt = [f for f in fops if f.name == root.operand_names[0]]
            if not nxt:
                break
            root = nxt[0]
            seen += 1
        if root.opcode == "dynamic-update-slice" and len(root.operand_names) > 1:
            upd = _shapes_bytes(tab.get(root.operand_names[1], ()))
            if upd:
                return upd
        return res_b

    def _trip_count(self, cond_name: str) -> float:
        best = 1.0
        stack, seen = [cond_name], set()
        while stack:
            name = stack.pop()
            if name in seen or name not in self.comps:
                continue
            seen.add(name)
            for op in self.comps[name]:
                for c in _CONST_S32_RE.findall(op.line):
                    best = max(best, float(c))
                stack.extend(_CALLS_RE.findall(op.line))
                stack.extend(_TO_APPLY_RE.findall(op.line))
        return best

    # ------------------------------------------------------------------
    def computation_cost(self, name: str, in_fusion: bool = False) -> Costs:
        key = (name, in_fusion)
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Costs()  # cycle guard
        total = Costs()
        for op in self.comps.get(name, ()):
            oc = op.opcode
            if oc in CONTROL_OPS:
                continue
            res_b = _shapes_bytes(op.result_shapes)
            res_e = _shapes_elems(op.result_shapes)
            opd_shapes = self._operand_shapes(name, op)
            opd_b = _shapes_bytes(opd_shapes)

            if oc == "fusion":
                m = _CALLS_RE.search(op.line)
                fname = m.group(1) if m else None
                if m:
                    total.add(self.computation_cost(fname, True))
                if not in_fusion:
                    if self.kernel_dequant:
                        dq = self._dequant_bytes(name, op)
                        if dq is not None:
                            total.bytes += dq
                            total.bump("dequant(packed)", 0.0, dq)
                            continue
                    res_eff = self._fusion_result_bytes(op, fname, res_b)
                    if self._is_dtype_shim_fusion(fname):
                        fb = 0.0
                        total.bump("dtype-shim(free)", 0.0, fb)
                    elif self._is_inplace_update_fusion(fname):
                        # predicated in-place write: update-sized traffic
                        fb = 2.0 * res_eff
                        total.bump("inplace-update", 0.0, fb)
                    else:
                        fb = res_eff + self._fusion_operand_bytes(
                            name, op, fname)
                        total.bump("fusion-io", 0.0, fb)
                    total.bytes += fb
                continue
            if oc == "call":
                m = _TO_APPLY_RE.search(op.line)
                if m:
                    total.add(self.computation_cost(m.group(1), in_fusion))
                continue
            if oc == "while":
                m = _WHILE_RE.search(op.line)
                if m:
                    trip = self._trip_count(m.group(1))
                    total.add(self.computation_cost(m.group(2), in_fusion),
                              trip)
                    total.add(self.computation_cost(m.group(1), True), trip)
                continue
            if oc == "conditional":
                m = _BRANCHES_RE.search(op.line)
                if m:
                    for n in m.group(1).split(","):
                        n = n.strip().lstrip("%")
                        if n:
                            total.add(self.computation_cost(n, in_fusion))
                continue

            base = oc[:-6] if oc.endswith("-start") else oc
            if base in COLLECTIVES:
                if base == "all-reduce":
                    byt = 2.0 * opd_b
                elif base == "all-gather":
                    byt = res_b
                else:
                    byt = opd_b
                total.collective_bytes[base] = \
                    total.collective_bytes.get(base, 0.0) + byt
                if not in_fusion:
                    total.bytes += res_b + opd_b
                    total.bump(base, 0.0, res_b + opd_b)
                continue
            if oc.endswith("-done"):
                continue

            # Sliced/indexed accesses touch only the moved elements (XLA
            # aliases dynamic-update-slice in place on TPU; gathers read the
            # gathered rows, not the whole table):
            if oc in ("dynamic-slice", "gather"):
                if not in_fusion:
                    total.bytes += 2.0 * res_b
                    total.bump(oc, 0.0, 2.0 * res_b)
                continue
            if oc == "dynamic-update-slice":
                upd = _shapes_bytes(self.symtab[name].get(
                    op.operand_names[1], ())) if len(op.operand_names) > 1 \
                    else res_b
                if not in_fusion:
                    total.bytes += 2.0 * upd
                    total.bump(oc, 0.0, 2.0 * upd)
                continue
            if oc in ("scatter", "select-and-scatter"):
                upd = _shapes_bytes(self.symtab[name].get(
                    op.operand_names[-1], ())) if op.operand_names else res_b
                m = _TO_APPLY_RE.search(op.line)
                if m:
                    total.add(self.computation_cost(m.group(1), True))
                total.flops += _elems_of(self.symtab[name].get(
                    op.operand_names[-1], ())) if op.operand_names else 0.0
                if not in_fusion:
                    total.bytes += 3.0 * upd
                continue

            flops = 0.0
            if oc == "dot":
                cd = _LHS_CDIMS_RE.search(op.line)
                contr = 1
                if opd_shapes and cd:
                    lhs_dims = [int(d) for d in opd_shapes[0][1].split(",") if d]
                    for ci in cd.group(1).split(","):
                        if ci:
                            contr *= lhs_dims[int(ci)]
                flops = 2.0 * res_e * contr
                if self.kernel_dequant:
                    # operands produced by dequant fusions are read packed
                    # inside the fused kernel
                    ops_in_comp = {o.name: o for o in self.comps.get(name, ())}
                    for on in op.operand_names:
                        src = ops_in_comp.get(on)
                        if src is not None and src.opcode == "fusion":
                            dq = self._dequant_bytes(name, src)
                            if dq is not None:
                                opd_b -= _shapes_bytes(src.result_shapes)
            elif oc == "convolution":
                flops = 2.0 * res_e
            elif oc in ("reduce", "reduce-window", "scatter",
                        "select-and-scatter", "sort", "map"):
                m = _TO_APPLY_RE.search(op.line)
                if m:
                    total.add(self.computation_cost(m.group(1), True))
                flops = _shapes_elems(opd_shapes)
            elif oc in ELEMENTWISE_1FLOP:
                flops = res_e
            elif oc in ELEMENTWISE_XFLOP:
                flops = res_e * ELEMENTWISE_XFLOP[oc]
            total.flops += flops
            if not in_fusion:
                total.bytes += res_b + opd_b
                total.bump(oc, flops, res_b + opd_b)
            else:
                total.bump(oc, flops, 0.0)
        self._memo[key] = total
        return total

    def entry_cost(self) -> Costs:
        return self.computation_cost(self._entry)


def analyze(hlo_text: str, kernel_dequant: bool = False) -> Costs:
    return HloCostWalker(hlo_text, kernel_dequant=kernel_dequant).entry_cost()
