"""repro — sparse ternary GEMM for quantized ML, grown into a JAX/Pallas
serving system.

Top-level surface (locked by ``tests/test_api_surface.py``): the typed
weight containers (``repro.core.weights``), the registry-dispatched GEMM
(``repro.kernels``), and the subsystem namespaces. Subpackages are imported
lazily so lightweight consumers (configs, scripts) don't pay the jax import.
"""
import importlib

__all__ = [
    # subsystem namespaces
    "configs", "core", "checkpoint", "data", "distributed", "kernels",
    "launch", "models", "obs", "optim", "paging", "serving", "spec",
    # the paper-technique surface
    "TernaryWeight", "Dense2Bit", "Tiled", "Bitplane", "Base3", "pack",
    "ternary_gemm", "ternary_gemm_plan",
]

_LAZY = {
    "TernaryWeight": ("repro.core.weights", "TernaryWeight"),
    "Dense2Bit": ("repro.core.weights", "Dense2Bit"),
    "Tiled": ("repro.core.weights", "Tiled"),
    "Bitplane": ("repro.core.weights", "Bitplane"),
    "Base3": ("repro.core.weights", "Base3"),
    "pack": ("repro.core.weights", "pack"),
    "ternary_gemm": ("repro.kernels.ops", "ternary_gemm"),
    "ternary_gemm_plan": ("repro.kernels.ops", "ternary_gemm_plan"),
}


def __getattr__(name):
    if name in _LAZY:
        module, attr = _LAZY[name]
        return getattr(importlib.import_module(module), attr)
    if name in __all__:
        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")
