"""Pallas TPU kernel: packed 2-bit sparse-ternary GEMM with fused epilogue.

TPU adaptation of the paper's kernel (see DESIGN.md §2). The mapping:

* paper's BlockedTCSC B-window  -> BlockSpec K-tiling: each grid step loads a
  (block_k/16, block_n) packed-word tile + a (block_m, block_k) X tile into
  VMEM, so every access the kernel makes is VMEM-resident (the paper's
  "confine irregular accesses to a cache window", except on TPU we remove the
  irregularity altogether and the window is the VMEM tile).
* paper's structural sign encoding -> 2-bit codes (0,+1,-1) decoded with pure
  VPU bit ops: v = (c & 1) - ((c >> 1) & 1). One pass, no ± branches -- the
  interleaving insight expressed as data-parallel arithmetic.
* paper's multi-accumulator unrolling -> f32 VMEM scratch accumulator carried
  across the K grid dimension, MXU `jnp.dot(..., preferred_element_type=f32)`.
* paper's symmetric SIMD padding -> zero-padding K/N to tile multiples
  (code 0 decodes to 0.0 and contributes exactly nothing).
* paper's fused PReLU (vectorized kernels) -> fused scale+bias+PReLU epilogue
  on the last K step.

Weight bandwidth is 2 bits/element = 16x less than f32 (8x less than bf16):
on a memory-bound GEMM (the paper's own diagnosis of this workload) that is
the roofline lever on TPU.

Mosaic note: the decode uses a (bk/16, 16, bn) -> (bk, bn) sublane reshape;
on real hardware a relayout may be inserted. Validated in interpret mode
(this container is CPU-only); `ops.ternary_gemm` picks interpret
automatically off the backend.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WORD_BITS = 32
K_PER_WORD = WORD_BITS // 2  # 16 ternary weights per uint32 word

# jax renamed TPUCompilerParams -> CompilerParams across versions; if a jax
# exposes neither, fail at import (AttributeError naming pltpu), not at the
# first kernel launch.
CompilerParams = (getattr(pltpu, "CompilerParams", None)
                  or pltpu.TPUCompilerParams)

__all__ = ["ternary_gemm_pallas", "ternary_gemm_skip_pallas", "K_PER_WORD"]


def _decode_tile(words: jnp.ndarray, out_dtype) -> jnp.ndarray:
    """(bk/16, bn) uint32 -> (bk, bn) ±1/0 tile, pure VPU ops."""
    q, bn = words.shape
    shifts = 2 * jax.lax.broadcasted_iota(jnp.uint32, (1, K_PER_WORD, 1), 1)
    c = (words[:, None, :] >> shifts) & 3
    vals = (c & 1).astype(jnp.int8) - ((c >> 1) & 1).astype(jnp.int8)
    return vals.reshape(q * K_PER_WORD, bn).astype(out_dtype)


def _kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
            nk: int, fuse_prelu: bool, prelu_alpha: float):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    t = _decode_tile(w_ref[...], x_ref.dtype)
    acc_ref[...] += jnp.dot(x_ref[...], t,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...]
        if scale_ref is not None:
            y = y * scale_ref[...].astype(jnp.float32)
        if bias_ref is not None:
            y = y + bias_ref[...].astype(jnp.float32)
        if fuse_prelu:
            y = jnp.where(y >= 0, y, prelu_alpha * y)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "fuse_prelu",
                     "prelu_alpha", "interpret"),
)
def ternary_gemm_pallas(
    x: jnp.ndarray,                    # (M, K)  f32/bf16, K % block_k == 0
    w_packed: jnp.ndarray,             # (K / 16, N) uint32 2-bit codes
    scale: Optional[jnp.ndarray] = None,   # (N,) per-channel alpha
    bias: Optional[jnp.ndarray] = None,    # (N,)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    fuse_prelu: bool = False,
    prelu_alpha: float = 0.25,
    interpret: bool = False,
) -> jnp.ndarray:
    """Y = X @ decode(w_packed) * scale + bias (+ PReLU). Shapes must be
    pre-padded to block multiples -- `ops.ternary_gemm` handles padding."""
    m, k = x.shape
    kw, n = w_packed.shape
    assert kw * K_PER_WORD == k, (kw, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        (m, n, k, block_m, block_n, block_k)
    nk = k // block_k
    bkw = block_k // K_PER_WORD

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bkw, block_n), lambda i, j, kk: (kk, j)),
    ]
    operands = [x, w_packed]
    if scale is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        operands.append(scale.reshape(1, n))
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        operands.append(bias.reshape(1, n))

    def kernel(*refs):
        x_ref, w_ref = refs[0], refs[1]
        idx = 2
        s_ref = b_ref = None
        if scale is not None:
            s_ref = refs[idx]; idx += 1
        if bias is not None:
            b_ref = refs[idx]; idx += 1
        o_ref, acc_ref = refs[idx], refs[idx + 1]
        _kernel(x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref,
                nk=nk, fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha)

    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# Sparsity-adaptive path: skip structurally-empty (block_k x block_n) tiles
# ---------------------------------------------------------------------------

def _skip_kernel(idx_ref, cnt_ref, x_ref, w_ref, scale_ref, bias_ref, o_ref,
                 acc_ref, *, max_occ: int, fuse_prelu: bool,
                 prelu_alpha: float):
    j = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Padded steps (s >= kt_counts[j]) re-point the DMA at a known tile and
    # contribute nothing; the guard keeps the accumulation exactly the sum
    # over occupied tiles in ascending K order.
    @pl.when(s < cnt_ref[j])
    def _body():
        t = _decode_tile(w_ref[...], x_ref.dtype)
        acc_ref[...] += jnp.dot(x_ref[...], t,
                                preferred_element_type=jnp.float32)

    @pl.when(s == max_occ - 1)
    def _epilogue():
        y = acc_ref[...]
        if scale_ref is not None:
            y = y * scale_ref[...].astype(jnp.float32)
        if bias_ref is not None:
            y = y + bias_ref[...].astype(jnp.float32)
        if fuse_prelu:
            y = jnp.where(y >= 0, y, prelu_alpha * y)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "fuse_prelu",
                     "prelu_alpha", "interpret"),
)
def ternary_gemm_skip_pallas(
    x: jnp.ndarray,                    # (M, K) f32/bf16, pre-padded
    w_packed: jnp.ndarray,             # (K / 16, N) uint32 2-bit codes
    kt_indices: jnp.ndarray,           # (N/block_n, max_occ) int32
    kt_counts: jnp.ndarray,            # (N/block_n,) int32
    scale: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    fuse_prelu: bool = False,
    prelu_alpha: float = 0.25,
    interpret: bool = False,
) -> jnp.ndarray:
    """Tile-skipping ternary GEMM (DESIGN.md §3).

    ``kt_indices``/``kt_counts`` are the ``TiledTernary`` occupancy metadata
    (pack-time tile shapes must equal ``block_k``/``block_n``). They ride in
    as scalar-prefetch operands, so the BlockSpec index maps can steer the
    K grid dimension through *occupied* K-tiles only: the grid is
    (M/bm, N/bn, max_occ) instead of (M/bm, N/bn, K/bk) — empty tiles are
    never DMA'd, decoded, or matmul'd. Semantics are exactly the dense
    kernel's (zero tiles contribute exact f32 zeros there).
    """
    m, k = x.shape
    kw, n = w_packed.shape
    assert kw * K_PER_WORD == k, (kw, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        (m, n, k, block_m, block_n, block_k)
    nn = n // block_n
    assert kt_indices.shape[0] == nn and kt_counts.shape == (nn,), \
        (kt_indices.shape, kt_counts.shape, nn)
    max_occ = kt_indices.shape[1]
    bkw = block_k // K_PER_WORD

    in_specs = [
        pl.BlockSpec((block_m, block_k),
                     lambda i, j, s, idx, cnt: (i, idx[j, s])),
        pl.BlockSpec((bkw, block_n),
                     lambda i, j, s, idx, cnt: (idx[j, s], j)),
    ]
    operands = [x, w_packed]
    if scale is not None:
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda i, j, s, idx, cnt: (0, j)))
        operands.append(scale.reshape(1, n))
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda i, j, s, idx, cnt: (0, j)))
        operands.append(bias.reshape(1, n))

    def kernel(idx_ref, cnt_ref, *refs):
        x_ref, w_ref = refs[0], refs[1]
        pos = 2
        s_ref = b_ref = None
        if scale is not None:
            s_ref = refs[pos]; pos += 1
        if bias is not None:
            b_ref = refs[pos]; pos += 1
        o_ref, acc_ref = refs[pos], refs[pos + 1]
        _skip_kernel(idx_ref, cnt_ref, x_ref, w_ref, s_ref, b_ref, o_ref,
                     acc_ref, max_occ=max_occ, fuse_prelu=fuse_prelu,
                     prelu_alpha=prelu_alpha)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m // block_m, nn, max_occ),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda i, j, s, idx, cnt: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(kt_indices, kt_counts, *operands)
