"""Pallas TPU kernel: packed 2-bit sparse-ternary GEMM with fused epilogue.

TPU adaptation of the paper's kernel (see DESIGN.md §2). The mapping:

* paper's BlockedTCSC B-window  -> BlockSpec K-tiling: each grid step loads a
  (block_k/16, block_n) packed-word tile + a (block_m, block_k) X tile into
  VMEM, so every access the kernel makes is VMEM-resident (the paper's
  "confine irregular accesses to a cache window", except on TPU we remove the
  irregularity altogether and the window is the VMEM tile).
* paper's structural sign encoding -> 2-bit codes (0,+1,-1) decoded with pure
  VPU bit ops: v = (c & 1) - ((c >> 1) & 1). One pass, no ± branches -- the
  interleaving insight expressed as data-parallel arithmetic.
* paper's multi-accumulator unrolling -> f32 VMEM scratch accumulator carried
  across the K grid dimension, MXU `jnp.dot(..., preferred_element_type=f32)`.
* paper's symmetric SIMD padding -> zero-padding K/N to tile multiples
  (code 0 decodes to 0.0 and contributes exactly nothing).
* paper's fused PReLU (vectorized kernels) -> fused scale+bias+PReLU epilogue
  on the last K step.

Weight bandwidth is 2 bits/element = 16x less than f32 (8x less than bf16):
on a memory-bound GEMM (the paper's own diagnosis of this workload) that is
the roofline lever on TPU.

Mosaic note: the decode uses a (bk/16, 16, bn) -> (bk, bn) sublane reshape;
on real hardware a relayout may be inserted. Validated in interpret mode
(this container is CPU-only); `ops.ternary_gemm` picks interpret
automatically off the backend.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WORD_BITS = 32
K_PER_WORD = WORD_BITS // 2  # 16 ternary weights per uint32 word
NIBBLES_PER_WORD = WORD_BITS // 4   # 8 nibbles = 8 codeword *pairs* / word

# jax renamed TPUCompilerParams -> CompilerParams across versions; if a jax
# exposes neither, fail at import (AttributeError naming pltpu), not at the
# first kernel launch.
CompilerParams = (getattr(pltpu, "CompilerParams", None)
                  or pltpu.TPUCompilerParams)

__all__ = ["ternary_gemm_pallas", "ternary_gemm_skip_pallas",
           "ternary_gemm_skip_db_pallas", "K_PER_WORD", "DECODE_MODES"]

# Decode strategies for the 2-bit code words (DESIGN.md §12):
#   "lut"   -- 16-entry lookup tables indexed by 4-bit nibble: one shift +
#              two table reads decode a *pair* of codewords (8 shifts/word
#              instead of 16 — the Litespark ternary-LUT trick).
#   "shift" -- per-codeword shift/mask arithmetic (the original path, kept
#              as the LUT oracle and the fallback for backends where a
#              small-table gather lowers poorly).
# Both produce identical int8 values, so kernel outputs are bitwise equal.
DECODE_MODES = ("lut", "shift")

# nibble -> decoded value of its low / high 2-bit codeword.
# code c: 0 -> 0, 1 -> +1, 2 -> -1, 3 -> 0 (same map as (c&1) - ((c>>1)&1)).
_CODE_VAL = np.array([0, 1, -1, 0], np.int8)
NIBBLE_LUT_LO = np.asarray(_CODE_VAL[np.arange(16) & 3])      # (16,) int8
NIBBLE_LUT_HI = np.asarray(_CODE_VAL[np.arange(16) >> 2])     # (16,) int8


def _nibble_luts():
    """The two 16-entry nibble tables, built *inside* the kernel trace.

    Pallas rejects kernels that capture array constants, so the tables are
    materialised from an iota each call — the compiler folds the 16-lane
    arithmetic to the same constant vectors as ``NIBBLE_LUT_LO/HI``."""
    idx = jax.lax.iota(jnp.int32, 16)
    lut_lo = ((idx & 1) - ((idx >> 1) & 1)).astype(jnp.int8)
    lut_hi = (((idx >> 2) & 1) - ((idx >> 3) & 1)).astype(jnp.int8)
    return lut_lo, lut_hi


def _decode_tile_shift(words: jnp.ndarray, out_dtype) -> jnp.ndarray:
    """(bk/16, bn) uint32 -> (bk, bn) ±1/0 tile, pure VPU shift/mask ops."""
    q, bn = words.shape
    shifts = 2 * jax.lax.broadcasted_iota(jnp.uint32, (1, K_PER_WORD, 1), 1)
    c = (words[:, None, :] >> shifts) & 3
    vals = (c & 1).astype(jnp.int8) - ((c >> 1) & 1).astype(jnp.int8)
    return vals.reshape(q * K_PER_WORD, bn).astype(out_dtype)


def _decode_tile_lut(words: jnp.ndarray, out_dtype) -> jnp.ndarray:
    """(bk/16, bn) uint32 -> (bk, bn) ±1/0 tile via 16-entry nibble LUTs.

    Each 4-bit nibble holds two adjacent 2-bit codewords; two table reads
    decode both at once. Value-identical to ``_decode_tile_shift`` (same
    int8 outputs), so downstream matmuls are bitwise equal."""
    q, bn = words.shape
    shifts = 4 * jax.lax.broadcasted_iota(jnp.uint32, (1, NIBBLES_PER_WORD, 1),
                                          1)
    nib = ((words[:, None, :] >> shifts) & 0xF).astype(jnp.int32)
    lut_lo, lut_hi = _nibble_luts()
    lo = jnp.take(lut_lo, nib)            # codeword 2i   (q, 8, bn)
    hi = jnp.take(lut_hi, nib)            # codeword 2i+1 (q, 8, bn)
    pair = jnp.stack([lo, hi], axis=2)    # (q, 8, 2, bn): K-order restored
    return pair.reshape(q * K_PER_WORD, bn).astype(out_dtype)


def _decode_tile(words: jnp.ndarray, out_dtype,
                 mode: str = "lut") -> jnp.ndarray:
    """(bk/16, bn) uint32 -> (bk, bn) ±1/0 tile. ``mode`` in DECODE_MODES;
    both modes are value-identical (pinned in tests/test_fused_mlp.py)."""
    if mode == "lut":
        return _decode_tile_lut(words, out_dtype)
    assert mode == "shift", mode
    return _decode_tile_shift(words, out_dtype)


def _kernel(x_ref, w_ref, scale_ref, bias_ref, o_ref, acc_ref, *,
            nk: int, fuse_prelu: bool, prelu_alpha: float,
            decode: str = "lut"):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    t = _decode_tile(w_ref[...], x_ref.dtype, decode)
    acc_ref[...] += jnp.dot(x_ref[...], t,
                            preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...]
        if scale_ref is not None:
            y = y * scale_ref[...].astype(jnp.float32)
        if bias_ref is not None:
            y = y + bias_ref[...].astype(jnp.float32)
        if fuse_prelu:
            y = jnp.where(y >= 0, y, prelu_alpha * y)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "fuse_prelu",
                     "prelu_alpha", "interpret", "decode"),
)
def ternary_gemm_pallas(
    x: jnp.ndarray,                    # (M, K)  f32/bf16, K % block_k == 0
    w_packed: jnp.ndarray,             # (K / 16, N) uint32 2-bit codes
    scale: Optional[jnp.ndarray] = None,   # (N,) per-channel alpha
    bias: Optional[jnp.ndarray] = None,    # (N,)
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    fuse_prelu: bool = False,
    prelu_alpha: float = 0.25,
    interpret: bool = False,
    decode: str = "lut",
) -> jnp.ndarray:
    """Y = X @ decode(w_packed) * scale + bias (+ PReLU). Shapes must be
    pre-padded to block multiples -- `ops.ternary_gemm` handles padding."""
    m, k = x.shape
    kw, n = w_packed.shape
    assert kw * K_PER_WORD == k, (kw, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        (m, n, k, block_m, block_n, block_k)
    nk = k // block_k
    bkw = block_k // K_PER_WORD

    in_specs = [
        pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((bkw, block_n), lambda i, j, kk: (kk, j)),
    ]
    operands = [x, w_packed]
    if scale is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        operands.append(scale.reshape(1, n))
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, block_n), lambda i, j, kk: (0, j)))
        operands.append(bias.reshape(1, n))

    def kernel(*refs):
        x_ref, w_ref = refs[0], refs[1]
        idx = 2
        s_ref = b_ref = None
        if scale is not None:
            s_ref = refs[idx]; idx += 1
        if bias is not None:
            b_ref = refs[idx]; idx += 1
        o_ref, acc_ref = refs[idx], refs[idx + 1]
        _kernel(x_ref, w_ref, s_ref, b_ref, o_ref, acc_ref,
                nk=nk, fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha,
                decode=decode)

    return pl.pallas_call(
        kernel,
        grid=(m // block_m, n // block_n, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)


# ---------------------------------------------------------------------------
# Sparsity-adaptive path: skip structurally-empty (block_k x block_n) tiles
# ---------------------------------------------------------------------------

def _skip_kernel(idx_ref, cnt_ref, x_ref, w_ref, scale_ref, bias_ref, o_ref,
                 acc_ref, *, max_occ: int, fuse_prelu: bool,
                 prelu_alpha: float, decode: str = "lut"):
    j = pl.program_id(1)
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Padded steps (s >= kt_counts[j]) re-point the DMA at a known tile and
    # contribute nothing; the guard keeps the accumulation exactly the sum
    # over occupied tiles in ascending K order.
    @pl.when(s < cnt_ref[j])
    def _body():
        t = _decode_tile(w_ref[...], x_ref.dtype, decode)
        acc_ref[...] += jnp.dot(x_ref[...], t,
                                preferred_element_type=jnp.float32)

    @pl.when(s == max_occ - 1)
    def _epilogue():
        y = acc_ref[...]
        if scale_ref is not None:
            y = y * scale_ref[...].astype(jnp.float32)
        if bias_ref is not None:
            y = y + bias_ref[...].astype(jnp.float32)
        if fuse_prelu:
            y = jnp.where(y >= 0, y, prelu_alpha * y)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "fuse_prelu",
                     "prelu_alpha", "interpret", "decode"),
)
def ternary_gemm_skip_pallas(
    x: jnp.ndarray,                    # (M, K) f32/bf16, pre-padded
    w_packed: jnp.ndarray,             # (K / 16, N) uint32 2-bit codes
    kt_indices: jnp.ndarray,           # (N/block_n, max_occ) int32
    kt_counts: jnp.ndarray,            # (N/block_n,) int32
    scale: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    fuse_prelu: bool = False,
    prelu_alpha: float = 0.25,
    interpret: bool = False,
    decode: str = "lut",
) -> jnp.ndarray:
    """Tile-skipping ternary GEMM (DESIGN.md §3).

    ``kt_indices``/``kt_counts`` are the ``TiledTernary`` occupancy metadata
    (pack-time tile shapes must equal ``block_k``/``block_n``). They ride in
    as scalar-prefetch operands, so the BlockSpec index maps can steer the
    K grid dimension through *occupied* K-tiles only: the grid is
    (M/bm, N/bn, max_occ) instead of (M/bm, N/bn, K/bk) — empty tiles are
    never DMA'd, decoded, or matmul'd. Semantics are exactly the dense
    kernel's (zero tiles contribute exact f32 zeros there).
    """
    m, k = x.shape
    kw, n = w_packed.shape
    assert kw * K_PER_WORD == k, (kw, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        (m, n, k, block_m, block_n, block_k)
    nn = n // block_n
    assert kt_indices.shape[0] == nn and kt_counts.shape == (nn,), \
        (kt_indices.shape, kt_counts.shape, nn)
    max_occ = kt_indices.shape[1]
    bkw = block_k // K_PER_WORD

    in_specs = [
        pl.BlockSpec((block_m, block_k),
                     lambda i, j, s, idx, cnt: (i, idx[j, s])),
        pl.BlockSpec((bkw, block_n),
                     lambda i, j, s, idx, cnt: (idx[j, s], j)),
    ]
    operands = [x, w_packed]
    if scale is not None:
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda i, j, s, idx, cnt: (0, j)))
        operands.append(scale.reshape(1, n))
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda i, j, s, idx, cnt: (0, j)))
        operands.append(bias.reshape(1, n))

    def kernel(idx_ref, cnt_ref, *refs):
        x_ref, w_ref = refs[0], refs[1]
        pos = 2
        s_ref = b_ref = None
        if scale is not None:
            s_ref = refs[pos]; pos += 1
        if bias is not None:
            b_ref = refs[pos]; pos += 1
        o_ref, acc_ref = refs[pos], refs[pos + 1]
        _skip_kernel(idx_ref, cnt_ref, x_ref, w_ref, s_ref, b_ref, o_ref,
                     acc_ref, max_occ=max_occ, fuse_prelu=fuse_prelu,
                     prelu_alpha=prelu_alpha, decode=decode)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m // block_m, nn, max_occ),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda i, j, s, idx, cnt: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.float32)],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(kt_indices, kt_counts, *operands)


# ---------------------------------------------------------------------------
# Double-buffered DMA variant: overlap the next occupied tile's HBM->VMEM
# copy with the current tile's MXU work
# ---------------------------------------------------------------------------

def _skip_db_kernel(idx_ref, cnt_ref, x_hbm, w_hbm, scale_ref, bias_ref,
                    o_ref, xs, ws, sem, acc_ref, *, block_m: int,
                    block_n: int, block_k: int, fuse_prelu: bool,
                    prelu_alpha: float, decode: str):
    """Grid is (M-tiles, N-tiles); the occupied-K-tile walk happens *inside*
    the kernel as an explicit two-slot ``make_async_copy`` pipeline: while
    tile ``s`` is decoded and matmul'd out of slot ``s % 2``, tile ``s + 1``
    is already in flight into the other slot. x and the packed words stay in
    HBM (``memory_space=ANY``); the kernel only ever touches the VMEM
    staging slots."""
    i = pl.program_id(0)
    j = pl.program_id(1)
    bkw = block_k // K_PER_WORD
    cnt = cnt_ref[j]

    acc_ref[...] = jnp.zeros_like(acc_ref)

    def tile_dma(slot, s):
        """Async copies for occupied tile ``s`` into staging ``slot``:
        the (bm, bk) X window and the (bk/16, bn) packed-word tile."""
        kt = idx_ref[j, s]
        x_dma = pltpu.make_async_copy(
            x_hbm.at[pl.ds(i * block_m, block_m),
                     pl.ds(kt * block_k, block_k)],
            xs.at[slot], sem.at[slot, 0])
        w_dma = pltpu.make_async_copy(
            w_hbm.at[pl.ds(kt * bkw, bkw), pl.ds(j * block_n, block_n)],
            ws.at[slot], sem.at[slot, 1])
        return x_dma, w_dma

    def start(slot, s):
        for dma in tile_dma(slot, s):
            dma.start()

    def wait(slot, s):
        for dma in tile_dma(slot, s):
            dma.wait()

    @pl.when(cnt > 0)
    def _pipeline():
        start(0, 0)                              # warm-up: first tile

        def body(s, _):
            cur = jax.lax.rem(s, 2)

            @pl.when(s + 1 < cnt)
            def _prefetch():                     # overlap: next tile's DMA
                start(jax.lax.rem(s + 1, 2), s + 1)

            wait(cur, s)
            t = _decode_tile(ws[cur], xs.dtype, decode)
            acc_ref[...] += jnp.dot(xs[cur], t,
                                    preferred_element_type=jnp.float32)
            return 0

        jax.lax.fori_loop(0, cnt, body, 0)

    y = acc_ref[...]
    if scale_ref is not None:
        y = y * scale_ref[...].astype(jnp.float32)
    if bias_ref is not None:
        y = y + bias_ref[...].astype(jnp.float32)
    if fuse_prelu:
        y = jnp.where(y >= 0, y, prelu_alpha * y)
    o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "fuse_prelu",
                     "prelu_alpha", "interpret", "decode"),
)
def ternary_gemm_skip_db_pallas(
    x: jnp.ndarray,                    # (M, K) f32/bf16, pre-padded
    w_packed: jnp.ndarray,             # (K / 16, N) uint32 2-bit codes
    kt_indices: jnp.ndarray,           # (N/block_n, max_occ) int32
    kt_counts: jnp.ndarray,            # (N/block_n,) int32
    scale: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 256,
    fuse_prelu: bool = False,
    prelu_alpha: float = 0.25,
    interpret: bool = False,
    decode: str = "lut",
) -> jnp.ndarray:
    """Tile-skipping ternary GEMM with an explicit double-buffered DMA
    pipeline (DESIGN.md §12).

    Same operands and semantics as ``ternary_gemm_skip_pallas`` — the
    occupied-tile metadata rides in as scalar prefetch — but instead of the
    implicit per-grid-step BlockSpec pipeline, the grid is only
    (M/bm, N/bn) and each kernel invocation walks its occupied K-tiles with
    two VMEM staging slots: tile ``s+1``'s HBM->VMEM ``make_async_copy``
    issues *before* tile ``s``'s decode + matmul, so DMA overlaps MXU work
    within a single output tile. Accumulation visits occupied tiles in the
    same ascending-K order as the skip kernel, so results are bitwise
    identical to both the skip and dense kernels.
    """
    m, k = x.shape
    kw, n = w_packed.shape
    assert kw * K_PER_WORD == k, (kw, k)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, \
        (m, n, k, block_m, block_n, block_k)
    nn = n // block_n
    assert kt_indices.shape[0] == nn and kt_counts.shape == (nn,), \
        (kt_indices.shape, kt_counts.shape, nn)
    bkw = block_k // K_PER_WORD

    # x / packed words stay in HBM; only scale/bias (tiny) are block-fed.
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),
        pl.BlockSpec(memory_space=pltpu.ANY),
    ]
    operands = [x, w_packed]
    if scale is not None:
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda i, j, idx, cnt: (0, j)))
        operands.append(scale.reshape(1, n))
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, block_n),
                                     lambda i, j, idx, cnt: (0, j)))
        operands.append(bias.reshape(1, n))

    def kernel(idx_ref, cnt_ref, *refs):
        x_hbm, w_hbm = refs[0], refs[1]
        pos = 2
        s_ref = b_ref = None
        if scale is not None:
            s_ref = refs[pos]; pos += 1
        if bias is not None:
            b_ref = refs[pos]; pos += 1
        o_ref = refs[pos]
        xs, ws, sem, acc_ref = refs[pos + 1:pos + 5]
        _skip_db_kernel(idx_ref, cnt_ref, x_hbm, w_hbm, s_ref, b_ref, o_ref,
                        xs, ws, sem, acc_ref, block_m=block_m,
                        block_n=block_n, block_k=block_k,
                        fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha,
                        decode=decode)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(m // block_m, nn),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_m, block_n),
                               lambda i, j, idx, cnt: (i, j)),
        scratch_shapes=[
            pltpu.VMEM((2, block_m, block_k), x.dtype),    # X staging slots
            pltpu.VMEM((2, bkw, block_n), jnp.uint32),     # word staging
            pltpu.SemaphoreType.DMA((2, 2)),               # (slot, x|w)
            pltpu.VMEM((block_m, block_n), jnp.float32),   # accumulator
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel"),
        ),
        interpret=interpret,
    )(kt_indices, kt_counts, *operands)
