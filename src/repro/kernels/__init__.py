from repro.kernels.autotune import Autotuner, BlockConfig, get_tuner
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ops import (SERVING_PHASES, GemmPlan, kernel_registry,
                               paged_attention_registry,
                               paged_decode_attention, pack_weights,
                               pack_weights_tiled, register_kernel,
                               register_paged_attn, serving_phase,
                               ternary_gemm, ternary_gemm_plan)
from repro.kernels.ternary_gemm import (K_PER_WORD, ternary_gemm_pallas,
                                        ternary_gemm_skip_pallas)
from repro.kernels.ternary_gemm_bitplane import ternary_gemm_bitplane

__all__ = ["ternary_gemm", "ternary_gemm_plan", "GemmPlan",
           "register_kernel", "kernel_registry", "serving_phase",
           "SERVING_PHASES",
           "pack_weights", "pack_weights_tiled",
           "ternary_gemm_pallas", "ternary_gemm_skip_pallas",
           "ternary_gemm_bitplane", "K_PER_WORD", "flash_attention_pallas",
           "paged_decode_attention", "register_paged_attn",
           "paged_attention_registry",
           "Autotuner", "BlockConfig", "get_tuner"]
