from repro.kernels.autotune import (Autotuner, BlockConfig,
                                    FusedBlockConfig, get_tuner)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.fused_mlp import fused_mlp_pallas
from repro.kernels.ops import (SERVING_PHASES, FusedMlpPlan, GemmPlan,
                               fused_mlp, fused_mlp_plan, fused_registry,
                               kernel_probe, kernel_registry,
                               paged_attention_registry,
                               paged_decode_attention, pack_weights,
                               pack_weights_tiled, precompute_fused_plans,
                               register_fused, register_kernel,
                               register_paged_attn, serving_phase,
                               ternary_gemm, ternary_gemm_plan)
from repro.kernels.ternary_gemm import (DECODE_MODES, K_PER_WORD,
                                        ternary_gemm_pallas,
                                        ternary_gemm_skip_db_pallas,
                                        ternary_gemm_skip_pallas)
from repro.kernels.ternary_gemm_bitplane import ternary_gemm_bitplane

__all__ = ["ternary_gemm", "ternary_gemm_plan", "GemmPlan",
           "register_kernel", "kernel_registry", "serving_phase",
           "SERVING_PHASES", "kernel_probe",
           "fused_mlp", "fused_mlp_plan", "FusedMlpPlan",
           "register_fused", "fused_registry", "precompute_fused_plans",
           "fused_mlp_pallas",
           "pack_weights", "pack_weights_tiled",
           "ternary_gemm_pallas", "ternary_gemm_skip_pallas",
           "ternary_gemm_skip_db_pallas", "DECODE_MODES",
           "ternary_gemm_bitplane", "K_PER_WORD", "flash_attention_pallas",
           "paged_decode_attention", "register_paged_attn",
           "paged_attention_registry",
           "Autotuner", "BlockConfig", "FusedBlockConfig", "get_tuner"]
