from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.ops import pack_weights, ternary_gemm
from repro.kernels.ternary_gemm import K_PER_WORD, ternary_gemm_pallas

__all__ = ["ternary_gemm", "pack_weights", "ternary_gemm_pallas",
           "K_PER_WORD", "flash_attention_pallas"]
