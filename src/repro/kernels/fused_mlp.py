"""Fused ternary MLP block: GEMM -> bias -> activation -> GEMM, one kernel.

The unfused chain (``models.layers.mlp_apply``) round-trips the hidden
activation ``h`` through HBM between the up- and down-projection — at 2-bit
weight density that (m, ff) tensor is the *dominant* memory traffic of the
block ("Above the Inner Loop", PAPERS.md: on bandwidth-bound hardware the
win above the inner loop is keeping operands resident across chained
GEMMs). This kernel keeps ``h`` in a VMEM scratch buffer for the lifetime
of one M-tile:

    grid = (M / block_m,)                       # one program per row tile
    x tile     : (block_m, K)   VMEM block      # reused by gate AND up proj
    weights    : HBM (memory_space=ANY), streamed per N-strip with
                 double-buffered ``make_async_copy`` (next strip's DMA
                 overlaps the current strip's decode + MXU work)
    h scratch  : (block_m, FF)  VMEM, never leaves the chip
    output     : (block_m, N)   written strip by strip

Bitwise equality with the unfused chain is a hard invariant (pinned in
tests/test_fused_mlp.py). It holds because every float op matches the
chain exactly: the same (block_k x block_n) decode tiles in the same
ascending-K order feed the same f32-accumulating ``jnp.dot``s, the
epilogue (scale -> bias, f32) and the cast to x.dtype happen per strip
exactly as the dense kernel's epilogue does, and the activation is the
same ``jax.nn.silu`` applied to the same x.dtype value. M-tiling is free:
XLA's dot is row-stable bitwise, so the fused block_m need not match the
chain's (K-tiling is NOT free, hence the matched block_k).

Gated (SwiGLU, ``h = silu(x@Wg) * (x@Wi)``) and ungated
(``h = act(x@Wi)``) variants share the kernel; the gate weight is simply
a second streamed operand.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ternary_gemm import (K_PER_WORD, CompilerParams,
                                        _decode_tile)

__all__ = ["fused_mlp_pallas", "ACTIVATIONS"]

ACTIVATIONS = ("silu", "relu", "none")


def _act(name: str, y: jnp.ndarray) -> jnp.ndarray:
    if name == "silu":
        return jax.nn.silu(y)
    if name == "relu":
        return jax.nn.relu(y)
    assert name == "none", name
    return y


def _round_up(v: int, mult: int) -> int:
    return -(-v // mult) * mult


def _pad2(a: jnp.ndarray, rows: int, cols: int) -> jnp.ndarray:
    return jnp.pad(a, ((0, rows - a.shape[0]), (0, cols - a.shape[1])))


def _fused_body(x_ref, wg_hbm, wi_hbm, wo_hbm, sg_ref, bg_ref, si_ref,
                bi_ref, so_ref, bo_ref, o_ref, wg_s, wi_s, wo_s, sem1,
                sem2, h_ref, *, bm, bn1, bk1, bn2, bk2, nf1, nk1, nf2, nk2,
                activation, decode):
    """One M-tile: up (+gate) projection strip pipeline into ``h_ref``,
    activation, then down projection strip pipeline into ``o_ref``."""
    bkw1 = bk1 // K_PER_WORD
    bkw2 = bk2 // K_PER_WORD
    gated = wg_hbm is not None
    dt = x_ref.dtype

    # Columns the up-projection strips never touch (bn1/bk2 misalignment
    # padding) must read as the chain's zero padding in the down proj.
    h_ref[...] = jnp.zeros_like(h_ref)

    # --- stage 1: h[:, j*bn1:(j+1)*bn1] strips, double-buffered weights ---

    def up_dmas(slot, j):
        dmas = [pltpu.make_async_copy(
            wi_hbm.at[:, pl.ds(j * bn1, bn1)], wi_s.at[slot],
            sem1.at[slot, 0])]
        if gated:
            dmas.append(pltpu.make_async_copy(
                wg_hbm.at[:, pl.ds(j * bn1, bn1)], wg_s.at[slot],
                sem1.at[slot, 1]))
        return dmas

    for dma in up_dmas(0, 0):
        dma.start()

    def up_strip(j, _):
        cur = jax.lax.rem(j, 2)

        @pl.when(j + 1 < nf1)
        def _prefetch():
            for dma in up_dmas(jax.lax.rem(j + 1, 2), j + 1):
                dma.start()

        for dma in up_dmas(cur, j):
            dma.wait()

        def ktile(t, accs):
            xt = x_ref[:, pl.ds(t * bk1, bk1)]
            acc_i, acc_g = accs
            ti = _decode_tile(wi_s[cur, pl.ds(t * bkw1, bkw1)], dt, decode)
            acc_i = acc_i + jnp.dot(xt, ti,
                                    preferred_element_type=jnp.float32)
            if gated:
                tg = _decode_tile(wg_s[cur, pl.ds(t * bkw1, bkw1)], dt,
                                  decode)
                acc_g = acc_g + jnp.dot(xt, tg,
                                        preferred_element_type=jnp.float32)
            return acc_i, acc_g

        zero = jnp.zeros((bm, bn1), jnp.float32)
        acc_i, acc_g = jax.lax.fori_loop(0, nk1, ktile, (zero, zero))

        def epilogue(acc, s_ref, b_ref):
            y = acc
            if s_ref is not None:
                y = y * s_ref[:, pl.ds(j * bn1, bn1)].astype(jnp.float32)
            if b_ref is not None:
                y = y + b_ref[:, pl.ds(j * bn1, bn1)].astype(jnp.float32)
            return y.astype(dt)

        yi = epilogue(acc_i, si_ref, bi_ref)
        if gated:
            h = _act(activation, epilogue(acc_g, sg_ref, bg_ref)) * yi
        else:
            h = _act(activation, yi)
        h_ref[:, pl.ds(j * bn1, bn1)] = h
        return 0

    jax.lax.fori_loop(0, nf1, up_strip, 0)

    # --- stage 2: o[:, j*bn2:(j+1)*bn2] strips over the resident h ---

    def down_dma(slot, j):
        return pltpu.make_async_copy(
            wo_hbm.at[:, pl.ds(j * bn2, bn2)], wo_s.at[slot],
            sem2.at[slot])

    down_dma(0, 0).start()

    def down_strip(j, _):
        cur = jax.lax.rem(j, 2)

        @pl.when(j + 1 < nf2)
        def _prefetch():
            down_dma(jax.lax.rem(j + 1, 2), j + 1).start()

        down_dma(cur, j).wait()

        def ktile(t, acc):
            ht = h_ref[:, pl.ds(t * bk2, bk2)]
            to = _decode_tile(wo_s[cur, pl.ds(t * bkw2, bkw2)], dt, decode)
            return acc + jnp.dot(ht, to,
                                 preferred_element_type=jnp.float32)

        acc = jax.lax.fori_loop(0, nk2, ktile,
                                jnp.zeros((bm, bn2), jnp.float32))
        y = acc
        if so_ref is not None:
            y = y * so_ref[:, pl.ds(j * bn2, bn2)].astype(jnp.float32)
        if bo_ref is not None:
            y = y + bo_ref[:, pl.ds(j * bn2, bn2)].astype(jnp.float32)
        o_ref[:, pl.ds(j * bn2, bn2)] = y.astype(o_ref.dtype)
        return 0

    jax.lax.fori_loop(0, nf2, down_strip, 0)


@functools.partial(
    jax.jit,
    static_argnames=("n", "ff", "block_m", "block_n1", "block_k1",
                     "block_n2", "block_k2", "activation", "interpret",
                     "decode"),
)
def fused_mlp_pallas(
    x: jnp.ndarray,                     # (M, K) f32/bf16
    wi_packed: jnp.ndarray,             # (K/16, >=ff) uint32: up proj
    wo_packed: jnp.ndarray,             # (ff/16, >=n) uint32: down proj
    wg_packed: Optional[jnp.ndarray] = None,   # (K/16, >=ff): gate proj
    scale_i: Optional[jnp.ndarray] = None,
    bias_i: Optional[jnp.ndarray] = None,
    scale_g: Optional[jnp.ndarray] = None,
    bias_g: Optional[jnp.ndarray] = None,
    scale_o: Optional[jnp.ndarray] = None,
    bias_o: Optional[jnp.ndarray] = None,
    *,
    n: int,
    ff: int,
    block_m: int = 128,
    block_n1: int = 128,
    block_k1: int = 256,
    block_n2: int = 128,
    block_k2: int = 256,
    activation: str = "silu",
    interpret: bool = False,
    decode: str = "lut",
) -> jnp.ndarray:
    """Fused ``act(x @ Wg) * (x @ Wi) @ Wo`` (gate optional) — see module
    docstring. Returns the (M, n) logical output; ``h`` never leaves VMEM.

    ``block_n1/block_k1`` tile the up/gate projections, ``block_n2/
    block_k2`` the down projection — pass the same blocks the unfused
    chain's plans resolve to and the result is bitwise identical to the
    two/three-call chain.
    """
    assert activation in ACTIVATIONS, activation
    m, k = x.shape
    assert wi_packed.shape[0] * K_PER_WORD >= k
    if wg_packed is not None:
        assert wg_packed.shape == wi_packed.shape, \
            (wg_packed.shape, wi_packed.shape)

    bm = min(block_m, max(8, 1 << (m - 1).bit_length()))
    mp = _round_up(m, bm)

    # Stage-1 K: the packed operand's word rows, padded to the K tile.
    k1p = _round_up(wi_packed.shape[0] * K_PER_WORD, block_k1)
    ff1 = _round_up(ff, block_n1)
    # Stage-2 K: ff padded exactly as the chain pads h (words, then tile) —
    # matching tile counts keeps the accumulation order identical.
    k2p = _round_up(_round_up(ff, K_PER_WORD), block_k2)
    n2p = _round_up(n, block_n2)
    hw = max(ff1, k2p)                  # h scratch width covers both views

    xp = _pad2(x, mp, k1p)
    wi_p = _pad2(wi_packed[:, :ff], k1p // K_PER_WORD, ff1)
    wo_p = _pad2(wo_packed[:, :n], k2p // K_PER_WORD, n2p)

    operands = [wi_p, wo_p]
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY)]
    if wg_packed is not None:
        operands.append(_pad2(wg_packed[:, :ff], k1p // K_PER_WORD, ff1))
        in_specs.append(pl.BlockSpec(memory_space=pltpu.ANY))

    def vec(v, width):
        return _pad2(v.reshape(1, -1), 1, width)

    epilogues = []                      # (present, width) in kernel order
    for v, width in ((scale_g, ff1), (bias_g, ff1), (scale_i, ff1),
                     (bias_i, ff1), (scale_o, n2p), (bias_o, n2p)):
        epilogues.append(v is not None)
        if v is not None:
            operands.append(vec(v, width))
            in_specs.append(pl.BlockSpec((1, width), lambda i: (0, 0)))

    nf1, nk1 = ff1 // block_n1, k1p // block_k1
    nf2, nk2 = n2p // block_n2, k2p // block_k2
    gated = wg_packed is not None

    def kernel(*refs):
        it = iter(refs)
        x_ref = next(it)
        wi_hbm, wo_hbm = next(it), next(it)
        wg_hbm = next(it) if gated else None
        eps = [next(it) if present else None for present in epilogues]
        o_ref = next(it)
        wg_s = next(it) if gated else None
        wi_s, wo_s, sem1, sem2, h_ref = it
        _fused_body(x_ref, wg_hbm, wi_hbm, wo_hbm, eps[0], eps[1], eps[2],
                    eps[3], eps[4], eps[5], o_ref, wg_s, wi_s, wo_s, sem1,
                    sem2, h_ref, bm=bm, bn1=block_n1, bk1=block_k1,
                    bn2=block_n2, bk2=block_k2, nf1=nf1, nk1=nk1, nf2=nf2,
                    nk2=nk2, activation=activation, decode=decode)

    scratch = []
    if gated:
        scratch.append(pltpu.VMEM((2, k1p // K_PER_WORD, block_n1),
                                  jnp.uint32))
    scratch += [
        pltpu.VMEM((2, k1p // K_PER_WORD, block_n1), jnp.uint32),  # wi
        pltpu.VMEM((2, k2p // K_PER_WORD, block_n2), jnp.uint32),  # wo
        pltpu.SemaphoreType.DMA((2, 2 if gated else 1)),
        pltpu.SemaphoreType.DMA((2,)),
        pltpu.VMEM((bm, hw), x.dtype),                             # h
    ]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=0,
        grid=(mp // bm,),
        in_specs=[pl.BlockSpec((bm, k1p), lambda i: (i, 0))] + in_specs,
        out_specs=pl.BlockSpec((bm, n2p), lambda i: (i, 0)),
        scratch_shapes=scratch,
    )
    y = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((mp, n2p), x.dtype),
        compiler_params=CompilerParams(dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xp, *operands)
    return y[:m, :n]
