"""Pallas TPU flash attention kernel.

§Perf cell B showed the XLA-level blockwise attention pays
O(B·H·S²/bkv) HBM bytes in accumulator/score round-trips; this kernel keeps
the running (m, l, acc) statistics in VMEM scratch across the KV grid steps,
so HBM traffic is just Q/K/V reads + O writes — the memory-roofline floor.

Layout: q/k/v as (BH, S, hd) (batch*heads flattened; GQA callers repeat or
reshape K/V). Grid (BH, nq, nkv) with the KV dimension innermost
("arbitrary" semantics → sequential accumulation). Causal masking skips
fully-masked KV blocks via @pl.when (no dot issued for them).

Validated in interpret mode against the naive oracle
(tests/test_flash_kernel.py); `ops`-style jit wrapper below.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ternary_gemm import CompilerParams

NEG_INF = -1e30

__all__ = ["flash_attention_pallas"]


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, nkv: int, block_q: int,
            block_kv: int, seq_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # causal: skip KV blocks strictly after this Q block's last row
    run = True
    if causal:
        run = ik * block_kv <= (iq + 1) * block_q - 1

    @pl.when(run)
    def _step():
        q = q_ref[0]                                   # (bq, hd)
        k = k_ref[0]                                   # (bkv, hd)
        v = v_ref[0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale  # (bq, bkv)
        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 0)
        k_pos = ik * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_kv), 1)
        mask = k_pos < seq_kv
        if causal:
            mask &= q_pos >= k_pos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
            p.astype(v.dtype), v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(ik == nkv - 1)
    def _epilogue():
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_kv", "interpret"))
def flash_attention_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                           *, causal: bool = True, block_q: int = 512,
                           block_kv: int = 512,
                           interpret: bool = False) -> jnp.ndarray:
    """q, k, v: (BH, S, hd) -> (BH, S, hd). S padded internally."""
    bh, sq, hd = q.shape
    _, skv, _ = k.shape
    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    pq, pkv = (-sq) % bq, (-skv) % bkv
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0)))
    nq = (sq + pq) // bq
    nkv = (skv + pkv) // bkv
    scale = 1.0 / (hd ** 0.5)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal, nkv=nkv,
                          block_q=bq, block_kv=bkv, seq_kv=skv),
        grid=(bh, nq, nkv),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bkv, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
    return out[:, :sq]
