"""Block-shape autotuner for the ternary Pallas kernels.

The TPU analogue of the paper's unroll-factor grid search (Figs 2-4): for a
given (M, K, N, sparsity, impl) problem, sweep candidate
(block_m, block_n, block_k) shapes and keep the winner. Two scoring modes:

* ``measure``  -- wall-clock the compiled kernel (only meaningful on a real
                  TPU backend; interpret-mode timing is Python-bound noise);
* ``model``    -- deterministic analytic score: modeled HBM-bound time for
                  the tile traffic (X re-reads per N-tile, packed W re-reads
                  per M-tile, output write) plus grid-overhead and
                  VMEM-pressure penalties. Used automatically off-TPU so the
                  tuner is reproducible in CI.

Winners are cached twice: in-process (dict) and on disk as JSON so tuning
survives across processes. Cache file format (DESIGN.md §5)::

    {"version": 1,
     "entries": {"dense:m128:k4096:n4096:s0.25": [128, 128, 512], ...}}

Keys bucket M to the next power of two and sparsity to the paper's grid
{1, 1/2, 1/4, 1/8, 1/16, 1/32}, so serving shapes that differ only in batch
hit the same entry. Consumers: ``ops.ternary_gemm`` (block args default to
the tuned shape), the ternary linear in ``models/layers.py``,
``benchmarks/kernel_bench.py``, and ``scripts/hillclimb.py``.

**Cross-op fusion keys** (DESIGN.md §12): the fused MLP lowering plans one
shared ``block_m`` plus per-projection (block_n, block_k) pairs for both
weights of the chain. Those live under ``fused:...`` keys — five-int
entries (``FusedBlockConfig``) in the same cache file, keyed on *both*
weights' shapes under the existing phase keys::

    "fused:m128:k1024:f4096:n1024:s1.0x1.0:pprefill": [128, 128, 512,
                                                       128, 512]

A fused entry is composed from the two per-GEMM entries on miss, so the
fused kernel's K/N tiling always agrees with what the unfused chain would
have used — that agreement is what makes the fused output bitwise equal.
"""
from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Callable, Dict, List, Optional, Tuple

from repro.kernels.ternary_gemm import K_PER_WORD

__all__ = ["BlockConfig", "FusedBlockConfig", "Autotuner", "get_tuner",
           "DEFAULT_CACHE_PATH"]

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
DEFAULT_CACHE_PATH = os.path.join("experiments", "autotune_cache.json")

# Modeled v5e-class machine — the single source for these numbers
# (benchmarks/kernel_bench.py imports them from here).
HBM_BW = 819e9
PEAK_FLOPS = 197e12
VMEM_BYTES = 16 * 2**20

# Candidate grid: the shapes the paper-style search sweeps. block_k spans
# the K-reuse axis, block_m/n the MXU tile axes.
CANDIDATE_BLOCKS: Tuple[Tuple[int, int, int], ...] = (
    (128, 128, 256), (128, 128, 512), (128, 128, 1024),
    (128, 256, 512), (256, 128, 512), (256, 256, 512),
    (64, 128, 512), (8, 128, 512), (8, 256, 512),
)

# Extra candidates considered for the serving decode phase: M = slots is
# GEMV-shaped (tiny block_m), so trade the M tile for deeper K reuse. The
# speculative-decoding verify phase (M = slots·(k+1), still small-M but
# GEMM-shaped) shares the widened grid so its own cache entries can land
# on the GEMV-leaning shapes when the model scores them best.
DECODE_CANDIDATE_BLOCKS: Tuple[Tuple[int, int, int], ...] = (
    (8, 128, 1024), (8, 256, 1024), (8, 512, 512), (16, 256, 512),
)

SPARSITY_GRID = (1.0, 0.5, 0.25, 0.125, 0.0625, 0.03125)


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    block_m: int
    block_n: int
    block_k: int

    def as_list(self) -> List[int]:
        return [self.block_m, self.block_n, self.block_k]

    def vmem_bytes(self, dtype_bytes: int = 2) -> int:
        x = self.block_m * self.block_k * dtype_bytes
        w = (self.block_k // K_PER_WORD) * self.block_n * 4
        dec = self.block_k * self.block_n * dtype_bytes
        acc = self.block_m * self.block_n * 4
        out = self.block_m * self.block_n * dtype_bytes
        return x + w + dec + acc + out


@dataclasses.dataclass(frozen=True)
class FusedBlockConfig:
    """Block plan for one fused MLP pair: a shared M tile plus the up- and
    down-projection's own (N, K) tiles. Serialized as a five-int cache
    entry (the arity is what distinguishes it from ``BlockConfig`` on
    load)."""

    block_m: int
    block_n1: int
    block_k1: int
    block_n2: int
    block_k2: int

    def as_list(self) -> List[int]:
        return [self.block_m, self.block_n1, self.block_k1,
                self.block_n2, self.block_k2]

    def up(self) -> BlockConfig:
        return BlockConfig(self.block_m, self.block_n1, self.block_k1)

    def down(self) -> BlockConfig:
        return BlockConfig(self.block_m, self.block_n2, self.block_k2)


def _pow2_bucket(v: int) -> int:
    return 1 << max(0, int(v - 1).bit_length())


def _sparsity_bucket(s: float) -> float:
    return min(SPARSITY_GRID, key=lambda g: abs(g - max(min(s, 1.0), 0.0)))


def cache_key(m: int, k: int, n: int, sparsity: float = 1.0,
              impl: str = "dense", fixed_n: Optional[int] = None,
              fixed_k: Optional[int] = None,
              phase: Optional[str] = None) -> str:
    """Layout-pinned block shapes (TiledTernary tile_n/tile_k) are part of
    the problem identity — two packs of the same logical shape with
    different tiles must not share (and thrash) one entry. Likewise the
    serving phase: decode (M=slots, GEMV-shaped) and prefill (M=B·L,
    GEMM-shaped) problems tune separately even at equal bucketed M."""
    key = (f"{impl}:m{_pow2_bucket(m)}:k{k}:n{n}"
           f":s{_sparsity_bucket(sparsity)}")
    if fixed_n is not None:
        key += f":bn{fixed_n}"
    if fixed_k is not None:
        key += f":bk{fixed_k}"
    if phase is not None:
        key += f":p{phase}"
    return key


def fused_cache_key(m: int, k: int, ff: int, n: int,
                    sparsity_up: float = 1.0, sparsity_down: float = 1.0,
                    phase: Optional[str] = None) -> str:
    """Key for a fused MLP pair: both weights' shapes (K->FF up, FF->N
    down) and both occupancies are the problem identity, under the same
    phase suffix the per-GEMM keys use."""
    key = (f"fused:m{_pow2_bucket(m)}:k{k}:f{ff}:n{n}"
           f":s{_sparsity_bucket(sparsity_up)}"
           f"x{_sparsity_bucket(sparsity_down)}")
    if phase is not None:
        key += f":p{phase}"
    return key


class Autotuner:
    """Process-wide block-shape cache with JSON persistence."""

    def __init__(self, path: Optional[str] = None, mode: str = "auto"):
        self._path = path if path is not None else os.environ.get(
            CACHE_ENV, DEFAULT_CACHE_PATH)
        self._mode = mode          # auto | model | measure
        self._cache: Dict[str, BlockConfig] = {}
        self._lock = threading.Lock()
        self._loaded = False

    # --- persistence ------------------------------------------------------
    def _load(self) -> None:
        if self._loaded:
            return
        self._loaded = True
        try:
            with open(self._path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return            # unreadable / corrupt file: degrade to re-tune
        for key, blk in data.get("entries", {}).items():
            # arity decides the entry type: 3 ints = one GEMM, 5 = a fused
            # pair. A malformed entry drops alone — it must not take the
            # rest of the cache down with it.
            try:
                ints = [int(v) for v in blk]
            except (ValueError, TypeError):
                continue
            if len(ints) == 3:
                self._cache[key] = BlockConfig(*ints)
            elif len(ints) == 5:
                self._cache[key] = FusedBlockConfig(*ints)

    def save(self) -> None:
        entries = {key: cfg.as_list() for key, cfg in sorted(
            self._cache.items())}
        d = os.path.dirname(self._path)
        if d:
            os.makedirs(d, exist_ok=True)
        tmp = self._path + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"version": 1, "entries": entries}, f, indent=1)
        os.replace(tmp, self._path)

    # --- candidate generation / scoring ----------------------------------
    def candidates(self, m: int, k: int, n: int,
                   fixed_n: Optional[int] = None,
                   fixed_k: Optional[int] = None,
                   phase: Optional[str] = None) -> List[BlockConfig]:
        """VMEM-feasible candidates; fixed_n/fixed_k pin block shapes that
        are dictated by the data layout (TiledTernary tile shapes). The
        decode phase widens the grid with GEMV-shaped candidates."""
        grid = CANDIDATE_BLOCKS
        if phase in ("decode", "verify"):
            grid = grid + DECODE_CANDIDATE_BLOCKS
        out, seen = [], set()
        for bm, bn, bk in grid:
            bm = min(bm, _pow2_bucket(max(m, 8)))
            bn = fixed_n if fixed_n is not None else bn
            bk = fixed_k if fixed_k is not None else bk
            cfg = BlockConfig(bm, bn, bk)
            if cfg in seen or cfg.vmem_bytes() > VMEM_BYTES:
                continue
            seen.add(cfg)
            out.append(cfg)
        if not out:   # degenerate fallback: smallest legal tile
            out.append(BlockConfig(min(8, _pow2_bucket(max(m, 8))),
                                   fixed_n or 128, fixed_k or 256))
        return out

    def _model_score(self, cfg: BlockConfig, m: int, k: int, n: int,
                     sparsity: float) -> float:
        """Modeled seconds for one GEMM pass, lower is better. Occupied
        fraction scales the K-dimension traffic (the skip path's lever)."""
        occ = max(min(sparsity, 1.0), 1.0 / 64)
        mp = -(-m // cfg.block_m) * cfg.block_m
        npad = -(-n // cfg.block_n) * cfg.block_n
        kp = -(-k // cfg.block_k) * cfg.block_k
        n_tiles = npad // cfg.block_n
        m_tiles = mp // cfg.block_m
        k_steps = max(1, round((kp // cfg.block_k) * occ))
        x_bytes = m_tiles * n_tiles * k_steps * cfg.block_m * cfg.block_k * 2
        w_bytes = (m_tiles * n_tiles * k_steps
                   * (cfg.block_k // K_PER_WORD) * cfg.block_n * 4)
        out_bytes = mp * npad * 2
        t_mem = (x_bytes + w_bytes + out_bytes) / HBM_BW
        grid = m_tiles * n_tiles * k_steps
        t_grid = grid * 1e-6          # per-step dispatch/DMA-setup overhead
        # mild pressure penalty as the working set approaches VMEM capacity
        t_vmem = t_mem * 0.25 * (cfg.vmem_bytes() / VMEM_BYTES)
        return t_mem + t_grid + t_vmem

    def _measure(self, cfg: BlockConfig, run: Callable[[BlockConfig], None],
                 repeats: int = 3) -> float:
        import time
        run(cfg)                      # compile + warm
        t0 = time.perf_counter()
        for _ in range(repeats):
            run(cfg)
        return (time.perf_counter() - t0) / repeats

    # --- the public entry -------------------------------------------------
    def lookup(self, m: int, k: int, n: int, sparsity: float = 1.0,
               impl: str = "dense", fixed_n: Optional[int] = None,
               fixed_k: Optional[int] = None,
               run: Optional[Callable[[BlockConfig], None]] = None,
               phase: Optional[str] = None) -> BlockConfig:
        """Best block shape for the problem; tunes and persists on miss.

        ``run``, if given and the mode resolves to ``measure``, is called
        per candidate to produce a wall-clock score; otherwise the analytic
        model decides (deterministic, CI-safe). ``phase`` ("prefill" /
        "decode" / None) separates serving-phase entries.
        """
        key = cache_key(m, k, n, sparsity, impl, fixed_n=fixed_n,
                        fixed_k=fixed_k, phase=phase)
        with self._lock:
            self._load()
            hit = self._cache.get(key)
        if isinstance(hit, BlockConfig) \
                and (fixed_n is None or hit.block_n == fixed_n) \
                and (fixed_k is None or hit.block_k == fixed_k):
            return hit

        mode = self._mode
        if mode == "auto":
            import jax
            mode = ("measure"
                    if run is not None and jax.default_backend() == "tpu"
                    else "model")
        cands = self.candidates(m, k, n, fixed_n=fixed_n, fixed_k=fixed_k,
                                phase=phase)
        if mode == "measure" and run is not None:
            scored = [(self._measure(c, run), c) for c in cands]
        else:
            scored = [(self._model_score(c, m, k, n, sparsity), c)
                      for c in cands]
        best = min(scored, key=lambda sc: sc[0])[1]
        with self._lock:
            self._cache[key] = best
            try:
                self.save()
            except OSError:
                pass      # read-only FS: in-process cache still works
        return best

    def lookup_fused(self, m: int, k: int, ff: int, n: int,
                     sparsity_up: float = 1.0, sparsity_down: float = 1.0,
                     fixed_n1: Optional[int] = None,
                     fixed_k1: Optional[int] = None,
                     fixed_n2: Optional[int] = None,
                     fixed_k2: Optional[int] = None,
                     phase: Optional[str] = None) -> FusedBlockConfig:
        """Block plan for a fused ``(K->FF) -> act -> (FF->N)`` MLP pair.

        On a miss the entry is *composed* from the two per-GEMM ``lookup``
        results (so fused and unfused chains always tile K/N identically —
        the bitwise-equality contract) with the shared M tile taken as the
        smaller of the two, then persisted under the fused key so later
        plans are a single cache hit. ``fixed_n1``/``fixed_k1`` pin the
        up-projection tiles when the pack layout dictates them (Tiled
        weights)."""
        key = fused_cache_key(m, k, ff, n, sparsity_up, sparsity_down,
                              phase=phase)
        with self._lock:
            self._load()
            hit = self._cache.get(key)
        if isinstance(hit, FusedBlockConfig) \
                and (fixed_n1 is None or hit.block_n1 == fixed_n1) \
                and (fixed_k1 is None or hit.block_k1 == fixed_k1) \
                and (fixed_n2 is None or hit.block_n2 == fixed_n2) \
                and (fixed_k2 is None or hit.block_k2 == fixed_k2):
            return hit
        up = self.lookup(m, k, ff, sparsity=sparsity_up,
                         impl="skip" if fixed_n1 is not None else "dense",
                         fixed_n=fixed_n1, fixed_k=fixed_k1, phase=phase)
        down = self.lookup(m, ff, n, sparsity=sparsity_down,
                           impl="skip" if fixed_n2 is not None else "dense",
                           fixed_n=fixed_n2, fixed_k=fixed_k2, phase=phase)
        best = FusedBlockConfig(min(up.block_m, down.block_m),
                                up.block_n, up.block_k,
                                down.block_n, down.block_k)
        with self._lock:
            self._cache[key] = best
            try:
                self.save()
            except OSError:
                pass
        return best

    def entries(self) -> Dict[str, BlockConfig]:
        with self._lock:
            self._load()
            return dict(self._cache)


_GLOBAL: Optional[Autotuner] = None
_GLOBAL_LOCK = threading.Lock()


def get_tuner() -> Autotuner:
    """The process-wide tuner (path from $REPRO_AUTOTUNE_CACHE)."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is None:
            _GLOBAL = Autotuner()
        return _GLOBAL
