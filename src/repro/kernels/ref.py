"""Pure-jnp oracles for ternary GEMM + faithful ports of the paper's
algorithm variants (BaseTCSC / BlockedTCSC / InterleavedTCSC).

Every function computes Y = X @ (alpha * T) + bias (optionally PReLU'd),
with T the {-1,0,+1} ternary matrix, and they all agree to float tolerance.
These serve as (a) correctness oracles for the Pallas kernel, and (b) the
paper-faithful baselines for the benchmark suite (benchmarks/paper_figs.py).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats

__all__ = [
    "prelu",
    "ternary_matmul_dense",
    "tcsc_matmul",
    "tcsc_matmul_blocked",
    "tcsc_matmul_interleaved",
    "packed2bit_matmul",
    "bitplane_matmul",
    "bitplane_matmul_factorized",
    "base3_matmul",
]


def prelu(y: jnp.ndarray, a: float | jnp.ndarray) -> jnp.ndarray:
    return jnp.where(y >= 0, y, a * y)


def _epilogue(y, alpha, bias, prelu_alpha):
    if alpha is not None:
        y = y * jnp.asarray(alpha, y.dtype).reshape(1, -1)
    if bias is not None:
        y = y + jnp.asarray(bias, y.dtype).reshape(1, -1)
    if prelu_alpha is not None:
        y = prelu(y, prelu_alpha)
    return y


def ternary_matmul_dense(x: jnp.ndarray, t: jnp.ndarray,
                         alpha: Optional[jnp.ndarray] = None,
                         bias: Optional[jnp.ndarray] = None,
                         prelu_alpha: Optional[float] = None) -> jnp.ndarray:
    """Oracle: decoded dense matmul. t: (K, N) in {-1,0,1} (any int/float dtype)."""
    y = jnp.dot(x, t.astype(x.dtype), preferred_element_type=jnp.float32)
    return _epilogue(y, alpha, bias, prelu_alpha).astype(x.dtype)


# ---------------------------------------------------------------------------
# Paper algorithm ports (gather + segment-sum = the JAX idiom for the
# column-wise add/sub loops of the paper's scalar kernels)
# ---------------------------------------------------------------------------

def tcsc_matmul(x: jnp.ndarray, w: formats.TCSC,
                alpha: Optional[jnp.ndarray] = None,
                bias: Optional[jnp.ndarray] = None,
                prelu_alpha: Optional[float] = None) -> jnp.ndarray:
    """BaseTCSC: two passes (all positives, then all negatives) per column."""
    _, n = w.shape
    seg_p = jnp.asarray(w.segment_ids_pos())
    seg_n = jnp.asarray(w.segment_ids_neg())
    # gather columns of X by row index -> (nnz, M); segment-sum by column id.
    xp = x.T[jnp.asarray(w.row_index_pos)]          # (nnz_pos, M)
    xn = x.T[jnp.asarray(w.row_index_neg)]          # (nnz_neg, M)
    yp = jax.ops.segment_sum(xp, seg_p, num_segments=n)
    yn = jax.ops.segment_sum(xn, seg_n, num_segments=n)
    y = (yp - yn).T
    return _epilogue(y, alpha, bias, prelu_alpha).astype(x.dtype)


def tcsc_matmul_blocked(x: jnp.ndarray, w: formats.BlockedTCSC,
                        alpha: Optional[jnp.ndarray] = None,
                        bias: Optional[jnp.ndarray] = None,
                        prelu_alpha: Optional[float] = None) -> jnp.ndarray:
    """BlockedTCSC: per K-block gathers confined to a [0, B) window."""
    _, n = w.shape
    y = jnp.zeros((n, x.shape[0]), dtype=jnp.float32)
    for b, blk in enumerate(w.blocks):
        base = b * w.block_size
        xs = x.T[base:base + w.block_size]          # the B-window of X
        xp = xs[jnp.asarray(blk.row_index_pos)]
        xn = xs[jnp.asarray(blk.row_index_neg)]
        y = y + jax.ops.segment_sum(xp, jnp.asarray(blk.segment_ids_pos()),
                                    num_segments=n)
        y = y - jax.ops.segment_sum(xn, jnp.asarray(blk.segment_ids_neg()),
                                    num_segments=n)
    return _epilogue(y.T, alpha, bias, prelu_alpha).astype(x.dtype)


def tcsc_matmul_interleaved(x: jnp.ndarray, w: formats.InterleavedTCSC,
                            alpha: Optional[jnp.ndarray] = None,
                            bias: Optional[jnp.ndarray] = None,
                            prelu_alpha: Optional[float] = None) -> jnp.ndarray:
    """InterleavedTCSC: single pass over one index array, signs structural."""
    _, n = w.shape
    signs = jnp.asarray(w.signs().astype(np.float32))
    seg = jnp.asarray(w.segment_ids())
    xs = x.T[jnp.asarray(w.all_indices)] * signs[:, None]
    y = jax.ops.segment_sum(xs, seg, num_segments=n).T
    return _epilogue(y, alpha, bias, prelu_alpha).astype(x.dtype)


# ---------------------------------------------------------------------------
# Packed-format XLA paths (used inside distributed models for dry-runs)
# ---------------------------------------------------------------------------

def packed2bit_matmul(x: jnp.ndarray, packed: jnp.ndarray, k: int,
                      alpha: Optional[jnp.ndarray] = None,
                      bias: Optional[jnp.ndarray] = None,
                      prelu_alpha: Optional[float] = None) -> jnp.ndarray:
    t = formats.decode_2bit(packed, k, dtype=x.dtype)
    return ternary_matmul_dense(x, t, alpha, bias, prelu_alpha)


def bitplane_matmul(x: jnp.ndarray, plus: jnp.ndarray, minus: jnp.ndarray,
                    k: int, alpha: Optional[jnp.ndarray] = None,
                    bias: Optional[jnp.ndarray] = None,
                    prelu_alpha: Optional[float] = None) -> jnp.ndarray:
    t = formats.decode_bitplanes(plus, minus, k, dtype=x.dtype)
    return ternary_matmul_dense(x, t, alpha, bias, prelu_alpha)


def bitplane_matmul_factorized(x: jnp.ndarray, plus: jnp.ndarray,
                               minus: jnp.ndarray, k: int,
                               alpha: Optional[jnp.ndarray] = None,
                               bias: Optional[jnp.ndarray] = None,
                               prelu_alpha: Optional[float] = None
                               ) -> jnp.ndarray:
    """Matmul factorization Y = (X @ P) - (X @ M): each 0/1 plane is its own
    binary matmul, the ternary combine happens on the accumulator
    (DESIGN.md §4). Oracle for the factorized Pallas path."""
    zeros = jnp.zeros_like(plus)
    p = formats.decode_bitplanes(plus, zeros, k, dtype=x.dtype)
    m = formats.decode_bitplanes(minus, zeros, k, dtype=x.dtype)
    y = (jnp.dot(x, p, preferred_element_type=jnp.float32)
         - jnp.dot(x, m, preferred_element_type=jnp.float32))
    return _epilogue(y, alpha, bias, prelu_alpha).astype(x.dtype)


def base3_matmul(x: jnp.ndarray, packed: jnp.ndarray, k: int,
                 alpha: Optional[jnp.ndarray] = None,
                 bias: Optional[jnp.ndarray] = None,
                 prelu_alpha: Optional[float] = None) -> jnp.ndarray:
    t = formats.decode_base3(packed, k, dtype=x.dtype)
    return ternary_matmul_dense(x, t, alpha, bias, prelu_alpha)
