"""jit'd public wrappers + registry-dispatched planning for the ternary
GEMM kernels.

``ternary_gemm(x, w)`` is the user-facing op; ``w`` is a
``repro.core.weights.TernaryWeight`` container (``Dense2Bit`` / ``Tiled`` /
``Bitplane`` / ``Base3``). Dispatch is two-stage:

1. **plan** — ``ternary_gemm_plan`` consults the kernel registry: each
   lowering registers ``(format, impl)`` with a priority and a capability
   predicate (shape / serving phase / pack-time occupancy), and the planner
   picks the best admissible impl for ``impl="auto"`` (e.g. the skipping
   kernel only below ``SKIP_OCCUPANCY_CUTOFF`` tile occupancy). Block
   shapes left ``None`` are resolved by the autotuner
   (``kernels.autotune``), keyed on (M, K, N, occupancy, impl, phase). The
   resulting ``GemmPlan`` is an inspectable value object (tests and
   benchmarks assert on it directly).
2. **lower** — the registered lowering for ``(plan.format, plan.impl)``
   runs the Pallas kernel (interpret mode off-TPU) or the XLA reference.

Registered impls:

* ``dense2bit``: ``dense`` (Pallas dense-decode), ``ref``;
* ``tiled``:     ``skip_db`` (double-buffered-DMA tile skipping,
                 DESIGN.md §12), ``skip`` (scalar-prefetch tile skipping,
                 DESIGN.md §3), ``dense`` fallback, ``ref``;
* ``bitplane``:  ``bitplane``, ``bitplane_factorized`` (MXU
                 ``Y=(X@P)-(X@M)``, DESIGN.md §4), ``ref``;
* ``base3``:     ``ref`` (LUT-gather decode — the paper's dropped format,
                 kept dispatchable for the benchmark record).

New formats/kernels plug in via ``weights.register_format`` +
``register_kernel`` without touching any call site.

A third registry fuses whole MLP blocks: ``fused_mlp(x, w_in, w_out,
w_gate)`` runs ``GEMM -> bias -> activation -> GEMM`` as one kernel with
the hidden activation resident in VMEM (``impl="pallas"``), falling back
to the literal unfused chain (``impl="chain"``) for formats the fused
kernel does not cover. Both are pinned bitwise-equal, so adoption in
``models.layers.mlp_apply`` is a pure performance decision.

**Removed shim**: the pre-container operand union (raw ``(K/16, N)``
uint32 code matrix, ``formats.TiledTernary``, ``(plus, minus)`` tuple)
went through its two deprecation cycles (PR 3 warned, this PR errors) —
``ternary_gemm`` now raises ``TypeError`` pointing at ``weights.pack`` /
``kernels.pack_weights*``.

Every path defines a custom VJP (dY/dX = g @ T^T; packed weights are
non-differentiable — training uses the QAT/STE latent-weight path in
``core.quantize``).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats, weights
from repro.kernels import ref
from repro.obs import clock as obs_clock
from repro.kernels import autotune as autotune_lib
from repro.kernels.fused_mlp import ACTIVATIONS, fused_mlp_pallas
from repro.kernels.ternary_gemm import (K_PER_WORD, ternary_gemm_pallas,
                                        ternary_gemm_skip_db_pallas,
                                        ternary_gemm_skip_pallas)
from repro.kernels.ternary_gemm_bitplane import (K_PER_BYTE,
                                                 ternary_gemm_bitplane)

__all__ = ["ternary_gemm", "ternary_gemm_plan", "GemmPlan", "KernelImpl",
           "register_kernel", "kernel_registry", "precompute_plans",
           "fused_mlp", "fused_mlp_plan", "FusedMlpPlan",
           "register_fused", "fused_registry", "precompute_fused_plans",
           "pack_weights", "pack_weights_tiled",
           "serving_phase", "current_phase", "SERVING_PHASES",
           "kernel_probe", "SKIP_OCCUPANCY_CUTOFF",
           "paged_decode_attention", "register_paged_attn",
           "paged_attention_registry"]

# Serving-phase tag consumed at trace time: prefill GEMMs are M=B·L
# GEMM-shaped, decode GEMMs are M=slots GEMV-shaped, verify GEMMs
# (speculative decoding, DESIGN.md §10) are M=slots·(k+1) small-GEMM
# shaped, and chunk GEMMs (chunked prefill, DESIGN.md §14) are
# M=P·chunk_tokens mid-size — no two of them may share (and thrash) one
# autotune entry even when their bucketed M collides.
SERVING_PHASES = ("prefill", "decode", "verify", "chunk")

_SERVING_PHASE: contextvars.ContextVar[Optional[str]] = \
    contextvars.ContextVar("repro_serving_phase", default=None)


@contextlib.contextmanager
def serving_phase(phase: Optional[str]):
    """Tag ``ternary_gemm`` dispatches traced inside this scope with one
    of ``SERVING_PHASES`` so the autotuner keys them separately (the
    serving engine wraps its phase jit calls in this)."""
    assert phase is None or phase in SERVING_PHASES, phase
    token = _SERVING_PHASE.set(phase)
    try:
        yield
    finally:
        _SERVING_PHASE.reset(token)


def current_phase() -> Optional[str]:
    return _SERVING_PHASE.get()


# Optional kernel timing probe (DESIGN.md §15): a callback receiving
# (plan, wall_seconds) for every *eager* ternary_gemm / fused_mlp
# dispatch inside the scope. The measured time spans lowering through
# block_until_ready, bracketed by a jax.profiler.TraceAnnotation so the
# same region shows up in an XLA profile. Dispatches under jit tracing
# are skipped — there is no wall time to measure at trace time, and the
# probe must not bake a callback into a compiled computation.
_KERNEL_PROBE: contextvars.ContextVar[Optional[Callable]] = \
    contextvars.ContextVar("repro_kernel_probe", default=None)


@contextlib.contextmanager
def kernel_probe(cb: Callable[[Any, float], None]):
    """``with kernel_probe(lambda plan, dt: ...):`` — time every eager
    kernel dispatch in the scope against its plan (whose ``roofline()``
    carries the modeled bytes/FLOPs/time for measured-vs-modeled
    reporting; see ``benchmarks/roofline.py --measured``)."""
    token = _KERNEL_PROBE.set(cb)
    try:
        yield
    finally:
        _KERNEL_PROBE.reset(token)


def _probe_dispatch(probe: Callable, plan, tag: str, lower: Callable):
    """Timed dispatch path shared by the two public ops."""
    t0 = obs_clock.now()
    with jax.profiler.TraceAnnotation(tag):
        y = lower()
        jax.block_until_ready(y)
    probe(plan, obs_clock.now() - t0)
    return y

# Above this occupied-tile fraction the skipping grid saves too little to
# justify the scalar-prefetch indirection; "auto" falls back to dense.
SKIP_OCCUPANCY_CUTOFF = 0.875


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pack_weights(t: np.ndarray, scale=None, bias=None) -> weights.Dense2Bit:
    """Host-side: (K, N) {-1,0,1} -> ``Dense2Bit`` container (16 weights per
    uint32 word, the dense kernel format)."""
    return weights.Dense2Bit.from_dense(np.asarray(t), scale=scale,
                                        bias=bias)


def pack_weights_tiled(t: np.ndarray, tile_k: int = 256,
                       tile_n: int = 128, scale=None,
                       bias=None) -> weights.Tiled:
    """Host-side: (K, N) {-1,0,1} -> ``Tiled`` container (packed words +
    per-tile occupancy metadata) for the skipping kernel."""
    return weights.Tiled.from_dense(np.asarray(t), tile_k=tile_k,
                                    tile_n=tile_n, scale=scale, bias=bias)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# 2-bit-code family (dense + skipping share the packed format and the VJP)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(6, 7, 8, 9, 10, 11, 12, 13))
def _gemm_2bit(x, w_packed, scale, bias, kt_idx, kt_cnt,
               n, block_m, block_n, block_k, fuse_prelu, prelu_alpha,
               interpret, db):
    """Forward: dense kernel when kt_idx is None, else one of the skipping
    kernels (``db`` selects the double-buffered-DMA variant). Returns the
    (m, n)-sliced logical output."""
    m = x.shape[0]
    bm = min(block_m, max(8, 1 << (m - 1).bit_length()))
    sp = None if scale is None else _pad_to(scale.reshape(-1), 0, block_n)
    bp = None if bias is None else _pad_to(bias.reshape(-1), 0, block_n)
    # x's K must first match the packed operand's (possibly padded) K — the
    # word rows can exceed ceil(k/block_k)*block_k when the pack used a
    # larger tile_k than the resolved block_k.
    kp = w_packed.shape[0] * K_PER_WORD
    xp = _pad_to(_pad_to(x, 1, kp), 0, bm)
    if kt_idx is None:
        xp = _pad_to(xp, 1, block_k)
        wp = _pad_to(_pad_to(w_packed, 0, block_k // K_PER_WORD), 1, block_n)
        y = ternary_gemm_pallas(
            xp, wp, sp, bp, block_m=bm, block_n=block_n, block_k=block_k,
            fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha,
            interpret=interpret)
    else:
        skip_kernel = (ternary_gemm_skip_db_pallas if db
                       else ternary_gemm_skip_pallas)
        y = skip_kernel(
            xp, w_packed, kt_idx, kt_cnt, sp, bp,
            block_m=bm, block_n=block_n, block_k=block_k,
            fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha,
            interpret=interpret)
    return y[:m, :n]


def _gemm_2bit_fwd(x, w_packed, scale, bias, kt_idx, kt_cnt, *static):
    y = _gemm_2bit(x, w_packed, scale, bias, kt_idx, kt_cnt, *static)
    fuse_prelu = static[4]
    return y, (x, w_packed, scale, bias, kt_idx, kt_cnt,
               y if fuse_prelu else None)


def _gemm_2bit_bwd(n, bm, bn, bk, fuse_prelu, prelu_alpha, interpret, db,
                   res, g):
    x, w_packed, scale, bias, kt_idx, kt_cnt, y = res
    kk = x.shape[1]  # logical K is x's trailing dim (x is unpadded)
    if fuse_prelu:
        g = jnp.where(y >= 0, g, prelu_alpha * g)
    # Bias grad exists only when a bias operand exists (scale is irrelevant).
    gb = (None if bias is None
          else jnp.sum(g, axis=0).astype(bias.dtype).reshape(bias.shape))
    t = formats.decode_2bit(w_packed, kk, dtype=x.dtype)[:, :n]
    if scale is not None:
        # dL/dscale = sum_m g * (x @ T): exact, costs one decode+matmul.
        ylin = jnp.dot(x, t, preferred_element_type=jnp.float32)
        gscale = jnp.sum(g.astype(jnp.float32) * ylin, axis=0).astype(
            scale.dtype).reshape(scale.shape)
        g = g * scale.reshape(1, -1).astype(g.dtype)
    else:
        gscale = None
    gx = jnp.dot(g, t.T, preferred_element_type=jnp.float32).astype(x.dtype)
    return (gx, jnp.zeros_like(w_packed), gscale, gb,
            None if kt_idx is None else jnp.zeros_like(kt_idx),
            None if kt_cnt is None else jnp.zeros_like(kt_cnt))


_gemm_2bit.defvjp(_gemm_2bit_fwd, _gemm_2bit_bwd)


# ---------------------------------------------------------------------------
# Bitplane family (combined decode / plane-factorized)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _gemm_bitplane(x, plus, minus, scale, block_m, block_n, block_k,
                   factorized, interpret):
    return ternary_gemm_bitplane(
        x, plus, minus, scale, block_m=block_m, block_n=block_n,
        block_k=block_k, factorized=factorized, interpret=interpret)


def _gemm_bitplane_fwd(x, plus, minus, scale, *static):
    y = _gemm_bitplane(x, plus, minus, scale, *static)
    return y, (x, plus, minus, scale)


def _gemm_bitplane_bwd(bm, bn, bk, factorized, interpret, res, g):
    x, plus, minus, scale = res
    kk = x.shape[1]
    t = formats.decode_bitplanes(plus, minus, kk, dtype=x.dtype)
    t = t[:, :g.shape[1]]
    if scale is not None:
        ylin = jnp.dot(x, t, preferred_element_type=jnp.float32)
        gscale = jnp.sum(g.astype(jnp.float32) * ylin, axis=0).astype(
            scale.dtype).reshape(scale.shape)
        g = g * scale.reshape(1, -1).astype(g.dtype)
    else:
        gscale = None
    gx = jnp.dot(g, t.T, preferred_element_type=jnp.float32).astype(x.dtype)
    return gx, jnp.zeros_like(plus), jnp.zeros_like(minus), gscale


_gemm_bitplane.defvjp(_gemm_bitplane_fwd, _gemm_bitplane_bwd)


# ---------------------------------------------------------------------------
# The kernel registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """Inspectable dispatch decision for one ternary GEMM.

    Produced by ``ternary_gemm_plan``; consumed by the registered lowering.
    ``block_*`` are ``None`` for reference (non-Pallas) impls.

    Example (doctest-runnable)::

        >>> import numpy as np
        >>> from repro.core import weights
        >>> from repro.kernels import ops
        >>> w = weights.pack(np.sign(np.random.randn(512, 256)), "dense2bit")
        >>> plan = ops.ternary_gemm_plan(w, m=128)
        >>> (plan.format, plan.impl, plan.m, plan.k, plan.n)
        ('dense2bit', 'dense', 128, 512, 256)
        >>> sorted(plan.roofline())     # doctest: +NORMALIZE_WHITESPACE
        ['achieved_flops', 'arithmetic_intensity', 'bound', 'bytes',
         'ceiling_flops', 'collective', 'collective_bytes', 'flops',
         'headroom', 'model_time_s', 'peak_flops', 'tp']

    Under tensor parallelism (``ternary_gemm_plan(..., partition=, tp=)``)
    ``m``/``k``/``n`` are the *per-shard* problem — ``partition="k"`` row
    splits K and carries an explicit ``collective="psum"`` (the all-reduce
    over partial products); ``partition="n"`` column splits N with no
    collective (the next row-split layer consumes the sharded activation).
    """

    format: str
    impl: str
    m: int
    k: int
    n: int
    block_m: Optional[int]
    block_n: Optional[int]
    block_k: Optional[int]
    phase: Optional[str]
    occupancy: float
    interpret: bool
    fuse_prelu: bool = False
    prelu_alpha: float = 0.25
    partition: Optional[str] = None      # None | "k" | "n"
    collective: Optional[str] = None     # None | "psum"
    tp: int = 1

    def traffic(self) -> Dict[str, float]:
        """Modeled FLOPs and HBM bytes for one pass, from the plan's block
        shapes and the pack-time occupancy metadata. Skip-family impls
        scale the K axis by the occupied-tile fraction — the same model
        the autotuner scores with, so plan and tune never disagree."""
        skipping = self.impl in ("skip", "skip_db")
        occ = self.occupancy if skipping else 1.0
        bm = self.block_m or min(128, max(8, 1 << (self.m - 1).bit_length()))
        bn = self.block_n or 128
        bk = self.block_k or 256
        mp = -(-self.m // bm) * bm
        npad = -(-self.n // bn) * bn
        kp = -(-self.k // bk) * bk
        m_tiles, n_tiles = mp // bm, npad // bn
        k_steps = max(1, round((kp // bk) * occ))
        flops = 2.0 * mp * npad * (k_steps * bk)
        x_bytes = m_tiles * n_tiles * k_steps * bm * bk * 2
        w_bytes = m_tiles * n_tiles * k_steps * (bk // K_PER_WORD) * bn * 4
        out_bytes = mp * npad * 2
        # ring all-reduce over the K-split partial products: each shard
        # sends/receives 2*(tp-1)/tp of the (m, n) f32 partial output
        coll = (2.0 * (self.tp - 1) / self.tp * self.m * self.n * 4
                if self.collective == "psum" and self.tp > 1 else 0.0)
        return {"flops": flops,
                "bytes": float(x_bytes + w_bytes + out_bytes),
                "collective_bytes": coll}

    def roofline(self) -> Dict[str, float]:
        """Roofline position of this plan on the modeled machine
        (``autotune.HBM_BW`` / ``autotune.PEAK_FLOPS``): achieved vs
        ceiling FLOP/s, arithmetic intensity, and remaining headroom.
        Emitted per registered kernel by ``benchmarks/roofline.py``."""
        t = self.traffic()
        ai = t["flops"] / max(t["bytes"], 1.0)
        ceiling = min(autotune_lib.PEAK_FLOPS, ai * autotune_lib.HBM_BW)
        # achieved = modeled time for this plan's tile traffic (the same
        # score the tuner minimized, incl. grid + VMEM-pressure overheads)
        cfg = autotune_lib.BlockConfig(
            self.block_m or 128, self.block_n or 128, self.block_k or 256)
        t_model = autotune_lib.Autotuner()._model_score(
            cfg, self.m, self.k, self.n,
            self.occupancy if self.impl in ("skip", "skip_db") else 1.0)
        achieved = t["flops"] / max(t_model, 1e-12)
        return {"flops": t["flops"], "bytes": t["bytes"],
                "arithmetic_intensity": ai,
                "ceiling_flops": ceiling,
                "achieved_flops": achieved,
                "peak_flops": autotune_lib.PEAK_FLOPS,
                "model_time_s": t_model,
                "headroom": max(0.0, 1.0 - achieved / max(ceiling, 1.0)),
                "bound": ("memory" if ceiling < autotune_lib.PEAK_FLOPS
                          else "compute"),
                "collective": self.collective,
                "collective_bytes": t["collective_bytes"],
                "tp": self.tp}


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One registered lowering: ``(format, impl)`` -> kernel.

    ``predicate(w, m, phase)`` gates ``impl="auto"`` selection (highest
    admissible ``priority`` wins); ``plan_blocks(w, m, phase, bm, bn, bk)``
    resolves block shapes (consulting the autotuner for ``None`` entries);
    ``lower(plan, x, w, scale, bias)`` executes."""

    format: str
    impl: str
    priority: int
    predicate: Callable[[weights.TernaryWeight, int, Optional[str]], bool]
    plan_blocks: Callable
    lower: Callable


_KERNELS: Dict[Tuple[str, str], KernelImpl] = {}


def register_kernel(fmt: str, impl: str, *, priority: int = 0,
                    predicate: Optional[Callable] = None,
                    plan_blocks: Optional[Callable] = None):
    """Decorator registering a lowering for ``(format, impl)``. The single
    extension point for new kernels — dispatch, ``impl="auto"`` selection
    and ``ternary_gemm_plan`` pick it up with no call-site changes.

    ``predicate(w, m, phase)`` gates ``impl="auto"`` (highest admissible
    ``priority`` wins); ``plan_blocks(w, m, phase, bm, bn, bk)`` resolves
    block shapes (``None`` entries usually consult the autotuner);
    the decorated ``fn(plan, x, w, scale, bias)`` executes.

    Example (doctest-runnable) — a reference lowering that only admits
    GEMV-shaped dispatches::

        >>> import numpy as np
        >>> from repro.core import weights
        >>> from repro.kernels import ops, ref
        >>> @ops.register_kernel("dense2bit", "gemv_ref", priority=1,
        ...                      predicate=lambda w, m, phase: m == 1)
        ... def _lower_gemv(plan, x, w, scale, bias):
        ...     return ref.packed2bit_matmul(x, w.packed, w.k)[:, :w.n]
        >>> w = weights.pack(np.sign(np.random.randn(64, 32)), "dense2bit")
        >>> ops.ternary_gemm_plan(w, m=1, impl="gemv_ref").impl
        'gemv_ref'
        >>> del ops._KERNELS[("dense2bit", "gemv_ref")]   # leave no trace
    """

    def deco(fn):
        _KERNELS[(fmt, impl)] = KernelImpl(
            format=fmt, impl=impl, priority=priority,
            predicate=predicate or (lambda w, m, phase: True),
            plan_blocks=plan_blocks or (lambda w, m, phase, bm, bn, bk:
                                        (bm, bn, bk)),
            lower=fn)
        return fn

    return deco


def kernel_registry() -> Dict[Tuple[str, str], KernelImpl]:
    """Snapshot of the registered ``(format, impl) -> KernelImpl`` table."""
    return dict(_KERNELS)


# --- block planning helpers -------------------------------------------------

def _blocks_dense(w, m, phase, bm, bn, bk):
    # Dense-decode traffic is occupancy-independent: tune under the dense
    # key (sparsity=1.0) so plans do not depend on pack-time nnz metadata
    # (keeps a restored checkpoint's plan identical to the packing boot's).
    if bm is None or bn is None or bk is None:
        cfg = autotune_lib.get_tuner().lookup(
            m, w.k, w.n, sparsity=1.0, impl="dense", phase=phase)
        bm = bm if bm is not None else cfg.block_m
        bn = bn if bn is not None else cfg.block_n
        bk = bk if bk is not None else cfg.block_k
    return bm, bn, bk


def _blocks_skip_impl(impl):
    def plan(w, m, phase, bm, bn, bk):
        # Pack-time tile shapes dictate the kernel's K/N blocks.
        if bn is not None and bn != w.tile_n:
            raise ValueError(f"impl={impl!r}: block_n={bn} must equal the "
                             f"pack's tile_n={w.tile_n}")
        if bk is not None and bk != w.tile_k:
            raise ValueError(f"impl={impl!r}: block_k={bk} must equal the "
                             f"pack's tile_k={w.tile_k}")
        if bm is None:
            bm = autotune_lib.get_tuner().lookup(
                m, w.k, w.n, sparsity=w.occupancy(), impl=impl,
                fixed_n=w.tile_n, fixed_k=w.tile_k, phase=phase).block_m
        return bm, w.tile_n, w.tile_k
    return plan


_blocks_skip = _blocks_skip_impl("skip")
_blocks_skip_db = _blocks_skip_impl("skip_db")


def _blocks_bitplane(impl):
    def plan(w, m, phase, bm, bn, bk):
        if bm is None or bn is None or bk is None:
            cfg = autotune_lib.get_tuner().lookup(
                m, w.k, w.n, impl=impl, phase=phase)
            bm = bm if bm is not None else cfg.block_m
            bn = bn if bn is not None else cfg.block_n
            bk = bk if bk is not None else cfg.block_k
        return bm, bn, bk
    return plan


def _no_blocks(w, m, phase, bm, bn, bk):
    return None, None, None


def _require_2d(w, *leaves):
    for leaf in leaves:
        if getattr(leaf, "ndim", 2) != 2:
            raise ValueError(
                f"{w.format_name} weight has stacked leaves "
                f"{tuple(leaf.shape)}; slice the stack (scan/vmap) down to "
                f"2-D before ternary_gemm")


# --- dense2bit lowerings ----------------------------------------------------

@register_kernel("dense2bit", "dense", priority=10,
                 plan_blocks=_blocks_dense)
def _lower_dense(plan, x, w, scale, bias):
    wp = jnp.asarray(w.packed)
    _require_2d(w, wp)
    return _gemm_2bit(x, wp[:, :w.n], scale, bias, None, None,
                      w.n, plan.block_m, plan.block_n, plan.block_k,
                      plan.fuse_prelu, plan.prelu_alpha, plan.interpret,
                      False)


@register_kernel("dense2bit", "ref", plan_blocks=_no_blocks)
def _lower_dense_ref(plan, x, w, scale, bias):
    wp = jnp.asarray(w.packed)
    _require_2d(w, wp)
    return ref.packed2bit_matmul(
        x, wp, w.k, alpha=scale, bias=bias,
        prelu_alpha=plan.prelu_alpha if plan.fuse_prelu else None)[:, :w.n]


# --- tiled lowerings --------------------------------------------------------

@register_kernel("tiled", "skip_db", priority=12,
                 predicate=lambda w, m, phase:
                     w.occupancy() <= SKIP_OCCUPANCY_CUTOFF,
                 plan_blocks=_blocks_skip_db)
def _lower_skip_db(plan, x, w, scale, bias):
    # Same occupied-tile walk as "skip", but the kernel stages each tile
    # through explicit double-buffered make_async_copy pipelines so the
    # next tile's DMA overlaps the current tile's MXU work (DESIGN.md §12).
    # Bitwise identical to "skip"/"dense" (same ascending-K accumulation).
    return _gemm_2bit(x, jnp.asarray(w.packed), scale, bias,
                      jnp.asarray(w.kt_indices), jnp.asarray(w.kt_counts),
                      w.n, plan.block_m, plan.block_n, plan.block_k,
                      plan.fuse_prelu, plan.prelu_alpha, plan.interpret,
                      True)


@register_kernel("tiled", "skip", priority=10,
                 predicate=lambda w, m, phase:
                     w.occupancy() <= SKIP_OCCUPANCY_CUTOFF,
                 plan_blocks=_blocks_skip)
def _lower_skip(plan, x, w, scale, bias):
    return _gemm_2bit(x, jnp.asarray(w.packed), scale, bias,
                      jnp.asarray(w.kt_indices), jnp.asarray(w.kt_counts),
                      w.n, plan.block_m, plan.block_n, plan.block_k,
                      plan.fuse_prelu, plan.prelu_alpha, plan.interpret,
                      False)


@register_kernel("tiled", "dense", priority=5, plan_blocks=_blocks_dense)
def _lower_tiled_dense(plan, x, w, scale, bias):
    # packed word columns map 1:1 to W columns -> drop the N padding
    return _gemm_2bit(x, jnp.asarray(w.packed)[:, :w.n], scale, bias,
                      None, None, w.n, plan.block_m, plan.block_n,
                      plan.block_k, plan.fuse_prelu, plan.prelu_alpha,
                      plan.interpret, False)


@register_kernel("tiled", "ref", plan_blocks=_no_blocks)
def _lower_tiled_ref(plan, x, w, scale, bias):
    return ref.packed2bit_matmul(
        x, jnp.asarray(w.packed)[:, :w.n], w.k, alpha=scale, bias=bias,
        prelu_alpha=plan.prelu_alpha if plan.fuse_prelu else None)


# --- bitplane lowerings -----------------------------------------------------

def _lower_bitplane_common(plan, x, w, scale, bias, factorized):
    plus, minus = jnp.asarray(w.plus), jnp.asarray(w.minus)
    _require_2d(w, plus)
    bm, bn, bk = plan.block_m, plan.block_n, plan.block_k
    xp = _pad_to(x, 1, plus.shape[0] * K_PER_BYTE)
    y = _gemm_bitplane(xp, plus, minus, scale, bm, bn, bk, factorized,
                       plan.interpret)
    if bias is not None:
        y = y + bias.reshape(1, -1).astype(y.dtype)
    if plan.fuse_prelu:
        y = jnp.where(y >= 0, y, jnp.asarray(plan.prelu_alpha, y.dtype) * y)
    return y


@register_kernel("bitplane", "bitplane", priority=10,
                 plan_blocks=_blocks_bitplane("bitplane"))
def _lower_bitplane(plan, x, w, scale, bias):
    return _lower_bitplane_common(plan, x, w, scale, bias, factorized=False)


@register_kernel("bitplane", "bitplane_factorized", priority=5,
                 plan_blocks=_blocks_bitplane("bitplane_factorized"))
def _lower_bitplane_fact(plan, x, w, scale, bias):
    return _lower_bitplane_common(plan, x, w, scale, bias, factorized=True)


@register_kernel("bitplane", "ref", plan_blocks=_no_blocks)
def _lower_bitplane_ref(plan, x, w, scale, bias):
    return ref.bitplane_matmul(
        x, jnp.asarray(w.plus), jnp.asarray(w.minus), w.k, alpha=scale,
        bias=bias,
        prelu_alpha=plan.prelu_alpha if plan.fuse_prelu else None)[:, :w.n]


# --- base3 lowering (the paper's value-compression format, ref-backed) ------

@register_kernel("base3", "ref", priority=10, plan_blocks=_no_blocks)
def _lower_base3_ref(plan, x, w, scale, bias):
    return ref.base3_matmul(
        x, jnp.asarray(w.packed), w.k, alpha=scale, bias=bias,
        prelu_alpha=plan.prelu_alpha if plan.fuse_prelu else None)[:, :w.n]


# ---------------------------------------------------------------------------
# Paged-attention kernel registry (DESIGN.md §9)
# ---------------------------------------------------------------------------
#
# Same registry discipline as the GEMM table above, for the paged KV-cache
# decode-attention lowerings: each impl registers a name, a priority and an
# admissibility predicate, and ``impl="auto"`` picks the best admissible one
# (the Pallas kernel on TPU backends, the gather + dense-identical JAX path
# elsewhere — the latter is what the paged-vs-dense token-exactness
# guarantee rests on). Lowerings live in ``repro.paging.kernels`` and
# register themselves on import.

@dataclasses.dataclass(frozen=True)
class PagedAttnImpl:
    """One registered paged decode-attention lowering."""

    impl: str
    priority: int
    predicate: Callable[..., bool]
    fn: Callable


_PAGED_ATTN: Dict[str, PagedAttnImpl] = {}


def register_paged_attn(impl: str, *, priority: int = 0,
                        predicate: Optional[Callable] = None):
    """Decorator registering a paged decode-attention lowering under
    ``impl``. ``predicate(q, k_pages, v_pages, block_table, lengths)``
    gates ``impl="auto"`` selection (highest admissible priority wins)."""

    def deco(fn):
        _PAGED_ATTN[impl] = PagedAttnImpl(
            impl=impl, priority=priority,
            predicate=predicate or (lambda *a, **k: True), fn=fn)
        return fn

    return deco


def paged_attention_registry() -> Dict[str, "PagedAttnImpl"]:
    """Snapshot of the registered paged-attention impl table.

    Example (doctest-runnable) — the two stock lowerings are always
    present, and each entry carries its selection metadata::

        >>> from repro.kernels import ops
        >>> table = ops.paged_attention_registry()
        >>> sorted(table)
        ['jax', 'pallas']
        >>> table["jax"].priority <= table["pallas"].priority
        True
    """
    _ensure_paged_impls()
    return dict(_PAGED_ATTN)


def _ensure_paged_impls() -> None:
    # the lowerings self-register on import; imported lazily so kernels.ops
    # stays importable without pulling the paging subsystem in
    import repro.paging.kernels  # noqa: F401


def paged_decode_attention(q, k_pages, v_pages, block_table, lengths, *,
                           window: int = 0, impl: str = "auto",
                           interpret: Optional[bool] = None):
    """Decode attention over block-table-indexed KV pages.

    q (B, H, hd); k_pages/v_pages (P, ps, KV, hd) arrays or
    ``paging.quant.Int8Pages``; block_table (B, T) int32; lengths (B,)
    int32 valid-token counts (including the current token). ``impl`` picks
    a registered lowering ("auto" = best admissible by priority)."""
    _ensure_paged_impls()
    if impl == "auto":
        cands = sorted(_PAGED_ATTN.values(), key=lambda pi: -pi.priority)
        chosen = next((pi for pi in cands
                       if pi.predicate(q, k_pages, v_pages, block_table,
                                       lengths)), cands[-1])
    else:
        chosen = _PAGED_ATTN.get(impl)
        if chosen is None:
            raise ValueError(f"no paged-attention impl {impl!r} registered; "
                             f"available: {sorted(_PAGED_ATTN)}")
    return chosen.fn(q, k_pages, v_pages, block_table, lengths,
                     window=window, interpret=interpret)


# ---------------------------------------------------------------------------
# The planner
# ---------------------------------------------------------------------------

def _coerce_weight(w: Any, k: Optional[int],
                   xk: Optional[int]) -> weights.TernaryWeight:
    """Accept only typed containers. The PR-3-era raw-operand union (raw
    packed word matrix / ``formats.TiledTernary`` / ``(plus, minus)``
    tuple) finished its deprecation cycle — name the migration target in
    the error instead of silently wrapping."""
    if isinstance(w, weights.TernaryWeight):
        return w
    if isinstance(w, formats.TiledTernary):
        hint = "weights.Tiled.from_tiled(w) or re-pack via weights.pack"
    elif isinstance(w, (tuple, list)) and len(w) == 2:
        hint = "weights.Bitplane.from_planes(plus, minus, k=K)"
    elif getattr(w, "ndim", 0) == 2:
        hint = ("weights.Dense2Bit.from_packed(w, k=K) or "
                "kernels.pack_weights(ternary)")
    else:
        hint = "repro.core.weights.pack(w, format)"
    raise TypeError(
        f"ternary_gemm no longer accepts raw weight operands "
        f"(got {type(w).__name__}); the DeprecationWarning shim was "
        f"removed after two release cycles. Pack into a typed container: "
        f"{hint}.")


def _validate_k(w: weights.TernaryWeight, xk: int, k: Optional[int]) -> None:
    """One K check for every format (the old dispatcher inferred K from x on
    the dense path but asserted on the operand for skip)."""
    if k is not None and k != w.k:
        raise ValueError(
            f"k={k} does not match the {w.format_name} weight's logical "
            f"K={w.k} (shape {w.shape})")
    if xk != w.k:
        raise ValueError(
            f"x has K={xk} columns but the {w.format_name} weight encodes "
            f"K={w.k} (shape {w.shape}); reshape x or repack the weight")


def ternary_gemm_plan(
    w: Any,
    m: int,
    *,
    k: Optional[int] = None,
    impl: str = "auto",
    phase: Optional[str] = "__current__",
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    fuse_prelu: bool = False,
    prelu_alpha: float = 0.25,
    interpret: Optional[bool] = None,
    partition: Optional[str] = None,
    tp: int = 1,
) -> GemmPlan:
    """Plan (but do not run) a ternary GEMM: registry + autotuner -> an
    inspectable ``GemmPlan``. ``phase`` defaults to the ambient
    ``serving_phase`` scope; ``k``, if given, is validated against the
    container. Planning uses only static container metadata, so it is
    trace-safe and cheap to precompute (the serving engine warms
    phase-keyed plans for every packed weight at build time).

    ``partition``/``tp`` plan one *shard* of a tensor-parallel GEMM
    (DESIGN.md §13): ``"k"`` row splits K ``tp`` ways and records the
    ``psum`` collective the partial products need; ``"n"`` column splits N
    with no collective. Shard boundaries must land on the container's pack
    multiples (``TernaryWeight.shard_constraints``) — the same rule
    ``weights.validate_spec_twin`` enforces on the spec twins.

    Example (doctest-runnable) — a sparse tiled pack below the occupancy
    cutoff selects the double-buffered skipping kernel, and the same
    weight plans independently per serving phase::

        >>> import numpy as np
        >>> from repro.core import weights
        >>> from repro.kernels import ops
        >>> t = np.sign(np.random.randn(512, 256))
        >>> t[:256] = 0                       # half the K tiles are empty
        >>> w = weights.pack(t, "tiled", tile_k=256, tile_n=128)
        >>> plan = ops.ternary_gemm_plan(w, m=64)
        >>> (plan.impl, plan.block_n, plan.block_k)
        ('skip_db', 128, 256)
        >>> ops.ternary_gemm_plan(w, m=8, phase="decode").phase
        'decode'
    """
    w = _coerce_weight(w, k, None)
    if phase == "__current__":
        phase = current_phase()
    interpret = _auto_interpret() if interpret is None else interpret
    if partition not in (None, "k", "n"):
        raise ValueError(f"partition must be 'k', 'n' or None, "
                         f"got {partition!r}")
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if tp == 1:
        partition = None
    if partition is not None:
        extent, multiple = w.shard_constraints()[partition]
        if extent % (tp * multiple) != 0:
            raise ValueError(
                f"{w.format_name} GEMM: {partition.upper()}-partitioning "
                f"{tp}-way puts shard boundaries every {extent / tp:g} of "
                f"{extent} values — off the {multiple}-value pack multiple; "
                f"repack or choose tp dividing {extent // multiple}")
    k_shard = w.k // tp if partition == "k" else w.k
    n_shard = w.n // tp if partition == "n" else w.n
    fmt = w.format_name
    if impl == "auto":
        cands = sorted((ki for ki in _KERNELS.values() if ki.format == fmt),
                       key=lambda ki: -ki.priority)
        if not cands:
            raise ValueError(f"no kernel registered for format {fmt!r}")
        chosen = next((ki for ki in cands if ki.predicate(w, m, phase)),
                      cands[-1])
    else:
        chosen = _KERNELS.get((fmt, impl))
        if chosen is None:
            avail = sorted(i for f, i in _KERNELS if f == fmt)
            raise ValueError(f"no impl {impl!r} registered for format "
                             f"{fmt!r}; available: {avail}")
    bm, bn, bk = chosen.plan_blocks(w, m, phase, block_m, block_n, block_k)
    if partition is not None:
        # per-shard tiles: clamp the global autotune blocks to the shard's
        # axis extent so the plan's tiling matches what one device runs
        bk = min(bk, k_shard) if bk else bk
        bn = min(bn, n_shard) if bn else bn
    return GemmPlan(format=fmt, impl=chosen.impl, m=m, k=k_shard, n=n_shard,
                    block_m=bm, block_n=bn, block_k=bk, phase=phase,
                    occupancy=w.occupancy(), interpret=interpret,
                    fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha,
                    partition=partition,
                    collective="psum" if partition == "k" else None,
                    tp=tp)


def precompute_plans(params, *, prefill_ms=(), decode_ms=(), verify_ms=(),
                     chunk_ms=(),
                     select: Optional[Callable] = None, impl: str = "auto",
                     shard: Optional[Callable] = None,
                     ) -> Dict[Tuple[int, ...], GemmPlan]:
    """Warm phase-keyed plans for ``TernaryWeight``s in a param tree.

    Called once at serving-engine build: every (weight, M-bucket, phase)
    combination the hot loop will dispatch gets its autotune entry resolved
    (and persisted) up front, so no serving step pays a first-call tune.
    ``select(path, w) -> bool`` filters which containers to plan — the
    engine selects only those that actually dispatch through
    ``ternary_gemm`` (packed linears), not containers a model materializes
    instead (MoE expert banks) — and ``impl`` should be the impl the apply
    path will dispatch (planning ``"ref"`` touches no autotune state).
    ``shard(path, w) -> (partition, tp)`` makes plans collective-aware
    under TP serving (``distributed.tp.gemm_shard_fn`` derives it from the
    placed arrays' shardings). Returns the plans keyed by
    (leaf index, m, phase) for introspection."""
    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda v: isinstance(v, weights.TernaryWeight))[0]
    ws = [(path, w) for path, w in flat
          if isinstance(w, weights.TernaryWeight)
          and (select is None or select(path, w))]
    plans: Dict[Tuple[int, ...], GemmPlan] = {}
    for i, (path, w) in enumerate(ws):
        part, ntp = shard(path, w) if shard is not None else (None, 1)
        for phase, ms in (("prefill", prefill_ms), ("decode", decode_ms),
                          ("verify", verify_ms), ("chunk", chunk_ms)):
            for m in ms:
                plans[(i, m, phase)] = ternary_gemm_plan(
                    w, m, impl=impl, phase=phase, partition=part, tp=ntp)
    return plans


# ---------------------------------------------------------------------------
# Fused MLP registry (DESIGN.md §12)
# ---------------------------------------------------------------------------
#
# Third registry, same discipline: ``fused_mlp`` runs the whole
# ``GEMM -> bias -> activation -> GEMM`` block through one registered
# lowering. ``"pallas"`` is the fused kernel (hidden activation resident in
# VMEM, weights streamed with double-buffered DMA); ``"chain"`` is the
# literal unfused call chain and covers every format the fused kernel does
# not. The two are pinned bitwise-equal (tests/test_fused_mlp.py), which
# is what lets ``models.layers.mlp_apply`` adopt the fusion transparently.

_FUSED_FORMATS = ("dense2bit", "tiled")


@dataclasses.dataclass(frozen=True)
class FusedMlpPlan:
    """Dispatch decision for one fused MLP block.

    ``block_n1/block_k1`` tile the up/gate projection, ``block_n2/
    block_k2`` the down projection; all are taken from the *chain's* own
    ``GemmPlan``s (via the fused autotune key), so the fused kernel tiles
    K identically to the unfused chain — the bitwise-equality contract.

    Under TP (``fused_mlp_plan(..., tp=)``) ``ff`` is the *per-shard*
    hidden width: up/gate column split the hidden dim, down row splits it
    back, and the single trailing ``psum`` (``collective``) reduces the
    partial outputs — the Megatron MLP layout (DESIGN.md §13)."""

    impl: str
    format_up: str
    format_down: str
    m: int
    k: int
    ff: int
    n: int
    gated: bool
    activation: str
    block_m: Optional[int]
    block_n1: Optional[int]
    block_k1: Optional[int]
    block_n2: Optional[int]
    block_k2: Optional[int]
    phase: Optional[str]
    occupancy_up: float
    occupancy_down: float
    interpret: bool
    collective: Optional[str] = None     # None | "psum"
    tp: int = 1

    def sub_plans(self) -> Tuple[GemmPlan, GemmPlan]:
        """The two chained ``GemmPlan``s this fusion replaces (gate shares
        the up plan) — the roofline baseline."""
        mk = dict(phase=self.phase, interpret=self.interpret, tp=self.tp)
        sharded = self.tp > 1
        up = GemmPlan(format=self.format_up, impl="dense", m=self.m,
                      k=self.k, n=self.ff, block_m=self.block_m,
                      block_n=self.block_n1, block_k=self.block_k1,
                      occupancy=self.occupancy_up,
                      partition="n" if sharded else None, **mk)
        down = GemmPlan(format=self.format_down, impl="dense", m=self.m,
                        k=self.ff, n=self.n, block_m=self.block_m,
                        block_n=self.block_n2, block_k=self.block_k2,
                        occupancy=self.occupancy_down,
                        partition="k" if sharded else None,
                        collective=self.collective, **mk)
        return up, down

    def roofline(self) -> Dict[str, float]:
        """Fused vs unfused roofline: the chain's HBM traffic (both GEMMs,
        plus the hidden activation's write + per-N-tile re-reads), the
        fused kernel's (x and each weight once per M tile, h never leaves
        VMEM), and the modeled speedup ratio the CI bench gates on."""
        up, down = self.sub_plans()
        n_up = 2 if self.gated else 1
        unfused_bytes = n_up * up.traffic()["bytes"] \
            + down.traffic()["bytes"]
        bm = self.block_m or 128
        mp = -(-self.m // bm) * bm
        m_tiles = mp // bm
        k1p = -(-self.k // (self.block_k1 or 256)) * (self.block_k1 or 256)
        ff1 = -(-self.ff // (self.block_n1 or 128)) * (self.block_n1 or 128)
        k2p = -(-self.ff // (self.block_k2 or 256)) * (self.block_k2 or 256)
        n2p = -(-self.n // (self.block_n2 or 128)) * (self.block_n2 or 128)
        w_up = (k1p // K_PER_WORD) * ff1 * 4
        w_down = (k2p // K_PER_WORD) * n2p * 4
        fused_bytes = float(
            mp * k1p * 2                      # x: once per M tile
            + m_tiles * (n_up * w_up + w_down)  # weights streamed per tile
            + mp * n2p * 2)                   # final output write
        nf1 = ff1 // (self.block_n1 or 128)
        nf2 = n2p // (self.block_n2 or 128)
        t_fused = (fused_bytes / autotune_lib.HBM_BW
                   + m_tiles * (nf1 + nf2) * 1e-6)
        tuner = autotune_lib.Autotuner()
        t_unfused = n_up * tuner._model_score(
            autotune_lib.BlockConfig(bm, self.block_n1 or 128,
                                     self.block_k1 or 256),
            self.m, self.k, self.ff, 1.0) \
            + tuner._model_score(
                autotune_lib.BlockConfig(bm, self.block_n2 or 128,
                                         self.block_k2 or 256),
                self.m, self.ff, self.n, 1.0)
        flops = 2.0 * self.m * self.ff * (n_up * self.k + self.n)
        ai = flops / max(fused_bytes, 1.0)
        ceiling = min(autotune_lib.PEAK_FLOPS, ai * autotune_lib.HBM_BW)
        achieved = flops / max(t_fused, 1e-12)
        coll = (2.0 * (self.tp - 1) / self.tp * self.m * self.n * 4
                if self.collective == "psum" and self.tp > 1 else 0.0)
        return {"flops": flops,
                "bytes": fused_bytes,
                "unfused_bytes": float(unfused_bytes),
                "collective": self.collective,
                "collective_bytes": coll,
                "tp": self.tp,
                "arithmetic_intensity": ai,
                "ceiling_flops": ceiling,
                "achieved_flops": achieved,
                "peak_flops": autotune_lib.PEAK_FLOPS,
                "model_time_s": t_fused,
                "unfused_model_time_s": t_unfused,
                "fused_speedup": t_unfused / max(t_fused, 1e-12),
                "headroom": max(0.0, 1.0 - achieved / max(ceiling, 1.0)),
                "bound": ("memory" if ceiling < autotune_lib.PEAK_FLOPS
                          else "compute")}


@dataclasses.dataclass(frozen=True)
class FusedImpl:
    """One registered fused-MLP lowering."""

    impl: str
    priority: int
    predicate: Callable[..., bool]
    fn: Callable


_FUSED: Dict[str, FusedImpl] = {}


def register_fused(impl: str, *, priority: int = 0,
                   predicate: Optional[Callable] = None):
    """Decorator registering a fused-MLP lowering under ``impl``.
    ``predicate(w_in, w_out, w_gate, m, phase)`` gates ``impl="auto"``
    selection (highest admissible priority wins)."""

    def deco(fn):
        _FUSED[impl] = FusedImpl(
            impl=impl, priority=priority,
            predicate=predicate or (lambda *a: True), fn=fn)
        return fn

    return deco


def fused_registry() -> Dict[str, FusedImpl]:
    """Snapshot of the registered fused-MLP impl table."""
    return dict(_FUSED)


def _fusable(w_in, w_out, w_gate, m, phase) -> bool:
    for w in (w_in, w_out) + (() if w_gate is None else (w_gate,)):
        if w.format_name not in _FUSED_FORMATS:
            return False
        if getattr(jnp.asarray(w.packed), "ndim", 2) != 2:
            return False
    if w_gate is not None:
        # the gate rides the up projection's strips: same shape required,
        # and its own chain plan must resolve the same K/N tiles
        if (w_gate.k, w_gate.n) != (w_in.k, w_in.n):
            return False
        up = ternary_gemm_plan(w_in, m, phase=phase)
        gate = ternary_gemm_plan(w_gate, m, phase=phase)
        if (up.block_n, up.block_k) != (gate.block_n, gate.block_k):
            return False
    return True


def fused_mlp_plan(w_in: Any, w_out: Any, w_gate: Any = None, *,
                   m: int, impl: str = "auto", activation: str = "silu",
                   phase: Optional[str] = "__current__",
                   interpret: Optional[bool] = None,
                   tp: int = 1) -> FusedMlpPlan:
    """Plan (but do not run) a fused MLP block; the fused analogue of
    ``ternary_gemm_plan``. Blocks resolve through the autotuner's fused
    key (``autotune.fused_cache_key``) pinned to the chain sub-plans'
    tiles, so fused and unfused tiling always agree. ``tp > 1`` plans one
    Megatron-MLP shard: the hidden dim is column split on the way up, row
    split on the way down, with an explicit trailing ``psum``."""
    w_in = _coerce_weight(w_in, None, None)
    w_out = _coerce_weight(w_out, None, None)
    if w_gate is not None:
        w_gate = _coerce_weight(w_gate, None, None)
    if w_out.k != w_in.n:
        raise ValueError(
            f"fused_mlp: down projection expects K={w_in.n} (the up "
            f"projection's N) but encodes K={w_out.k}")
    if w_gate is not None and (w_gate.k, w_gate.n) != (w_in.k, w_in.n):
        raise ValueError(
            f"fused_mlp: gate shape {(w_gate.k, w_gate.n)} must match the "
            f"up projection's {(w_in.k, w_in.n)}")
    assert activation in ACTIVATIONS, activation
    if tp < 1:
        raise ValueError(f"tp must be >= 1, got {tp}")
    if tp > 1:
        for which, wgt, dim in (("up N", w_in, "n"), ("down K", w_out, "k")):
            extent, multiple = wgt.shard_constraints()[dim]
            if extent % (tp * multiple) != 0:
                raise ValueError(
                    f"fused_mlp: {tp}-way TP splits the {which} axis every "
                    f"{extent / tp:g} of {extent} values — off the "
                    f"{multiple}-value pack multiple of {wgt.format_name}")
    ff_shard = w_in.n // tp
    if phase == "__current__":
        phase = current_phase()
    interpret = _auto_interpret() if interpret is None else interpret

    if impl == "auto":
        cands = sorted(_FUSED.values(), key=lambda fi: -fi.priority)
        if not cands:
            raise ValueError("no fused-MLP lowerings registered")
        chosen = next((fi for fi in cands
                       if fi.predicate(w_in, w_out, w_gate, m, phase)),
                      cands[-1])
    else:
        chosen = _FUSED.get(impl)
        if chosen is None:
            raise ValueError(f"no fused-MLP impl {impl!r} registered; "
                             f"available: {sorted(_FUSED)}")

    bm = bn1 = bk1 = bn2 = bk2 = None
    if chosen.impl == "pallas":
        up = ternary_gemm_plan(w_in, m, phase=phase, interpret=interpret,
                               partition="n" if tp > 1 else None, tp=tp)
        down = ternary_gemm_plan(w_out, m, phase=phase, interpret=interpret,
                                 partition="k" if tp > 1 else None, tp=tp)
        cfg = autotune_lib.get_tuner().lookup_fused(
            m, w_in.k, ff_shard, w_out.n,
            sparsity_up=w_in.occupancy(), sparsity_down=w_out.occupancy(),
            fixed_n1=up.block_n, fixed_k1=up.block_k,
            fixed_n2=down.block_n, fixed_k2=down.block_k, phase=phase)
        bm, bn1, bk1 = cfg.block_m, cfg.block_n1, cfg.block_k1
        bn2, bk2 = cfg.block_n2, cfg.block_k2
    return FusedMlpPlan(
        impl=chosen.impl, format_up=w_in.format_name,
        format_down=w_out.format_name, m=m, k=w_in.k, ff=ff_shard,
        n=w_out.n, gated=w_gate is not None, activation=activation,
        block_m=bm, block_n1=bn1, block_k1=bk1, block_n2=bn2,
        block_k2=bk2, phase=phase, occupancy_up=w_in.occupancy(),
        occupancy_down=w_out.occupancy(), interpret=interpret,
        collective="psum" if tp > 1 else None, tp=tp)


def _apply_act(name: str, y: jnp.ndarray) -> jnp.ndarray:
    if name == "silu":
        return jax.nn.silu(y)
    if name == "relu":
        return jax.nn.relu(y)
    return y


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(10, 11, 12, 13, 14, 15, 16, 17, 18))
def _fused_2bit(x, wi_p, wo_p, wg_p, si, bi, sg, bg, so, bo,
                n, ff, bm, bn1, bk1, bn2, bk2, activation, interpret):
    return fused_mlp_pallas(
        x, wi_p, wo_p, wg_p, scale_i=si, bias_i=bi, scale_g=sg, bias_g=bg,
        scale_o=so, bias_o=bo, n=n, ff=ff, block_m=bm, block_n1=bn1,
        block_k1=bk1, block_n2=bn2, block_k2=bk2, activation=activation,
        interpret=interpret)


def _fused_2bit_fwd(x, wi_p, wo_p, wg_p, si, bi, sg, bg, so, bo, *static):
    y = _fused_2bit(x, wi_p, wo_p, wg_p, si, bi, sg, bg, so, bo, *static)
    return y, (x, wi_p, wo_p, wg_p, si, bi, sg, bg, so, bo)


def _fused_2bit_bwd(n, ff, bm, bn1, bk1, bn2, bk2, activation, interpret,
                    res, g):
    # Differentiate a reference chain over the decoded weights: packed
    # operands are non-differentiable (same contract as _gemm_2bit_bwd),
    # everything else routes through jax.vjp of the float chain.
    x, wi_p, wo_p, wg_p, si, bi, sg, bg, so, bo = res
    k = x.shape[1]
    ti = formats.decode_2bit(wi_p, k, dtype=x.dtype)[:, :ff]
    to = formats.decode_2bit(wo_p, ff, dtype=x.dtype)[:, :n]
    tg = (None if wg_p is None
          else formats.decode_2bit(wg_p, k, dtype=x.dtype)[:, :ff])

    def epi(y, s, b):
        if s is not None:
            y = y * s.reshape(1, -1).astype(y.dtype)
        if b is not None:
            y = y + b.reshape(1, -1).astype(y.dtype)
        return y

    def chain(d):
        yi = epi(jnp.dot(d["x"], ti, preferred_element_type=jnp.float32),
                 d.get("si"), d.get("bi"))
        if tg is not None:
            yg = epi(jnp.dot(d["x"], tg,
                             preferred_element_type=jnp.float32),
                     d.get("sg"), d.get("bg"))
            h = _apply_act(activation, yg) * yi
        else:
            h = _apply_act(activation, yi)
        h = h.astype(x.dtype)
        return epi(jnp.dot(h, to, preferred_element_type=jnp.float32),
                   d.get("so"), d.get("bo")).astype(x.dtype)

    diff = {"x": x}
    for name, v in (("si", si), ("bi", bi), ("sg", sg), ("bg", bg),
                    ("so", so), ("bo", bo)):
        if v is not None:
            diff[name] = v
    _, vjp = jax.vjp(chain, diff)
    (gd,) = vjp(g)
    return (gd["x"], jnp.zeros_like(wi_p), jnp.zeros_like(wo_p),
            None if wg_p is None else jnp.zeros_like(wg_p),
            gd.get("si"), gd.get("bi"), gd.get("sg"), gd.get("bg"),
            gd.get("so"), gd.get("bo"))


_fused_2bit.defvjp(_fused_2bit_fwd, _fused_2bit_bwd)


@register_fused("pallas", priority=10, predicate=_fusable)
def _lower_fused_pallas(plan, x, w_in, w_out, w_gate):
    wi = jnp.asarray(w_in.packed)[:, :w_in.n]
    wo = jnp.asarray(w_out.packed)[:, :w_out.n]
    wg = None if w_gate is None else jnp.asarray(w_gate.packed)[:, :w_gate.n]
    return _fused_2bit(
        x, wi, wo, wg, w_in.scale, w_in.bias,
        None if w_gate is None else w_gate.scale,
        None if w_gate is None else w_gate.bias,
        w_out.scale, w_out.bias,
        plan.n, plan.ff, plan.block_m, plan.block_n1, plan.block_k1,
        plan.block_n2, plan.block_k2, plan.activation, plan.interpret)


@register_fused("chain", priority=0)
def _lower_fused_chain(plan, x, w_in, w_out, w_gate):
    # The literal unfused chain: the bitwise-equality oracle for the fused
    # kernel, and the fallback for formats it does not cover (bitplane,
    # base3, stacked leaves). Each GEMM dispatches through the normal
    # registry, so this is exactly what mlp_apply did before fusion.
    yi = ternary_gemm(x, w_in, interpret=plan.interpret)
    if w_gate is not None:
        yg = ternary_gemm(x, w_gate, interpret=plan.interpret)
        h = _apply_act(plan.activation, yg) * yi
    else:
        h = _apply_act(plan.activation, yi)
    return ternary_gemm(h, w_out, interpret=plan.interpret)


def fused_mlp(x: jnp.ndarray, w_in: Any, w_out: Any, w_gate: Any = None,
              *, activation: str = "silu", impl: str = "auto",
              interpret: Optional[bool] = None) -> jnp.ndarray:
    """Fused ternary MLP block: ``act(x @ Wg) * (x @ Wi) @ Wo`` (gate
    optional; ``act(x @ Wi) @ Wo`` without it), scale/bias taken from each
    container's own metadata.

    ``impl="pallas"`` keeps the hidden activation in VMEM for the whole
    block; ``impl="chain"`` is the unfused call chain; ``"auto"`` picks
    the best admissible lowering — both produce bitwise-identical outputs,
    so the choice is purely a bandwidth decision (see
    ``FusedMlpPlan.roofline``)."""
    if x.ndim != 2:
        raise ValueError(f"fused_mlp expects 2-D x, got {x.shape}; "
                         f"reshape leading dims into M first")
    plan = fused_mlp_plan(w_in, w_out, w_gate, m=x.shape[0], impl=impl,
                          activation=activation, interpret=interpret)
    w_in = _coerce_weight(w_in, None, None)
    w_out = _coerce_weight(w_out, None, None)
    if w_gate is not None:
        w_gate = _coerce_weight(w_gate, None, None)
    if x.shape[1] != w_in.k:
        raise ValueError(f"x has K={x.shape[1]} but the up projection "
                         f"encodes K={w_in.k}")
    probe = _KERNEL_PROBE.get()
    if probe is not None and not isinstance(x, jax.core.Tracer):
        return _probe_dispatch(
            probe, plan,
            f"fused_mlp[{plan.impl} m={plan.m} k={plan.k} ff={plan.ff}]",
            lambda: _FUSED[plan.impl].fn(plan, x, w_in, w_out, w_gate))
    return _FUSED[plan.impl].fn(plan, x, w_in, w_out, w_gate)


def precompute_fused_plans(params, *, prefill_ms=(), decode_ms=(),
                           verify_ms=(), chunk_ms=(), impl: str = "auto",
                           tp: int = 1,
                           ) -> Dict[Tuple[int, ...], FusedMlpPlan]:
    """Warm phase-keyed *fused* plans for MLP-shaped subtrees: any dict
    with packed ``"in"``/``"out"`` (and optionally ``"gate"``) linears.
    The fused analogue of ``precompute_plans`` — the serving engine calls
    both at build time so no hot-loop dispatch pays a first-call tune.

    Scan-stacked containers ((L, K/16, N) leaves) plan through their
    layer-0 slice: inside the scan each step sees the 2-D per-layer view,
    and that — not the stacked tree — is what dispatch keys on."""
    found = []

    def _container(node):
        if isinstance(node, dict):
            w = node.get("w_packed")
            if isinstance(w, weights.TernaryWeight):
                words = getattr(w, "packed", getattr(w, "plus", None))
                if words is not None and words.ndim == 3:
                    return jax.tree_util.tree_map(lambda a: a[0], w)
                return w
        return None

    def walk(node):
        if isinstance(node, dict):
            wi, wo = _container(node.get("in")), _container(node.get("out"))
            if wi is not None and wo is not None and wo.k == wi.n:
                found.append((wi, wo, _container(node.get("gate"))))
            for v in node.values():
                walk(v)
        elif isinstance(node, (list, tuple)):
            for v in node:
                walk(v)

    walk(params)
    plans: Dict[Tuple[int, ...], FusedMlpPlan] = {}
    for i, (wi, wo, wg) in enumerate(found):
        for phase, ms in (("prefill", prefill_ms), ("decode", decode_ms),
                          ("verify", verify_ms), ("chunk", chunk_ms)):
            for m in ms:
                plans[(i, m, phase)] = fused_mlp_plan(
                    wi, wo, wg, m=m, impl=impl, phase=phase, tp=tp)
    return plans


# ---------------------------------------------------------------------------
# The public op
# ---------------------------------------------------------------------------

def ternary_gemm(
    x: jnp.ndarray,
    w: Any,
    scale: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    k: Optional[int] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    fuse_prelu: bool = False,
    prelu_alpha: float = 0.25,
    interpret: Optional[bool] = None,
    impl: str = "auto",
) -> jnp.ndarray:
    """Y = X @ decode(w) * scale + bias (+PReLU). Any (M, K, N).

    ``w`` is a ``repro.core.weights.TernaryWeight`` (raw operands raise
    ``TypeError`` — pack via ``weights.pack``); ``scale``/``bias`` default
    to the container's own metadata. ``impl`` selects a registered
    lowering explicitly ("auto" plans by format/occupancy/phase — see
    module docstring); ``block_*`` left ``None`` consult the autotuner.
    ``k`` is redundant with the container and validated against it.
    """
    w = _coerce_weight(w, k, x.shape[1])
    _validate_k(w, x.shape[1], k)
    scale = w.scale if scale is None else scale
    bias = w.bias if bias is None else bias
    plan = ternary_gemm_plan(
        w, x.shape[0], impl=impl, block_m=block_m, block_n=block_n,
        block_k=block_k, fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha,
        interpret=interpret)
    probe = _KERNEL_PROBE.get()
    if probe is not None and not isinstance(x, jax.core.Tracer):
        return _probe_dispatch(
            probe, plan,
            f"ternary_gemm[{plan.format}/{plan.impl} m={plan.m} "
            f"k={plan.k} n={plan.n}]",
            lambda: _KERNELS[(plan.format, plan.impl)].lower(
                plan, x, w, scale, bias))
    return _KERNELS[(plan.format, plan.impl)].lower(plan, x, w, scale, bias)
