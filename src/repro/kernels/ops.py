"""jit'd public wrappers + the unified dispatcher for the ternary GEMM
kernels.

``ternary_gemm`` is the user-facing op. It accepts the weight operand in any
of the kernel formats and routes to the right Pallas kernel:

* ``(K/16, N) uint32`` packed 2-bit codes      -> dense-decode kernel;
* ``formats.TiledTernary``                     -> sparsity-adaptive skipping
  kernel (scalar-prefetch over pack-time occupancy metadata, DESIGN.md §3),
  falling back to dense when the weight is effectively dense;
* ``(plus, minus)`` uint8 bitplane pair        -> bitplane kernel, optionally
  the plane-factorized ``Y = (X @ P) - (X @ M)`` MXU path (DESIGN.md §4).

``impl`` selects explicitly ("dense" | "skip" | "bitplane" |
"bitplane_factorized" | "ref"); the default "auto" picks by format and
occupancy. Block shapes left as ``None`` are resolved by the autotuner
(``kernels.autotune``), keyed on (M, K, N, sparsity, impl).

Each path pads to tile multiples, picks interpret mode off the backend (CPU
container -> interpret=True; real TPU -> compiled Mosaic), and defines a
custom VJP so the op is usable under ``jax.grad`` (dY/dX = g @ T^T; packed
weights are non-differentiable -- training uses the QAT/STE latent-weight
path in ``core.quantize``).
"""
from __future__ import annotations

import contextlib
import contextvars
import functools
from typing import Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats
from repro.kernels import ref
from repro.kernels import autotune as autotune_lib
from repro.kernels.ternary_gemm import (K_PER_WORD, ternary_gemm_pallas,
                                        ternary_gemm_skip_pallas)
from repro.kernels.ternary_gemm_bitplane import (K_PER_BYTE,
                                                 ternary_gemm_bitplane)

__all__ = ["ternary_gemm", "pack_weights", "pack_weights_tiled",
           "TernaryGemmConfig", "serving_phase", "current_phase"]

WORDS = 32

# Serving-phase tag consumed at trace time: prefill GEMMs are M=B·L
# GEMM-shaped, decode GEMMs are M=slots GEMV-shaped, and the two must not
# share (and thrash) one autotune entry even when their bucketed M collides.
_SERVING_PHASE: contextvars.ContextVar[Optional[str]] = \
    contextvars.ContextVar("repro_serving_phase", default=None)


@contextlib.contextmanager
def serving_phase(phase: Optional[str]):
    """Tag ``ternary_gemm`` dispatches traced inside this scope as
    ``"prefill"`` or ``"decode"`` so the autotuner keys them separately
    (the serving engine wraps its prefill/decode jit calls in this)."""
    assert phase in (None, "prefill", "decode"), phase
    token = _SERVING_PHASE.set(phase)
    try:
        yield
    finally:
        _SERVING_PHASE.reset(token)


def current_phase() -> Optional[str]:
    return _SERVING_PHASE.get()

# Above this occupied-tile fraction the skipping grid saves too little to
# justify the scalar-prefetch indirection; "auto" falls back to dense.
SKIP_OCCUPANCY_CUTOFF = 0.875

WeightOperand = Union[jnp.ndarray, np.ndarray, formats.TiledTernary,
                      Tuple[jnp.ndarray, jnp.ndarray]]


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pack_weights(t: np.ndarray) -> np.ndarray:
    """Host-side: (K, N) {-1,0,1} -> (ceil(K/16), N) uint32 kernel format."""
    return formats.pack_2bit(np.asarray(t), word=WORDS)


def pack_weights_tiled(t: np.ndarray, tile_k: int = 256,
                       tile_n: int = 128) -> formats.TiledTernary:
    """Host-side: (K, N) {-1,0,1} -> TiledTernary (packed words + per-tile
    occupancy metadata) for the skipping kernel."""
    return formats.TiledTernary.from_dense(np.asarray(t), tile_k=tile_k,
                                           tile_n=tile_n)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# 2-bit-code family (dense + skipping share the packed format and the VJP)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10, 11, 12))
def _gemm_2bit(x, w_packed, scale, bias, kt_idx, kt_cnt,
               n, block_m, block_n, block_k, fuse_prelu, prelu_alpha,
               interpret):
    """Forward: dense kernel when kt_idx is None, else the skipping kernel.
    Returns the (m, n)-sliced logical output."""
    m = x.shape[0]
    bm = min(block_m, max(8, 1 << (m - 1).bit_length()))
    sp = None if scale is None else _pad_to(scale.reshape(-1), 0, block_n)
    bp = None if bias is None else _pad_to(bias.reshape(-1), 0, block_n)
    # x's K must first match the packed operand's (possibly padded) K — the
    # word rows can exceed ceil(k/block_k)*block_k when the pack used a
    # larger tile_k than the resolved block_k.
    kp = w_packed.shape[0] * K_PER_WORD
    xp = _pad_to(_pad_to(x, 1, kp), 0, bm)
    if kt_idx is None:
        xp = _pad_to(xp, 1, block_k)
        wp = _pad_to(_pad_to(w_packed, 0, block_k // K_PER_WORD), 1, block_n)
        y = ternary_gemm_pallas(
            xp, wp, sp, bp, block_m=bm, block_n=block_n, block_k=block_k,
            fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha,
            interpret=interpret)
    else:
        y = ternary_gemm_skip_pallas(
            xp, w_packed, kt_idx, kt_cnt, sp, bp,
            block_m=bm, block_n=block_n, block_k=block_k,
            fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha,
            interpret=interpret)
    return y[:m, :n]


def _gemm_2bit_fwd(x, w_packed, scale, bias, kt_idx, kt_cnt, *static):
    y = _gemm_2bit(x, w_packed, scale, bias, kt_idx, kt_cnt, *static)
    fuse_prelu = static[4]
    return y, (x, w_packed, scale, bias, kt_idx, kt_cnt,
               y if fuse_prelu else None)


def _gemm_2bit_bwd(n, bm, bn, bk, fuse_prelu, prelu_alpha, interpret,
                   res, g):
    x, w_packed, scale, bias, kt_idx, kt_cnt, y = res
    kk = x.shape[1]  # logical K is x's trailing dim (x is unpadded)
    if fuse_prelu:
        g = jnp.where(y >= 0, g, prelu_alpha * g)
    # Bias grad exists only when a bias operand exists (scale is irrelevant).
    gb = (None if bias is None
          else jnp.sum(g, axis=0).astype(bias.dtype).reshape(bias.shape))
    t = formats.decode_2bit(w_packed, kk, dtype=x.dtype)[:, :n]
    if scale is not None:
        # dL/dscale = sum_m g * (x @ T): exact, costs one decode+matmul.
        ylin = jnp.dot(x, t, preferred_element_type=jnp.float32)
        gscale = jnp.sum(g.astype(jnp.float32) * ylin, axis=0).astype(
            scale.dtype).reshape(scale.shape)
        g = g * scale.reshape(1, -1).astype(g.dtype)
    else:
        gscale = None
    gx = jnp.dot(g, t.T, preferred_element_type=jnp.float32).astype(x.dtype)
    return (gx, jnp.zeros_like(w_packed), gscale, gb,
            None if kt_idx is None else jnp.zeros_like(kt_idx),
            None if kt_cnt is None else jnp.zeros_like(kt_cnt))


_gemm_2bit.defvjp(_gemm_2bit_fwd, _gemm_2bit_bwd)


# ---------------------------------------------------------------------------
# Bitplane family (combined decode / plane-factorized)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _gemm_bitplane(x, plus, minus, scale, block_m, block_n, block_k,
                   factorized, interpret):
    return ternary_gemm_bitplane(
        x, plus, minus, scale, block_m=block_m, block_n=block_n,
        block_k=block_k, factorized=factorized, interpret=interpret)


def _gemm_bitplane_fwd(x, plus, minus, scale, *static):
    y = _gemm_bitplane(x, plus, minus, scale, *static)
    return y, (x, plus, minus, scale)


def _gemm_bitplane_bwd(bm, bn, bk, factorized, interpret, res, g):
    x, plus, minus, scale = res
    kk = x.shape[1]
    t = formats.decode_bitplanes(plus, minus, kk, dtype=x.dtype)
    t = t[:, :g.shape[1]]
    if scale is not None:
        ylin = jnp.dot(x, t, preferred_element_type=jnp.float32)
        gscale = jnp.sum(g.astype(jnp.float32) * ylin, axis=0).astype(
            scale.dtype).reshape(scale.shape)
        g = g * scale.reshape(1, -1).astype(g.dtype)
    else:
        gscale = None
    gx = jnp.dot(g, t.T, preferred_element_type=jnp.float32).astype(x.dtype)
    return gx, jnp.zeros_like(plus), jnp.zeros_like(minus), gscale


_gemm_bitplane.defvjp(_gemm_bitplane_fwd, _gemm_bitplane_bwd)


# ---------------------------------------------------------------------------
# The dispatcher
# ---------------------------------------------------------------------------

def _resolve_impl(w: WeightOperand, impl: str) -> str:
    if isinstance(w, formats.TiledTernary):
        if impl == "auto":
            return ("skip"
                    if w.occupancy_fraction() <= SKIP_OCCUPANCY_CUTOFF
                    else "dense")
        return impl
    if isinstance(w, (tuple, list)):
        return {"auto": "bitplane"}.get(impl, impl)
    return {"auto": "dense"}.get(impl, impl)


def ternary_gemm(
    x: jnp.ndarray,
    w: WeightOperand,
    scale: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    k: Optional[int] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    fuse_prelu: bool = False,
    prelu_alpha: float = 0.25,
    interpret: Optional[bool] = None,
    impl: str = "auto",
) -> jnp.ndarray:
    """Y = X @ decode(w) * scale + bias (+PReLU). Any (M, K, N).

    ``w`` is a packed uint32 code matrix, a ``formats.TiledTernary``, or a
    ``(plus, minus)`` bitplane pair; ``impl`` routes (see module docstring).
    ``block_*`` left as ``None`` consult the autotuner.
    """
    interpret = _auto_interpret() if interpret is None else interpret
    impl = _resolve_impl(w, impl)
    m = x.shape[0]
    tuner = autotune_lib.get_tuner()
    phase = current_phase()

    if impl == "skip":
        assert isinstance(w, formats.TiledTernary), \
            "impl='skip' needs a TiledTernary weight operand"
        kk, n = w.shape
        assert k is None or k == kk, (k, kk)
        # Pack-time tile shapes dictate the kernel's K/N blocks.
        assert block_n is None or block_n == w.tile_n, (block_n, w.tile_n)
        assert block_k is None or block_k == w.tile_k, (block_k, w.tile_k)
        bm = block_m if block_m is not None else tuner.lookup(
            m, kk, n, sparsity=w.occupancy_fraction(), impl="skip",
            fixed_n=w.tile_n, fixed_k=w.tile_k, phase=phase).block_m
        return _gemm_2bit(x, jnp.asarray(w.packed), scale, bias,
                          jnp.asarray(w.kt_indices), jnp.asarray(w.kt_counts),
                          n, bm, w.tile_n, w.tile_k,
                          fuse_prelu, prelu_alpha, interpret)

    if impl in ("bitplane", "bitplane_factorized"):
        assert isinstance(w, (tuple, list)) and len(w) == 2, \
            f"impl={impl!r} needs a (plus, minus) bitplane pair"
        plus, minus = w
        kb, n = plus.shape
        kk = x.shape[1] if k is None else k
        assert kb * K_PER_BYTE >= kk
        if block_m is None or block_n is None or block_k is None:
            cfg = tuner.lookup(m, kk, n, impl=impl, phase=phase)
            block_m = block_m if block_m is not None else cfg.block_m
            block_n = block_n if block_n is not None else cfg.block_n
            block_k = block_k if block_k is not None else cfg.block_k
        bm, bn, bk = block_m, block_n, block_k
        xp = _pad_to(x, 1, kb * K_PER_BYTE)
        y = _gemm_bitplane(xp, plus, minus, scale, bm, bn, bk,
                           impl == "bitplane_factorized", interpret)
        if bias is not None:
            y = y + bias.reshape(1, -1).astype(y.dtype)
        if fuse_prelu:
            y = jnp.where(y >= 0, y, jnp.asarray(prelu_alpha, y.dtype) * y)
        return y

    # 2-bit-code paths ("dense" / "ref")
    if isinstance(w, formats.TiledTernary):
        # packed word columns map 1:1 to W columns -> drop the N padding
        w_packed = jnp.asarray(w.packed)[:, :w.shape[1]]
    else:
        w_packed = w
    kw, n = w_packed.shape
    kk = x.shape[1] if k is None else k
    assert kw * K_PER_WORD >= kk, (kw, kk)

    if impl == "ref":
        return ref.packed2bit_matmul(
            x, w_packed, kk, alpha=scale, bias=bias,
            prelu_alpha=prelu_alpha if fuse_prelu else None)[:, :n]

    assert impl == "dense", f"unknown impl {impl!r}"
    if block_m is None or block_n is None or block_k is None:
        sparsity = (w.occupancy_fraction()
                    if isinstance(w, formats.TiledTernary) else 1.0)
        cfg = tuner.lookup(m, kk, n, sparsity=sparsity, impl="dense",
                           phase=phase)
        block_m = block_m if block_m is not None else cfg.block_m
        block_n = block_n if block_n is not None else cfg.block_n
        block_k = block_k if block_k is not None else cfg.block_k
    bm, bn, bk = block_m, block_n, block_k
    return _gemm_2bit(x, w_packed, scale, bias, None, None,
                      n, bm, bn, bk, fuse_prelu, prelu_alpha, interpret)


class TernaryGemmConfig:
    """Block-shape configuration record used by the benchmark sweeps
    (the TPU analogue of the paper's unroll-factor grid search, Figs 2-4)."""

    def __init__(self, block_m=128, block_n=128, block_k=512):
        self.block_m, self.block_n, self.block_k = block_m, block_n, block_k

    def vmem_bytes(self, dtype_bytes=2) -> int:
        return autotune_lib.BlockConfig(
            self.block_m, self.block_n, self.block_k).vmem_bytes(dtype_bytes)
