"""jit'd public wrappers around the ternary GEMM kernel.

``ternary_gemm`` is the user-facing op: it pads to tile multiples, picks
interpret mode off the backend (CPU container -> interpret=True; real TPU ->
compiled Mosaic), and defines a custom VJP so the op is usable under
``jax.grad`` (dY/dX = g @ T^T; packed weights are non-differentiable --
training uses the QAT/STE latent-weight path in ``core.quantize``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats
from repro.kernels import ref
from repro.kernels.ternary_gemm import K_PER_WORD, ternary_gemm_pallas

__all__ = ["ternary_gemm", "pack_weights", "TernaryGemmConfig"]


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pack_weights(t: np.ndarray) -> np.ndarray:
    """Host-side: (K, N) {-1,0,1} -> (ceil(K/16), N) uint32 kernel format."""
    return formats.pack_2bit(np.asarray(t), word=WORDS)


WORDS = 32


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8, 9, 10))
def ternary_gemm(
    x: jnp.ndarray,
    w_packed: jnp.ndarray,
    scale: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    k: Optional[int] = None,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    fuse_prelu: bool = False,
    prelu_alpha: float = 0.25,
    interpret: Optional[bool] = None,
) -> jnp.ndarray:
    """Y = X @ decode(w_packed) * scale + bias (+PReLU). Any (M, K, N)."""
    m, kx = x.shape
    k = kx if k is None else k
    kw, n = w_packed.shape
    assert kw * K_PER_WORD >= k
    interpret = _auto_interpret() if interpret is None else interpret

    bm = min(block_m, max(8, 1 << (m - 1).bit_length()))
    xp = _pad_to(_pad_to(x, 0, bm), 1, block_k)
    wp = _pad_to(_pad_to(w_packed, 0, block_k // K_PER_WORD), 1, block_n)
    sp = None if scale is None else _pad_to(scale.reshape(-1), 0, block_n)
    bp = None if bias is None else _pad_to(bias.reshape(-1), 0, block_n)

    y = ternary_gemm_pallas(
        xp, wp, sp, bp,
        block_m=bm, block_n=block_n, block_k=block_k,
        fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha, interpret=interpret)
    return y[:m, :n]


def _fwd(x, w_packed, scale, bias, k, bm, bn, bk, fuse_prelu, prelu_alpha,
         interpret):
    y = ternary_gemm(x, w_packed, scale, bias, k, bm, bn, bk, fuse_prelu,
                     prelu_alpha, interpret)
    return y, (x, w_packed, scale, y if fuse_prelu else None)


def _bwd(k, bm, bn, bk, fuse_prelu, prelu_alpha, interpret, res, g):
    x, w_packed, scale, y = res
    kk = x.shape[1] if k is None else k
    if fuse_prelu:
        g = jnp.where(y >= 0, g, prelu_alpha * g)
    gb = jnp.sum(g, axis=0)                       # bias grad
    if scale is not None:
        # y_pre_scale is not stored; scale grad via recompute-free identity:
        # dL/dscale = sum_m g * (x @ T)  = sum_m g * (y_lin); approximate via
        # decode path (exact, costs one decode+matmul).
        t = formats.decode_2bit(w_packed, kk, dtype=x.dtype)
        ylin = jnp.dot(x, t, preferred_element_type=jnp.float32)
        gscale = jnp.sum(g.astype(jnp.float32) * ylin, axis=0).astype(
            scale.dtype).reshape(scale.shape)
        g = g * scale.reshape(1, -1).astype(g.dtype)
        gx = jnp.dot(g, t.T, preferred_element_type=jnp.float32).astype(x.dtype)
    else:
        t = formats.decode_2bit(w_packed, kk, dtype=x.dtype)
        gscale = None
        gx = jnp.dot(g, t.T, preferred_element_type=jnp.float32).astype(x.dtype)
    return (gx, jnp.zeros_like(w_packed), gscale,
            None if res[2] is None and gb is None else gb)


ternary_gemm.defvjp(_fwd, _bwd)


class TernaryGemmConfig:
    """Block-shape configuration record used by the benchmark sweeps
    (the TPU analogue of the paper's unroll-factor grid search, Figs 2-4)."""

    def __init__(self, block_m=128, block_n=128, block_k=512):
        self.block_m, self.block_n, self.block_k = block_m, block_n, block_k

    def vmem_bytes(self, dtype_bytes=2) -> int:
        x = self.block_m * self.block_k * dtype_bytes
        w = (self.block_k // K_PER_WORD) * self.block_n * 4
        dec = self.block_k * self.block_n * dtype_bytes
        acc = self.block_m * self.block_n * 4
        out = self.block_m * self.block_n * dtype_bytes
        return x + w + dec + acc + out
