"""jit'd public wrappers + registry-dispatched planning for the ternary
GEMM kernels.

``ternary_gemm(x, w)`` is the user-facing op; ``w`` is a
``repro.core.weights.TernaryWeight`` container (``Dense2Bit`` / ``Tiled`` /
``Bitplane`` / ``Base3``). Dispatch is two-stage:

1. **plan** — ``ternary_gemm_plan`` consults the kernel registry: each
   lowering registers ``(format, impl)`` with a priority and a capability
   predicate (shape / serving phase / pack-time occupancy), and the planner
   picks the best admissible impl for ``impl="auto"`` (e.g. the skipping
   kernel only below ``SKIP_OCCUPANCY_CUTOFF`` tile occupancy). Block
   shapes left ``None`` are resolved by the autotuner
   (``kernels.autotune``), keyed on (M, K, N, occupancy, impl, phase). The
   resulting ``GemmPlan`` is an inspectable value object (tests and
   benchmarks assert on it directly).
2. **lower** — the registered lowering for ``(plan.format, plan.impl)``
   runs the Pallas kernel (interpret mode off-TPU) or the XLA reference.

Registered impls:

* ``dense2bit``: ``dense`` (Pallas dense-decode), ``ref``;
* ``tiled``:     ``skip`` (scalar-prefetch tile skipping, DESIGN.md §3),
                 ``dense`` fallback, ``ref``;
* ``bitplane``:  ``bitplane``, ``bitplane_factorized`` (MXU
                 ``Y=(X@P)-(X@M)``, DESIGN.md §4), ``ref``;
* ``base3``:     ``ref`` (LUT-gather decode — the paper's dropped format,
                 kept dispatchable for the benchmark record).

New formats/kernels plug in via ``weights.register_format`` +
``register_kernel`` without touching any call site.

**Deprecation shim**: the pre-container operand union (raw ``(K/16, N)``
uint32 code matrix, ``formats.TiledTernary``, ``(plus, minus)`` tuple) is
still accepted — it is wrapped into the equivalent container with a
``DeprecationWarning`` and produces bit-identical results. This shim is the
only place the old union exists.

Every path defines a custom VJP (dY/dX = g @ T^T; packed weights are
non-differentiable — training uses the QAT/STE latent-weight path in
``core.quantize``).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import functools
import warnings
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats, weights
from repro.kernels import ref
from repro.kernels import autotune as autotune_lib
from repro.kernels.ternary_gemm import (K_PER_WORD, ternary_gemm_pallas,
                                        ternary_gemm_skip_pallas)
from repro.kernels.ternary_gemm_bitplane import (K_PER_BYTE,
                                                 ternary_gemm_bitplane)

__all__ = ["ternary_gemm", "ternary_gemm_plan", "GemmPlan", "KernelImpl",
           "register_kernel", "kernel_registry", "precompute_plans",
           "pack_weights", "pack_weights_tiled",
           "serving_phase", "current_phase", "SERVING_PHASES",
           "SKIP_OCCUPANCY_CUTOFF",
           "paged_decode_attention", "register_paged_attn",
           "paged_attention_registry"]

# Serving-phase tag consumed at trace time: prefill GEMMs are M=B·L
# GEMM-shaped, decode GEMMs are M=slots GEMV-shaped, verify GEMMs
# (speculative decoding, DESIGN.md §10) are M=slots·(k+1) small-GEMM
# shaped — no two of them may share (and thrash) one autotune entry even
# when their bucketed M collides.
SERVING_PHASES = ("prefill", "decode", "verify")

_SERVING_PHASE: contextvars.ContextVar[Optional[str]] = \
    contextvars.ContextVar("repro_serving_phase", default=None)


@contextlib.contextmanager
def serving_phase(phase: Optional[str]):
    """Tag ``ternary_gemm`` dispatches traced inside this scope as
    ``"prefill"``, ``"decode"`` or ``"verify"`` so the autotuner keys them
    separately (the serving engine wraps its phase jit calls in this)."""
    assert phase is None or phase in SERVING_PHASES, phase
    token = _SERVING_PHASE.set(phase)
    try:
        yield
    finally:
        _SERVING_PHASE.reset(token)


def current_phase() -> Optional[str]:
    return _SERVING_PHASE.get()

# Above this occupied-tile fraction the skipping grid saves too little to
# justify the scalar-prefetch indirection; "auto" falls back to dense.
SKIP_OCCUPANCY_CUTOFF = 0.875


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def pack_weights(t: np.ndarray, scale=None, bias=None) -> weights.Dense2Bit:
    """Host-side: (K, N) {-1,0,1} -> ``Dense2Bit`` container (16 weights per
    uint32 word, the dense kernel format)."""
    return weights.Dense2Bit.from_dense(np.asarray(t), scale=scale,
                                        bias=bias)


def pack_weights_tiled(t: np.ndarray, tile_k: int = 256,
                       tile_n: int = 128, scale=None,
                       bias=None) -> weights.Tiled:
    """Host-side: (K, N) {-1,0,1} -> ``Tiled`` container (packed words +
    per-tile occupancy metadata) for the skipping kernel."""
    return weights.Tiled.from_dense(np.asarray(t), tile_k=tile_k,
                                    tile_n=tile_n, scale=scale, bias=bias)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


# ---------------------------------------------------------------------------
# 2-bit-code family (dense + skipping share the packed format and the VJP)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9, 10, 11, 12))
def _gemm_2bit(x, w_packed, scale, bias, kt_idx, kt_cnt,
               n, block_m, block_n, block_k, fuse_prelu, prelu_alpha,
               interpret):
    """Forward: dense kernel when kt_idx is None, else the skipping kernel.
    Returns the (m, n)-sliced logical output."""
    m = x.shape[0]
    bm = min(block_m, max(8, 1 << (m - 1).bit_length()))
    sp = None if scale is None else _pad_to(scale.reshape(-1), 0, block_n)
    bp = None if bias is None else _pad_to(bias.reshape(-1), 0, block_n)
    # x's K must first match the packed operand's (possibly padded) K — the
    # word rows can exceed ceil(k/block_k)*block_k when the pack used a
    # larger tile_k than the resolved block_k.
    kp = w_packed.shape[0] * K_PER_WORD
    xp = _pad_to(_pad_to(x, 1, kp), 0, bm)
    if kt_idx is None:
        xp = _pad_to(xp, 1, block_k)
        wp = _pad_to(_pad_to(w_packed, 0, block_k // K_PER_WORD), 1, block_n)
        y = ternary_gemm_pallas(
            xp, wp, sp, bp, block_m=bm, block_n=block_n, block_k=block_k,
            fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha,
            interpret=interpret)
    else:
        y = ternary_gemm_skip_pallas(
            xp, w_packed, kt_idx, kt_cnt, sp, bp,
            block_m=bm, block_n=block_n, block_k=block_k,
            fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha,
            interpret=interpret)
    return y[:m, :n]


def _gemm_2bit_fwd(x, w_packed, scale, bias, kt_idx, kt_cnt, *static):
    y = _gemm_2bit(x, w_packed, scale, bias, kt_idx, kt_cnt, *static)
    fuse_prelu = static[4]
    return y, (x, w_packed, scale, bias, kt_idx, kt_cnt,
               y if fuse_prelu else None)


def _gemm_2bit_bwd(n, bm, bn, bk, fuse_prelu, prelu_alpha, interpret,
                   res, g):
    x, w_packed, scale, bias, kt_idx, kt_cnt, y = res
    kk = x.shape[1]  # logical K is x's trailing dim (x is unpadded)
    if fuse_prelu:
        g = jnp.where(y >= 0, g, prelu_alpha * g)
    # Bias grad exists only when a bias operand exists (scale is irrelevant).
    gb = (None if bias is None
          else jnp.sum(g, axis=0).astype(bias.dtype).reshape(bias.shape))
    t = formats.decode_2bit(w_packed, kk, dtype=x.dtype)[:, :n]
    if scale is not None:
        # dL/dscale = sum_m g * (x @ T): exact, costs one decode+matmul.
        ylin = jnp.dot(x, t, preferred_element_type=jnp.float32)
        gscale = jnp.sum(g.astype(jnp.float32) * ylin, axis=0).astype(
            scale.dtype).reshape(scale.shape)
        g = g * scale.reshape(1, -1).astype(g.dtype)
    else:
        gscale = None
    gx = jnp.dot(g, t.T, preferred_element_type=jnp.float32).astype(x.dtype)
    return (gx, jnp.zeros_like(w_packed), gscale, gb,
            None if kt_idx is None else jnp.zeros_like(kt_idx),
            None if kt_cnt is None else jnp.zeros_like(kt_cnt))


_gemm_2bit.defvjp(_gemm_2bit_fwd, _gemm_2bit_bwd)


# ---------------------------------------------------------------------------
# Bitplane family (combined decode / plane-factorized)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7, 8))
def _gemm_bitplane(x, plus, minus, scale, block_m, block_n, block_k,
                   factorized, interpret):
    return ternary_gemm_bitplane(
        x, plus, minus, scale, block_m=block_m, block_n=block_n,
        block_k=block_k, factorized=factorized, interpret=interpret)


def _gemm_bitplane_fwd(x, plus, minus, scale, *static):
    y = _gemm_bitplane(x, plus, minus, scale, *static)
    return y, (x, plus, minus, scale)


def _gemm_bitplane_bwd(bm, bn, bk, factorized, interpret, res, g):
    x, plus, minus, scale = res
    kk = x.shape[1]
    t = formats.decode_bitplanes(plus, minus, kk, dtype=x.dtype)
    t = t[:, :g.shape[1]]
    if scale is not None:
        ylin = jnp.dot(x, t, preferred_element_type=jnp.float32)
        gscale = jnp.sum(g.astype(jnp.float32) * ylin, axis=0).astype(
            scale.dtype).reshape(scale.shape)
        g = g * scale.reshape(1, -1).astype(g.dtype)
    else:
        gscale = None
    gx = jnp.dot(g, t.T, preferred_element_type=jnp.float32).astype(x.dtype)
    return gx, jnp.zeros_like(plus), jnp.zeros_like(minus), gscale


_gemm_bitplane.defvjp(_gemm_bitplane_fwd, _gemm_bitplane_bwd)


# ---------------------------------------------------------------------------
# The kernel registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GemmPlan:
    """Inspectable dispatch decision for one ternary GEMM.

    Produced by ``ternary_gemm_plan``; consumed by the registered lowering.
    ``block_*`` are ``None`` for reference (non-Pallas) impls."""

    format: str
    impl: str
    m: int
    k: int
    n: int
    block_m: Optional[int]
    block_n: Optional[int]
    block_k: Optional[int]
    phase: Optional[str]
    occupancy: float
    interpret: bool
    fuse_prelu: bool = False
    prelu_alpha: float = 0.25


@dataclasses.dataclass(frozen=True)
class KernelImpl:
    """One registered lowering: ``(format, impl)`` -> kernel.

    ``predicate(w, m, phase)`` gates ``impl="auto"`` selection (highest
    admissible ``priority`` wins); ``plan_blocks(w, m, phase, bm, bn, bk)``
    resolves block shapes (consulting the autotuner for ``None`` entries);
    ``lower(plan, x, w, scale, bias)`` executes."""

    format: str
    impl: str
    priority: int
    predicate: Callable[[weights.TernaryWeight, int, Optional[str]], bool]
    plan_blocks: Callable
    lower: Callable


_KERNELS: Dict[Tuple[str, str], KernelImpl] = {}


def register_kernel(fmt: str, impl: str, *, priority: int = 0,
                    predicate: Optional[Callable] = None,
                    plan_blocks: Optional[Callable] = None):
    """Decorator registering a lowering for ``(format, impl)``. The single
    extension point for new kernels — dispatch, ``impl="auto"`` selection
    and ``ternary_gemm_plan`` pick it up with no call-site changes."""

    def deco(fn):
        _KERNELS[(fmt, impl)] = KernelImpl(
            format=fmt, impl=impl, priority=priority,
            predicate=predicate or (lambda w, m, phase: True),
            plan_blocks=plan_blocks or (lambda w, m, phase, bm, bn, bk:
                                        (bm, bn, bk)),
            lower=fn)
        return fn

    return deco


def kernel_registry() -> Dict[Tuple[str, str], KernelImpl]:
    """Snapshot of the registered ``(format, impl) -> KernelImpl`` table."""
    return dict(_KERNELS)


# --- block planning helpers -------------------------------------------------

def _blocks_dense(w, m, phase, bm, bn, bk):
    # Dense-decode traffic is occupancy-independent: tune under the dense
    # key (sparsity=1.0) so plans do not depend on pack-time nnz metadata
    # (keeps a restored checkpoint's plan identical to the packing boot's).
    if bm is None or bn is None or bk is None:
        cfg = autotune_lib.get_tuner().lookup(
            m, w.k, w.n, sparsity=1.0, impl="dense", phase=phase)
        bm = bm if bm is not None else cfg.block_m
        bn = bn if bn is not None else cfg.block_n
        bk = bk if bk is not None else cfg.block_k
    return bm, bn, bk


def _blocks_skip(w, m, phase, bm, bn, bk):
    # Pack-time tile shapes dictate the kernel's K/N blocks.
    if bn is not None and bn != w.tile_n:
        raise ValueError(f"impl='skip': block_n={bn} must equal the pack's "
                         f"tile_n={w.tile_n}")
    if bk is not None and bk != w.tile_k:
        raise ValueError(f"impl='skip': block_k={bk} must equal the pack's "
                         f"tile_k={w.tile_k}")
    if bm is None:
        bm = autotune_lib.get_tuner().lookup(
            m, w.k, w.n, sparsity=w.occupancy(), impl="skip",
            fixed_n=w.tile_n, fixed_k=w.tile_k, phase=phase).block_m
    return bm, w.tile_n, w.tile_k


def _blocks_bitplane(impl):
    def plan(w, m, phase, bm, bn, bk):
        if bm is None or bn is None or bk is None:
            cfg = autotune_lib.get_tuner().lookup(
                m, w.k, w.n, impl=impl, phase=phase)
            bm = bm if bm is not None else cfg.block_m
            bn = bn if bn is not None else cfg.block_n
            bk = bk if bk is not None else cfg.block_k
        return bm, bn, bk
    return plan


def _no_blocks(w, m, phase, bm, bn, bk):
    return None, None, None


def _require_2d(w, *leaves):
    for leaf in leaves:
        if getattr(leaf, "ndim", 2) != 2:
            raise ValueError(
                f"{w.format_name} weight has stacked leaves "
                f"{tuple(leaf.shape)}; slice the stack (scan/vmap) down to "
                f"2-D before ternary_gemm")


# --- dense2bit lowerings ----------------------------------------------------

@register_kernel("dense2bit", "dense", priority=10,
                 plan_blocks=_blocks_dense)
def _lower_dense(plan, x, w, scale, bias):
    wp = jnp.asarray(w.packed)
    _require_2d(w, wp)
    return _gemm_2bit(x, wp[:, :w.n], scale, bias, None, None,
                      w.n, plan.block_m, plan.block_n, plan.block_k,
                      plan.fuse_prelu, plan.prelu_alpha, plan.interpret)


@register_kernel("dense2bit", "ref", plan_blocks=_no_blocks)
def _lower_dense_ref(plan, x, w, scale, bias):
    wp = jnp.asarray(w.packed)
    _require_2d(w, wp)
    return ref.packed2bit_matmul(
        x, wp, w.k, alpha=scale, bias=bias,
        prelu_alpha=plan.prelu_alpha if plan.fuse_prelu else None)[:, :w.n]


# --- tiled lowerings --------------------------------------------------------

@register_kernel("tiled", "skip", priority=10,
                 predicate=lambda w, m, phase:
                     w.occupancy() <= SKIP_OCCUPANCY_CUTOFF,
                 plan_blocks=_blocks_skip)
def _lower_skip(plan, x, w, scale, bias):
    return _gemm_2bit(x, jnp.asarray(w.packed), scale, bias,
                      jnp.asarray(w.kt_indices), jnp.asarray(w.kt_counts),
                      w.n, plan.block_m, plan.block_n, plan.block_k,
                      plan.fuse_prelu, plan.prelu_alpha, plan.interpret)


@register_kernel("tiled", "dense", priority=5, plan_blocks=_blocks_dense)
def _lower_tiled_dense(plan, x, w, scale, bias):
    # packed word columns map 1:1 to W columns -> drop the N padding
    return _gemm_2bit(x, jnp.asarray(w.packed)[:, :w.n], scale, bias,
                      None, None, w.n, plan.block_m, plan.block_n,
                      plan.block_k, plan.fuse_prelu, plan.prelu_alpha,
                      plan.interpret)


@register_kernel("tiled", "ref", plan_blocks=_no_blocks)
def _lower_tiled_ref(plan, x, w, scale, bias):
    return ref.packed2bit_matmul(
        x, jnp.asarray(w.packed)[:, :w.n], w.k, alpha=scale, bias=bias,
        prelu_alpha=plan.prelu_alpha if plan.fuse_prelu else None)


# --- bitplane lowerings -----------------------------------------------------

def _lower_bitplane_common(plan, x, w, scale, bias, factorized):
    plus, minus = jnp.asarray(w.plus), jnp.asarray(w.minus)
    _require_2d(w, plus)
    bm, bn, bk = plan.block_m, plan.block_n, plan.block_k
    xp = _pad_to(x, 1, plus.shape[0] * K_PER_BYTE)
    y = _gemm_bitplane(xp, plus, minus, scale, bm, bn, bk, factorized,
                       plan.interpret)
    if bias is not None:
        y = y + bias.reshape(1, -1).astype(y.dtype)
    if plan.fuse_prelu:
        y = jnp.where(y >= 0, y, jnp.asarray(plan.prelu_alpha, y.dtype) * y)
    return y


@register_kernel("bitplane", "bitplane", priority=10,
                 plan_blocks=_blocks_bitplane("bitplane"))
def _lower_bitplane(plan, x, w, scale, bias):
    return _lower_bitplane_common(plan, x, w, scale, bias, factorized=False)


@register_kernel("bitplane", "bitplane_factorized", priority=5,
                 plan_blocks=_blocks_bitplane("bitplane_factorized"))
def _lower_bitplane_fact(plan, x, w, scale, bias):
    return _lower_bitplane_common(plan, x, w, scale, bias, factorized=True)


@register_kernel("bitplane", "ref", plan_blocks=_no_blocks)
def _lower_bitplane_ref(plan, x, w, scale, bias):
    return ref.bitplane_matmul(
        x, jnp.asarray(w.plus), jnp.asarray(w.minus), w.k, alpha=scale,
        bias=bias,
        prelu_alpha=plan.prelu_alpha if plan.fuse_prelu else None)[:, :w.n]


# --- base3 lowering (the paper's value-compression format, ref-backed) ------

@register_kernel("base3", "ref", priority=10, plan_blocks=_no_blocks)
def _lower_base3_ref(plan, x, w, scale, bias):
    return ref.base3_matmul(
        x, jnp.asarray(w.packed), w.k, alpha=scale, bias=bias,
        prelu_alpha=plan.prelu_alpha if plan.fuse_prelu else None)[:, :w.n]


# ---------------------------------------------------------------------------
# Paged-attention kernel registry (DESIGN.md §9)
# ---------------------------------------------------------------------------
#
# Same registry discipline as the GEMM table above, for the paged KV-cache
# decode-attention lowerings: each impl registers a name, a priority and an
# admissibility predicate, and ``impl="auto"`` picks the best admissible one
# (the Pallas kernel on TPU backends, the gather + dense-identical JAX path
# elsewhere — the latter is what the paged-vs-dense token-exactness
# guarantee rests on). Lowerings live in ``repro.paging.kernels`` and
# register themselves on import.

@dataclasses.dataclass(frozen=True)
class PagedAttnImpl:
    """One registered paged decode-attention lowering."""

    impl: str
    priority: int
    predicate: Callable[..., bool]
    fn: Callable


_PAGED_ATTN: Dict[str, PagedAttnImpl] = {}


def register_paged_attn(impl: str, *, priority: int = 0,
                        predicate: Optional[Callable] = None):
    """Decorator registering a paged decode-attention lowering under
    ``impl``. ``predicate(q, k_pages, v_pages, block_table, lengths)``
    gates ``impl="auto"`` selection (highest admissible priority wins)."""

    def deco(fn):
        _PAGED_ATTN[impl] = PagedAttnImpl(
            impl=impl, priority=priority,
            predicate=predicate or (lambda *a, **k: True), fn=fn)
        return fn

    return deco


def paged_attention_registry() -> Dict[str, "PagedAttnImpl"]:
    """Snapshot of the registered paged-attention impl table."""
    _ensure_paged_impls()
    return dict(_PAGED_ATTN)


def _ensure_paged_impls() -> None:
    # the lowerings self-register on import; imported lazily so kernels.ops
    # stays importable without pulling the paging subsystem in
    import repro.paging.kernels  # noqa: F401


def paged_decode_attention(q, k_pages, v_pages, block_table, lengths, *,
                           window: int = 0, impl: str = "auto",
                           interpret: Optional[bool] = None):
    """Decode attention over block-table-indexed KV pages.

    q (B, H, hd); k_pages/v_pages (P, ps, KV, hd) arrays or
    ``paging.quant.Int8Pages``; block_table (B, T) int32; lengths (B,)
    int32 valid-token counts (including the current token). ``impl`` picks
    a registered lowering ("auto" = best admissible by priority)."""
    _ensure_paged_impls()
    if impl == "auto":
        cands = sorted(_PAGED_ATTN.values(), key=lambda pi: -pi.priority)
        chosen = next((pi for pi in cands
                       if pi.predicate(q, k_pages, v_pages, block_table,
                                       lengths)), cands[-1])
    else:
        chosen = _PAGED_ATTN.get(impl)
        if chosen is None:
            raise ValueError(f"no paged-attention impl {impl!r} registered; "
                             f"available: {sorted(_PAGED_ATTN)}")
    return chosen.fn(q, k_pages, v_pages, block_table, lengths,
                     window=window, interpret=interpret)


# ---------------------------------------------------------------------------
# The planner
# ---------------------------------------------------------------------------

def _coerce_weight(w: Any, k: Optional[int],
                   xk: Optional[int]) -> weights.TernaryWeight:
    """Deprecation shim: wrap the pre-container operand union into the
    equivalent typed container (bit-identical lowering)."""
    if isinstance(w, weights.TernaryWeight):
        return w
    warnings.warn(
        "passing a raw packed array / formats.TiledTernary / (plus, minus) "
        "tuple to ternary_gemm is deprecated; pack into a "
        "repro.core.weights.TernaryWeight (weights.pack / "
        "kernels.pack_weights*) instead",
        DeprecationWarning, stacklevel=3)
    if isinstance(w, formats.TiledTernary):
        return weights.Tiled.from_tiled(w)
    if isinstance(w, (tuple, list)):
        if len(w) != 2:
            raise TypeError(f"bitplane operand must be a (plus, minus) "
                            f"pair, got length {len(w)}")
        kk = k if k is not None else xk
        if kk is None:
            raise ValueError("cannot infer K for a bare bitplane pair; "
                             "pass k= or use weights.Bitplane")
        return weights.Bitplane.from_planes(w[0], w[1], k=kk)
    if getattr(w, "ndim", 0) == 2:
        kk = k if k is not None else xk
        if kk is None:
            # Don't guess from the padded word count: a plan built on the
            # wrong K would misdescribe (and mis-warm the autotuner for)
            # the dispatch ternary_gemm later executes.
            raise ValueError("cannot infer K for a raw packed word matrix; "
                             "pass k= or use weights.Dense2Bit")
        return weights.Dense2Bit.from_packed(w, k=kk)
    raise TypeError(
        f"unsupported ternary_gemm weight operand {type(w).__name__}; "
        f"expected a repro.core.weights.TernaryWeight")


def _validate_k(w: weights.TernaryWeight, xk: int, k: Optional[int]) -> None:
    """One K check for every format (the old dispatcher inferred K from x on
    the dense path but asserted on the operand for skip)."""
    if k is not None and k != w.k:
        raise ValueError(
            f"k={k} does not match the {w.format_name} weight's logical "
            f"K={w.k} (shape {w.shape})")
    if xk != w.k:
        raise ValueError(
            f"x has K={xk} columns but the {w.format_name} weight encodes "
            f"K={w.k} (shape {w.shape}); reshape x or repack the weight")


def ternary_gemm_plan(
    w: Any,
    m: int,
    *,
    k: Optional[int] = None,
    impl: str = "auto",
    phase: Optional[str] = "__current__",
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    fuse_prelu: bool = False,
    prelu_alpha: float = 0.25,
    interpret: Optional[bool] = None,
) -> GemmPlan:
    """Plan (but do not run) a ternary GEMM: registry + autotuner -> an
    inspectable ``GemmPlan``. ``phase`` defaults to the ambient
    ``serving_phase`` scope; ``k`` is only needed to plan a *deprecated*
    raw operand, whose logical K the container union carried implicitly.
    Planning uses only static container metadata, so it is trace-safe and
    cheap to precompute (the serving engine warms phase-keyed plans for
    every packed weight at build time)."""
    w = _coerce_weight(w, k, None)
    if phase == "__current__":
        phase = current_phase()
    interpret = _auto_interpret() if interpret is None else interpret
    fmt = w.format_name
    if impl == "auto":
        cands = sorted((ki for ki in _KERNELS.values() if ki.format == fmt),
                       key=lambda ki: -ki.priority)
        if not cands:
            raise ValueError(f"no kernel registered for format {fmt!r}")
        chosen = next((ki for ki in cands if ki.predicate(w, m, phase)),
                      cands[-1])
    else:
        chosen = _KERNELS.get((fmt, impl))
        if chosen is None:
            avail = sorted(i for f, i in _KERNELS if f == fmt)
            raise ValueError(f"no impl {impl!r} registered for format "
                             f"{fmt!r}; available: {avail}")
    bm, bn, bk = chosen.plan_blocks(w, m, phase, block_m, block_n, block_k)
    return GemmPlan(format=fmt, impl=chosen.impl, m=m, k=w.k, n=w.n,
                    block_m=bm, block_n=bn, block_k=bk, phase=phase,
                    occupancy=w.occupancy(), interpret=interpret,
                    fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha)


def precompute_plans(params, *, prefill_ms=(), decode_ms=(), verify_ms=(),
                     select: Optional[Callable] = None, impl: str = "auto",
                     ) -> Dict[Tuple[int, ...], GemmPlan]:
    """Warm phase-keyed plans for ``TernaryWeight``s in a param tree.

    Called once at serving-engine build: every (weight, M-bucket, phase)
    combination the hot loop will dispatch gets its autotune entry resolved
    (and persisted) up front, so no serving step pays a first-call tune.
    ``select(path, w) -> bool`` filters which containers to plan — the
    engine selects only those that actually dispatch through
    ``ternary_gemm`` (packed linears), not containers a model materializes
    instead (MoE expert banks) — and ``impl`` should be the impl the apply
    path will dispatch (planning ``"ref"`` touches no autotune state).
    Returns the plans keyed by (leaf index, m, phase) for introspection."""
    flat = jax.tree_util.tree_flatten_with_path(
        params, is_leaf=lambda v: isinstance(v, weights.TernaryWeight))[0]
    ws = [(path, w) for path, w in flat
          if isinstance(w, weights.TernaryWeight)
          and (select is None or select(path, w))]
    plans: Dict[Tuple[int, ...], GemmPlan] = {}
    for i, (_, w) in enumerate(ws):
        for phase, ms in (("prefill", prefill_ms), ("decode", decode_ms),
                          ("verify", verify_ms)):
            for m in ms:
                plans[(i, m, phase)] = ternary_gemm_plan(w, m, impl=impl,
                                                         phase=phase)
    return plans


# ---------------------------------------------------------------------------
# The public op
# ---------------------------------------------------------------------------

def ternary_gemm(
    x: jnp.ndarray,
    w: Any,
    scale: Optional[jnp.ndarray] = None,
    bias: Optional[jnp.ndarray] = None,
    k: Optional[int] = None,
    block_m: Optional[int] = None,
    block_n: Optional[int] = None,
    block_k: Optional[int] = None,
    fuse_prelu: bool = False,
    prelu_alpha: float = 0.25,
    interpret: Optional[bool] = None,
    impl: str = "auto",
) -> jnp.ndarray:
    """Y = X @ decode(w) * scale + bias (+PReLU). Any (M, K, N).

    ``w`` is a ``repro.core.weights.TernaryWeight``; ``scale``/``bias``
    default to the container's own metadata. ``impl`` selects a registered
    lowering explicitly ("auto" plans by format/occupancy/phase — see
    module docstring); ``block_*`` left ``None`` consult the autotuner.
    ``k`` is redundant with the container (validated) and kept for the
    deprecated raw-operand union.
    """
    w = _coerce_weight(w, k, x.shape[1])
    _validate_k(w, x.shape[1], k)
    scale = w.scale if scale is None else scale
    bias = w.bias if bias is None else bias
    plan = ternary_gemm_plan(
        w, x.shape[0], impl=impl, block_m=block_m, block_n=block_n,
        block_k=block_k, fuse_prelu=fuse_prelu, prelu_alpha=prelu_alpha,
        interpret=interpret)
    return _KERNELS[(plan.format, plan.impl)].lower(plan, x, w, scale, bias)
