"""Pallas ternary GEMM consuming the *bitplane* format — the most literal
TPU translation of the paper's TCSC structural-sign encoding: the sign of a
weight is *which plane* its bit lives in (plus/minus), exactly as TCSC
encodes sign by *which index array* a row id lives in (DESIGN.md §2).

Same grid/accumulation structure as the 2-bit kernel; decode is
``(plus_bit - minus_bit)`` — one subtract per weight, no sign branches (the
paper's interleaving insight as pure data-parallel arithmetic). 2 bits/weight
like the 2-bit codes, but the two planes can also be streamed independently
(e.g. plus-plane-only for unsigned masks).

``factorized=True`` switches to the matmul factorization
``Y = (X @ P) - (X @ M)`` (DESIGN.md §4): each 0/1 plane is bit-expanded and
fed to the MXU as its own binary matmul, and the ternary combine happens
once on the (bm, bn) accumulator instead of per-element on the (bk, bn)
decode — no signed ternary tile is ever materialized.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ternary_gemm import CompilerParams

K_PER_BYTE = 8

__all__ = ["ternary_gemm_bitplane"]


def _unpack_plane(plane, out_dtype):
    """(bk/8, bn) uint8 plane -> (bk, bn) 0/1 tile (no sign combine)."""
    q, bn = plane.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (1, K_PER_BYTE, 1), 1)
    bits = (plane[:, None, :] >> shifts) & 1
    return bits.reshape(q * K_PER_BYTE, bn).astype(out_dtype)


def _decode_planes(plus, minus, out_dtype):
    """(bk/8, bn) uint8 planes -> (bk, bn) ±1/0 tile."""
    q, bn = plus.shape
    shifts = jax.lax.broadcasted_iota(jnp.uint8, (1, K_PER_BYTE, 1), 1)
    p = (plus[:, None, :] >> shifts) & 1
    m = (minus[:, None, :] >> shifts) & 1
    vals = p.astype(jnp.int8) - m.astype(jnp.int8)
    return vals.reshape(q * K_PER_BYTE, bn).astype(out_dtype)


def _kernel(x_ref, p_ref, m_ref, scale_ref, o_ref, acc_ref, *, nk: int,
            factorized: bool = False):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    if factorized:
        # Y = (X @ P) - (X @ M): two binary-plane MXU passes, ternary
        # combine deferred to the accumulator (DESIGN.md §4).
        p = _unpack_plane(p_ref[...], x_ref.dtype)
        m = _unpack_plane(m_ref[...], x_ref.dtype)
        acc_ref[...] += (
            jnp.dot(x_ref[...], p, preferred_element_type=jnp.float32)
            - jnp.dot(x_ref[...], m, preferred_element_type=jnp.float32))
    else:
        t = _decode_planes(p_ref[...], m_ref[...], x_ref.dtype)
        acc_ref[...] += jnp.dot(x_ref[...], t,
                                preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        y = acc_ref[...]
        if scale_ref is not None:
            y = y * scale_ref[...].astype(jnp.float32)
        o_ref[...] = y.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "factorized",
                              "interpret"))
def ternary_gemm_bitplane(
    x: jnp.ndarray,                 # (M, K)
    plus: jnp.ndarray,              # (K/8, N) uint8
    minus: jnp.ndarray,             # (K/8, N) uint8
    scale: Optional[jnp.ndarray] = None,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 512,
    factorized: bool = False,
    interpret: bool = False,
) -> jnp.ndarray:
    m, k = x.shape
    kb, n = plus.shape
    assert kb * K_PER_BYTE == k

    bm = min(block_m, max(8, 1 << (m - 1).bit_length()))
    bn = min(block_n, n)
    bk = min(block_k, k)
    pad = lambda a, i, mult: jnp.pad(
        a, [(0, (-a.shape[d]) % (mult if d == i else 1))
            for d in range(a.ndim)])
    xp = pad(pad(x, 0, bm), 1, bk)
    pp = pad(pad(plus, 0, bk // K_PER_BYTE), 1, bn)
    mp = pad(pad(minus, 0, bk // K_PER_BYTE), 1, bn)
    sp = None if scale is None else pad(scale.reshape(1, -1), 1, bn)
    mm, kk = xp.shape
    nn = pp.shape[1]
    nkk = kk // bk

    in_specs = [
        pl.BlockSpec((bm, bk), lambda i, j, s: (i, s)),
        pl.BlockSpec((bk // K_PER_BYTE, bn), lambda i, j, s: (s, j)),
        pl.BlockSpec((bk // K_PER_BYTE, bn), lambda i, j, s: (s, j)),
    ]
    operands = [xp, pp, mp]
    if sp is not None:
        in_specs.append(pl.BlockSpec((1, bn), lambda i, j, s: (0, j)))
        operands.append(sp)

    def kernel(*refs):
        s_ref = refs[3] if sp is not None else None
        o_ref, acc_ref = refs[-2], refs[-1]
        _kernel(refs[0], refs[1], refs[2], s_ref, o_ref, acc_ref, nk=nkk,
                factorized=factorized)

    y = pl.pallas_call(
        kernel,
        grid=(mm // bm, nn // bn, nkk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mm, nn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*operands)
    return y[:m, :n]
