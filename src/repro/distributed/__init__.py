from repro.distributed import compression, fault_tolerance, sharding

__all__ = ["sharding", "compression", "fault_tolerance"]
