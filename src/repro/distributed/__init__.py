from repro.distributed import (compression, fault_tolerance, router,
                               sharding, tp)

__all__ = ["sharding", "compression", "fault_tolerance", "tp", "router"]
