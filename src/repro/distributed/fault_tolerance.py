"""Fault tolerance: checkpoint/restart supervision + straggler watchdog.

At 1000+ node scale the failure model is: some worker dies (hardware,
preemption), the job restarts on the surviving/replacement set, training
resumes from the last checkpoint with a possibly different device count.
The pieces here implement that contract host-side:

* ``TrainSupervisor``  — wraps the step loop: periodic + on-failure
  checkpoints, bounded restart-with-backoff, resume from ``latest_step``.
  Elasticity comes from the checkpoint layer (logical state; restore maps
  onto whatever mesh the restarted job builds — see checkpoint.py).
* ``StragglerWatchdog`` — EWMA step-time tracker flagging slow steps
  (> factor x EWMA). Policy hook: log + count; at scale the hook triggers
  data re-balancing / hot-spare swap. The watchdog is what converts "one
  slow node" from a silent 30% throughput tax into an actionable signal.
"""
from __future__ import annotations

import logging
from typing import Callable, Optional

from repro import checkpoint as ckpt_lib
from repro.obs import clock as obs_clock
from repro.obs.metrics import MetricsRegistry

log = logging.getLogger("repro.ft")


class StragglerWatchdog:
    """EWMA step-time tracker flagging slow steps (> factor x EWMA).

    The EWMA and the straggler count are registry-backed
    (``repro.obs.metrics``) — the same ``Ewma``/``Counter`` mechanism
    behind the serving engine's step-time budgeter, so train and serve
    share one step-time implementation and a supervisor's
    ``registry.snapshot()`` includes both for free. The public surface
    (constructor keywords, ``ewma``/``straggler_steps`` attributes,
    ``observe`` semantics: flag against the *pre-update* EWMA, seed on
    first observation, never flag the seed) is unchanged.
    """

    def __init__(self, factor: float = 2.0, alpha: float = 0.1,
                 ewma: Optional[float] = None, straggler_steps: int = 0,
                 events: Optional[list] = None, events_cap: int = 256,
                 registry: Optional[MetricsRegistry] = None):
        self.factor = factor
        self.alpha = alpha
        self.registry = registry if registry is not None else MetricsRegistry()
        self._ewma = self.registry.ewma("step_time_s", alpha=alpha)
        self._count = self.registry.counter("straggler_steps")
        if ewma is not None:
            self._ewma.value = float(ewma)
        if straggler_steps:
            self._count.value = int(straggler_steps)
        # `events` is a bounded ring of the most recent straggler records
        # (step, dt, ewma) — a week-long job on a flaky node could
        # otherwise grow this list without limit. `straggler_steps` stays
        # exact over every observation; only the retained detail is capped.
        self.events: list = list(events) if events is not None else []
        self.events_cap = events_cap
        self._ring_i = 0

    @property
    def ewma(self) -> Optional[float]:
        return self._ewma.value

    @ewma.setter
    def ewma(self, v: Optional[float]) -> None:
        self._ewma.value = v

    @property
    def straggler_steps(self) -> int:
        return self._count.value

    @straggler_steps.setter
    def straggler_steps(self, v: int) -> None:
        self._count.value = int(v)

    def observe(self, step: int, dt: float) -> bool:
        ewma = self._ewma.value
        is_straggler = ewma is not None and dt > self.factor * ewma
        if is_straggler:
            self._count.inc()
            if len(self.events) < self.events_cap:
                self.events.append((step, dt, ewma))
            else:
                self.events[self._ring_i] = (step, dt, ewma)
                self._ring_i = (self._ring_i + 1) % self.events_cap
            log.warning("straggler: step %d took %.3fs (ewma %.3fs)",
                        step, dt, ewma)
        self._ewma.update(dt)
        return is_straggler


class TrainSupervisor:
    """Run a step function with checkpoint/restart semantics.

    make_state(restore_step_or_None) -> (step, state): builds fresh state or
    restores; step_fn(step, state) -> (state, metrics). Any exception inside
    step_fn triggers: emergency checkpoint attempt -> state rebuild (the
    "restart") -> resume from last durable step. ``max_restarts`` bounds the
    crash loop.
    """

    def __init__(self, ckpt_dir: str, make_state: Callable,
                 step_fn: Callable, ckpt_every: int = 100,
                 max_restarts: int = 3, watchdog: Optional[StragglerWatchdog] = None):
        self.ckpt_dir = ckpt_dir
        self.make_state = make_state
        self.step_fn = step_fn
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.watchdog = watchdog or StragglerWatchdog()
        self.restarts = 0

    def run(self, num_steps: int, failure_injector: Optional[Callable] = None):
        """Returns (final_state, history). failure_injector(step) may raise
        (test hook simulating node failure)."""
        resume = ckpt_lib.latest_step(self.ckpt_dir)
        step, state = self.make_state(resume)
        history = []
        while step < num_steps:
            try:
                t0 = obs_clock.now()
                if failure_injector is not None:
                    failure_injector(step)
                state, metrics = self.step_fn(step, state)
                dt = obs_clock.now() - t0
                self.watchdog.observe(step, dt)
                history.append((step, metrics))
                step += 1
                if step % self.ckpt_every == 0 or step == num_steps:
                    ckpt_lib.save(self.ckpt_dir, step, state)
            except Exception as e:  # noqa: BLE001 — any worker failure
                self.restarts += 1
                log.error("step %d failed (%s); restart %d/%d",
                          step, e, self.restarts, self.max_restarts)
                if self.restarts > self.max_restarts:
                    raise
                # restart resumes from the newest checkpoint that passes
                # its manifest checksums — a save torn by the very failure
                # we're recovering from must not seed a crash loop
                resume = ckpt_lib.latest_step(self.ckpt_dir, verify=True)
                step, state = self.make_state(resume)
        return state, history
