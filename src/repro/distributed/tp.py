"""Tensor-parallel serving placement (DESIGN.md §13).

The model's logical PartitionSpecs already encode the Megatron-style TP
layout: QKV / up / gate projections are ``P("fsdp", "model")`` (N-dim
column split — each device computes its own output columns, no collective)
and down / o projections are ``P("model", "fsdp")`` (K-dim row split —
each device holds a K-slice and XLA inserts the ``psum`` over partial
products). ``shard_params`` makes those specs real at serve time: it
validates every packed ``TernaryWeight`` spec twin against the mesh
(shard boundaries must land on 2-bit pack-word / tile multiples —
``weights.validate_spec_twin``), resolves logical names through
``distributed.sharding.resolve_specs`` and ``device_put``s the tree.
Execution then follows the data under GSPMD; off-TPU the packed linears
dispatch the ``"ref"`` decode+dot lowering, which XLA partitions along the
same splits.

Serving topology is dp x tp: ``replica_meshes`` carves ``dp`` disjoint
tp-sized single-axis ``("model",)`` meshes out of the device list, one per
engine replica (``serving.ContinuousScheduler(mesh=...)``); the
data-parallel layer on top is ``distributed.router.Router``. Develop on a
forced host mesh: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import weights
from repro.distributed import sharding

__all__ = ["parse_mesh", "replica_meshes", "validate_param_specs",
           "shard_params", "cache_sharding", "replicated_sharding",
           "device_put_cache", "mesh_axis_sizes", "gemm_shard_fn"]


def parse_mesh(arg: str) -> Tuple[int, int]:
    """``"dp,tp"`` -> (dp, tp). A bare ``"tp"`` means dp=1."""
    parts = [p.strip() for p in str(arg).split(",") if p.strip()]
    if len(parts) == 1:
        parts = ["1"] + parts
    if len(parts) != 2:
        raise ValueError(f"--mesh expects 'dp,tp', got {arg!r}")
    dp, tp = (int(p) for p in parts)
    if dp < 1 or tp < 1:
        raise ValueError(f"--mesh sizes must be >= 1, got dp={dp} tp={tp}")
    return dp, tp


def replica_meshes(dp: int, tp: int, devices=None) -> List[Mesh]:
    """``dp`` disjoint single-axis ``("model",)`` meshes of ``tp`` devices
    each — one per data-parallel engine replica. Replica r owns devices
    ``[r*tp, (r+1)*tp)`` of ``devices`` (default ``jax.devices()``)."""
    devices = list(jax.devices() if devices is None else devices)
    need = dp * tp
    if len(devices) < need:
        raise ValueError(
            f"mesh dp={dp} x tp={tp} needs {need} devices, have "
            f"{len(devices)} — on CPU force a host mesh with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={need}")
    return [Mesh(np.asarray(devices[r * tp:(r + 1) * tp]), ("model",))
            for r in range(dp)]


def validate_param_specs(params, specs, mesh, *, fsdp: bool = False) -> int:
    """Walk the param/spec-twin trees together, validating every packed
    ``TernaryWeight`` container's twin against the mesh (pack-word / tile
    shard boundaries — ``weights.validate_spec_twin``). Returns the number
    of containers checked; raises ``ValueError`` on the first bad twin."""
    checked = 0

    def check(spec, p):
        nonlocal checked
        if isinstance(p, weights.TernaryWeight):
            weights.validate_spec_twin(p, spec, mesh, fsdp=fsdp)
            checked += 1
        return spec

    jax.tree.map(
        check, specs, params,
        is_leaf=lambda x: isinstance(x, (weights.TernaryWeight, P)))
    return checked


def shard_params(params, specs, mesh: Mesh, *, fsdp: bool = False,
                 validate: bool = True):
    """Place a param tree on ``mesh`` according to its logical spec tree
    (``LM.init_with_specs_abstract`` structure). Packed containers are
    validated first unless ``validate=False``."""
    if validate:
        validate_param_specs(params, specs, mesh, fsdp=fsdp)
    shardings = sharding.resolve_specs(specs, params, mesh, fsdp)
    return jax.device_put(params, shardings)


def cache_sharding(layers, cfg, mesh: Mesh):
    """NamedSharding tree for a serving cache layer tree (dense slot rows
    or paged page arrays): the KV-head axis is sharded over ``"model"`` —
    matching the column-split K/V projections, so TP attention reads and
    writes only its local heads — wherever the head count divides the axis;
    everything else (SSM rows, int8 page scales with indivisible heads,
    the flat/opt layouts) replicates. Replication is always *correct*
    under GSPMD — this is a memory/locality optimization, never a
    numerics switch."""
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    tp = dict(mesh.shape).get("model", 1)
    shardable = tp > 1 and kv % tp == 0

    def spec(x):
        shp = tuple(getattr(x, "shape", ()))
        if shardable and len(shp) >= 2 and shp[-1] == hd and shp[-2] == kv:
            return NamedSharding(
                mesh, P(*([None] * (len(shp) - 2)), "model", None))
        if shardable and len(shp) >= 1 and shp[-1] == kv:
            # int8 page scales: (..., page_size, KV) rides with its page
            return NamedSharding(mesh, P(*([None] * (len(shp) - 1)),
                                         "model"))
        return NamedSharding(mesh, P())

    return jax.tree.map(spec, layers)


def replicated_sharding(tree, mesh: Mesh):
    """Fully-replicated NamedSharding tree (small device mirrors: position
    and token vectors, block tables, masks)."""
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def device_put_cache(layers, cfg, mesh: Optional[Mesh]):
    """Shard-place a cache layer tree (no-op without a mesh)."""
    if mesh is None:
        return layers
    return jax.device_put(layers, cache_sharding(layers, cfg, mesh))


def mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(getattr(mesh, "shape", mesh))


def gemm_shard_fn(mesh: Mesh):
    """``shard(path, w) -> (partition, tp)`` for ``ops.precompute_plans``:
    reads the *placed* packed array's sharding spec (set by
    ``shard_params``), so the collective recorded in each ``GemmPlan`` is
    derived from where the bits actually live, not re-declared. Packed
    words are (K-packed, N)-shaped: ``"model"`` on the trailing axis is
    the Megatron column split (no collective), on the leading axis the row
    split whose partial products need the ``psum``."""
    tp = mesh_axis_sizes(mesh).get("model", 1)

    def has_model(entry) -> bool:
        return entry == "model" or (isinstance(entry, tuple)
                                    and "model" in entry)

    def shard(path, w):
        arr = getattr(w, "packed", None)
        if arr is None:
            arr = getattr(w, "plus", None)
        spec = getattr(getattr(arr, "sharding", None), "spec", None)
        ndim = getattr(arr, "ndim", 0)
        if spec is None or tp <= 1 or ndim < 2:
            return None, 1
        # placed specs drop trailing Nones: pad back to ndim so the last
        # two entries really are the (K-packed, N) axes
        entries = tuple(spec) + (None,) * (ndim - len(spec))
        if has_model(entries[-1]):
            return "n", tp
        if has_model(entries[-2]):
            return "k", tp
        return None, 1

    return shard
