"""Ternary gradient compression (TernGrad-style) with error feedback.

The paper's value system {-1, 0, +1} applied to the *communication* layer:
data-parallel gradient sync sends per-tensor scale + ternarized gradient
instead of full-precision gradients. Used on the cross-pod axis of the DP
trainer (``launch/train.py --compress-grads``), where inter-pod links are the
scarcest bandwidth.

Wire-format analysis (recorded in EXPERIMENTS.md, mirroring the paper's own
"value compression dropped" finding): a ring all-reduce must *sum* at every
hop, and sums of ternary values are no longer ternary — so the collective is
expressed as a bf16 psum of the ternary codes (2x byte reduction vs f32)
rather than a 2-bit wire format (a 2-bit all-gather would move
(n-1) * size/16 bytes: worse than a ring reduce-scatter beyond n = 32).
Error feedback keeps the compression unbiased over time.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = ["ternarize_gradient", "compressed_psum", "init_error_state"]


def ternarize_gradient(g: jnp.ndarray, err: jnp.ndarray,
                       threshold_factor: float = 0.7
                       ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """(g + err) -> (ternary codes int8-valued bf16, scale, new_err)."""
    gf = g.astype(jnp.float32) + err
    absg = jnp.abs(gf)
    delta = threshold_factor * jnp.mean(absg)
    mask = absg > delta
    t = jnp.sign(gf) * mask
    nnz = jnp.maximum(jnp.sum(mask), 1)
    scale = jnp.sum(absg * mask) / nnz
    new_err = gf - scale * t
    return t.astype(jnp.bfloat16), scale, new_err


def compressed_psum(grads, err_state, axis_name: str,
                    threshold_factor: float = 0.7):
    """Inside shard_map/pmap: ternarize+psum each leaf across ``axis_name``.

    Returns (synced grads, new error state). Scales are averaged across
    workers (cheap scalar psum); codes go over the wire at bf16 width.
    """
    n = jax.lax.psum(1, axis_name)

    def sync(g, err):
        t, scale, new_err = ternarize_gradient(g, err, threshold_factor)
        t_sum = jax.lax.psum(t, axis_name)              # bf16 on the wire
        s_avg = jax.lax.psum(scale, axis_name) / n
        return (t_sum.astype(jnp.float32) * s_avg / n).astype(g.dtype), new_err

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(err_state)
    pairs = [sync(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([p[0] for p in pairs]),
            treedef.unflatten([p[1] for p in pairs]))


def init_error_state(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32)
        if jnp.issubdtype(p.dtype, jnp.floating) else jnp.zeros((), jnp.float32),
        params)
