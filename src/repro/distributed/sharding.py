"""Logical-axis PartitionSpec resolution.

Model code emits PartitionSpecs with *logical* names ("fsdp", "model",
"expert", plus literal mesh names like "pod"/"data" in cache specs).
``resolve_specs`` turns them into mesh-valid specs against the actual mesh
and the actual array shapes, enforcing:

* divisibility — a dim not divisible by the axis (product) is replicated
  (e.g. kv=8 heads on a 16-way model axis, batch=1 on the data axis);
* no axis reuse within one spec (expert-parallelism steals the "model"
  axis from the d_ff dim for E % model == 0 archs — DESIGN §6);
* fsdp off -> "fsdp" resolves to None (params replicated over data axes).
"""
from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

FSDP = "fsdp"
MODEL = "model"
EXPERT = "expert"


def _axes_size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def resolve_spec(spec: P, shape: Sequence[int], mesh: Mesh,
                 fsdp: bool) -> P:
    names = set(mesh.axis_names)
    used = set()
    out = []
    entries = tuple(spec) + (None,) * (len(shape) - len(tuple(spec)))
    for dim, ax in zip(shape, entries):
        resolved: Any = None
        candidates: Tuple = ()
        if ax is None:
            candidates = ()
        elif ax == FSDP:
            candidates = (batch_axes(mesh),) if fsdp else ()
        elif ax == EXPERT:
            candidates = (MODEL,)
        elif isinstance(ax, (tuple, list)):
            kept = tuple(a for a in ax if a in names and a not in used)
            candidates = (kept,) if kept else ()
        else:
            candidates = (ax,) if ax in names else ()
        for cand in candidates:
            cand_t = (cand,) if isinstance(cand, str) else tuple(cand)
            if not cand_t or any(c in used for c in cand_t):
                continue
            if dim % _axes_size(mesh, cand_t) == 0:
                resolved = cand if isinstance(cand, str) else cand_t
                used.update(cand_t)
                break
        out.append(resolved)
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def resolve_specs(spec_tree, shape_tree, mesh: Mesh, fsdp: bool):
    """Map a logical spec tree + matching shape tree -> NamedSharding tree."""
    def resolve(spec, shaped):
        shape = getattr(shaped, "shape", ())
        return NamedSharding(mesh, resolve_spec(spec, shape, mesh, fsdp))
    return jax.tree.map(resolve, spec_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, P))


def batch_sharding(batch_tree, mesh: Mesh):
    """Shard dim 0 (global batch) over ("pod","data") where divisible."""
    axes = batch_axes(mesh)

    def spec(x):
        shape = getattr(x, "shape", ())
        if shape and shape[0] % _axes_size(mesh, axes) == 0:
            return NamedSharding(mesh, P(axes, *([None] * (len(shape) - 1))))
        return NamedSharding(mesh, P())
    return jax.tree.map(spec, batch_tree)


def replicated(tree, mesh: Mesh):
    return jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)


def opt_state_shardings(param_shardings, opt_state_shape, mesh: Mesh):
    """Adam m/v mirror the param shardings; scalars replicated."""
    rep = NamedSharding(mesh, P())

    def like(sub_shapes):
        flat_p, treedef = jax.tree.flatten(param_shardings)
        flat_s = treedef.flatten_up_to(sub_shapes)
        out = [p if getattr(s, "ndim", 0) > 0 else rep
               for p, s in zip(flat_p, flat_s)]
        return treedef.unflatten(out)

    return {"m": like(opt_state_shape["m"]),
            "v": like(opt_state_shape["v"]),
            "step": rep}
