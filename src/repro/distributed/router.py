"""Data-parallel multi-replica router (DESIGN.md §13).

The tensor-parallel layer (``distributed.tp``) scales one engine *up*;
this scales engines *out*: ``dp`` independent ``ContinuousScheduler``
replicas — each on its own disjoint tp-mesh (or single device) with its
own page pool and prefix cache — behind one placement policy.

Placement is sticky prefix-cache-aware: a request goes to the replica
whose prefix cache holds the longest leading run of the prompt's pages
(``PrefixCache.probe`` — non-mutating, so probing every replica skews no
per-replica LRU or hit counters), because only *that* replica can turn
the shared prefix into skipped prefill work. Ties — and prompts no
replica has seen — fall back to least load (queued + live requests), so
cold traffic still balances. Stickiness is bounded: when the favored
replica's load exceeds the lightest replica's by more than
``spill_threshold`` requests, the request spills to the lightest one —
a hot prefix must not starve the rest of the fleet while other replicas
idle (the rebuilt prefix pages make the spilled replica a future
affinity target too).

Replicas drain interleaved, one scheduler step each round-robin turn, so
replica 0's long generations never head-of-line block replica 1's admits.
Greedy decoding is deterministic per engine, so routing never changes
tokens — only which cache produces them.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Sequence

import numpy as np

from repro.obs import clock as obs_clock

__all__ = ["Router"]


class Router:
    """Prefix-affinity request router over engine replicas."""

    def __init__(self, engines: Sequence[Any], *, spill_threshold: int = 4):
        if not engines:
            raise ValueError("Router needs at least one engine replica")
        if spill_threshold < 0:
            raise ValueError(
                f"spill_threshold must be >= 0, got {spill_threshold}")
        self.engines = list(engines)
        self.spill_threshold = spill_threshold
        self.routed = 0
        self.affinity_candidates = 0
        self.affinity_hits = 0
        self.spills = 0
        self.placements: List[int] = []

    # ------------------------------------------------------------------
    def _probe(self, engine, prompt: np.ndarray) -> int:
        prefix = getattr(engine.pool, "prefix", None)
        return prefix.probe(prompt) if prefix is not None else 0

    @staticmethod
    def _load(engine) -> int:
        return engine.queue.depth() + len(engine._live)

    def place(self, prompt: np.ndarray) -> int:
        """Replica index for this prompt: longest cached prefix, ties by
        least load, spilled to the least-loaded replica when the favorite
        is ``spill_threshold`` requests deeper than the lightest."""
        prompt = np.asarray(prompt, np.int32).reshape(-1)
        probes = [self._probe(e, prompt) for e in self.engines]
        loads = [self._load(e) for e in self.engines]
        best_probe = max(probes)
        # least-loaded overall (lowest index breaks exact ties — stable)
        lightest = min(range(len(loads)), key=lambda i: (loads[i], i))
        if best_probe > 0:
            self.affinity_candidates += 1
            favorite = min(
                (i for i in range(len(probes)) if probes[i] == best_probe),
                key=lambda i: (loads[i], i))
            if loads[favorite] - loads[lightest] > self.spill_threshold:
                self.spills += 1
                return lightest
            self.affinity_hits += 1
            return favorite
        return lightest

    def submit(self, prompt: np.ndarray, max_new: int, **kw):
        """Place and enqueue one request; returns the engine's Request."""
        idx = self.place(prompt)
        self.routed += 1
        self.placements.append(idx)
        return self.engines[idx].submit(prompt, max_new, **kw)

    # ------------------------------------------------------------------
    def _pending(self) -> List[Any]:
        return [e for e in self.engines if e.queue or e._live]

    def run(self) -> Dict[str, Any]:
        """Drain every replica, interleaved one step per turn; returns the
        fleet metrics dict (placement counters + per-replica summaries)."""
        for e in self.engines:
            assert e.params is not None, "load(params) every replica first"
        t0 = obs_clock.now()
        budget = sum(
            (e.queue.depth() + len(e._live)) * e.max_len * 16 + 1
            for e in self.engines)
        idle = 0
        while True:
            pending = self._pending()
            if not pending:
                break
            before = sum(e.prefill_steps + e.decode_steps
                         + e.total_drained for e in self.engines)
            for e in pending:
                e.step()
            if sum(e.prefill_steps + e.decode_steps + e.total_drained
                   for e in self.engines) == before:
                # every pending replica idled (retry-backoff windows):
                # waiting is free, so it must not eat the progress budget
                idle += 1
                assert idle < 1_000_000, "router stuck on idle ticks"
                time.sleep(5e-4)
            else:
                idle = 0
                budget -= len(pending)
                assert budget > 0, "router failed to make progress"
        wall = obs_clock.now() - t0
        per_replica = []
        gen = 0
        for e in self.engines:
            done = e._finished
            r_gen = sum(len(r.tokens) for r in done)
            gen += r_gen
            prefix = getattr(e.pool, "prefix", None)
            per_replica.append({
                "drained": e.total_drained,
                "generated_tokens": r_gen,
                "prefill_steps": e.prefill_steps,
                "decode_steps": e.decode_steps,
                "prefix_hit_rate": (prefix.hit_rate
                                    if prefix is not None else None),
                "mesh": (None if e.mesh is None
                         else {"axes": dict(e.mesh.shape)}),
            })
        return {
            "engine": "router",
            "replicas": len(self.engines),
            "routed": self.routed,
            "placements": list(self.placements),
            "affinity": {
                "candidates": self.affinity_candidates,
                "hits": self.affinity_hits,
                "rate": (self.affinity_hits / self.affinity_candidates
                         if self.affinity_candidates else None),
            },
            "spills": self.spills,
            "per_replica": per_replica,
            "generated_tokens": gen,
            "wall_s": round(wall, 4),
            "tok_per_s": round(gen / wall, 2) if wall > 0 else None,
        }
