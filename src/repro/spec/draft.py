"""Draft-model construction for self-speculative decoding (DESIGN.md §10).

A *draft* is any cheap model whose greedy continuations of the target's
token stream are often the target's own — the verify step (``spec.verify``)
accepts the longest matching prefix, so draft quality moves throughput,
never correctness. Three construction strategies live behind the one
``DraftModel`` protocol (a name + an ``LM`` + its params):

* ``resparsify`` — re-ternarize the target's packed ``TernaryWeight``
  containers at a *higher sparsity* (lower nnz fraction) into fresh
  containers of the same registered format. The paper's sparsity-stability
  observation is the bet: a ternary network keeps most of its argmax
  behaviour as small-magnitude columns are dropped, while every sparse
  kernel in this repo gets faster as occupancy falls. The draft shares the
  target's architecture, embeddings and lm_head; only the GEMM operands
  shrink.
* ``layer_skip`` — run a *prefix* of the target's stack (sliced scan
  groups) plus the shared final norm + lm_head. The residual stream makes
  truncated-depth logits a decent predictor of full-depth logits; draft
  cost scales with the kept fraction of layers.
* ``external`` — any smaller ``ModelConfig`` with its own params (a
  distilled or otherwise-trained drafter).

Drafting itself (``make_draft_round``) is a single jitted call per engine
round: one *re-sync* feed (writes the draft's K/V for the newest committed
token — exactly the catch-up token when the previous round accepted the
whole window, and an idempotent rewrite otherwise) followed by ``k``
chained greedy feeds producing the proposal tokens. The draft owns its own
dense KV cache (``LM.init_cache`` slot rows managed by the engine); it
never touches the target's paged pool, so rollback only ever concerns the
target cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import weights
from repro.models import LM

__all__ = ["DraftModel", "Draft", "SpecConfig", "build_draft",
           "resparsify", "layer_skip", "external", "make_draft_round"]


@runtime_checkable
class DraftModel(Protocol):
    """What the engine needs from a draft: a display name, the draft
    ``LM`` (its config may differ from the target's) and its params."""

    name: str
    model: LM
    params: Any


@dataclasses.dataclass
class Draft:
    name: str
    model: LM
    params: Any


@dataclasses.dataclass(frozen=True)
class SpecConfig:
    """Speculative-decoding knobs for ``ContinuousScheduler(spec=...)``.

    ``draft`` is a strategy name (``"resparsify"`` / ``"layer_skip"`` /
    ``"external"``) resolved against the loaded params by ``build_draft``,
    or a ready ``DraftModel`` instance. ``k`` is the proposal depth: each
    engine round drafts ``k`` tokens and verifies the ``k+1``-token window
    in one target forward (the engine reserves ``k`` cache positions of
    headroom per slot)."""

    draft: Any = "layer_skip"
    k: int = 4
    draft_sparsity: float = 0.125      # resparsify: target nnz fraction
    draft_layers: int = 0              # layer_skip: 0 = half, period-rounded
    draft_cfg: Optional[ModelConfig] = None   # external
    draft_params: Any = None                  # external


# ---------------------------------------------------------------------------
# Strategies
# ---------------------------------------------------------------------------

def _reternarize(eff: np.ndarray, sparsity: float):
    """Re-ternarize one effective (scale-applied) ternary matrix at a lower
    nnz fraction. Ranking is *global* |w|: within a ternary matrix every
    nonzero of column n shares magnitude alpha_n, so a per-channel quantile
    (``quantize.ternarize_target_sparsity``'s default) is degenerate here —
    the global quantile instead drops whole low-scale columns' weight mass
    first. Survivor scales are the TWN L1-optimal per-channel mean, exactly
    as ``core.quantize.ternarize`` computes them."""
    absw = np.abs(eff)
    delta = np.quantile(absw.reshape(-1), 1.0 - sparsity)
    mask = (absw >= delta) & (absw > 0)
    t = (np.sign(eff) * mask).astype(np.int8)
    cnt = np.maximum(mask.sum(axis=0), 1)
    alpha = ((absw * mask).sum(axis=0) / cnt).astype(np.float32)
    return t, alpha


def _resparsify_container(w: weights.TernaryWeight, sparsity: float,
                          ) -> weights.TernaryWeight:
    eff = np.asarray(w.materialize(jnp.float32, with_scale=True))
    lead, (kk, n) = eff.shape[:-2], eff.shape[-2:]
    e2 = eff.reshape((-1, kk, n))
    ts, alphas = zip(*(_reternarize(e2[i], sparsity)
                       for i in range(e2.shape[0])))
    t = np.stack(ts).reshape(lead + (kk, n))
    alpha = np.stack(alphas).reshape(lead + (n,))
    cls = weights.FORMATS[w.format_name]
    return cls.from_dense(t, scale=jnp.asarray(alpha), bias=w.bias)


def resparsify(model: LM, params, sparsity: float) -> Draft:
    """Higher-sparsity re-ternarization of the target's packed weights: a
    draft that shares the target's config, embeddings and unpacked params
    and replaces every ``TernaryWeight`` container with a fresh pack at
    ``sparsity`` nnz fraction (same registered format -> same kernels,
    lower occupancy -> cheaper skip/sparse dispatch)."""
    if not 0.0 < sparsity <= 1.0:
        raise ValueError(f"draft sparsity {sparsity} not in (0, 1]")
    n_packed = 0

    def conv(v):
        nonlocal n_packed
        if isinstance(v, weights.TernaryWeight):
            n_packed += 1
            return _resparsify_container(v, sparsity)
        return v

    dparams = jax.tree_util.tree_map(
        conv, params, is_leaf=lambda v: isinstance(v, weights.TernaryWeight))
    if n_packed == 0:
        raise ValueError(
            "resparsify found no TernaryWeight containers in the params — "
            "pack them first (models.layers.pack_params / --packed), or use "
            "the layer_skip/external draft strategies")
    return Draft(name=f"resparsify(s={sparsity:g})", model=model,
                 params=dparams)


def layer_skip(model: LM, params, n_layers: int) -> Draft:
    """Depth-truncated self-draft: the first ``n_layers`` of the target
    stack (sliced scan groups — params are shared, not copied) + the
    target's own final norm and lm_head."""
    cfg = model.cfg
    if not 0 < n_layers < cfg.num_layers:
        raise ValueError(f"layer_skip needs 0 < n_layers < {cfg.num_layers},"
                         f" got {n_layers}")
    if n_layers % model.period:
        raise ValueError(f"n_layers={n_layers} must be a multiple of the "
                         f"stack period {model.period} (scan groups slice "
                         f"whole periods)")
    g = n_layers // model.period
    dmodel = LM(dataclasses.replace(cfg, num_layers=n_layers))
    dparams = dict(params)
    for j in range(len(model.block_kinds)):
        dparams[f"block{j}"] = jax.tree.map(lambda x: x[:g],
                                            params[f"block{j}"])
    return Draft(name=f"layer_skip({n_layers}/{cfg.num_layers})",
                 model=dmodel, params=dparams)


def external(cfg: ModelConfig, params=None, *, key=None) -> Draft:
    """Any independent (typically smaller) model as the drafter. ``params``
    default to a fresh init — useful only for plumbing tests; real use
    passes a trained/distilled checkpoint."""
    m = LM(cfg)
    if params is None:
        params = m.init(key if key is not None else jax.random.PRNGKey(0))
    return Draft(name=f"external({cfg.name})", model=m, params=params)


def build_draft(spec: SpecConfig, model: LM, params) -> DraftModel:
    """Resolve a ``SpecConfig`` against the loaded target params."""
    if not isinstance(spec.draft, str):
        return spec.draft
    if spec.draft == "resparsify":
        return resparsify(model, params, spec.draft_sparsity)
    if spec.draft == "layer_skip":
        n = spec.draft_layers
        if not n:
            n = max(model.period,
                    (model.cfg.num_layers // 2)
                    // model.period * model.period)
        return layer_skip(model, params, n)
    if spec.draft == "external":
        if spec.draft_cfg is None:
            raise ValueError("draft='external' needs SpecConfig.draft_cfg")
        return external(spec.draft_cfg, spec.draft_params)
    raise ValueError(f"unknown draft strategy {spec.draft!r}; expected "
                     f"'resparsify', 'layer_skip', 'external' or a "
                     f"DraftModel instance")


# ---------------------------------------------------------------------------
# The drafting loop (one jitted call per engine round)
# ---------------------------------------------------------------------------

def make_draft_round(draft: DraftModel, max_len: int, k: int):
    """Jitted per-round drafter: re-sync feed + ``k`` chained greedy feeds.

    ``(params, layers, pos, prev_tok, tok) -> (layers, drafts (B, k))``
    where ``pos``/``prev_tok``/``tok`` are the engine's per-slot position /
    second-newest / newest committed-token vectors. The re-sync feed writes
    ``prev_tok``'s K/V at ``pos - 1``: after a fully-accepted round that is
    exactly the one committed token the draft never fed (the catch-up);
    otherwise it rewrites a value the draft already holds. Free slots
    (pos 0) compute garbage into rows the next admission overwrites."""
    dlm = draft.model

    def round_(params, layers, pos, prev_tok, tok):
        pos_c = jnp.minimum(pos, max_len - 1 - k)
        cache = {"layers": layers, "pos": jnp.maximum(pos_c - 1, 0)}
        _, cache = dlm.decode_step(params, cache, prev_tok[:, None])
        cur, drafts = tok, []
        for _ in range(k):
            logits, cache = dlm.decode_step(params, cache, cur[:, None])
            cur = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
            drafts.append(cur)
        return cache["layers"], jnp.stack(drafts, axis=1)

    return jax.jit(round_, donate_argnums=(1,))
