"""Self-speculative decoding subsystem (DESIGN.md §10).

Decode is bandwidth-bound GEMV; the paper's sparse ternary kernels peak on
small-M GEMM. Speculative decoding converts one into the other: a cheap
*draft* proposes ``k`` tokens per slot (``spec.draft`` — re-sparsified
ternary weights, a depth-truncated prefix of the same stack, or an external
model), the target verifies the whole ``(slots, k+1)`` window in a single
forward (``spec.verify`` — bitwise-equal to sequential decode, so greedy
longest-prefix acceptance keeps serving **token-exact by construction**),
and ``spec.rollback`` restores cache invariants for the rejected tail
(length bookkeeping on dense slot pools; O(1) page reclamation on the
paged pool). The engine runs draft -> verify -> rollback inside the
continuous-batching loop: ``ContinuousScheduler(cfg, ...,
spec=SpecConfig(draft="resparsify", k=4))``.
"""
from repro.spec.draft import (Draft, DraftModel, SpecConfig, build_draft,
                              external, layer_skip, make_draft_round,
                              resparsify)
from repro.spec.rollback import rollback_dense, rollback_paged
from repro.spec.verify import longest_prefix_match, make_verify_step

__all__ = [
    "SpecConfig", "DraftModel", "Draft", "build_draft",
    "resparsify", "layer_skip", "external",
    "make_draft_round", "make_verify_step", "longest_prefix_match",
    "rollback_dense", "rollback_paged",
]
