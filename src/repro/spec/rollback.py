"""Cache rollback for rejected speculative tokens (DESIGN.md §10).

The verify step writes K/V for the whole ``k+1`` window before acceptance
is known; a round that accepts ``n_acc < k`` drafts leaves the rejected
tokens' K/V at positions ``pos + n_acc + 1 .. pos + k``. Rollback restores
the invariant that committed cache state is what sequential decode would
have produced:

* **dense slot pools** roll back by *length bookkeeping alone*: the
  engine's per-slot position vector is the single source of valid length,
  every attention mask derives from it (``k_pos <= q_pos`` / valid-length
  masks), and the next round's window rewrites the rejected positions
  before anything can attend to them. Nothing device-side to undo —
  ``rollback_dense`` exists to make that invariant explicit (and to keep
  the call-site symmetric with the paged path).

* **paged pools** additionally own *pages*: a rejected window tail may
  have grown the slot's block table into pages that now hold only garbage.
  ``rollback_paged`` truncates the block table to the committed length via
  ``PagePool.truncate`` — tail pages drop to refcount 0 and return to the
  free list O(1). Refcount-correctness under prefix sharing/COW is
  inherited from the pool: truncation only ever touches decode-grown tail
  pages (committed length >= prompt length, so registered prompt pages are
  never in the dropped range), and a page another slot still references is
  impossible in the tail (the engine's ``ensure_append`` horizon made
  every window page privately owned before the speculative writes).
"""
from __future__ import annotations

__all__ = ["rollback_dense", "rollback_paged"]


def rollback_dense(pool, slot: int, n_tokens: int) -> int:
    """Dense rollback is pure bookkeeping (see module docstring): the
    engine's position vector already reflects ``n_tokens``; no pages exist
    to reclaim. Returns 0 for metric symmetry with ``rollback_paged``."""
    del pool, slot, n_tokens
    return 0


def rollback_paged(pool, slot: int, n_tokens: int) -> int:
    """Truncate ``slot``'s block table to ``n_tokens`` committed tokens and
    return the number of tail pages reclaimed to the free list."""
    return pool.truncate(slot, n_tokens)
