"""Multi-token verification for self-speculative decoding (DESIGN.md §10).

One jitted call per engine round runs the whole ``(slots, k+1)`` window —
the newest committed token plus the draft's ``k`` proposals — through the
target's ``LM.decode_step``. The window forward is *bitwise* equal to
``k+1`` sequential single-token decodes (pinned in tests/test_spec.py for
dense and paged caches): every window token's logits are exactly what
sequential greedy decode at its position would have produced, so the
longest-prefix-match acceptance below emits, by construction, a prefix of
the sequential engine's token stream — speculative serving is token-exact,
not approximately so.

Shape note for the kernels: the verify forward's GEMMs are M = slots·(k+1)
— the small-GEMM regime where the paper's sparse ternary kernels beat the
GEMV-shaped plain decode (the entire point of converting decode into
verify). The engine traces this call under ``serving_phase("verify")`` so
those dispatches autotune separately from the M = slots decode entries.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["longest_prefix_match", "make_verify_step"]


def longest_prefix_match(window: jnp.ndarray, greedy: jnp.ndarray):
    """Greedy (exact-match) acceptance, jit-safe.

    ``window`` (B, k+1): the fed tokens ``[t, d_1..d_k]``; ``greedy``
    (B, k+1): the target's argmax at each window position (``greedy[:, j]``
    is the target's next token after ``window[:, j]``). Draft token
    ``d_{j+1}`` is accepted iff it equals ``greedy[:, j]`` *and* every
    earlier draft token was accepted. Returns ``(n_acc (B,), bonus (B,))``:
    the per-slot accepted count in [0, k] and the bonus token
    ``greedy[b, n_acc[b]]`` — the target's continuation after the last
    accepted token, emitted for free (so a round always emits
    ``n_acc + 1`` tokens)."""
    match = (window[:, 1:] == greedy[:, :-1]).astype(jnp.int32)
    n_acc = jnp.sum(jnp.cumprod(match, axis=1), axis=1)
    bonus = jnp.take_along_axis(greedy, n_acc[:, None], axis=1)[:, 0]
    return n_acc, bonus


def make_verify_step(model, max_len: int, k: int, *, paged: bool = False,
                     guard: bool = False):
    """Build the jitted verify step for a target ``LM``.

    Dense: ``(params, layers, pos, window) ->
    (layers, greedy (B, k+1), n_acc (B,), bonus (B,))``; paged takes the
    device block table after ``layers``. The cache-position clamp keeps
    free slots' garbage window writes in range — live rows never clamp
    (the engine reserves ``k`` positions of headroom at submit).

    ``guard=True`` is the fault-hardened variant (DESIGN.md §11): the call
    takes a trailing ``nan_mask (B,)`` bool (fault injection corrupts the
    masked slots' window logits to NaN *before* the guard, so the guard is
    exercised end to end; the all-false mask is a bitwise no-op) and
    returns a trailing ``ok (B,)`` finite-check over each slot's whole
    window — the engine quarantines ``~ok`` slots instead of committing
    their garbage."""

    def verify(params, layers, pos, window, table=None, nan_mask=None):
        cache = {"layers": layers, "pos": jnp.minimum(pos, max_len - 1 - k)}
        if table is not None:
            cache["block_table"] = table
        logits, new_cache = model.decode_step(params, cache, window)
        if nan_mask is not None:
            logits = jnp.where(nan_mask[:, None, None], jnp.nan, logits)
        greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        n_acc, bonus = longest_prefix_match(window, greedy)
        out = (new_cache["layers"], greedy, n_acc, bonus)
        if nan_mask is not None:
            ok = jnp.all(jnp.isfinite(logits), axis=(1, 2))
            out = out + (ok,)
        return out

    if guard:
        if paged:
            fn = jax.jit(lambda params, layers, table, pos, window, mask:
                         verify(params, layers, pos, window, table, mask),
                         donate_argnums=(1,))
        else:
            fn = jax.jit(lambda params, layers, pos, window, mask:
                         verify(params, layers, pos, window, None, mask),
                         donate_argnums=(1,))
    elif paged:
        fn = jax.jit(lambda params, layers, table, pos, window:
                     verify(params, layers, pos, window, table),
                     donate_argnums=(1,))
    else:
        fn = jax.jit(lambda params, layers, pos, window:
                     verify(params, layers, pos, window),
                     donate_argnums=(1,))
    return fn
