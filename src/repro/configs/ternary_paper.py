"""The paper's own benchmark configuration (§4): Y = X·W + b microbenchmark
shapes, exposed as a pseudo-architecture so the benchmark harness and
quickstart can select it. A small decoder-only LM whose every projection is
ternary-quantized, mirroring the paper's target use (ternary-quantized LLM
inference), with K-range covering the paper's sweep (1024..16384).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="ternary-paper",
    family="dense",
    num_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=32768,
    quantization="ternary",
    ternary_min_dim=512,
    fsdp=False,
)

# The paper's microbenchmark parameter grid (Figs 6-11)
PAPER_SPARSITIES = (0.5, 0.25, 0.125, 0.0625)
PAPER_K_RANGE = (1024, 2048, 4096, 8192, 16384)
PAPER_BLOCK_SIZE = 4096
