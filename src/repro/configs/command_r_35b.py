"""command-r-35b [dense] — hf:CohereForAI/c4ai-command-r-v01 (unverified).

40L, d_model=8192, 64H (GQA kv=8), d_ff=22528, vocab=256000, no-bias,
rope_theta=8e6 (cohere), full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="command-r-35b",
    family="dense",
    num_layers=40,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22528,
    vocab_size=256000,
    use_bias=False,
    rope_theta=8_000_000.0,
    grad_accum=8,
    fsdp=True,
)
