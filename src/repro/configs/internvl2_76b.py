"""internvl2-76b [VLM] — arXiv:2404.16821 (unverified).

LM backbone (InternLM2-ish per the assignment row): 80L, d_model=8192,
64H (GQA kv=8), d_ff=28672, vocab=128256. The InternViT frontend is a STUB
per the assignment: ``input_specs()`` provides precomputed (B, S_v, d_model)
patch embeddings prepended to the text sequence. Full attention ->
long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    frontend="vision_patches",
    frontend_seq=1024,        # patch positions in the 4k train cell
    rope_theta=1_000_000.0,
    grad_accum=8,
    fsdp=True,
)
