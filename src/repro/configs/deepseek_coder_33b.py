"""deepseek-coder-33b [dense, llama-arch] — arXiv:2401.14196 (hf).

62L, d_model=7168, 56H (GQA kv=8), d_ff=19200, vocab=32256.
56 heads % 16 != 0 -> attention uses the embed-contraction TP fallback
(DESIGN §6). Full attention -> long_500k skipped.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab_size=32256,
    rope_theta=100_000.0,
    grad_accum=8,
    fsdp=True,
)
