"""mixtral-8x22b [MoE] — arXiv:2401.04088 (hf).

56L, d_model=6144, 48H (GQA kv=8), d_ff=16384 (expert), vocab=32768,
8 experts top-2, SWA (window 4096 per the assignment's "SWA" tag; the rolling
window-bounded KV cache is what makes long_500k decode runnable).
8 experts % 16 != 0 -> experts TP-shard on d_ff_expert, not EP (DESIGN §6).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,
    vocab_size=32768,
    num_experts=8,
    num_experts_per_tok=2,
    d_ff_expert=16384,
    sliding_window=4096,
    rope_theta=1_000_000.0,
    grad_accum=8,
    fsdp=True,
)
