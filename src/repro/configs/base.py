"""Model / run configuration schema.

One ``ModelConfig`` instance fully describes an architecture; the assigned
architectures live in sibling modules (one file per arch) and register
themselves in ``repro.configs.REGISTRY``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "pad_to_multiple"]


def pad_to_multiple(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0            # 0 -> d_model // num_heads
    use_bias: bool = False
    tie_embeddings: bool = False
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    sliding_window: int = 0      # 0 -> full attention
    attn_impl: str = "flash"     # flash | naive
    attn_block_q: int = 512
    attn_block_kv: int = 1024

    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1           # MoE on layers where (layer % moe_every == moe_offset)
    moe_offset: int = 0
    capacity_factor: float = 1.25
    n_shared_experts: int = 0    # kimi-style shared expert (dense, always-on)
    moe_route_blocks: int = 0    # >0: route per token-block (align with the
                                 # DP shard count) — dispatch becomes local
                                 # gathers + expert all-to-all instead of
                                 # global-token all-reduces (§Perf D1)

    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_conv: int = 4
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    ssm_groups: int = 1

    # --- hybrid (jamba-style) ---
    attn_period: int = 0         # every `attn_period`-th layer is attention
    attn_offset: int = 0         # which layer in the period is attention

    # --- enc-dec (seamless-style) ---
    enc_layers: int = 0          # >0 -> encoder-decoder
    frontend: str = ""           # "" | "audio_frames" | "vision_patches"
    frontend_seq: int = 0        # stub frontend positions in train/prefill seq

    # --- quantization (the paper's technique) ---
    quantization: str = "none"   # none | ternary (QAT/STE) | ternary_packed
    ternary_threshold: float = 0.7
    ternary_min_dim: int = 512   # only ternarize matmuls with min dim >= this
    ternary_kernel: str = "auto"  # auto | pallas | xla — packed-linear path:
                                  # pallas = autotuned Pallas ternary_gemm,
                                  # xla = dense-decode XLA reference,
                                  # auto = pallas on TPU backends else xla
    fused_mlp: str = "auto"       # auto | off — fuse packed MLP blocks into
                                  # one kernel (GEMM->act->GEMM, hidden act
                                  # resident in VMEM) when the Pallas path
                                  # is active; bitwise-equal to unfused

    # --- numerics / memory ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    remat: str = "full"          # none | full
    logits_chunk: int = 0        # chunked CE loss (0 = off)

    # --- distribution ---
    fsdp: bool = False           # shard params/opt-state over the data axes
    opt_state_dtype: str = "float32"   # bf16 option = quantized opt states
    grad_accum: int = 1          # microbatch count for gradient accumulation
    decode_cache_shard: str = "seq"    # seq | heads | flat | auto
                                       # (seq: GSPMD select-guarded DUS;
                                       #  flat: (B,S,kv*hd) channel-sharded)
    cache_dtype: str = "bfloat16"      # KV/SSM-conv cache storage dtype
    cache_layout: str = "bshd"         # bshd | opt — opt: K (B,KV,S,hd) /
                                       # V (B,KV,hd,S): transpose-free dots
    paged_attn_impl: str = "auto"      # paged decode-attention lowering
                                       # (auto | jax | pallas — DESIGN.md §9;
                                       # auto = pallas on TPU, else the
                                       # dense-bit-identical jax gather)
    head_pad: int = 0                  # pad q-heads to a TP-divisible count
                                       # (zero wo rows -> identical function)
    gqa_repeat_kv: bool = False        # repeat K/V to H heads: all attention
                                       # einsums shard on the head axis
                                       # (the TP > kv_heads fallback)

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    # ------------------------------------------------------------------
    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def layer_kind(self, i: int) -> str:
        """'attn' or 'ssm' mixer for decoder layer i."""
        if self.family == "ssm":
            return "ssm"
        if self.family == "hybrid" and self.attn_period:
            return "attn" if (i % self.attn_period == self.attn_offset) else "ssm"
        return "attn"

    def layer_ffn(self, i: int) -> str:
        """'moe', 'mlp' or 'none' for decoder layer i."""
        if self.d_ff == 0 and self.num_experts == 0:
            return "none"
        if self.num_experts and (i % self.moe_every == self.moe_offset):
            return "moe"
        return "mlp" if self.d_ff else "none"

    def padded_vocab(self, multiple: int = 16) -> int:
        return pad_to_multiple(self.vocab_size, multiple)

    # ------------------------------------------------------------------
    def param_count(self) -> int:
        """Total parameter count (embedding + layers), analytic."""
        d, v = self.d_model, self.padded_vocab()
        hd = self.head_dim
        total = v * d  # embed
        if not self.tie_embeddings:
            total += d * v

        def attn_params():
            p = d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd \
                + self.num_heads * hd * d
            if self.use_bias:
                p += (self.num_heads + 2 * self.num_kv_heads) * hd + d
            return p

        def mlp_params(ff):
            return 3 * d * ff  # gated (SwiGLU): in, gate, out

        def ssm_params():
            di, s, h = self.d_inner, self.ssm_state, self.ssm_heads
            proj_in = d * (2 * di + 2 * self.ssm_groups * s + h)
            conv = self.ssm_conv * (di + 2 * self.ssm_groups * s)
            return proj_in + conv + 3 * h + di + di * d

        def layer_params(i):
            p = 2 * d  # norms
            p += attn_params() if self.layer_kind(i) == "attn" else ssm_params()
            ffn = self.layer_ffn(i)
            if ffn == "moe":
                p += d * self.num_experts
                p += self.num_experts * mlp_params(self.d_ff_expert)
                p += self.n_shared_experts * mlp_params(self.d_ff_expert)
            elif ffn == "mlp":
                p += mlp_params(self.d_ff)
            return p

        for i in range(self.num_layers):
            total += layer_params(i)
        if self.is_encdec:
            for _ in range(self.enc_layers):
                total += 2 * d + attn_params() + mlp_params(self.d_ff)
            # cross attention per decoder layer
            total += self.num_layers * (d + attn_params())
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared experts only)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        d = self.d_model
        per_expert = 3 * d * self.d_ff_expert
        n_moe_layers = sum(1 for i in range(self.num_layers)
                           if self.layer_ffn(i) == "moe")
        inactive = n_moe_layers * (self.num_experts - self.num_experts_per_tok) \
            * per_expert
        return full - inactive

    # ------------------------------------------------------------------
    def reduced(self, **overrides) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        changes = dict(
            num_layers=min(self.num_layers, 4) if not self.attn_period
            else self.attn_period,
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=32,
            d_ff=256 if self.d_ff else 0,
            vocab_size=512,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            num_experts_per_tok=min(self.num_experts_per_tok, 2),
            d_ff_expert=128 if self.d_ff_expert else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_head_dim=32,
            ssm_chunk=16,
            enc_layers=2 if self.enc_layers else 0,
            capacity_factor=4.0,   # no token dropping in smoke tests:
                                   # keeps decode == forward exactly
            frontend_seq=8 if self.frontend else 0,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            attn_block_q=16,
            attn_block_kv=32,
            remat="none",
            fsdp=False,
        )
        changes.update(overrides)
        return dataclasses.replace(self, **changes)

    def supports_shape(self, shape_name: str) -> Tuple[bool, str]:
        """(supported, reason-if-not). long_500k needs sub-quadratic attention."""
        if shape_name == "long_500k":
            subquad = (self.family in ("ssm", "hybrid")
                       or self.sliding_window > 0)
            if not subquad:
                return False, ("full quadratic attention; 500k decode cache "
                               "infeasible (see DESIGN.md §4)")
        return True, ""
