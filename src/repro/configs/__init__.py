"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the full published config;
``get_config(name, reduced=True)`` returns the CPU-smoke-test reduction.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List

from repro.configs.base import SHAPES, ModelConfig, ShapeConfig

_ARCH_MODULES = [
    "seamless_m4t_large_v2",
    "mistral_nemo_12b",
    "command_r_35b",
    "granite_3_8b",
    "deepseek_coder_33b",
    "jamba_v0_1_52b",
    "kimi_k2_1t_a32b",
    "mixtral_8x22b",
    "mamba2_130m",
    "internvl2_76b",
    "ternary_paper",
]

REGISTRY: Dict[str, ModelConfig] = {}


def _load():
    if REGISTRY:
        return
    for mod in _ARCH_MODULES:
        m = importlib.import_module(f"repro.configs.{mod}")
        cfg = m.CONFIG
        REGISTRY[cfg.name] = cfg


def list_archs() -> List[str]:
    _load()
    return sorted(REGISTRY)


def get_config(name: str, reduced: bool = False, **overrides) -> ModelConfig:
    _load()
    name = name.replace("_", "-")
    cfg = REGISTRY[name]
    if reduced:
        cfg = cfg.reduced()
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg


__all__ = ["get_config", "list_archs", "REGISTRY", "SHAPES", "ModelConfig",
           "ShapeConfig"]
