"""kimi-k2-1t-a32b [MoE, trillion-param] — arXiv:2501.kimi2 (paper-table,
unverified).

61L, d_model=7168, 64H (GQA kv=8), vocab=163840, MoE 384e top-8 with expert
d_ff=2048 (the assignment's exact numbers; the real Kimi-K2 additionally has
MLA attention, one dense first layer and a shared expert — not in the
assignment table, so not modeled; noted per DESIGN.md).

Memory note: ~1T params cannot *train* on <= 2 v5e pods; the dry-run
compiles and EXPERIMENTS.md reports honest bytes/device. fsdp + bf16
optimizer state are on to minimize the gap.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,                   # all layers MoE per the assignment row
    vocab_size=163840,
    num_experts=384,
    num_experts_per_tok=8,
    d_ff_expert=2048,
    rope_theta=50_000.0,
    grad_accum=8,
    fsdp=True,
    opt_state_dtype="bfloat16",
    param_dtype="bfloat16",
)
