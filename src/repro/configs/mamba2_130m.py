"""mamba2-130m [SSM, attention-free] — arXiv:2405.21060 (unverified).

24L, d_model=768, d_ff=0 (pure mamba blocks), vocab=50280, ssm_state=128,
SSD (state-space duality). Attention-free -> runs long_500k (O(1) state).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=256,
    rope_theta=0.0,
    grad_accum=1,
    fsdp=False,
)
