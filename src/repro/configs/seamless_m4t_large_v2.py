"""seamless-m4t-large-v2 [audio, enc-dec] — arXiv:2308.11596 (hf).

24L (24 enc + 24 dec), d_model=1024, 16H (GQA kv=16 = MHA), d_ff=8192,
vocab=256206. Multimodal: the speech frontend is a STUB per the assignment —
``input_specs()`` feeds precomputed (B, S_enc, 1024) frame embeddings into
the text encoder stack. Simplifications vs. the full SeamlessM4T (noted per
DESIGN.md): RoPE replaces the original positional schemes; conformer
convolutions in the speech encoder are not modeled (frontend is a stub).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    num_layers=24,          # decoder
    enc_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    use_bias=True,
    norm_type="layernorm",
    frontend="audio_frames",
    frontend_seq=2048,      # enc positions in the 4k train cell (see DESIGN)
    quantization="none",
    grad_accum=4,
    fsdp=False,
)
