"""jamba-v0.1-52b [hybrid Mamba+attn, MoE] — arXiv:2403.19887 (hf).

32L, d_model=4096, 32H (GQA kv=8), d_ff=14336, vocab=65536; MoE 16e top-2
every 2nd layer; attention every 8th layer (1:7 attn:mamba interleave,
attn_layer_offset=4 as in the HF config). Mamba layers use SSD/Mamba2 form
(DESIGN §4 notes the Mamba1->SSD substitution). Hybrid -> runs long_500k.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    rope_theta=0.0,           # jamba uses no positional encoding
    attn_period=8,
    attn_offset=4,
    num_experts=16,
    num_experts_per_tok=2,
    d_ff_expert=14336,
    moe_every=2,
    moe_offset=1,
    ssm_state=16,
    ssm_conv=4,
    ssm_head_dim=64,
    ssm_expand=2,
    grad_accum=8,
    fsdp=True,
)
