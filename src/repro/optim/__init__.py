from repro.optim.optimizers import (adamw, clip_by_global_norm, global_norm,
                                    sgd_momentum)
from repro.optim.schedules import constant, warmup_cosine

__all__ = ["adamw", "sgd_momentum", "clip_by_global_norm", "global_norm",
           "warmup_cosine", "constant"]
