"""Optimizers, built from scratch (no optax in this environment).

``adamw`` returns (init_fn, update_fn) closures over hyperparameters.
Optimizer state mirrors the param pytree (so param PartitionSpecs apply
leaf-for-leaf — ZeRO-style sharding falls out of fsdp param specs), plus a
scalar step. ``state_dtype='bfloat16'`` stores m/v in bf16 — the
quantized-optimizer-state option used by the 1T-param config.
"""
from __future__ import annotations

from typing import Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["adamw", "sgd_momentum", "clip_by_global_norm", "global_norm"]


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree) if jnp.issubdtype(x.dtype, jnp.floating)]
    return jnp.sqrt(jnp.sum(jnp.asarray(leaves)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(
        lambda g: (g * factor).astype(g.dtype)
        if jnp.issubdtype(g.dtype, jnp.floating) else g, grads), norm


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1, state_dtype: str = "float32"
          ) -> Tuple[Callable, Callable]:
    sdt = jnp.dtype(state_dtype)

    def init(params):
        zeros = lambda p: (jnp.zeros_like(p, dtype=sdt)
                           if jnp.issubdtype(p.dtype, jnp.floating)
                           else jnp.zeros((), sdt))
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params, lr):
        step = state["step"] + 1
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            if not jnp.issubdtype(p.dtype, jnp.floating):
                return p, m, v
            gf = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(gf)
            mhat = m_new / c1
            vhat = v_new / c2
            delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
            p_new = p.astype(jnp.float32) - lr * delta
            return (p_new.astype(p.dtype), m_new.astype(sdt), v_new.astype(sdt))

        flat_p, treedef = jax.tree.flatten(params)
        flat_g = treedef.flatten_up_to(grads)
        flat_m = treedef.flatten_up_to(state["m"])
        flat_v = treedef.flatten_up_to(state["v"])
        out = [upd(g, m, v, p)
               for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
        new_p = treedef.unflatten([o[0] for o in out])
        new_m = treedef.unflatten([o[1] for o in out])
        new_v = treedef.unflatten([o[2] for o in out])
        return new_p, {"m": new_m, "v": new_v, "step": step}

    return init, update


def sgd_momentum(momentum: float = 0.9) -> Tuple[Callable, Callable]:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        new_m = jax.tree.map(lambda m, g: momentum * m + g, state["m"], grads)
        new_p = jax.tree.map(lambda p, m: (p - lr * m).astype(p.dtype),
                             params, new_m)
        return new_p, {"m": new_m, "step": state["step"] + 1}

    return init, update
