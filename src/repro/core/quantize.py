"""Ternary quantization: TWN-style absmean thresholding + QAT (STE).

The paper consumes ternary matrices produced by quantization (its §1 cites
ternary quantization of LLM weights); this module is the substrate that
*produces* them, so the technique is integrated end-to-end:

* ``ternarize``                -- TWN: threshold Δ = t·mean|W|, per-channel
                                  scale α = mean|W| over the surviving mask.
* ``ternarize_target_sparsity``-- exact-sparsity variant (paper benchmarks
                                  sweep s ∈ {1/2, 1/4, 1/8, 1/16}).
* ``ste_ternarize``            -- straight-through estimator for QAT: forward
                                  quantizes, backward is identity (clipped).

All functions are pure-jnp and jittable; per-channel means per output column
(axis 0 of the (K, N) weight).
"""
from __future__ import annotations

from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp

__all__ = [
    "ternarize",
    "ternarize_target_sparsity",
    "ste_ternarize",
    "effective_weight",
]


def ternarize(w: jnp.ndarray, threshold_factor: float = 0.7,
              per_channel: bool = True) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """TWN ternarization. Returns (T int8 in {-1,0,1}, alpha f32 scale).

    Δ = threshold_factor · mean(|W|);  T = sign(W)·1[|W| > Δ];
    α = mean(|W| over |W| > Δ)  (the L1-optimal scale for the mask).
    """
    absw = jnp.abs(w)
    axes = (0,) if per_channel else None
    delta = threshold_factor * jnp.mean(absw, axis=axes, keepdims=True)
    mask = absw > delta
    t = jnp.sign(w) * mask
    denom = jnp.maximum(jnp.sum(mask, axis=axes, keepdims=True), 1)
    alpha = jnp.sum(absw * mask, axis=axes, keepdims=True) / denom
    return t.astype(jnp.int8), alpha.astype(jnp.float32)


def ternarize_target_sparsity(w: jnp.ndarray, sparsity: float,
                              per_channel: bool = True
                              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Ternarize keeping exactly a ``sparsity`` fraction of nonzeros
    (paper convention: sparsity = nnz fraction). Threshold is the
    (1 - sparsity) |W|-quantile per channel."""
    absw = jnp.abs(w)
    axes = 0 if per_channel else None
    delta = jnp.quantile(absw.astype(jnp.float32), 1.0 - sparsity, axis=axes,
                         keepdims=True)
    mask = absw >= delta
    t = jnp.sign(w) * mask
    denom = jnp.maximum(jnp.sum(mask, axis=(0,) if per_channel else None,
                                keepdims=True), 1)
    alpha = jnp.sum(absw * mask, axis=(0,) if per_channel else None,
                    keepdims=True) / denom
    return t.astype(jnp.int8), alpha.astype(jnp.float32)


@partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_ternarize(w: jnp.ndarray, threshold_factor: float = 0.7) -> jnp.ndarray:
    """QAT forward: effective ternary weight α·T. Backward: straight-through
    (gradient clipped to |w| <= 1 range scale, standard STE practice)."""
    t, alpha = ternarize(w, threshold_factor)
    return (t.astype(w.dtype) * alpha.astype(w.dtype))


def _ste_fwd(w, threshold_factor):
    return ste_ternarize(w, threshold_factor), w


def _ste_bwd(threshold_factor, w, g):
    # Straight-through with soft clipping: pass gradients where |w| is not
    # saturated far beyond the quantization range.
    scale = jnp.mean(jnp.abs(w), axis=0, keepdims=True) + 1e-8
    passthrough = (jnp.abs(w) <= 2.0 * scale).astype(g.dtype)
    return (g * passthrough,)


ste_ternarize.defvjp(_ste_fwd, _ste_bwd)


def effective_weight(w: jnp.ndarray, quantization: str,
                     threshold_factor: float = 0.7) -> jnp.ndarray:
    """Forward weight under a quantization mode: 'none' | 'ternary'."""
    if quantization == "ternary":
        return ste_ternarize(w, threshold_factor)
    return w
