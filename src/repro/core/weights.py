"""First-class ternary-weight containers (the typed replacement for the old
untyped ``ternary_gemm`` weight-operand union).

A ``TernaryWeight`` is a JAX pytree: jit/vmap/scan-safe and
``jax.device_put``-table. Array payloads (packed codes, occupancy metadata,
per-channel scale, bias) are pytree *leaves*; everything a kernel planner
needs at trace time (logical shape, tile shapes, pack-time occupancy
summaries) is static auxiliary data, so planning works even when the leaves
are tracers (weights passed as jit arguments) and the container survives
``jax.lax.scan`` slicing of stacked parameter trees unchanged.

One subclass per storage format, registered by name in ``FORMATS``:

* ``Dense2Bit`` -- 2-bit codes, 16 weights per uint32 word (the dense
  Pallas kernel format). Supports stacked leading dims for scan-stacked /
  per-expert weights.
* ``Tiled``     -- 2-bit codes + per-(K-tile, N-tile) occupancy metadata
  (the sparsity-adaptive skipping kernel format, DESIGN.md §3).
* ``Bitplane``  -- plus/minus uint8 bit-masks (structural sign encoding,
  DESIGN.md §4).
* ``Base3``     -- 5 trits per byte (the paper's value-compression format;
  LUT-gather decode, reference kernel only).

Uniform interface::

    wc = weights.pack(w, format="tiled", tile_k=256)   # float or ternary in
    wc.shape, wc.k, wc.n          # logical (K, N)
    wc.occupancy()                # static nnz / tile-occupancy fraction
    wc.nbytes                     # payload bytes (leaves)
    wc.materialize(jnp.float32)   # decoded {-1,0,+1} dense matrix
    kernels.ops.ternary_gemm(x, wc)

New formats register in one place (``@register_format``) and become
dispatchable once a kernel lowering is registered for them in
``repro.kernels.ops`` (see ``register_kernel`` there).

Sharding convention: parameter spec trees mirror the container structure —
build the spec twin with ``dataclasses.replace(wc, packed=P(...), ...)`` so
the two trees flatten identically (``models/layers.py`` does this).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats, quantize

__all__ = [
    "TernaryWeight",
    "Dense2Bit",
    "Tiled",
    "Bitplane",
    "Base3",
    "FORMATS",
    "register_format",
    "pack",
    "ternarize_stacked",
    "validate_spec_twin",
]

# name -> container class; the single place new layouts register.
FORMATS: Dict[str, Type["TernaryWeight"]] = {}


class _PackStat(int):
    """Pack-time statistic (nnz / occupied-tile count) riding in pytree aux
    data. It survives flatten/unflatten but is excluded from treedef
    *identity* (always-equal under ``==``, constant hash): a packed-from-
    latent container (real nnz) stays structurally compatible with its
    init-time sharding-spec twin (nnz=-1) and with other packs of the same
    layout — ``tree_map``/``resolve_specs``/scan stacking never see a
    mismatch. Safe because every registered kernel lowering computes the
    same Y: statistics steer impl *choice*, never numerics."""

    __slots__ = ()

    def __eq__(self, other):
        return isinstance(other, _PackStat)

    def __ne__(self, other):
        return not isinstance(other, _PackStat)

    def __hash__(self):
        return 0


def register_format(name: str):
    """Class decorator: register a ``TernaryWeight`` subclass under ``name``
    and make it a JAX pytree (with named key paths, so checkpoints get
    readable leaf keys like ``.../w_packed/packed``).

    The subclass declares its array fields in ``_leaves``; every other
    dataclass field is static aux data (must be hashable). Fields named in
    ``_stats`` are wrapped in ``_PackStat`` so they ride along without
    contributing to treedef identity."""

    def deco(cls):
        cls.format_name = name
        FORMATS[name] = cls
        field_names = [f.name for f in dataclasses.fields(cls)]
        leaf_names = tuple(cls._leaves)
        stat_names = frozenset(cls._stats)
        static_names = tuple(n for n in field_names if n not in leaf_names)

        def aux_of(obj):
            return tuple(
                _PackStat(getattr(obj, n)) if n in stat_names
                else getattr(obj, n) for n in static_names)

        def flatten_with_keys(obj):
            children = [(jax.tree_util.GetAttrKey(n), getattr(obj, n))
                        for n in leaf_names]
            return children, aux_of(obj)

        def flatten(obj):
            return [getattr(obj, n) for n in leaf_names], aux_of(obj)

        def unflatten(aux, children):
            kw = dict(zip(leaf_names, children))
            # unwrap stats back to plain ints: _PackStat's always-equal
            # semantics belong to treedef aux only, never to the fields
            # user code compares against
            kw.update((n, int(v) if n in stat_names else v)
                      for n, v in zip(static_names, aux))
            return cls(**kw)

        jax.tree_util.register_pytree_with_keys(
            cls, flatten_with_keys, unflatten, flatten)
        return cls

    return deco


def _nbytes(v) -> int:
    if v is None:
        return 0
    if hasattr(v, "nbytes"):
        return int(v.nbytes)
    size = getattr(v, "size", None)
    dt = getattr(v, "dtype", None)
    if size is not None and dt is not None:        # tracers / shape structs
        return int(size) * np.dtype(dt).itemsize
    return 0


class TernaryWeight:
    """Base class: common derived views over the per-format dataclasses.

    Subclasses are frozen dataclasses with fields split into array leaves
    (``_leaves``) and static aux metadata. All carry:

    * ``shape`` -- logical (K, N) of the encoded ternary matrix (leading
      stack dims of the leaves, if any, are *not* part of ``shape``);
    * ``nnz``   -- pack-time nonzero count (-1 when unknown, e.g. a wrapped
      pre-packed buffer);
    * ``scale`` / ``bias`` -- optional per-output-channel epilogue operands
      consumed by ``ternary_gemm`` when the caller passes none explicitly.
    """

    format_name = "abstract"
    _leaves: Tuple[str, ...] = ()
    _stats: Tuple[str, ...] = ("nnz",)

    # --- logical geometry -------------------------------------------------
    @property
    def k(self) -> int:
        return self.shape[0]

    @property
    def n(self) -> int:
        return self.shape[1]

    @property
    def nbytes(self) -> int:
        """Total payload bytes across array leaves (codes + metadata +
        scale/bias), the serving-memory figure of merit."""
        return sum(_nbytes(getattr(self, f)) for f in self._leaves)

    def occupancy(self) -> float:
        """Nonzero fraction recorded at pack time (1.0 when unknown — the
        dense assumption). ``Tiled`` overrides with the tile-occupancy
        fraction the skip planner consumes."""
        if self.nnz < 0:
            return 1.0
        return self.nnz / max(self.k * self.n, 1)

    def shard_constraints(self) -> Dict[str, Tuple[int, int]]:
        """Tensor-parallel shard-boundary constraints of the *physical*
        encoding: ``{"k": (extent, multiple), "n": (extent, multiple)}``.

        ``extent`` is the physical size of that logical axis as stored
        (tile-padded for ``Tiled``) and ``multiple`` the value count one
        indivisible pack unit covers (a 2-bit uint32 word spans 16 K
        values, a bitplane byte 8, a base-3 byte 5, a skip tile
        ``tile_k``/``tile_n``). A mesh shard boundary that does not land
        on ``multiple`` would split a pack word/tile across devices —
        ``validate_spec_twin`` rejects such specs at placement time."""
        return {"k": (self.k, 1), "n": (self.n, 1)}

    # --- conversions ------------------------------------------------------
    def materialize(self, dtype=jnp.float32, with_scale: bool = False):
        """Decode to the dense {-1,0,+1} matrix (stacked leading dims of the
        leaves are preserved). ``with_scale`` multiplies the per-channel
        scale in, yielding the effective float weight."""
        raise NotImplementedError

    def replace(self, **kw) -> "TernaryWeight":
        """``dataclasses.replace`` passthrough (handy for attaching
        scale/bias after construction, or building sharding-spec twins)."""
        return dataclasses.replace(self, **kw)

    def device_put(self, device=None) -> "TernaryWeight":
        return jax.device_put(self, device)

    def _apply_scale(self, t, with_scale: bool, dtype):
        if with_scale and self.scale is not None:
            t = t * jnp.asarray(self.scale).astype(dtype)[..., None, :]
        return t

    def __repr__(self) -> str:  # leaves may be tracers; keep repr static
        return (f"{type(self).__name__}(shape={self.shape}, "
                f"nnz={self.nnz}, nbytes={self.nbytes})")


def _pack_stacked(t: np.ndarray, pack_fn) -> np.ndarray:
    """Apply a 2-D host packer over arbitrary leading stack dims."""
    lead = t.shape[:-2]
    t2 = t.reshape((-1,) + t.shape[-2:])
    packed = np.stack([pack_fn(t2[i]) for i in range(t2.shape[0])])
    return packed.reshape(lead + packed.shape[-2:])


def _decode_stacked(packed, decode_fn, k: int, dtype):
    """vmap a 2-D decoder over arbitrary leading stack dims."""
    p = jnp.asarray(packed)
    lead = p.shape[:-2]
    p2 = p.reshape((-1,) + p.shape[-2:])
    dec = jax.vmap(lambda q: decode_fn(q, k, dtype))(p2)
    return dec.reshape(lead + dec.shape[-2:])


# ---------------------------------------------------------------------------
# Dense2Bit — 16 weights / uint32 word (the dense Pallas kernel format)
# ---------------------------------------------------------------------------

@register_format("dense2bit")
@dataclasses.dataclass(frozen=True)
class Dense2Bit(TernaryWeight):
    packed: Any                       # (..., ceil(K/16), N) uint32
    scale: Optional[Any]              # (..., N) or None
    bias: Optional[Any]               # (..., N) or None
    shape: Tuple[int, int]            # logical (K, N)
    nnz: int = -1

    _leaves = ("packed", "scale", "bias")

    @classmethod
    def from_dense(cls, t, scale=None, bias=None) -> "Dense2Bit":
        """Host-side pack of a {-1,0,+1} matrix (any leading stack dims).
        ``nnz`` records the *mean per-matrix* count so ``occupancy()`` stays
        a fraction of the logical (K, N) both stacked and scan-sliced."""
        t = np.asarray(t)
        n_stack = max(int(np.prod(t.shape[:-2], dtype=np.int64)), 1)
        return cls(packed=jnp.asarray(_pack_stacked(t, formats.pack_2bit)),
                   scale=scale, bias=bias, shape=t.shape[-2:],
                   nnz=int(round(np.count_nonzero(t) / n_stack)))

    @classmethod
    def from_packed(cls, packed, k: int, scale=None, bias=None,
                    nnz: int = -1) -> "Dense2Bit":
        """Wrap an existing packed word buffer (``formats.pack_2bit``
        layout). ``k`` is the logical K; words may be K-padded beyond it."""
        kw, n = packed.shape[-2:]
        if kw * 16 < k:
            raise ValueError(
                f"packed words cover K={kw * 16} < logical k={k}")
        return cls(packed=packed, scale=scale, bias=bias, shape=(k, n),
                   nnz=nnz)

    def materialize(self, dtype=jnp.float32, with_scale: bool = False):
        t = _decode_stacked(self.packed, formats.decode_2bit, self.k, dtype)
        return self._apply_scale(t[..., :self.n], with_scale, dtype)

    def shard_constraints(self) -> Dict[str, Tuple[int, int]]:
        return {"k": (self.k, 16), "n": (self.n, 1)}


# ---------------------------------------------------------------------------
# Tiled — 2-bit codes + per-tile occupancy metadata (skip kernel format)
# ---------------------------------------------------------------------------

@register_format("tiled")
@dataclasses.dataclass(frozen=True)
class Tiled(TernaryWeight):
    packed: Any                       # (Kp/16, Np) uint32 (K/N tile-padded)
    kt_indices: Any                   # (n_ntiles, max_occ) int32
    kt_counts: Any                    # (n_ntiles,) int32
    scale: Optional[Any]
    bias: Optional[Any]
    shape: Tuple[int, int]            # logical (K, N)
    tile_k: int = 256
    tile_n: int = 128
    nnz: int = -1
    occupied_tiles: int = 0           # pack-time occupied-tile count

    _leaves = ("packed", "kt_indices", "kt_counts", "scale", "bias")
    _stats = ("nnz", "occupied_tiles")

    @classmethod
    def from_tiled(cls, tt: formats.TiledTernary, scale=None,
                   bias=None) -> "Tiled":
        return cls(packed=jnp.asarray(tt.packed),
                   kt_indices=jnp.asarray(tt.kt_indices),
                   kt_counts=jnp.asarray(tt.kt_counts),
                   scale=scale, bias=bias, shape=tt.shape,
                   tile_k=tt.tile_k, tile_n=tt.tile_n,
                   nnz=int(tt.tile_nnz.sum()),
                   occupied_tiles=tt.occupied_tiles())

    @classmethod
    def from_dense(cls, t, scale=None, bias=None, tile_k: int = 256,
                   tile_n: int = 128) -> "Tiled":
        tt = formats.TiledTernary.from_dense(np.asarray(t), tile_k=tile_k,
                                             tile_n=tile_n)
        return cls.from_tiled(tt, scale=scale, bias=bias)

    # --- tile geometry (all static: derived from shapes + aux) -----------
    @property
    def n_ktiles(self) -> int:
        return self.packed.shape[-2] * 16 // self.tile_k

    @property
    def n_ntiles(self) -> int:
        return self.packed.shape[-1] // self.tile_n

    @property
    def max_occ(self) -> int:
        return self.kt_indices.shape[-1]

    def total_tiles(self) -> int:
        return self.n_ktiles * self.n_ntiles

    def visited_tiles(self) -> int:
        """Static grid bound of the skip kernel: N-tiles x max occupancy."""
        return self.n_ntiles * self.max_occ

    def occupancy(self) -> float:
        """Occupied-tile fraction — the skip/dense planning signal."""
        return self.occupied_tiles / max(self.total_tiles(), 1)

    def materialize(self, dtype=jnp.float32, with_scale: bool = False):
        kp = self.packed.shape[-2] * 16
        t = formats.decode_2bit(jnp.asarray(self.packed), kp, dtype)
        return self._apply_scale(t[:self.k, :self.n], with_scale, dtype)

    def shard_constraints(self) -> Dict[str, Tuple[int, int]]:
        # the occupancy metadata (kt_indices/kt_counts) is per (K-tile,
        # N-tile): shard boundaries must land on whole tiles of the
        # *padded* grid, not just on pack words
        return {"k": (self.n_ktiles * self.tile_k, self.tile_k),
                "n": (self.n_ntiles * self.tile_n, self.tile_n)}


# ---------------------------------------------------------------------------
# Bitplane — plus/minus uint8 masks (structural sign encoding)
# ---------------------------------------------------------------------------

@register_format("bitplane")
@dataclasses.dataclass(frozen=True)
class Bitplane(TernaryWeight):
    plus: Any                         # (ceil(K/8), N) uint8
    minus: Any                        # (ceil(K/8), N) uint8
    scale: Optional[Any]
    bias: Optional[Any]
    shape: Tuple[int, int]
    nnz: int = -1

    _leaves = ("plus", "minus", "scale", "bias")

    @classmethod
    def from_dense(cls, t, scale=None, bias=None) -> "Bitplane":
        t = np.asarray(t)
        plus, minus = formats.pack_bitplanes(t)
        return cls(plus=jnp.asarray(plus), minus=jnp.asarray(minus),
                   scale=scale, bias=bias, shape=t.shape,
                   nnz=int(np.count_nonzero(t)))

    @classmethod
    def from_planes(cls, plus, minus, k: int, scale=None, bias=None,
                    nnz: int = -1) -> "Bitplane":
        if plus.shape != minus.shape:
            raise ValueError(f"plane shapes differ: {plus.shape} vs "
                             f"{minus.shape}")
        kb, n = plus.shape[-2:]
        if kb * 8 < k:
            raise ValueError(f"bitplanes cover K={kb * 8} < logical k={k}")
        return cls(plus=plus, minus=minus, scale=scale, bias=bias,
                   shape=(k, n), nnz=nnz)

    def materialize(self, dtype=jnp.float32, with_scale: bool = False):
        t = formats.decode_bitplanes(jnp.asarray(self.plus),
                                     jnp.asarray(self.minus), self.k,
                                     dtype=dtype)
        return self._apply_scale(t[..., :self.n], with_scale, dtype)

    def shard_constraints(self) -> Dict[str, Tuple[int, int]]:
        return {"k": (self.k, 8), "n": (self.n, 1)}


# ---------------------------------------------------------------------------
# Base3 — 5 trits / byte (paper's value compression; ref kernel only)
# ---------------------------------------------------------------------------

@register_format("base3")
@dataclasses.dataclass(frozen=True)
class Base3(TernaryWeight):
    packed: Any                       # (ceil(K/5), N) uint8
    scale: Optional[Any]
    bias: Optional[Any]
    shape: Tuple[int, int]
    nnz: int = -1

    _leaves = ("packed", "scale", "bias")

    @classmethod
    def from_dense(cls, t, scale=None, bias=None) -> "Base3":
        t = np.asarray(t)
        return cls(packed=jnp.asarray(formats.pack_base3(t)),
                   scale=scale, bias=bias, shape=t.shape,
                   nnz=int(np.count_nonzero(t)))

    def materialize(self, dtype=jnp.float32, with_scale: bool = False):
        t = formats.decode_base3(jnp.asarray(self.packed), self.k,
                                 dtype=dtype)
        return self._apply_scale(t[..., :self.n], with_scale, dtype)

    def shard_constraints(self) -> Dict[str, Tuple[int, int]]:
        return {"k": (self.k, 5), "n": (self.n, 1)}


# ---------------------------------------------------------------------------
# pack — the one entry point producers use
# ---------------------------------------------------------------------------

def ternarize_stacked(w, threshold: float = 0.7):
    """Host-side per-matrix ternarization (TWN absmean) over arbitrary
    leading stack dims: (..., K, N) float -> ({-1,0,1} (..., K, N) int8,
    per-channel scales (..., N) f32)."""
    w = np.asarray(w)
    lead, (k, n) = w.shape[:-2], w.shape[-2:]
    w2 = w.reshape((-1, k, n))
    ts, scales = [], []
    for i in range(w2.shape[0]):
        t, alpha = quantize.ternarize(jnp.asarray(w2[i], jnp.float32),
                                      threshold)
        ts.append(np.asarray(t))
        scales.append(np.asarray(alpha, np.float32).reshape(-1))
    return (np.stack(ts).reshape(lead + (k, n)),
            np.stack(scales).reshape(lead + (n,)))


def pack(w, format: str = "dense2bit", *, scale=None, bias=None,
         threshold: float = 0.7, **opts) -> TernaryWeight:
    """Pack a weight matrix into the requested ternary container.

    ``w`` is either an already-ternary {-1,0,+1} integer matrix, or a float
    matrix which is first ternarized per-matrix (TWN absmean,
    ``core.quantize``; leading stack dims supported where the format
    supports them) — in the float case the per-channel ternarization scale
    becomes the container's ``scale`` unless one is passed explicitly.
    ``**opts`` are format-specific (e.g. ``tile_k``/``tile_n`` for
    ``"tiled"``).
    """
    if format not in FORMATS:
        raise ValueError(f"unknown ternary format {format!r}; registered: "
                         f"{sorted(FORMATS)}")
    w = np.asarray(w)
    if np.issubdtype(w.dtype, np.floating) or w.dtype.kind == "V":
        t, scales = ternarize_stacked(w, threshold)
        if scale is None:
            scale = jnp.asarray(scales)
    else:
        t = w
    return FORMATS[format].from_dense(t, scale=scale, bias=bias, **opts)


# ---------------------------------------------------------------------------
# Spec-twin validation — pack-boundary enforcement for tensor parallelism
# ---------------------------------------------------------------------------

def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    """Accept a ``jax.sharding.Mesh`` (or anything with ``.shape``
    mapping axis name -> size) or a plain ``{name: size}`` dict."""
    shape = getattr(mesh, "shape", mesh)
    return dict(shape)


def _resolve_split(ax, sizes: Dict[str, int], used: set, fsdp: bool):
    """Mirror ``distributed.sharding.resolve_spec``'s axis-name resolution
    (logical "fsdp"/"expert" names, tuples, literal mesh names, the
    no-reuse rule) *without* its silent replicate-on-indivisible fallback —
    return (split size, resolved axis names)."""
    if ax is None:
        return 1, ()
    if ax == "fsdp":
        axes = (tuple(a for a in ("pod", "data") if a in sizes)
                if fsdp else ())
    elif ax == "expert":
        axes = ("model",) if "model" in sizes else ()
    elif isinstance(ax, (tuple, list)):
        axes = tuple(a for a in ax if a in sizes)
    else:
        axes = (ax,) if ax in sizes else ()
    axes = tuple(a for a in axes if a not in used)
    size = 1
    for a in axes:
        size *= sizes[a]
    used.update(axes)
    return size, axes


def validate_spec_twin(wc: TernaryWeight, twin, mesh, *,
                       fsdp: bool = False) -> None:
    """Reject a PartitionSpec spec twin whose shard boundaries would split
    a pack word or skip tile across devices.

    ``twin`` is the container's sharding-spec twin (the same dataclass with
    PartitionSpec leaves, as built by ``models.layers.linear_init``);
    ``mesh`` supplies the axis sizes. The physical encodings are
    indivisible below their pack unit — 16 values per 2-bit uint32 word,
    8 per bitplane byte, 5 per base-3 byte, a whole ``tile_k x tile_n``
    tile for the skip format — so a K (or N, for tiled) shard boundary off
    that multiple has no representable per-device layout. Today such specs
    would be silently replicated at resolve time; serving placement calls
    this first so they fail loudly with the offending axis and the nearest
    legal boundary instead.

    Raises ``ValueError``; returns ``None`` when the twin is legal.
    """
    spec = None
    for name in ("packed", "plus"):
        cand = getattr(twin, name, None)
        if cand is not None and not isinstance(cand, TernaryWeight):
            spec = cand
            break
    if spec is None:                      # nothing sharded -> nothing to do
        return
    sizes = _mesh_axis_sizes(mesh)
    cons = wc.shard_constraints()
    # align the spec to the primary leaf's trailing (K-pack, N) axes —
    # scan-stacked twins carry leading None entries (transformer._stack_specs)
    entries = tuple(spec)
    if len(entries) < 2:
        entries = (None,) * (2 - len(entries)) + entries
    used: set = set()
    splits = []
    for ax in entries[:-2]:               # leading stack dims burn axes too
        _resolve_split(ax, sizes, used, fsdp)
    for ax in entries[-2:]:
        splits.append(_resolve_split(ax, sizes, used, fsdp))
    for (tp, axes), dim in zip(splits, ("k", "n")):
        if tp <= 1:
            continue
        extent, multiple = cons[dim]
        if extent % (tp * multiple) == 0:
            continue
        per_shard = extent / tp
        legal = max(multiple, int(round(per_shard / multiple)) * multiple)
        raise ValueError(
            f"{wc.format_name} spec twin: sharding {dim.upper()} over mesh "
            f"axis {axes if len(axes) > 1 else axes[0]!r} ({tp}-way) puts "
            f"shard boundaries every {per_shard:g} of {extent} values — "
            f"off the {multiple}-value pack multiple of {wc!r}. Per-shard "
            f"{dim.upper()} must be a multiple of {multiple} that divides "
            f"{extent}; nearest legal boundary is {legal}.")
