from repro.core import formats, quantize

__all__ = ["formats", "quantize"]
