from repro.core import formats, quantize, weights
from repro.core.weights import (Base3, Bitplane, Dense2Bit, TernaryWeight,
                                Tiled, pack, register_format)

__all__ = ["formats", "quantize", "weights", "TernaryWeight", "Dense2Bit",
           "Tiled", "Bitplane", "Base3", "pack", "register_format"]
