"""Sparse ternary weight formats.

This module is the JAX/TPU adaptation of the paper's data-format contributions:

* ``TCSC``            -- the paper's baseline Ternary Compressed Sparse Column.
* ``BlockedTCSC``     -- K-axis blocked TCSC (paper's cache-window insight).
* ``InterleavedTCSC`` -- single-pass interleaved +/- index groups.
* ``pack_bitplanes``  -- two packed bit-masks (plus/minus plane). Structural
                         sign encoding, vector-decodable (TPU-native TCSC).
* ``pack_2bit``       -- 2-bit codes, 16 weights / int32 word: the format the
                         Pallas kernel consumes.
* ``TiledTernary``    -- 2-bit codes + per-(K-tile, N-tile) occupancy metadata
                         recorded at pack time; feeds the scalar-prefetch
                         tile-skipping Pallas kernel (DESIGN.md §3).
* ``pack_base3``      -- the paper's 5-values-per-byte base-3 compression
                         (prototyped & dropped in the paper; kept here for the
                         benchmark record).

Construction happens host-side in numpy; all ``decode_*`` functions are pure
jnp and jittable (they run inside the XLA ternary path and the tests).

Conventions: the ternary matrix ``W`` has shape ``(K, N)`` with values in
{-1, 0, +1} (stored as int8). ``Y = X @ W`` with ``X: (M, K)``.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

__all__ = [
    "TCSC",
    "BlockedTCSC",
    "InterleavedTCSC",
    "pack_bitplanes",
    "decode_bitplanes",
    "pack_2bit",
    "decode_2bit",
    "TiledTernary",
    "pack_base3",
    "decode_base3",
    "base3_lut",
    "random_ternary",
    "random_tile_ternary",
]


# ---------------------------------------------------------------------------
# Random ternary generation (benchmark / test input, paper §4 setup)
# ---------------------------------------------------------------------------

def random_ternary(rng: np.random.Generator, k: int, n: int, sparsity: float) -> np.ndarray:
    """Random ternary (K, N) int8 matrix with ``sparsity`` nnz fraction.

    Follows the paper's convention: ``sparsity`` is the *fraction of non-zero*
    elements (s in {1/2, 1/4, 1/8, 1/16}), split evenly between +1 and -1.
    """
    nnz = int(round(k * n * sparsity))
    w = np.zeros(k * n, dtype=np.int8)
    idx = rng.choice(k * n, size=nnz, replace=False)
    signs = rng.integers(0, 2, size=nnz, dtype=np.int8) * 2 - 1
    w[idx] = signs
    return w.reshape(k, n)


def random_tile_ternary(rng: np.random.Generator, k: int, n: int,
                        tile_k: int, tile_n: int, sparsity: float,
                        inner_density: float = 0.5) -> np.ndarray:
    """Tile-structured sparse ternary (K, N): the workload the skipping
    kernel is built for (pruned / expert-gated weights, DESIGN.md §3).

    Each N-tile column gets the same number of occupied K-tiles
    (``round(min(1, sparsity/inner_density) * n_ktiles)``, chosen at random),
    and occupied tiles are filled i.i.d. so the *overall* nnz fraction is
    ``sparsity`` — occupancy falls in proportion to sparsity, uniformly
    enough that the static max-occupancy grid bound is tight.
    """
    assert k % tile_k == 0 and n % tile_n == 0, (k, n, tile_k, tile_n)
    nkt, nnt = k // tile_k, n // tile_n
    w = np.zeros((k, n), dtype=np.int8)
    if sparsity <= 0:
        return w
    frac = min(1.0, sparsity / inner_density)
    per_col = max(1, int(round(frac * nkt)))
    inner = sparsity * nkt / per_col
    for j in range(nnt):
        for r in rng.choice(nkt, size=per_col, replace=False):
            w[r * tile_k:(r + 1) * tile_k, j * tile_n:(j + 1) * tile_n] = \
                random_ternary(rng, tile_k, tile_n, inner)
    return w


# ---------------------------------------------------------------------------
# TCSC -- the paper's baseline format (§2)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TCSC:
    """Ternary Compressed Sparse Column.

    For column j: +1 rows are ``row_index_pos[col_start_pos[j]:col_start_pos[j+1]]``
    and -1 rows are ``row_index_neg[col_start_neg[j]:col_start_neg[j+1]]``.
    Sign is structural (array choice); no value array exists.
    """

    col_start_pos: np.ndarray  # (N+1,) int32
    col_start_neg: np.ndarray  # (N+1,) int32
    row_index_pos: np.ndarray  # (nnz_pos,) int32
    row_index_neg: np.ndarray  # (nnz_neg,) int32
    shape: Tuple[int, int]     # (K, N)

    @classmethod
    def from_dense(cls, w: np.ndarray) -> "TCSC":
        k, n = w.shape
        col_start_pos = np.zeros(n + 1, dtype=np.int32)
        col_start_neg = np.zeros(n + 1, dtype=np.int32)
        rows_pos, rows_neg = [], []
        for j in range(n):
            pos = np.nonzero(w[:, j] > 0)[0]
            neg = np.nonzero(w[:, j] < 0)[0]
            rows_pos.append(pos)
            rows_neg.append(neg)
            col_start_pos[j + 1] = col_start_pos[j] + len(pos)
            col_start_neg[j + 1] = col_start_neg[j] + len(neg)
        cat = lambda xs: (np.concatenate(xs).astype(np.int32) if xs else np.zeros(0, np.int32))
        return cls(col_start_pos, col_start_neg, cat(rows_pos), cat(rows_neg), (k, n))

    def to_dense(self) -> np.ndarray:
        k, n = self.shape
        w = np.zeros((k, n), dtype=np.int8)
        for j in range(n):
            w[self.row_index_pos[self.col_start_pos[j]:self.col_start_pos[j + 1]], j] = 1
            w[self.row_index_neg[self.col_start_neg[j]:self.col_start_neg[j + 1]], j] = -1
        return w

    # Flattened (segment-sum friendly) views used by the jnp reference kernels.
    def segment_ids_pos(self) -> np.ndarray:
        return np.repeat(np.arange(self.shape[1], dtype=np.int32), np.diff(self.col_start_pos))

    def segment_ids_neg(self) -> np.ndarray:
        return np.repeat(np.arange(self.shape[1], dtype=np.int32), np.diff(self.col_start_neg))

    def nbytes(self) -> int:
        return (self.col_start_pos.nbytes + self.col_start_neg.nbytes
                + self.row_index_pos.nbytes + self.row_index_neg.nbytes)


# ---------------------------------------------------------------------------
# BlockedTCSC -- §3 "Blocking"
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class BlockedTCSC:
    """TCSC re-organized block-by-block along K (block size B).

    Iteration order becomes: for each block b, for each column j, process rows
    in [b*B, (b+1)*B) -- confining the X gather window to B elements. Arrays
    are the per-block TCSC arrays concatenated; ``blocks[b]`` is a TCSC whose
    row indices are *relative to the block base* (so the gather window is
    [0, B) for every phase, exactly the paper's locality property).
    """

    block_size: int
    blocks: Tuple[TCSC, ...]
    shape: Tuple[int, int]

    @classmethod
    def from_dense(cls, w: np.ndarray, block_size: int = 4096) -> "BlockedTCSC":
        k, n = w.shape
        blocks = []
        for b0 in range(0, k, block_size):
            blocks.append(TCSC.from_dense(w[b0:b0 + block_size, :]))
        return cls(block_size, tuple(blocks), (k, n))

    def to_dense(self) -> np.ndarray:
        return np.concatenate([b.to_dense() for b in self.blocks], axis=0)

    def nbytes(self) -> int:
        return sum(b.nbytes() for b in self.blocks)


# ---------------------------------------------------------------------------
# InterleavedTCSC -- §3 "Interleaving"
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InterleavedTCSC:
    """Interleaved +/- groups in a single index vector (group size G).

    Per column, three segments (paper's ``col_segment_ptr``):
      1. interleaved groups: G positive indices then G negative indices,
         repeated while both signs have >= G left;
      2. remaining positives;
      3. remaining negatives.
    ``col_segment_ptr`` has 3 pointers per column + final end: shape (3N+1,).
    """

    group: int
    all_indices: np.ndarray      # (nnz,) int32
    col_segment_ptr: np.ndarray  # (3N+1,) int32
    shape: Tuple[int, int]

    @classmethod
    def from_dense(cls, w: np.ndarray, group: int = 4) -> "InterleavedTCSC":
        k, n = w.shape
        idx_chunks = []
        ptr = [0]
        total = 0
        for j in range(n):
            pos = np.nonzero(w[:, j] > 0)[0].astype(np.int32)
            neg = np.nonzero(w[:, j] < 0)[0].astype(np.int32)
            g = min(len(pos), len(neg)) // group
            inter = np.empty(2 * g * group, dtype=np.int32)
            for t in range(g):
                inter[2 * t * group: (2 * t + 1) * group] = pos[t * group:(t + 1) * group]
                inter[(2 * t + 1) * group: (2 * t + 2) * group] = neg[t * group:(t + 1) * group]
            rem_pos = pos[g * group:]
            rem_neg = neg[g * group:]
            idx_chunks += [inter, rem_pos, rem_neg]
            total += len(inter)
            ptr.append(total)          # end of interleaved segment
            total += len(rem_pos)
            ptr.append(total)          # end of remaining-positive segment
            total += len(rem_neg)
            ptr.append(total)          # end of remaining-negative segment
        all_indices = (np.concatenate(idx_chunks).astype(np.int32)
                       if idx_chunks else np.zeros(0, np.int32))
        return cls(group, all_indices, np.asarray(ptr, dtype=np.int32), (k, n))

    def to_dense(self) -> np.ndarray:
        k, n = self.shape
        w = np.zeros((k, n), dtype=np.int8)
        g = self.group
        for j in range(n):
            s0, s1, s2, s3 = self.col_segment_ptr[3 * j:3 * j + 4]
            inter = self.all_indices[s0:s1]
            for t in range(len(inter) // (2 * g)):
                w[inter[2 * t * g:(2 * t + 1) * g], j] = 1
                w[inter[(2 * t + 1) * g:(2 * t + 2) * g], j] = -1
            w[self.all_indices[s1:s2], j] = 1
            w[self.all_indices[s2:s3], j] = -1
        return w

    def signs(self) -> np.ndarray:
        """+1/-1 sign per entry of ``all_indices`` (decoded structurally)."""
        n = self.shape[1]
        g = self.group
        out = np.empty_like(self.all_indices, dtype=np.int8)
        for j in range(n):
            s0, s1, s2, s3 = self.col_segment_ptr[3 * j:3 * j + 4]
            span = s1 - s0
            pattern = np.tile(np.repeat(np.array([1, -1], np.int8), g), span // (2 * g) + 1)
            out[s0:s1] = pattern[:span]
            out[s1:s2] = 1
            out[s2:s3] = -1
        return out

    def segment_ids(self) -> np.ndarray:
        counts = self.col_segment_ptr[3::3] - self.col_segment_ptr[:-1:3]
        return np.repeat(np.arange(self.shape[1], dtype=np.int32), counts)

    def nbytes(self) -> int:
        return self.all_indices.nbytes + self.col_segment_ptr.nbytes


# ---------------------------------------------------------------------------
# Bitplane packing -- TPU-native structural-sign format
# ---------------------------------------------------------------------------

def pack_bitplanes(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Pack (K, N) ternary into two uint8 bitplanes of shape (ceil(K/8), N).

    Bit ``r`` of ``plus[q, n]`` is 1 iff ``w[8q + r, n] == +1`` (same for
    minus/-1). The sign lives in *which plane* the bit occupies -- the
    paper's structural-sign-encoding insight, in vector-decodable form.
    """
    k, n = w.shape
    kp = -(-k // 8) * 8
    wp = np.zeros((kp, n), dtype=np.int8)
    wp[:k] = w
    plus = (wp == 1).astype(np.uint8).reshape(kp // 8, 8, n)
    minus = (wp == -1).astype(np.uint8).reshape(kp // 8, 8, n)
    shifts = (1 << np.arange(8, dtype=np.uint8)).reshape(1, 8, 1)
    return ((plus * shifts).sum(1).astype(np.uint8),
            (minus * shifts).sum(1).astype(np.uint8))


def decode_bitplanes(plus: jnp.ndarray, minus: jnp.ndarray, k: int,
                     dtype=jnp.bfloat16) -> jnp.ndarray:
    """jnp decode: two (K/8, N) uint8 planes -> (k, N) ±1/0 matrix."""
    q, n = plus.shape
    shifts = jnp.arange(8, dtype=jnp.uint8).reshape(1, 8, 1)
    p = (plus[:, None, :] >> shifts) & 1
    m = (minus[:, None, :] >> shifts) & 1
    vals = (p.astype(jnp.int8) - m.astype(jnp.int8)).reshape(q * 8, n)
    return vals[:k].astype(dtype)


# ---------------------------------------------------------------------------
# 2-bit packing -- the Pallas kernel format (16 weights / int32 word)
# ---------------------------------------------------------------------------
# Codes: 0 -> 0, 1 -> +1, 2 -> -1 (3 unused). decode(c) = (c & 1) - ((c>>1)&1).

def pack_2bit(w: np.ndarray, word: int = 32) -> np.ndarray:
    """Pack (K, N) ternary into (ceil(K/(word/2)), N) u{word} codes.

    With the default 32-bit words, row q holds k = word/2 = 16 consecutive
    K-entries: bits [2r, 2r+2) of word[q, n] encode w[16q + r, n].
    """
    assert word in (8, 32)
    per = word // 2
    k, n = w.shape
    kp = -(-k // per) * per
    codes = np.zeros((kp, n), dtype=np.uint32)
    codes[:k][w == 1] = 1
    codes[:k][w == -1] = 2
    codes = codes.reshape(kp // per, per, n)
    shifts = (2 * np.arange(per, dtype=np.uint32)).reshape(1, per, 1)
    packed = np.bitwise_or.reduce(codes << shifts, axis=1)
    return packed.astype(np.uint8 if word == 8 else np.uint32)


def decode_2bit(packed: jnp.ndarray, k: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    """jnp decode: (K/per, N) packed words -> (k, N) ±1/0 matrix."""
    word = 8 * packed.dtype.itemsize
    per = word // 2
    q, n = packed.shape
    shifts = (2 * jnp.arange(per, dtype=packed.dtype)).reshape(1, per, 1)
    c = (packed[:, None, :] >> shifts) & 3
    vals = ((c & 1).astype(jnp.int8) - ((c >> 1) & 1).astype(jnp.int8))
    return vals.reshape(q * per, n)[:k].astype(dtype)


# ---------------------------------------------------------------------------
# TiledTernary -- 2-bit codes + pack-time tile-occupancy metadata
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TiledTernary:
    """2-bit-packed ternary weights + per-(K-tile, N-tile) occupancy.

    The blocked-TCSC insight taken to its TPU conclusion: at pack time we
    record which (tile_k x tile_n) tiles contain any nonzero, as

    * ``tile_nnz``   -- (n_ktiles, n_ntiles) int32 nnz per tile (the bitmap
                        is ``tile_nnz > 0``);
    * ``kt_indices`` -- (n_ntiles, max_occ) int32: for each N-tile column,
                        the occupied K-tile ids in ascending order, padded to
                        the static ``max_occ`` with the id of an *unoccupied*
                        tile (so even an unguarded visit contributes zero);
    * ``kt_counts``  -- (n_ntiles,) int32 valid prefix length of each row.

    ``packed`` holds the K/N-padded 2-bit codes, so a (tile_k/16, tile_n)
    word tile is addressable by (K-tile id, N-tile id) BlockSpec indices.
    The skipping kernel prefetches ``kt_indices``/``kt_counts`` as scalars
    and iterates the K grid dimension only ``max_occ`` times per N-tile,
    DMA-ing only occupied tiles (DESIGN.md §3). ``tile_k`` must be a
    multiple of 16 (one uint32 word row = 16 K entries).
    """

    packed: np.ndarray       # (Kp/16, Np) uint32
    kt_indices: np.ndarray   # (n_ntiles, max_occ) int32
    kt_counts: np.ndarray    # (n_ntiles,) int32
    tile_nnz: np.ndarray     # (n_ktiles, n_ntiles) int32
    tile_k: int
    tile_n: int
    shape: Tuple[int, int]   # logical (K, N) before padding

    @classmethod
    def from_dense(cls, w: np.ndarray, tile_k: int = 256,
                   tile_n: int = 128) -> "TiledTernary":
        assert tile_k % 16 == 0, tile_k
        k, n = w.shape
        kp = -(-k // tile_k) * tile_k
        npad = -(-n // tile_n) * tile_n
        wp = np.zeros((kp, npad), dtype=np.int8)
        wp[:k, :n] = w
        nkt, nnt = kp // tile_k, npad // tile_n
        tile_nnz = (wp.reshape(nkt, tile_k, nnt, tile_n) != 0) \
            .sum(axis=(1, 3)).astype(np.int32)
        occ = tile_nnz > 0
        counts = occ.sum(axis=0).astype(np.int32)
        max_occ = max(int(counts.max(initial=0)), 1)
        idx = np.zeros((nnt, max_occ), dtype=np.int32)
        for j in range(nnt):
            ks = np.nonzero(occ[:, j])[0].astype(np.int32)
            idx[j, :len(ks)] = ks
            if len(ks) < max_occ:
                free = np.setdiff1d(np.arange(nkt, dtype=np.int32), ks)
                idx[j, len(ks):] = free[0] if len(free) else 0
        return cls(pack_2bit(wp), idx, counts, tile_nnz, tile_k, tile_n,
                   (k, n))

    # --- derived views ---------------------------------------------------
    @property
    def n_ktiles(self) -> int:
        return self.tile_nnz.shape[0]

    @property
    def n_ntiles(self) -> int:
        return self.tile_nnz.shape[1]

    @property
    def max_occ(self) -> int:
        return self.kt_indices.shape[1]

    def occupancy(self) -> np.ndarray:
        """(n_ktiles, n_ntiles) bool bitmap."""
        return self.tile_nnz > 0

    def occupied_tiles(self) -> int:
        return int(self.kt_counts.sum())

    def total_tiles(self) -> int:
        return self.n_ktiles * self.n_ntiles

    def occupancy_fraction(self) -> float:
        return self.occupied_tiles() / max(self.total_tiles(), 1)

    def visited_tiles(self) -> int:
        """Grid steps the skipping kernel takes per M-tile row: the static
        ``max_occ`` bound x N-tiles (>= occupied_tiles by raggedness)."""
        return self.n_ntiles * self.max_occ

    def to_dense(self) -> np.ndarray:
        k, n = self.shape
        kp = self.n_ktiles * self.tile_k
        dec = np.asarray(decode_2bit(jnp.asarray(self.packed), kp, jnp.int8))
        return dec[:k, :n]

    def nbytes(self) -> int:
        return (self.packed.nbytes + self.kt_indices.nbytes
                + self.kt_counts.nbytes + self.tile_nnz.nbytes)


# ---------------------------------------------------------------------------
# Base-3 value compression -- paper §3 "Value Compression" (dropped there;
# kept here for the benchmark record, decode needs a 243-entry LUT gather)
# ---------------------------------------------------------------------------

def base3_lut() -> np.ndarray:
    """(243, 5) int8 lookup: code -> five {-1,0,+1} values (digit 0 first)."""
    codes = np.arange(243)
    digits = np.stack([(codes // 3**t) % 3 for t in range(5)], axis=1)
    return (digits.astype(np.int8) - (digits == 2).astype(np.int8) * 3)


def pack_base3(w: np.ndarray) -> np.ndarray:
    """Pack (K, N) ternary into (ceil(K/5), N) uint8 base-3 codes."""
    k, n = w.shape
    kp = -(-k // 5) * 5
    trits = np.zeros((kp, n), dtype=np.uint8)
    trits[:k][w == 1] = 1
    trits[:k][w == -1] = 2
    trits = trits.reshape(kp // 5, 5, n)
    weights = (3 ** np.arange(5, dtype=np.uint32)).reshape(1, 5, 1)
    return (trits.astype(np.uint32) * weights).sum(1).astype(np.uint8)


def decode_base3(packed: jnp.ndarray, k: int, dtype=jnp.bfloat16) -> jnp.ndarray:
    """jnp decode via the 243-entry LUT (a gather -- the reason this format
    loses on TPU, mirroring the paper's drop decision on CPU)."""
    lut = jnp.asarray(base3_lut())  # (243, 5) int8
    q, n = packed.shape
    vals = lut[packed.astype(jnp.int32)]          # (q, n, 5) gather
    vals = jnp.transpose(vals, (0, 2, 1)).reshape(q * 5, n)
    return vals[:k].astype(dtype)
