"""Mesh-agnostic checkpointing.

Checkpoints store the *logical* (unsharded) state as one ``.npz`` per save
plus a small JSON manifest — restore works onto any mesh / device count
(elastic scaling: save on 512 chips, restore on 256, or on 1 CPU for tests).
Atomic rename prevents torn checkpoints on failure mid-save; ``latest_step``
+ step-tagged directories give restartability.

For multi-host deployments, ``save`` is called on the leader only (process
index 0); leaves are fetched with ``jax.device_get`` which assembles the
logical array from shards.

Packed ``repro.core.weights.TernaryWeight`` containers serialize leaf-wise
through the same path (their pytree key paths name the container fields,
e.g. ``.../w_packed/packed``), so a server can ``restore`` a packed tree
into a ``quantization="ternary_packed"`` model skeleton and boot without
re-quantizing or re-packing anything.
"""
from __future__ import annotations

import json
import logging
import os
import re
import shutil
import tempfile
import zlib
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

log = logging.getLogger("repro.checkpoint")

SEP = "/"


class CheckpointCorruptError(RuntimeError):
    """A stored array's bytes no longer match the manifest's checksum (bit
    rot, torn write that survived the atomic rename, manual tampering).
    Carries the offending file and leaf key so operators can tell *which*
    checkpoint/array to discard."""

    def __init__(self, path: str, key: str, expected: int, got: int):
        self.path = path
        self.key = key
        super().__init__(
            f"checkpoint corrupt: {os.path.join(path, 'state.npz')} leaf "
            f"{key!r} crc32 {got:#010x} != manifest {expected:#010x}")


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(_path_str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):       # GetAttrKey: TernaryWeight container fields
        return str(p.name)
    return str(p)


def _to_savable(v: np.ndarray) -> np.ndarray:
    """numpy can't serialize ml_dtypes (bfloat16, fp8) through savez — store
    them as same-width unsigned ints; the manifest records the true dtype."""
    if v.dtype.kind == "V" or str(v.dtype) in ("bfloat16", "float8_e4m3fn",
                                               "float8_e5m2"):
        return v.view({1: np.uint8, 2: np.uint16, 4: np.uint32}[v.dtype.itemsize])
    return v


def _from_saved(arr: np.ndarray, dtype_str: str) -> np.ndarray:
    if str(arr.dtype) != dtype_str:
        import ml_dtypes
        try:
            return arr.view(np.dtype(dtype_str))
        except TypeError:
            return arr.view(ml_dtypes.bfloat16 if dtype_str == "bfloat16"
                            else np.dtype(dtype_str))
    return arr


def save(ckpt_dir: str, step: int, state: Any) -> str:
    """Atomically write state under ckpt_dir/step_<n>/ ."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = {k: np.asarray(jax.device_get(v)) for k, v in _flatten(state).items()}
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        np.savez(os.path.join(tmp, "state.npz"),
                 **{k.replace(SEP, "|"): _to_savable(v)
                    for k, v in flat.items()})
        manifest = {
            "step": step,
            # crc32 of the *stored* bytes (the _to_savable view), so
            # restore can verify straight off the npz without re-viewing
            "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype),
                           "crc32": zlib.crc32(_to_savable(v).tobytes())}
                       for k, v in flat.items()},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f, indent=1)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def _step_corrupt(path: str) -> bool:
    """True when a step directory fails its integrity check: unreadable
    npz/manifest, or any leaf whose stored bytes miss their manifest crc.
    Leaves without a recorded crc (pre-checksum checkpoints) pass."""
    try:
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        with np.load(os.path.join(path, "state.npz")) as data:
            for k in data.files:
                want = manifest["leaves"].get(
                    k.replace("|", SEP), {}).get("crc32")
                if want is not None and zlib.crc32(
                        np.ascontiguousarray(data[k]).tobytes()) != want:
                    return True
    except (OSError, ValueError, KeyError, json.JSONDecodeError):
        return True
    return False


def latest_step(ckpt_dir: str, verify: bool = False) -> Optional[int]:
    """Newest step under ``ckpt_dir``. ``verify=True`` checksums candidates
    newest-first and returns the newest *intact* one (skipping corrupt
    steps with a warning) — what restart supervision wants, so one rotted
    save degrades to the previous checkpoint instead of a crash loop."""
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted((int(m.group(1)) for d in os.listdir(ckpt_dir)
                    if (m := re.fullmatch(r"step_(\d+)", d))), reverse=True)
    if not verify:
        return steps[0] if steps else None
    for s in steps:
        path = os.path.join(ckpt_dir, f"step_{s:08d}")
        if _step_corrupt(path):
            log.warning("skipping corrupt checkpoint %s", path)
            continue
        return s
    return None


def restore(ckpt_dir: str, step: Optional[int] = None, target: Any = None,
            shardings: Any = None) -> Tuple[int, Any]:
    """Restore (step, state). ``target`` (a pytree of arrays or
    ShapeDtypeStructs) fixes the tree structure; ``shardings`` (matching
    pytree of NamedSharding) places leaves onto the *current* mesh —
    re-meshing happens here. Every leaf with a manifest checksum is
    verified before use; a mismatch raises ``CheckpointCorruptError``
    naming the file and leaf."""
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    data = np.load(os.path.join(path, "state.npz"))
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat = {}
    for k in data.files:
        key = k.replace("|", SEP)
        meta = manifest["leaves"].get(key, {})
        want_crc = meta.get("crc32")
        if want_crc is not None:
            got = zlib.crc32(np.ascontiguousarray(data[k]).tobytes())
            if got != want_crc:
                raise CheckpointCorruptError(path, key, want_crc, got)
        dt = meta.get("dtype", "")
        flat[key] = _from_saved(data[k], dt) if dt else data[k]
    if target is None:
        return step, flat

    leaves_with_path, treedef = jax.tree_util.tree_flatten_with_path(target)
    shard_flat = (_flatten(shardings) if shardings is not None else {})
    out = []
    for path_k, leaf in leaves_with_path:
        key = SEP.join(_path_str(p) for p in path_k)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = flat[key]
        want = getattr(leaf, "dtype", None)
        if want is not None and arr.dtype != want:
            arr = arr.astype(want)
        sh = shard_flat.get(key)
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return step, jax.tree_util.tree_unflatten(treedef, out)
