from repro.checkpoint.checkpoint import (CheckpointCorruptError, latest_step,
                                         restore, save)

__all__ = ["save", "restore", "latest_step", "CheckpointCorruptError"]
