"""Deterministic, shardable synthetic data pipeline.

Generates learnable token streams (per-sequence affine recurrences
``x_{t+1} = (a*x_t + b) mod V`` plus noise) so end-to-end training runs show
decreasing loss. Batches are a pure function of (seed, step) — any worker can
regenerate any step, which is what makes checkpoint/restart and elastic
re-sharding trivially consistent: there is no pipeline state to snapshot.

``global_batch(step)`` returns numpy arrays for the full logical batch;
``sharded_batch`` device_puts them with the batch PartitionSpec.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.configs.base import ModelConfig


class SyntheticLM:
    def __init__(self, cfg: ModelConfig, batch: int, seq_len: int,
                 seed: int = 0, noise: float = 0.05):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq_len
        self.seed = seed
        self.noise = noise
        # frontend split (vlm / encdec): text tokens occupy the tail
        self.n_front = cfg.frontend_seq if cfg.frontend or cfg.is_encdec else 0
        if cfg.family == "vlm":
            self.text_len = max(self.seq - self.n_front, 16)
        else:
            self.text_len = self.seq if not cfg.is_encdec else \
                max(self.seq - self.n_front, 16)

    def _rng(self, step: int) -> np.random.Generator:
        return np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))

    def global_batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = self._rng(step)
        v = cfg.vocab_size
        b, s = self.batch, self.text_len + 1
        a = rng.integers(1, 8, size=(b, 1))
        c = rng.integers(0, v, size=(b, 1))
        x = np.empty((b, s), dtype=np.int64)
        x[:, 0] = rng.integers(0, v, size=b)
        for t in range(1, s):
            x[:, t] = (a[:, 0] * x[:, t - 1] + c[:, 0]) % v
        flip = rng.random((b, s)) < self.noise
        x[flip] = rng.integers(0, v, size=int(flip.sum()))
        batch = {
            "tokens": x[:, :-1].astype(np.int32),
            "targets": x[:, 1:].astype(np.int32),
        }
        if cfg.family == "vlm":
            batch["vision_embeds"] = rng.standard_normal(
                (b, self.n_front, cfg.d_model)).astype(np.float32) * 0.02
        if cfg.is_encdec:
            batch["enc_embeds"] = rng.standard_normal(
                (b, self.n_front, cfg.d_model)).astype(np.float32) * 0.02
        return batch

    def sharded_batch(self, step: int, mesh=None, batch_axes=("data",)):
        import jax
        import jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        arrs = self.global_batch(step)
        if mesh is None:
            return {k: jnp.asarray(a) for k, a in arrs.items()}
        axes = tuple(a for a in batch_axes if a in mesh.axis_names)
        out = {}
        for k, a in arrs.items():
            spec = P(axes if a.shape[0] % _axes_size(mesh, axes) == 0 else None,
                     *([None] * (a.ndim - 1)))
            out[k] = jax.device_put(a, NamedSharding(mesh, spec))
        return out


def _axes_size(mesh, axes) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size
