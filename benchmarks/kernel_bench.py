"""Kernel-level benchmarks: BlockSpec sweep (the TPU analogue of the paper's
unroll-factor grid search, Figs 2-4), value-compression comparison (paper
§Value Compression), and the kernel's structural VMEM/roofline analysis.

Pallas interpret-mode wall time is Python-bound and meaningless as a perf
number; the kernel's performance claims on TPU are *structural* (VMEM
working set, bytes moved, MXU-aligned tiles) and are reported as such. The
XLA dense-decode path (same algorithm the kernel implements) is timed for a
real end-to-end CPU number.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import record, time_fn
from repro.core import formats, weights
from repro.kernels import ref
from repro.kernels.autotune import BlockConfig, CANDIDATE_BLOCKS, HBM_BW
from repro.kernels.autotune import PEAK_FLOPS as PEAK


def block_sweep(quick: bool = False):
    """BlockSpec shape sweep: VMEM footprint + modeled HBM-bound time per
    (block_m, block_n, block_k) for a 4096x4096 ternary GEMM tile-set.
    Mirrors the paper's Figs 2-4 parameter search, adapted to the VMEM
    hierarchy (DESIGN.md §2)."""
    m, k, n = 512, 4096, 4096
    # Same candidate grid the autotuner sweeps (single source of truth).
    shapes = list(CANDIDATE_BLOCKS)
    if quick:
        shapes = shapes[:3]
    for bm, bn, bk in shapes:
        cfg = BlockConfig(bm, bn, bk)
        vmem = cfg.vmem_bytes()
        # bytes per output tile pass: X tile per k-step + packed W + out
        ksteps = k // bk
        x_bytes = m * k * 2 * (n // bn)      # X re-read per N tile
        w_bytes = (k // 16) * n * 4          # packed weights once per M tile
        w_bytes *= (m // bm)
        out_bytes = m * n * 2
        total = x_bytes + w_bytes + out_bytes
        t_model = total / HBM_BW
        flops = 2 * m * k * n
        mxu_frac = flops / PEAK / max(t_model, flops / PEAK)
        record(f"block_sweep/bm={bm},bn={bn},bk={bk}", t_model,
               f"vmem_kb={vmem // 1024},modeled_mxu_frac={mxu_frac:.2f}")


def value_compression(quick: bool = False):
    """Paper §Value Compression: 2-bit (kernel format) vs base-3 (5/byte,
    LUT decode) vs bitplanes — decode cost (CPU wall time of the XLA decode)
    and bytes/weight. The paper dropped base-3 on CPU; the same verdict
    falls out here from the LUT-gather decode cost."""
    k, n = (2048, 1024) if quick else (4096, 4096)
    w = formats.random_ternary(np.random.default_rng(0), k, n, 0.25)
    p2 = jnp.asarray(formats.pack_2bit(w))
    pb, mb = (jnp.asarray(a) for a in formats.pack_bitplanes(w))
    b3 = jnp.asarray(formats.pack_base3(w))
    fns = {
        "decode2bit": (jax.jit(lambda: formats.decode_2bit(p2, k)), p2.nbytes),
        "decode_bitplane": (jax.jit(lambda: formats.decode_bitplanes(pb, mb, k)),
                            pb.nbytes + mb.nbytes),
        "decode_base3_LUT": (jax.jit(lambda: formats.decode_base3(b3, k)),
                             b3.nbytes),
    }
    for name, (fn, nbytes) in fns.items():
        t = time_fn(fn)
        bits = nbytes * 8 / (k * n)
        record(f"value_compression/{name}", t,
               f"bits_per_weight={bits:.2f}")


def end_to_end_layer(quick: bool = False):
    """One ternary FFN layer (in+gate+out) bf16-dense vs 2-bit-packed decode
    path: the weight-bandwidth argument end to end. CPU wall time + modeled
    TPU HBM time for both."""
    d, ff = (1024, 4096) if quick else (2048, 8192)
    m = 256
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((m, d)), jnp.bfloat16)
    ws = [formats.random_ternary(rng, d, ff, 0.25),
          formats.random_ternary(rng, d, ff, 0.25),
          formats.random_ternary(rng, ff, d, 0.25)]
    dense = [jnp.asarray(w, jnp.bfloat16) for w in ws]
    packed = [jnp.asarray(formats.pack_2bit(w)) for w in ws]

    def ffn_dense(x):
        h = jax.nn.silu(x @ dense[0]) * (x @ dense[1])
        return h @ dense[2]

    def ffn_packed(x):
        h = jax.nn.silu(ref.packed2bit_matmul(x, packed[0], d)) \
            * ref.packed2bit_matmul(x, packed[1], d)
        return ref.packed2bit_matmul(h, packed[2], ff)

    for name, fn, wbytes in [
        ("ffn_dense_bf16", jax.jit(ffn_dense), sum(w.size * 2 for w in ws)),
        ("ffn_packed_2bit", jax.jit(ffn_packed), sum(p.nbytes for p in packed)),
    ]:
        t = time_fn(fn, x)
        t_tpu_mem = (wbytes + x.nbytes + m * d * 2) / HBM_BW
        record(f"e2e_layer/{name}", t,
               f"weight_mb={wbytes / 2**20:.1f},modeled_tpu_mem_us={t_tpu_mem * 1e6:.1f}")


def pallas_kernel_check(quick: bool = False):
    """Correctness + structural numbers of the Pallas kernel (interpret)."""
    from repro.kernels import ops
    m, k, n = 128, 1024, 512
    rng = np.random.default_rng(1)
    w = formats.random_ternary(rng, k, n, 0.25)
    x = jnp.asarray(rng.standard_normal((m, k)), jnp.float32)
    wc = weights.pack(w, "dense2bit")
    y = ops.ternary_gemm(x, wc, block_n=128, block_k=256)
    y0 = ref.ternary_matmul_dense(x, jnp.asarray(w))
    err = float(jnp.max(jnp.abs(y - y0)))
    cfg = BlockConfig(128, 128, 256)
    record("pallas/interpret_allclose", 0.0,
           f"max_err={err:.2e},vmem_kb={cfg.vmem_bytes() // 1024}")
    assert err < 1e-3


def flash_kernel_check(quick: bool = False):
    """Pallas flash attention kernel: correctness (interpret) + the §Perf B
    structural claim — HBM traffic = q/k/v/o streaming vs the XLA path's
    score-tensor round-trips."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.models.attention import naive_attention
    import jax
    bh, s, hd = 4, 256, 64
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((bh, s, hd)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, s, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, hd)), jnp.float32)
    o = flash_attention_pallas(q, k, v, causal=True, block_q=64,
                               block_kv=64, interpret=True)
    o_ref = naive_attention(q[:, :, None], k[:, :, None], v[:, :, None],
                            causal=True, window=0)[:, :, 0]
    err = float(jnp.max(jnp.abs(o - o_ref)))
    assert err < 1e-3
    # structural: bytes for one (B=2/chip, H=4/chip, S=32k, hd=128) layer
    B, H, S, HD = 2, 4, 32768, 128
    stream = 4 * B * H * S * HD * 2                     # q,k,v,o bf16
    xla_scores = 3 * B * H * (S * S // 2) * 4 / (S // 4096)  # per-block f32
    record("flash_kernel/interpret_allclose", 0.0,
           f"max_err={err:.2e},hbm_stream_mb={stream / 2**20:.0f},"
           f"xla_score_roundtrip_mb={xla_scores / 2**20:.0f}")


def sparsity_skip(quick: bool = False):
    """Tile-skipping kernel: tiles visited vs occupancy across the paper's
    sparsity grid {1/2, 1/4, 1/8, 1/16} (DESIGN.md §3).

    The structural number is for a 4096x4096 weight (the acceptance shape):
    grid steps the skipping kernel takes (N-tiles x static max-occupancy)
    over the dense kernel's full tile count. Correctness is checked at a
    small shape in interpret mode, bit-exact vs the dense-decode kernel.
    """
    from repro.kernels import ops
    tile_k, tile_n = 256, 128
    k, n = (1024, 1024) if quick else (4096, 4096)
    rng = np.random.default_rng(0)
    for s in (0.5, 0.25, 0.125, 0.0625):
        w = formats.random_tile_ternary(rng, k, n, tile_k, tile_n, s)
        tt = formats.TiledTernary.from_dense(w, tile_k=tile_k, tile_n=tile_n)
        total = tt.total_tiles()
        visited = tt.visited_tiles()
        record(f"sparsity_skip/s=1_{int(round(1 / s))}", 0.0,
               f"tiles={total},occupied={tt.occupied_tiles()},"
               f"visited={visited},visit_frac={visited / total:.3f},"
               f"occ_frac={tt.occupancy_fraction():.3f}")

    # interpret-mode parity at a CI-sized shape (dense pallas vs skipping)
    m, kc, nc = 16, 256, 128
    wd = formats.random_tile_ternary(rng, kc, nc, 64, 32, 0.125)
    ttc = weights.pack(wd, "tiled", tile_k=64, tile_n=32)
    x = jnp.asarray(np.random.default_rng(1).standard_normal((m, kc)),
                    jnp.float32)
    y_skip = ops.ternary_gemm(x, ttc, impl="skip")
    y_dense = ops.ternary_gemm(x, ttc, block_n=32, block_k=64, impl="dense")
    exact = bool(jnp.all(y_skip == y_dense))
    record("sparsity_skip/interpret_bit_exact", 0.0,
           f"exact={exact},visit_frac={ttc.visited_tiles() / ttc.total_tiles():.3f}")
    assert exact


def autotune_sweep(quick: bool = False):
    """Exercise the block-shape autotuner (kernels.autotune): tuned picks
    for serving-ish shapes, and the JSON cache round-trip (DESIGN.md §5)."""
    import os
    import tempfile
    from repro.kernels.autotune import Autotuner
    path = os.path.join(tempfile.mkdtemp(prefix="repro_autotune_"),
                        "cache.json")
    tuner = Autotuner(path=path, mode="model")
    shapes = [(8, 4096, 4096, 1.0), (256, 4096, 4096, 1.0),
              (256, 4096, 4096, 0.125)]
    if quick:
        shapes = shapes[:2]
    for m, k, n, s in shapes:
        cfg = tuner.lookup(m, k, n, sparsity=s)
        record(f"autotune/m={m},k={k},n={n},s={s}", 0.0,
               f"block={cfg.block_m}x{cfg.block_n}x{cfg.block_k},"
               f"vmem_kb={cfg.vmem_bytes() // 1024}")
    reloaded = Autotuner(path=path, mode="model")
    roundtrip = reloaded.entries() == tuner.entries()
    record("autotune/json_roundtrip", 0.0,
           f"entries={len(tuner.entries())},roundtrip_ok={roundtrip}")
    assert roundtrip and len(tuner.entries()) >= len(shapes) - 1


def fused_mlp_block(quick: bool = False):
    """Fused ternary MLP (GEMM->act->GEMM, hidden act resident in VMEM,
    DESIGN.md §12) vs the unfused chain.

    The gated number is the *modeled* fused speedup at the pinned MLP
    shape (m=512, d=1024, ff=4096): unfused re-reads the (m, ff) hidden
    activation through HBM twice, fused never spills it, so the ratio is
    machine-independent (``FusedMlpPlan.roofline()``). Correctness is the
    bitwise fused==chain check at a CI-sized shape in interpret mode —
    the same contract tests/test_fused_mlp.py pins across formats/phases.
    """
    from repro.kernels import ops

    # modeled speedup at the pinned bench shape (CI-gated >= 1.2x: the
    # recorded ratio is capped at 1.6 and check_regression's 25% ratio
    # tolerance puts the floor at 1.6 * 0.75 = 1.2)
    m, d, ff = 512, 1024, 4096
    rng = np.random.default_rng(0)
    wi = weights.pack(formats.random_ternary(rng, d, ff, 0.25), "dense2bit")
    wg = weights.pack(formats.random_ternary(rng, d, ff, 0.25), "dense2bit")
    wo = weights.pack(formats.random_ternary(rng, ff, d, 0.25), "dense2bit")
    plan = ops.fused_mlp_plan(wi, wo, wg, m=m, impl="pallas", phase=None)
    rl = plan.roofline()
    speedup = rl["fused_speedup"]
    record(f"fused_mlp/pinned_m={m},d={d},ff={ff}", rl["model_time_s"],
           f"ratio={min(speedup, 1.6):.2f},modeled={speedup:.2f},"
           f"unfused_bytes={int(rl['unfused_bytes'])},"
           f"fused_bytes={int(rl['bytes'])}")
    assert speedup >= 1.2, f"modeled fused speedup {speedup:.2f} < 1.2"

    # bitwise parity at a CI-sized shape (interpret mode)
    mc, dc, ffc = (16, 256, 512) if quick else (32, 512, 1024)
    wi = weights.pack(formats.random_ternary(rng, dc, ffc, 0.25), "dense2bit")
    wg = weights.pack(formats.random_ternary(rng, dc, ffc, 0.25), "dense2bit")
    wo = weights.pack(formats.random_ternary(rng, ffc, dc, 0.25), "dense2bit")
    x = jnp.asarray(np.random.default_rng(1).standard_normal((mc, dc)),
                    jnp.float32)
    y_fused = ops.fused_mlp(x, wi, wo, wg, impl="pallas")
    y_chain = ops.fused_mlp(x, wi, wo, wg, impl="chain")
    exact = bool(jnp.all(y_fused == y_chain))
    record("fused_mlp/interpret_bit_exact", 0.0,
           f"exact={exact},m={mc},d={dc},ff={ffc}")
    assert exact


ALL = [block_sweep, value_compression, end_to_end_layer, pallas_kernel_check,
       flash_kernel_check, sparsity_skip, autotune_sweep, fused_mlp_block]
