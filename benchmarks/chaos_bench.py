"""Chaos soak of the serving engine's fault tolerance (DESIGN.md §11).

The same mixed-length paged workload runs twice: once fault-free (the
reference), once under a seeded fault storm — NaN-corrupted logits, forced
page-pool OOM bursts, slow steps — plus one doomed request carrying an
already-expired deadline. The soak pins the failure-model contract:

* **termination** — every submitted request reaches a terminal state
  (``done``, or ``failed`` with a reason code); the doomed request fails
  with reason ``"deadline"`` and nothing wedges.
* **isolation + exactness** — every surviving request's tokens are exactly
  the fault-free run's (quarantine replays are token-exact under greedy
  decode; faults in one slot never perturb another slot's stream).
* **no leaks** — after the drain the page pool is fully reclaimed (all
  slots free, refcounts zero).
* **bounded degradation** — chaos throughput / clean throughput is the
  gated ``ratio=`` entry: retries and injected sleeps cost wall time, but
  the engine must keep most of its throughput rather than collapsing.
"""
from __future__ import annotations

import jax

from benchmarks.common import record
from repro.configs import get_config
from repro.launch.serve import build_workload, run_continuous
from repro.serving import ContinuousScheduler, FaultConfig, ResilienceConfig


def _engine(cfg, slots, max_len, page_size, n_pages, faults=None):
    return ContinuousScheduler(
        cfg, max_slots=slots, max_len=max_len, cache="paged",
        page_size=page_size, n_pages=n_pages, paged_attn="jax",
        faults=faults,
        resilience=ResilienceConfig(max_retries=3))


def chaos_soak(quick: bool = False):
    cfg = get_config("ternary-paper", reduced=True, num_layers=2)
    requests, slots = (12, 4) if quick else (24, 6)
    prompt_len = 16
    gen_lens = (4, 24) if quick else (8, 48)
    page_size = 8
    max_len = prompt_len + max(gen_lens) + 1
    n_pages = slots * (-(-max_len // page_size)) + 8
    prompts, gens, _ = build_workload(cfg, requests, prompt_len, gen_lens)

    clean = _engine(cfg, slots, max_len, page_size, n_pages)
    params = clean.model.init(jax.random.PRNGKey(0))
    clean.load(params)
    run_continuous(clean, prompts, gens)          # compile warmup
    outs_clean, m_clean = run_continuous(clean, prompts, gens)

    # seeded storm, rates only: the injector's rng stream is seeded and the
    # warmup pass replays the identical workload, so the timed pass's fault
    # schedule is fully deterministic — the quarantine/injection asserts
    # below are repeatable, not probabilistic. (Step-pinned *_at lists
    # can't be used here: the warmup pass would consume those steps.)
    storm = FaultConfig(seed=7, nan_rate=0.05, oom_rate=0.05, oom_burst=2,
                        slow_rate=0.02, slow_s=0.002)
    chaos = _engine(cfg, slots, max_len, page_size, n_pages, faults=storm)
    chaos.load(params)
    run_continuous(chaos, prompts, gens)          # compile warmup
    reqs = [chaos.submit(p, g) for p, g in zip(prompts, gens)]
    doomed = chaos.submit(prompts[0], int(gens[0]), deadline_s=0.0)
    m_chaos = chaos.run()

    # termination: every request terminal, the doomed one by deadline
    for r in reqs + [doomed]:
        assert r.terminal, f"request {r.rid} not terminal: {r.state}"
    assert doomed.state == "failed" and doomed.fail_reason == "deadline", (
        doomed.state, doomed.fail_reason)

    # isolation + exactness: survivors match the fault-free run token for
    # token (failed requests are excluded — they have no output contract)
    survivors = [r for r in reqs if r.state == "done"]
    exact = all(list(r.tokens) == list(o)
                for r, o in zip(reqs, outs_clean) if r.state == "done")
    assert exact, "a surviving request diverged from the fault-free run"

    # no leaks: the pool drained refcount-clean
    assert chaos.pool.all_reclaimed, "page pool leaked after chaos drain"

    fl = m_chaos["faults"]
    assert sum(fl["injected"].values()) > 0, "storm injected nothing"
    assert fl["quarantines"] >= 1, "nan_at schedule never quarantined"

    ratio = m_chaos["tok_per_s"] / m_clean["tok_per_s"]
    record("serving/chaos", m_chaos["wall_s"],
           f"tok_per_s={m_chaos['tok_per_s']},"
           f"injected={sum(fl['injected'].values())},"
           f"quarantines={fl['quarantines']},retries={fl['retries']},"
           f"failed={fl['failed_requests']},"
           f"survivors={len(survivors)}/{requests}")
    record("serving/clean_for_chaos", m_clean["wall_s"],
           f"tok_per_s={m_clean['tok_per_s']}")
    # the gated ratio is capped at 0.95: chaos wall time swings with how
    # many retries the storm lands, and recording a lucky near-1.0 run
    # would push the CI floor (baseline x 0.75) above what a normal run
    # sustains. The floor asserts the engine keeps >= 40% throughput
    # under the storm — degradation stays bounded, not graceful-in-name.
    record("serving/chaos_survival", 0.0,
           f"ratio={min(ratio, 0.95):.2f},measured={ratio:.2f},"
           f"token_exact={exact}")
    assert ratio >= 0.4, (
        f"throughput collapsed under chaos: {m_chaos['tok_per_s']} vs "
        f"{m_clean['tok_per_s']} tok/s (ratio {ratio:.2f})")


ALL = [chaos_soak]
