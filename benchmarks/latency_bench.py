"""Latency-percentile benchmark under offered load (DESIGN.md §14).

Throughput benchmarks (serving_bench) drive the engine closed-loop, which
hides head-of-line blocking: a whole-prompt prefill monopolises the model
for its full duration, so an interactive request that arrives just behind
a long prompt waits the entire prefill before its first token. Chunked
prefill bounds that wait at one token-budgeted step. This module measures
exactly that effect:

* ``latency_chunked_vs_whole`` — a *pinned* arrival pattern (a long
  batch-class prompt immediately shadowed by short interactive requests,
  repeated) replayed open-loop against the whole-prompt engine and the
  chunked engine. The gated entry is the interactive-class p99-TTFT ratio
  (whole / chunked), capped at 2.0 so the CI floor (baseline * 0.75) sits
  at the issue's >= 1.5x contract without riding a lucky run. The pattern
  is structural — the ratio is ~(long-prefill wall / step wall), several x
  on any host — so the gate is machine-independent.

* ``latency_load_sweep`` — the seeded Poisson/bursty harness at a few
  offered rates, reporting p50/p99 TTFT and TPOT (observability entries:
  coverage-gated, times not individually gated).

Both engines replay the identical schedule, and greedy decoding is
deterministic per request, so token-exactness across admission policies is
asserted alongside the latency claim.
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from benchmarks.common import record
from repro.configs import get_config
from repro.serving import (Arrival, ContinuousScheduler, SchedConfig,
                           SLOClass, TrafficConfig, make_schedule,
                           run_open_loop)

INTERACTIVE = SLOClass("interactive", ttft_target_s=0.2,
                       tpot_target_s=0.05, priority=0)
BATCH = SLOClass("batch", ttft_target_s=None, tpot_target_s=None,
                 priority=1)


def _pct(vals, q):
    return float(np.percentile(np.asarray(vals, np.float64), q))


def _ms(x):
    return f"{x * 1e3:.1f}"


def _engine(cfg, slots, max_len, params=None, **kw):
    eng = ContinuousScheduler(cfg, max_slots=slots, max_len=max_len, **kw)
    if params is None:
        params = eng.model.init(jax.random.PRNGKey(0))
    eng.load(params)
    return eng, params


def _shadowed_schedule(cfg, *, rounds, long_len, short_len, shorts, gap_s,
                       seed=0):
    """The head-of-line pattern: at each round start a long batch prompt
    arrives, and ``shorts`` interactive requests arrive 10ms behind it —
    inside the window where whole-prompt admission is busy prefilling."""
    rng = np.random.default_rng(seed)
    sched = []
    for i in range(rounds):
        t = i * gap_s
        sched.append(Arrival(
            t=t, prompt=rng.integers(0, cfg.vocab_size, size=long_len,
                                     dtype=np.int32),
            max_new=8, slo=BATCH))
        for j in range(shorts):
            sched.append(Arrival(
                t=t + 0.01 + 0.002 * j,
                prompt=rng.integers(0, cfg.vocab_size, size=short_len,
                                    dtype=np.int32),
                max_new=16, slo=INTERACTIVE))
    return sched


def _streams(reqs):
    return [list(r.tokens) for r in reqs]


def latency_chunked_vs_whole(quick: bool = False):
    cfg = get_config("ternary-paper", reduced=True, num_layers=2)
    # the long prompt must dwarf a decode step for the head-of-line
    # effect to be structural: at reduced-model scale a 1024-token
    # prefill is ~20-40x one decode step on CPU hosts
    rounds = 4 if quick else 8
    long_len = 1024 if quick else 2048
    gap_s = 0.3 if quick else 0.5
    slots = 4
    max_len = long_len + 16 + 1
    sched = _shadowed_schedule(cfg, rounds=rounds, long_len=long_len,
                               short_len=8, shorts=3, gap_s=gap_s)

    whole, params = _engine(cfg, slots, max_len)
    chunked, _ = _engine(cfg, slots, max_len, params,
                         sched=SchedConfig(chunk_tokens=32))

    # pass 1 per engine: compile warmup (the open loop hits each (P,S)
    # window shape once); pass 2: measured
    run_open_loop(whole, sched)
    reqs_w, mw = run_open_loop(whole, sched)
    run_open_loop(chunked, sched)
    reqs_c, mc = run_open_loop(chunked, sched)

    exact = _streams(reqs_w) == _streams(reqs_c)
    ttft_w = [r.ttft_s for r in reqs_w if r.slo is INTERACTIVE]
    ttft_c = [r.ttft_s for r in reqs_c if r.slo is INTERACTIVE]
    p99_w, p99_c = _pct(ttft_w, 99), _pct(ttft_c, 99)
    ratio = p99_w / p99_c
    tpot_c = [r.tpot_s for r in reqs_c if r.tpot_s is not None]
    tpot_w = [r.tpot_s for r in reqs_w if r.tpot_s is not None]

    record("latency/whole_prompt", mw["traffic"]["makespan_s"],
           f"p50_ttft_ms={_ms(_pct(ttft_w, 50))},"
           f"p99_ttft_ms={_ms(p99_w)},"
           f"p99_tpot_ms={_ms(_pct(tpot_w, 99))}")
    record("latency/chunked", mc["traffic"]["makespan_s"],
           f"p50_ttft_ms={_ms(_pct(ttft_c, 50))},"
           f"p99_ttft_ms={_ms(p99_c)},"
           f"p99_tpot_ms={_ms(_pct(tpot_c, 99))},"
           f"chunk_steps={mc['sched']['chunk_steps']}")
    # gated: capped at 2.0 so the CI floor (x0.75) is exactly the issue's
    # 1.5x contract; the measured ratio (typically >> 2) rides along as
    # an uncapped report field
    record("latency/p99_ttft_chunked_vs_whole", 0.0,
           f"ratio={min(ratio, 2.0):.2f},token_exact={exact},"
           f"measured={ratio:.2f}")
    assert exact, "chunked streams diverged from whole-prompt admission"
    assert ratio >= 1.5, (
        f"interactive p99 TTFT improved only {ratio:.2f}x "
        f"(whole {p99_w * 1e3:.1f}ms vs chunked {p99_c * 1e3:.1f}ms)")


def latency_load_sweep(quick: bool = False):
    cfg = get_config("ternary-paper", reduced=True, num_layers=2)
    n = 16 if quick else 48
    rates = (4.0, 12.0) if quick else (4.0, 12.0, 24.0)
    eng, _ = _engine(cfg, 4, 128 + 16 + 1,
                     sched=SchedConfig(chunk_tokens=32))

    def one(name, tc):
        sched = make_schedule(tc, cfg.vocab_size,
                              classes=(INTERACTIVE, BATCH),
                              class_weights=(0.75, 0.25))
        reqs, m = run_open_loop(eng, sched)
        lat = m["latency"]
        record(name, m["traffic"]["makespan_s"],
               f"p50_ttft_ms={_ms(lat['ttft_s']['p50'])},"
               f"p99_ttft_ms={_ms(lat['ttft_s']['p99'])},"
               f"p99_tpot_ms={_ms(lat['tpot_s']['p99'])},"
               f"max_lag_s={m['traffic']['max_submit_lag_s']}")
        assert m["drained"] == n, (m["drained"], n)

    for rate in rates:
        one(f"latency/sweep_poisson_r{int(rate)}",
            TrafficConfig(kind="poisson", rate=rate, n_requests=n,
                          prompt_lens=(8, 32, 128),
                          prompt_weights=(0.5, 0.3, 0.2),
                          gen_lens=(8, 16), seed=11))
    one("latency/sweep_bursty_r12",
        TrafficConfig(kind="bursty", rate=12.0, n_requests=n,
                      prompt_lens=(8, 32, 128),
                      prompt_weights=(0.5, 0.3, 0.2),
                      gen_lens=(8, 16), burst_size=6, seed=11))


ALL = [latency_chunked_vs_whole, latency_load_sweep]


def main(argv=None):
    """Standalone CLI for the CI latency-smoke leg: runs only this
    module's benches and writes the same JSON shape as run.py --json, so
    check_regression.py --prefix latency/ gates it against the shared
    baseline."""
    from benchmarks.common import RESULTS, emit_header
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json", default="",
                    help="also write results as JSON to this path")
    args = ap.parse_args(argv)

    emit_header()
    for bench in ALL:
        bench(quick=args.quick)
    if args.json:
        entries = {r["name"]: {"us_per_call": r["us_per_call"],
                               "derived": r["derived"]} for r in RESULTS}
        with open(args.json, "w") as f:
            json.dump({"version": 1, "quick": args.quick,
                       "entries": entries}, f, indent=1)
        print(f"wrote {len(entries)} entries to {args.json}")


if __name__ == "__main__":
    main()
