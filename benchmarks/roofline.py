"""Roofline aggregation: read experiments/dryrun/*.json (written by
``repro.launch.dryrun``) and emit the §Roofline table (CSV + markdown)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 2**30   # v5e-class


def load(out_dir: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_rows(recs: List[Dict], mesh: str = "16x16") -> List[Dict]:
    rows = []
    for r in recs:
        if r.get("mesh") != mesh or r.get("quant") not in ("none", ""):
            continue
        if r.get("overrides"):
            continue
        row = {"arch": r["arch"], "shape": r["shape"], "status": r["status"]}
        if r["status"] == "ok":
            tc, tm, tl = (r["t_compute_s"], r["t_memory_s"],
                          r["t_collective_s"])
            dom = r["dominant"]
            t_bound = max(tc, tm, tl)
            row.update({
                "t_compute_s": f"{tc:.3e}", "t_memory_s": f"{tm:.3e}",
                "t_collective_s": f"{tl:.3e}", "dominant": dom,
                "roofline_frac": f"{tc / t_bound:.3f}" if t_bound else "",
                "useful_ratio": f"{(r.get('useful_flops_ratio') or 0):.2f}",
                "hbm_frac": f"{(r['memory'].get('argument_size_in_bytes', 0) + r['memory'].get('temp_size_in_bytes', 0)) / HBM_PER_CHIP:.2f}"
                if r.get("memory") else "",
            })
        else:
            row["dominant"] = r.get("reason", r.get("error", ""))[:60]
        rows.append(row)
    return rows


def markdown_table(rows: List[Dict]) -> str:
    if not rows:
        return "(no dry-run records yet)"
    cols = ["arch", "shape", "status", "t_compute_s", "t_memory_s",
            "t_collective_s", "dominant", "roofline_frac", "useful_ratio",
            "hbm_frac"]
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(out)


def main(out_dir: str = "experiments/dryrun"):
    recs = load(out_dir)
    for mesh in ("16x16", "2x16x16"):
        rows = roofline_rows(recs, mesh)
        if not rows:
            continue
        print(f"\n== roofline {mesh} ==")
        for r in rows:
            print(",".join(str(r.get(c, "")) for c in
                           ("arch", "shape", "status", "dominant",
                            "t_compute_s", "t_memory_s", "t_collective_s")))


if __name__ == "__main__":
    main()
