"""Roofline reporting, two layers:

1. **Model-level aggregation** — read experiments/dryrun/*.json (written
   by ``repro.launch.dryrun``) and emit the §Roofline table (CSV +
   markdown).
2. **Per-kernel report** (DESIGN.md §12) — build a representative
   ``GemmPlan``/``FusedMlpPlan`` for every registered kernel lowering and
   emit each plan's ``roofline()`` dict (achieved vs ceiling FLOP/s,
   modeled HBM bytes from block shapes + occupancy metadata, headroom) as
   JSON alongside the bench output. CI runs ``roofline.py --quick --json
   roofline_ci.json`` in the bench leg and uploads the artifact; README
   ("Reading a roofline report") explains how to interpret it.
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
HBM_PER_CHIP = 16 * 2**30   # v5e-class


def load(out_dir: str = "experiments/dryrun") -> List[Dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_rows(recs: List[Dict], mesh: str = "16x16") -> List[Dict]:
    rows = []
    for r in recs:
        if r.get("mesh") != mesh or r.get("quant") not in ("none", ""):
            continue
        if r.get("overrides"):
            continue
        row = {"arch": r["arch"], "shape": r["shape"], "status": r["status"]}
        if r["status"] == "ok":
            tc, tm, tl = (r["t_compute_s"], r["t_memory_s"],
                          r["t_collective_s"])
            dom = r["dominant"]
            t_bound = max(tc, tm, tl)
            row.update({
                "t_compute_s": f"{tc:.3e}", "t_memory_s": f"{tm:.3e}",
                "t_collective_s": f"{tl:.3e}", "dominant": dom,
                "roofline_frac": f"{tc / t_bound:.3f}" if t_bound else "",
                "useful_ratio": f"{(r.get('useful_flops_ratio') or 0):.2f}",
                "hbm_frac": f"{(r['memory'].get('argument_size_in_bytes', 0) + r['memory'].get('temp_size_in_bytes', 0)) / HBM_PER_CHIP:.2f}"
                if r.get("memory") else "",
            })
        else:
            row["dominant"] = r.get("reason", r.get("error", ""))[:60]
        rows.append(row)
    return rows


def markdown_table(rows: List[Dict]) -> str:
    if not rows:
        return "(no dry-run records yet)"
    cols = ["arch", "shape", "status", "t_compute_s", "t_memory_s",
            "t_collective_s", "dominant", "roofline_frac", "useful_ratio",
            "hbm_frac"]
    out = ["| " + " | ".join(cols) + " |",
           "|" + "|".join("---" for _ in cols) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(r.get(c, "")) for c in cols) + " |")
    return "\n".join(out)


def _ternary(rng, k: int, n: int, density: float = 0.5):
    import numpy as np
    w = rng.integers(-1, 2, size=(k, n)).astype(np.int8)
    return np.where(rng.random((k, n)) < density, w, 0).astype(np.int8)


def _measure(op, reps: int = 3) -> float:
    """Best-of-``reps`` eager wall time via ``ops.kernel_probe`` (lowering
    through block_until_ready). The first call compiles and is discarded.
    A nesting op (fused_mlp's chain impl dispatches probed ternary_gemms
    inside it) reports *last*, so the final callback per invocation is
    the outermost measurement."""
    from repro.kernels import ops

    best = None
    for i in range(reps + 1):
        times: List[float] = []
        with ops.kernel_probe(lambda _plan, dt: times.append(dt)):
            op()
        assert times, "probe missed the dispatch"
        if i and (best is None or times[-1] < best):
            best = times[-1]
    return best


def _measured_fields(roofline: Dict, dt: float) -> Dict:
    """Measured achieved-vs-peak columns next to the model's: the modeled
    roofline says what the kernel *could* do on the reference part; these
    say what this host actually did."""
    flops = roofline["flops"]
    return {
        "measured_time_s": dt,
        "measured_flops": flops / dt if dt > 0 else None,
        # >1: slower than the model's bound — the gap is host dispatch,
        # interpret-mode overhead, or unmodeled memory traffic
        "measured_vs_model": (dt / roofline["model_time_s"]
                              if roofline["model_time_s"] else None),
        "measured_vs_peak": (flops / dt / roofline["peak_flops"]
                             if dt > 0 else None),
    }


def kernel_report(quick: bool = False,
                  measured: bool = False) -> Dict[str, Dict]:
    """Per-registered-kernel roofline: one representative plan per
    ``(format, impl)`` lowering in the GEMM registry plus one per fused-MLP
    impl, each entry carrying the plan's modeled ``roofline()`` dict
    (achieved vs ceiling FLOP/s, HBM bytes from occupancy metadata).
    ``measured=True`` additionally times each lowering eagerly through
    ``ops.kernel_probe`` and reports measured achieved-vs-peak next to
    the model (DESIGN.md §15)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import weights
    from repro.kernels import ops

    m, k, ff, n = (128, 512, 1024, 512) if quick else (512, 1024, 4096, 1024)
    rng = np.random.default_rng(0)
    packed = {fmt: weights.pack(_ternary(rng, k, n), fmt)
              for fmt in ("dense2bit", "tiled", "bitplane")}
    x = jnp.asarray(rng.normal(size=(m, k)).astype(np.float32))

    report: Dict[str, Dict] = {}
    for (fmt, impl) in sorted(ops.kernel_registry()):
        w = packed.get(fmt)
        if w is None:
            continue
        plan = ops.ternary_gemm_plan(w, m, impl=impl, phase=None)
        rec = {
            "kind": "gemm", "m": m, "k": k, "n": n,
            "blocks": {"block_m": plan.block_m, "block_n": plan.block_n,
                       "block_k": plan.block_k},
            "occupancy": plan.occupancy,
            "roofline": plan.roofline(),
        }
        if measured:
            dt = _measure(lambda w=w, impl=impl:
                          ops.ternary_gemm(x, w, impl=impl))
            rec["measured"] = _measured_fields(rec["roofline"], dt)
        report[f"{fmt}/{impl}"] = rec

    wi = weights.pack(_ternary(rng, k, ff), "dense2bit")
    wg = weights.pack(_ternary(rng, k, ff), "dense2bit")
    wo = weights.pack(_ternary(rng, ff, n), "dense2bit")
    for impl in sorted(ops.fused_registry()):
        plan = ops.fused_mlp_plan(wi, wo, wg, m=m, impl=impl, phase=None)
        rec = {
            "kind": "fused_mlp", "m": m, "k": k, "ff": ff, "n": n,
            "blocks": {"block_m": plan.block_m, "block_n1": plan.block_n1,
                       "block_k1": plan.block_k1, "block_n2": plan.block_n2,
                       "block_k2": plan.block_k2},
            "roofline": plan.roofline(),
        }
        if measured:
            dt = _measure(lambda impl=impl:
                          ops.fused_mlp(x, wi, wo, wg, impl=impl))
            rec["measured"] = _measured_fields(rec["roofline"], dt)
        report[f"fused_mlp/{impl}"] = rec
    return report


def write_kernel_report(path: str, quick: bool = False,
                        measured: bool = False) -> Dict[str, Dict]:
    report = kernel_report(quick=quick, measured=measured)
    doc = {"version": 1, "quick": quick, "kernels": report}
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
    return report


def print_kernel_report(report: Dict[str, Dict]) -> None:
    print("\n== kernel roofline ==")
    has_measured = any("measured" in rec for rec in report.values())
    cols = ("kernel,bound,arithmetic_intensity,achieved_gflops,"
            "ceiling_gflops,headroom")
    if has_measured:
        cols += ",measured_ms,measured_gflops,measured_vs_model"
    print(cols)
    for name, rec in sorted(report.items()):
        rl = rec["roofline"]
        row = (f"{name},{rl['bound']},{rl['arithmetic_intensity']:.1f},"
               f"{rl['achieved_flops'] / 1e9:.1f},"
               f"{rl['ceiling_flops'] / 1e9:.1f},{rl['headroom']:.3f}")
        if has_measured:
            ms = rec.get("measured")
            row += (",,," if ms is None else
                    f",{ms['measured_time_s'] * 1e3:.3f},"
                    f"{ms['measured_flops'] / 1e9:.2f},"
                    f"{ms['measured_vs_model']:.1f}")
        print(row)


def main(out_dir: str = "experiments/dryrun"):
    recs = load(out_dir)
    for mesh in ("16x16", "2x16x16"):
        rows = roofline_rows(recs, mesh)
        if not rows:
            continue
        print(f"\n== roofline {mesh} ==")
        for r in rows:
            print(",".join(str(r.get(c, "")) for c in
                           ("arch", "shape", "status", "dominant",
                            "t_compute_s", "t_memory_s", "t_collective_s")))


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="small representative shapes (CI bench leg)")
    ap.add_argument("--measured", action="store_true",
                    help="time each lowering eagerly (ops.kernel_probe) "
                         "and report measured achieved-vs-peak next to "
                         "the modeled roofline")
    ap.add_argument("--json", default="",
                    help="write the per-kernel roofline report to this path")
    ap.add_argument("--out-dir", default="experiments/dryrun",
                    help="dry-run records for the model-level table")
    args = ap.parse_args()
    main(args.out_dir)
    rep = (write_kernel_report(args.json, quick=args.quick,
                               measured=args.measured) if args.json
           else kernel_report(quick=args.quick, measured=args.measured))
    print_kernel_report(rep)
